"""EFMVFL × LM backbones (DESIGN.md §4): two organizations hold different
private views of the same customers — one a text log (LM backbone), one
tabular features (identity backbone).  They federate a logistic head with
the paper's protocols; raw features and representations never move.

  PYTHONPATH=src python examples/vfl_lm_head.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.core import vfl_lm
from repro.core.trainer import VFLConfig
from repro.core.vfl_lm import BackboneParty, identity_backbone
from repro.models import registry as models


def main():
    rng = np.random.default_rng(5)
    n = 512

    # Party C: tabular features + the label
    X_tab, y = _tabular_task(rng, n)

    # Party B1: token sequences correlated with the label
    cfg_lm = registry.get_smoke_config("gpt-100m")
    api = models.build(cfg_lm)
    params = api.init_params(jax.random.key(0))
    tokens = _token_view(rng, y, cfg_lm.vocab_size, n, seq=24)
    extract = vfl_lm.make_lm_backbone(api, params, batch_size=64)

    parties = [
        BackboneParty("C", identity_backbone, X_tab),
        BackboneParty("B1", extract, tokens),
    ]
    cfg = VFLConfig(glm="logistic", lr=0.3, max_iter=25, batch_size=256,
                    he_backend="mock", tol=0.0, seed=6)
    res, quality = vfl_lm.train_federated_head(parties, y, cfg)
    print(f"iterations : {res.n_iter}")
    print(f"train AUC  : {quality['train_auc']:.3f}")
    print(f"total comm : {res.meter.total_mb:.2f} MB")
    assert quality["train_auc"] > 0.60, "joint model should beat chance"

    # ablation: tabular-only head (shows the LM party adds signal)
    res_solo, q_solo = vfl_lm.train_federated_head(
        [BackboneParty("C", identity_backbone, X_tab),
         BackboneParty("B1", identity_backbone,
                       rng.normal(size=(n, 4)))],      # noise party
        y, cfg)
    print(f"AUC with noise party instead of LM: {q_solo['train_auc']:.3f}")


def _tabular_task(rng, n):
    X = rng.normal(size=(n, 8))
    w = rng.normal(size=8)
    logits = 0.7 * (X @ w) + 0.5 * rng.normal(size=n)
    y = np.where(logits > np.median(logits), 1.0, -1.0)
    return X, y


def _token_view(rng, y, vocab, n, seq):
    """Positive customers draw tokens from one half of the vocab."""
    toks = np.empty((n, seq), np.int32)
    half = vocab // 2
    for i in range(n):
        lo, hi = (0, half) if y[i] > 0 else (half, vocab)
        toks[i] = rng.integers(lo, hi, seq)
    return toks


if __name__ == "__main__":
    main()
