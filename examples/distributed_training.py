"""EFMVFL across real OS processes: k parties on localhost TCP.

Spawns one process per party (`runtime.netparty.PartyServer`) plus a
conductor, trains a logistic AND a Poisson GLM over `SocketTransport`,
and verifies against the single-process `LocalTransport` run that the
wire changes nothing:

  * losses and final weights bit-identical,
  * per-tag analytic comm bytes identical,
  * measured on-the-wire payload bytes equal to the analytic
    `wire_bytes()` accounting for every tag.

Then it scores a batch through the same socket path (each party ships
its local score share `infer.wx_share` to C over the mesh).

  PYTHONPATH=src python examples/distributed_training.py [--smoke]
      [--parties 3] [--he mock|paillier] [--key-bits 256]

The default mock HE backend keeps the demo quick while metering the
exact ciphertext byte counts a real key would; pass `--he paillier`
for real keys (each party process generates and keeps its own private
key — peers only ever learn the public modulus from the handshake).
"""
import argparse

import numpy as np

from repro.core import glm as glm_lib
from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.launch.cluster import SocketCluster
from repro.runtime import LocalTransport
from repro.runtime.messages import TAG_PROTOCOL


def run_one(glm: str, args) -> None:
    n = 160 if args.smoke else 400
    iters = 2 if args.smoke else 4
    if glm == "poisson":
        X, y = synthetic.dvisits(n=n, seed=7)
    else:
        X, y = synthetic.credit_default(n=n, d=12, seed=3)
    parts = vertical.split_columns(X, args.parties)
    names = ["C"] + [f"B{i}" for i in range(1, args.parties)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm=glm, lr=0.1, max_iter=iters,
                    batch_size=min(64, n // 2), he_backend=args.he,
                    key_bits=args.key_bits, tol=0.0, seed=11)

    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    print(f"\n=== {glm}: {args.parties} real processes over TCP "
          f"({args.he} backend) ===")
    with SocketCluster(parties, y, cfg) as cluster:
        res = cluster.train()
        # -- the wire must change nothing --------------------------------
        assert res.losses == local.losses, "loss trace diverged"
        for nm in local.weights:
            np.testing.assert_array_equal(res.weights[nm],
                                          local.weights[nm])
        assert dict(res.meter.by_tag) == dict(local.meter.by_tag)
        assert dict(res.measured_meter.by_tag) == dict(res.meter.by_tag)
        print(f"bit-identical to LocalTransport over {res.n_iter} "
              f"iterations: losses {[round(v, 4) for v in res.losses]}")
        print(f"wall clock {res.runtime_s:.2f}s "
              f"(includes {args.parties} process spawns + handshake)")
        print("per-tag wire traffic (measured == analytic, asserted):")
        for tag, nbytes in sorted(res.meter.by_tag.items()):
            measured = res.measured_meter.by_tag[tag]
            print(f"  {tag:18s} {measured:>9d} B   {TAG_PROTOCOL[tag]}")
        print(f"frame overhead (preludes + headers, not protocol bytes): "
              f"{res.wire_overhead_bytes} B")

        # -- serving over the same wire ----------------------------------
        rows = {p.name: p.X[:8] for p in parties}
        preds = cluster.score(rows)
    wx = sum(p.X[:8] @ local.weights[p.name] for p in parties)
    np.testing.assert_allclose(preds, glm_lib.GLMS[glm].predict(wx))
    print(f"scored 8 rows over the socket path; first 4: "
          f"{np.round(preds[:4], 4)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--parties", type=int, default=3,
                    help="number of party processes (>= 3 exercises the "
                         "CP broadcast legs)")
    ap.add_argument("--he", default="mock", choices=("mock", "paillier"))
    ap.add_argument("--key-bits", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI wire smoke)")
    args = ap.parse_args()
    for glm in ("logistic", "poisson"):
        run_one(glm, args)
    print("\ndistributed training OK: both GLMs bit-identical to the "
          "single-process runtime, measured bytes == analytic accounting")


if __name__ == "__main__":
    main()
