"""Resumable EFMVFL training: kill -9 a party mid-run, recover, verify.

Demonstrates the full crash-recovery story on the real wire
(docs/fault_tolerance.md):

  1. trains a k-party socket cluster with party-local checkpoints
     (`cfg.checkpoint_every` iterations; each party persists only ITS
     OWN TrainState slice — weights, stream cursors, meters — never a
     share or key material),
  2. SIGKILLs one party mid-run (`--kill-at/--kill-party`), letting the
     supervisor (`launch.cluster.train_vfl_socket_resilient`) detect the
     loss, force-restart the cluster, and run the resume handshake (all
     parties agree on the max common checkpointed step, roll back,
     audit the replicated stream counters),
  3. verifies the recovered run is BIT-IDENTICAL to an uninterrupted
     single-process run: losses, final weights, per-tag analytic comm
     bytes, and measured-on-the-wire payload bytes.

  PYTHONPATH=src python examples/resumable_training.py [--smoke]
      [--parties 3] [--glm logistic] [--he mock|paillier]
      [--kill-at 2] [--kill-party B1] [--checkpoint-every 1]
"""
import argparse
import tempfile

import numpy as np

from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.launch.cluster import train_vfl_socket_resilient
from repro.runtime import LocalTransport


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--glm", default="logistic",
                    choices=("logistic", "poisson"))
    ap.add_argument("--he", default="mock", choices=("mock", "paillier"))
    ap.add_argument("--key-bits", type=int, default=256)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--kill-at", type=int, default=2,
                    help="iteration at which to SIGKILL a party")
    ap.add_argument("--kill-party", default="B1")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="default: a fresh temporary directory")
    args = ap.parse_args()

    n = 160 if args.smoke else 400
    iters = args.iters or (3 if args.smoke else 5)
    if args.glm == "poisson":
        X, y = synthetic.dvisits(n=n, seed=7)
    else:
        X, y = synthetic.credit_default(n=n, d=12, seed=3)
    parts = vertical.split_columns(X, args.parties)
    names = ["C"] + [f"B{i}" for i in range(1, args.parties)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm=args.glm, lr=0.1, max_iter=iters,
                    batch_size=min(64, n // 2), he_backend=args.he,
                    key_bits=args.key_bits, tol=0.0, seed=11,
                    checkpoint_every=args.checkpoint_every)
    assert args.kill_party in names[1:] + ["C"]
    assert 0 < args.kill_at < iters, "kill must land mid-run"

    print(f"reference: uninterrupted single-process run "
          f"({args.glm}, k={args.parties}, {args.he})…")
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())

    ckpt_dir = args.checkpoint_dir or tempfile.mkdtemp(
        prefix="efmvfl-resume-")
    print(f"supervised socket run: checkpoint_every="
          f"{args.checkpoint_every} -> {ckpt_dir}")
    print(f"  kill plan: SIGKILL {args.kill_party} at iteration "
          f"{args.kill_at}")
    res = train_vfl_socket_resilient(
        parties, y, cfg, checkpoint_dir=ckpt_dir,
        kill_plan={args.kill_at: args.kill_party})

    print(f"  restarts        : {res.restarts}")
    print(f"  resumed at step : {res.resume_report.get('step')}")
    print(f"  dealer draws    : {res.resume_report.get('dealer_drawn')} "
          "(audited equal across parties)")
    print(f"  per-party rng   : {res.resume_report.get('rng_drawn')}")

    assert res.restarts >= 1, "the kill must have triggered a restart"
    assert res.losses == ref.losses, "loss trace diverged"
    for nm in ref.weights:
        np.testing.assert_array_equal(res.weights[nm], ref.weights[nm])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert dict(res.measured_meter.by_tag) == dict(ref.meter.by_tag)
    print("recovered run is bit-identical to the uninterrupted run "
          "(losses, weights, analytic AND measured per-tag bytes) ✓")
    print(f"losses: {[round(v, 4) for v in res.losses]}")


if __name__ == "__main__":
    main()
