"""Batched serving demo: prefill a batch of prompts, then decode with the
KV cache (the serve_step the decode_* dry-run cells lower).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import registry as models


def main():
    cfg = registry.get_smoke_config("qwen3-4b")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))

    B, prompt_len, gen_len, max_len = 8, 16, 32, 64
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, prompt_len), dtype=np.int32)

    prefill = jax.jit(lambda p, t: api.prefill(p, t, max_len=max_len))
    decode = jax.jit(api.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, jnp.asarray(prompts))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"prefilled {B}x{prompt_len}, decoded {B}x{gen_len} tokens "
          f"in {dt:.2f}s → {B * gen_len / dt:.1f} tok/s (CPU, smoke config)")
    print("sample:", np.asarray(out[0])[:16].tolist())
    assert out.shape == (B, gen_len)


if __name__ == "__main__":
    main()
