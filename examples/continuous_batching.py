"""Continuous-batching serving: ragged requests enter and leave the
decode batch every step (slots > requests-in-flight are recycled live).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import registry as models
from repro.serve import ServeEngine


def main():
    cfg = registry.get_smoke_config("qwen3-4b")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    eng = ServeEngine(api, params, n_slots=4, max_len=128)

    rng = np.random.default_rng(1)
    n_req = 12
    for i in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=int(
            rng.integers(4, 14))), max_new_tokens=int(rng.integers(4, 20)))

    t0 = time.perf_counter()
    steps = 0
    while eng.busy:
        active = eng.step()
        steps += 1
        if steps % 8 == 0:
            print(f"step {steps:3d}: {active} active, "
                  f"{len(eng.finished)}/{n_req} done, "
                  f"{len(eng.queue)} queued")
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.generated) for r in eng.finished)
    print(f"\nserved {n_req} ragged requests ({total_toks} tokens) in "
          f"{steps} steps / {dt:.1f}s with 4 slots "
          f"→ {total_toks/dt:.1f} tok/s (CPU, smoke config)")
    assert len(eng.finished) == n_req


if __name__ == "__main__":
    main()
