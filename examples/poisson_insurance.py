"""EFMVFL Poisson regression (§4.2): doctor-visit counts, two parties —
the paper's second GLM instantiation, with the e^{WX} share products.

  PYTHONPATH=src python examples/poisson_insurance.py
"""
import numpy as np

from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def main():
    X, y = synthetic.dvisits(n=4000, seed=3)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y)
    parts = vertical.split_columns(Xtr, 2)
    parties = [PartyData("C", parts[0]), PartyData("B1", parts[1])]
    cfg = VFLConfig(glm="poisson", lr=0.1, max_iter=20, batch_size=512,
                    he_backend="mock", tol=1e-4, seed=4)
    res = trainer.train_vfl(parties, ytr, cfg)

    te_parts = vertical.split_columns(Xte, 2)
    pred = np.exp(np.clip(res.predict_wx(
        [PartyData("C", te_parts[0]), PartyData("B1", te_parts[1])]),
        -20, 10))
    print(f"iterations : {res.n_iter}")
    print(f"test MAE   : {metrics.mae(yte, pred):.3f}")
    print(f"test RMSE  : {metrics.rmse(yte, pred):.3f}")
    print(f"total comm : {res.meter.total_mb:.2f} MB")


if __name__ == "__main__":
    main()
