"""Multi-party EFMVFL (§4.3): four parties, random computing-party
selection per iteration, REAL Paillier keys (256-bit demo size).

  PYTHONPATH=src python examples/multiparty_credit_scoring.py
"""
import numpy as np

from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def main():
    X, y = synthetic.credit_default(n=400, d=16, seed=1)
    parts = vertical.split_columns(X, 4)
    names = ["C", "B1", "B2", "B3"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]

    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=4, batch_size=128,
                    he_backend="paillier", key_bits=256,
                    cp_selection="random", tol=0.0, seed=2)
    print("running 4-party EFMVFL with real Paillier (256-bit demo keys;"
          " production uses 1024+)…")
    res = trainer.train_vfl(parties, y, cfg)
    wx = res.predict_wx(parties)
    print(f"iterations   : {res.n_iter}")
    print(f"losses       : {[round(l, 4) for l in res.losses]}")
    print(f"train AUC    : {metrics.auc(y, wx):.3f}")
    print(f"total comm   : {res.meter.total_mb:.2f} MB")
    print("per-party weights held locally:",
          {p.name: res.weights[p.name].shape for p in parties})


if __name__ == "__main__":
    main()
