"""Multi-party EFMVFL (§4.3): four parties, random computing-party
selection per iteration, REAL Paillier keys (256-bit demo size) — run on
the actor runtime, then served with the runtime-backed scoring engine.

  PYTHONPATH=src python examples/multiparty_credit_scoring.py
"""
import numpy as np

from repro.core import metrics
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.runtime import LocalTransport, VFLScheduler
from repro.runtime.messages import TAG_PROTOCOL
from repro.serve import VFLScoringEngine


def main():
    X, y = synthetic.credit_default(n=400, d=16, seed=1)
    parts = vertical.split_columns(X, 4)
    names = ["C", "B1", "B2", "B3"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]

    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=4, batch_size=128,
                    he_backend="paillier", key_bits=256,
                    cp_selection="random", tol=0.0, seed=2)
    print("running 4-party EFMVFL with real Paillier (256-bit demo keys;"
          " production uses 1024+)…")
    sched = VFLScheduler(parties, y, cfg, transport=LocalTransport())
    res = sched.run()
    wx = res.predict_wx(parties)
    print(f"iterations   : {res.n_iter}")
    print(f"losses       : {[round(l, 4) for l in res.losses]}")
    print(f"train AUC    : {metrics.auc(y, wx):.3f}")
    print(f"total comm   : {res.meter.total_mb:.2f} MB "
          f"in {res.rounds} rounds")
    print("per-tag traffic (message type → paper line):")
    for tag, nbytes in sorted(res.meter.by_tag.items()):
        print(f"  {tag:18s} {nbytes / 1e6:8.3f} MB   {TAG_PROTOCOL[tag]}")
    print("per-party weights held locally:",
          {p.name: res.weights[p.name].shape for p in parties})

    # -- runtime-backed serving: same actors, same transport seam ----------
    engine = VFLScoringEngine(sched.parties, max_batch=32)
    rows = list(range(0, 64))
    for i in rows:
        engine.submit({nm: part[i] for nm, part in zip(names, parts)})
    done = engine.run()
    probs = np.array([r.prediction for r in done])
    print(f"served {len(done)} scoring requests; "
          f"first 5 probabilities: {np.round(probs[:5], 3)}")
    print(f"serving comm : {engine.transport.meter.total_bytes} B "
          f"in {engine.transport.rounds} rounds")


if __name__ == "__main__":
    main()
