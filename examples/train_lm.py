"""End-to-end LM training driver: ~110M-parameter gpt-100m for a few
hundred steps with checkpointing + auto-resume (the launch/train.py
production path).

  PYTHONPATH=src python examples/train_lm.py            # ~110M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --quick    # smoke-size demo

The full profile takes a while on CPU (the same binary drives TPU pods via
the sharding rules); --quick finishes in ~1 minute.
"""
import sys

from repro.launch import train


def main():
    quick = "--quick" in sys.argv
    args = ["--arch", "gpt-100m", "--ckpt-dir", "/tmp/repro_gpt100m",
            "--ckpt-every", "50", "--resume"]
    if quick:
        args += ["--smoke", "--steps", "60", "--batch", "4", "--seq", "128",
                 "--log-every", "10"]
    else:
        args += ["--steps", "300", "--batch", "4", "--seq", "128",
                 "--log-every", "5"]
    res = train.main(args)
    assert res["final_loss"] < res["first_loss"], "loss should decrease"
    print("OK: loss decreased "
          f"{res['first_loss']:.3f} → {res['final_loss']:.3f}")


if __name__ == "__main__":
    main()
