"""Quickstart: 2-party EFMVFL logistic regression on a credit-default
task — the paper's headline experiment in ~40 lines of public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def main():
    # Party C (bank: labels + 12 features), party B1 (bureau: 12 features)
    X, y = synthetic.credit_default(n=6000, d=24, seed=0)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y, ratio=0.7)
    parts_tr = vertical.split_columns(Xtr, 2)
    parties = [PartyData("C", parts_tr[0]), PartyData("B1", parts_tr[1])]

    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=15, batch_size=1024,
                    he_backend="mock",     # byte-exact wire accounting;
                    key_bits=1024,         # switch to "paillier" for real HE
                    tol=1e-4, seed=0)
    res = trainer.train_vfl(parties, ytr, cfg)

    parts_te = vertical.split_columns(Xte, 2)
    wx = res.predict_wx([PartyData("C", parts_te[0]),
                         PartyData("B1", parts_te[1])])
    print(f"iterations        : {res.n_iter}")
    print(f"final train loss  : {res.losses[-1]:.4f}")
    print(f"test AUC          : {metrics.auc(yte, wx):.3f}")
    print(f"test KS           : {metrics.ks(yte, wx):.3f}")
    print(f"total comm        : {res.meter.total_mb:.2f} MB")
    print("comm by protocol  :")
    for tag, mb in res.meter.summary().items():
        if tag != "TOTAL_MB":
            print(f"  {tag:24s} {mb:8.3f} MB")
    # centralized oracle — federated quality should match (paper Fig. 1)
    w_c, _ = trainer.train_centralized(Xtr, ytr, cfg)
    print(f"centralized AUC   : {metrics.auc(yte, Xte @ w_c):.3f}")


if __name__ == "__main__":
    main()
