"""Fault tolerance: checkpoint integrity, torn-write recovery, and
bit-identical resume after a simulated node failure."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.checkpoint.manager import save_checkpoint
from repro.data.tokens import TokenStream

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"x": 1})
    got = load_checkpoint(str(tmp_path), t)
    assert got is not None
    step, tree, extra = got
    assert step == 7 and extra == {"x": 1}
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(t["a"]))
    assert tree["b"]["c"].dtype == np.asarray(t["b"]["c"]).dtype


def test_torn_write_skipped(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    # corrupt the newest archive (simulated crash mid-write + stale manifest)
    with open(tmp_path / "step_2.npz", "r+b") as f:
        f.seek(0)
        f.write(b"garbage")
    got = load_checkpoint(str(tmp_path), t)
    assert got is not None and got[0] == 1    # falls back to the valid one


def test_keep_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree())
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(tmp_path) if f.endswith(".json"))
    assert steps == [3, 4]


def test_token_stream_resumable():
    s1 = TokenStream(512, 2, 16, seed=3)
    batches = [s1.next_batch() for _ in range(5)]
    state = s1.save_state()
    more = [s1.next_batch() for _ in range(3)]
    s2 = TokenStream(512, 2, 16, seed=3)
    s2.load_state(state)
    for want in more:
        got = s2.next_batch()
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    del batches


@pytest.mark.slow
def test_kill_and_resume_bitwise_identical(tmp_path):
    """Train 60 steps in one go vs. die at 30 + resume: identical params."""
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "gpt-100m", "--smoke", "--batch", "2", "--seq", "32",
              "--ckpt-every", "10", "--log-every", "1000"]
    d_full, d_fail = str(tmp_path / "full"), str(tmp_path / "fail")

    r = subprocess.run(common + ["--steps", "60", "--ckpt-dir", d_full],
                       env=ENV, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]

    r = subprocess.run(common + ["--steps", "60", "--ckpt-dir", d_fail,
                                 "--die-at-step", "30"],
                       env=ENV, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 42        # simulated node failure
    r = subprocess.run(common + ["--steps", "60", "--ckpt-dir", d_fail,
                                 "--resume"],
                       env=ENV, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]

    import json
    with open(os.path.join(d_full, "step_60.json")) as f:
        sa = json.load(f)
    with open(os.path.join(d_fail, "step_60.json")) as f:
        sb = json.load(f)
    assert sa["step"] == sb["step"] == 60
    na = np.load(os.path.join(d_full, "step_60.npz"))
    nb = np.load(os.path.join(d_fail, "step_60.npz"))
    assert sorted(na.files) == sorted(nb.files)
    for k in na.files:
        np.testing.assert_array_equal(na[k], nb[k], err_msg=k)
