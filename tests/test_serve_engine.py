"""Continuous-batching serve engine: slot reuse, per-slot depths, and
equivalence of the vmapped decode with the plain decode path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import registry as models
from repro.serve import ServeEngine


def _setup(n_slots=3, max_len=64):
    cfg = registry.get_smoke_config("qwen3-4b")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    return cfg, api, params, ServeEngine(api, params, n_slots=n_slots,
                                         max_len=max_len)


def test_continuous_batching_completes_all():
    cfg, api, params, eng = _setup()
    rng = np.random.default_rng(0)
    want = {}
    for i in range(8):                       # 8 requests > 3 slots
        n_new = int(rng.integers(3, 9))      # ragged lengths
        rid = eng.submit(rng.integers(0, cfg.vocab_size, size=12), n_new)
        want[rid] = n_new
    done = eng.run()
    assert len(done) == 8
    for req in done:
        assert len(req.generated) == want[req.rid]
        assert all(0 <= t < cfg.vocab_size for t in req.generated)


def test_engine_matches_plain_decode():
    """A single request through the engine produces the same tokens as a
    manual prefill + greedy decode loop."""
    cfg, api, params, eng = _setup(n_slots=2, max_len=64)
    prompt = np.arange(16, dtype=np.int32) % cfg.vocab_size
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run()
    got = done[0].generated

    logits, cache = api.prefill(params, jnp.asarray(prompt)[None],
                                max_len=64)
    tok = int(jnp.argmax(logits[0]))
    manual = [tok]
    t = jnp.asarray([[tok]], jnp.int32)
    for _ in range(5):
        logits, cache = api.decode_step(params, cache, t)
        tok = int(jnp.argmax(logits[0]))
        manual.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    assert got == manual, (got, manual)


def test_vfl_scoring_engine_matches_predict_wx():
    """Federated GLM serving: the runtime-backed scoring engine (party
    actors + infer.wx_share messages) reproduces TrainResult.predict_wx
    through the inverse link, with metered serving traffic."""
    from repro.core import glm as glm_lib
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.runtime import VFLScheduler
    from repro.serve import VFLScoringEngine

    X, y = synthetic.credit_default(n=300, d=8, seed=21)
    parts = vertical.split_columns(X, 3)
    names = ["C", "B1", "B2"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=4, batch_size=128,
                    he_backend="mock", tol=0.0, seed=13)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()

    eng = VFLScoringEngine(sched.parties, max_batch=50)
    n_req = 120                                   # 120 rows > 2 full batches
    for i in range(n_req):
        eng.submit({nm: part[i] for nm, part in zip(names, parts)})
    done = eng.run()
    assert len(done) == n_req
    got = np.array([r.prediction for r in sorted(done, key=lambda r: r.rid)])
    want = glm_lib.GLMS["logistic"].predict(
        res.predict_wx(parties))[:n_req]
    np.testing.assert_allclose(got, want, rtol=1e-12)
    # serving traffic was metered at the transport boundary
    assert eng.transport.meter.by_tag["infer.wx_share"] == n_req * 2 * 8
    assert eng.transport.rounds > 0


def test_vfl_scoring_engine_over_socket_cluster():
    """Distributed serving: the same engine API backed by real party
    processes — feature slices fan out as control frames, score shares
    travel party→C over the TCP mesh as encoded `infer.wx_share`
    frames."""
    from repro.core import glm as glm_lib
    from repro.core import trainer
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.launch.cluster import SocketCluster
    from repro.serve import VFLScoringEngine

    X, y = synthetic.credit_default(n=200, d=9, seed=21)
    parts = vertical.split_columns(X, 3)
    names = ["C", "B1", "B2"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=13)
    local = trainer.train_vfl(parties, y, cfg)
    with SocketCluster(parties, y, cfg) as cl:
        cl.train()
        eng = VFLScoringEngine(cluster=cl, max_batch=16)
        n_req = 40                               # > 2 micro-batches
        for i in range(n_req):
            eng.submit({nm: part[i] for nm, part in zip(names, parts)})
        done = eng.run()
    assert len(done) == n_req
    got = np.array([r.prediction
                    for r in sorted(done, key=lambda r: r.rid)])
    want = glm_lib.GLMS["logistic"].predict(
        local.predict_wx(parties))[:n_req]
    np.testing.assert_allclose(got, want, rtol=1e-12)


def _tiny_trained_parties():
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.runtime import VFLScheduler

    X, y = synthetic.credit_default(n=120, d=6, seed=5)
    parts = vertical.split_columns(X, 3)
    names = ["C", "B1", "B2"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=13)
    sched = VFLScheduler(parties, y, cfg)
    sched.run()
    return sched, names, parts


def test_vfl_submit_rejects_mismatched_feature_keys():
    """Satellite bugfix: a feature dict whose keys disagree with the
    party roster is refused at submit time with a named error that
    spells out what is missing/unexpected — not a bare KeyError from
    np.stack halfway through a later batch."""
    import pytest

    from repro.serve import FeatureKeyError, VFLScoringEngine

    sched, names, parts = _tiny_trained_parties()
    eng = VFLScoringEngine(sched.parties, max_batch=8)
    row = {nm: part[0] for nm, part in zip(names, parts)}

    bad = dict(row)
    del bad["B2"]
    bad["B9"] = row["B1"]
    with pytest.raises(FeatureKeyError) as ei:
        eng.submit(bad)
    assert ei.value.missing == ["B2"]
    assert ei.value.unexpected == ["B9"]
    assert "B2" in str(ei.value) and "B9" in str(ei.value)

    with pytest.raises(FeatureKeyError):
        eng.submit({})                        # everything missing
    assert eng.batcher.pending == 0           # nothing was half-admitted
    eng.submit(row)                           # the good row still goes in
    assert len(eng.run()) == 1


def test_vfl_busy_reflects_in_flight_cluster_batch():
    """Satellite bugfix regression: `busy` must stay True WHILE a
    cluster-mode batch is being scored (old code reported False the
    moment the queue drained, letting run() return early)."""
    from repro.serve import VFLScoringEngine

    observed = []

    class StubCluster:
        names = ["C", "B1", "B2"]
        tp = None

        def publish_model(self, version):
            return {}

        def score(self, X, version=None):
            observed.append(eng.busy)         # mid-flight: must be True
            n = X["C"].shape[0]
            return np.zeros(n)

    eng = VFLScoringEngine(cluster=StubCluster(), max_batch=4)
    for _ in range(6):
        eng.submit({nm: np.zeros(2) for nm in StubCluster.names})
    assert eng.busy
    done = eng.run()
    assert len(done) == 6
    assert observed and all(observed), \
        f"busy went False while a batch was in flight: {observed}"
    assert not eng.busy


def test_vfl_deadline_batching_service_mode():
    """Tentpole: with max_wait_s > 0 the engine is a service — requests
    below max_batch sit until the deadline, then the worker thread
    closes and scores the batch without any client call."""
    import time as _time

    from repro.serve import VFLScoringEngine

    sched, names, parts = _tiny_trained_parties()
    eng = VFLScoringEngine(sched.parties, max_batch=64, max_wait_s=0.02)
    eng.start(poll_interval_s=0.002)
    try:
        for i in range(5):                     # 5 << max_batch: only the
            eng.submit({nm: part[i]            # deadline can close this
                        for nm, part in zip(names, parts)})
        deadline = _time.monotonic() + 5.0
        while len(eng.finished) < 5 and _time.monotonic() < deadline:
            _time.sleep(0.005)
    finally:
        eng.stop(drain=True)
    assert len(eng.finished) == 5
    assert all(r.prediction is not None for r in eng.finished)
    assert all(r.model_version == 0 for r in eng.finished)
    assert all(r.t_done >= r.t_submit for r in eng.finished)
