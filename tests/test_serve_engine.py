"""Continuous-batching serve engine: slot reuse, per-slot depths, and
equivalence of the vmapped decode with the plain decode path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import registry as models
from repro.serve import ServeEngine


def _setup(n_slots=3, max_len=64):
    cfg = registry.get_smoke_config("qwen3-4b")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    return cfg, api, params, ServeEngine(api, params, n_slots=n_slots,
                                         max_len=max_len)


def test_continuous_batching_completes_all():
    cfg, api, params, eng = _setup()
    rng = np.random.default_rng(0)
    want = {}
    for i in range(8):                       # 8 requests > 3 slots
        n_new = int(rng.integers(3, 9))      # ragged lengths
        rid = eng.submit(rng.integers(0, cfg.vocab_size, size=12), n_new)
        want[rid] = n_new
    done = eng.run()
    assert len(done) == 8
    for req in done:
        assert len(req.generated) == want[req.rid]
        assert all(0 <= t < cfg.vocab_size for t in req.generated)


def test_engine_matches_plain_decode():
    """A single request through the engine produces the same tokens as a
    manual prefill + greedy decode loop."""
    cfg, api, params, eng = _setup(n_slots=2, max_len=64)
    prompt = np.arange(16, dtype=np.int32) % cfg.vocab_size
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run()
    got = done[0].generated

    logits, cache = api.prefill(params, jnp.asarray(prompt)[None],
                                max_len=64)
    tok = int(jnp.argmax(logits[0]))
    manual = [tok]
    t = jnp.asarray([[tok]], jnp.int32)
    for _ in range(5):
        logits, cache = api.decode_step(params, cache, t)
        tok = int(jnp.argmax(logits[0]))
        manual.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    assert got == manual, (got, manual)


def test_vfl_scoring_engine_matches_predict_wx():
    """Federated GLM serving: the runtime-backed scoring engine (party
    actors + infer.wx_share messages) reproduces TrainResult.predict_wx
    through the inverse link, with metered serving traffic."""
    from repro.core import glm as glm_lib
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.runtime import VFLScheduler
    from repro.serve import VFLScoringEngine

    X, y = synthetic.credit_default(n=300, d=8, seed=21)
    parts = vertical.split_columns(X, 3)
    names = ["C", "B1", "B2"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=4, batch_size=128,
                    he_backend="mock", tol=0.0, seed=13)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()

    eng = VFLScoringEngine(sched.parties, max_batch=50)
    n_req = 120                                   # 120 rows > 2 full batches
    for i in range(n_req):
        eng.submit({nm: part[i] for nm, part in zip(names, parts)})
    done = eng.run()
    assert len(done) == n_req
    got = np.array([r.prediction for r in sorted(done, key=lambda r: r.rid)])
    want = glm_lib.GLMS["logistic"].predict(
        res.predict_wx(parties))[:n_req]
    np.testing.assert_allclose(got, want, rtol=1e-12)
    # serving traffic was metered at the transport boundary
    assert eng.transport.meter.by_tag["infer.wx_share"] == n_req * 2 * 8
    assert eng.transport.rounds > 0


def test_vfl_scoring_engine_over_socket_cluster():
    """Distributed serving: the same engine API backed by real party
    processes — feature slices fan out as control frames, score shares
    travel party→C over the TCP mesh as encoded `infer.wx_share`
    frames."""
    from repro.core import glm as glm_lib
    from repro.core import trainer
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.launch.cluster import SocketCluster
    from repro.serve import VFLScoringEngine

    X, y = synthetic.credit_default(n=200, d=9, seed=21)
    parts = vertical.split_columns(X, 3)
    names = ["C", "B1", "B2"]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=13)
    local = trainer.train_vfl(parties, y, cfg)
    with SocketCluster(parties, y, cfg) as cl:
        cl.train()
        eng = VFLScoringEngine(cluster=cl, max_batch=16)
        n_req = 40                               # > 2 micro-batches
        for i in range(n_req):
            eng.submit({nm: part[i] for nm, part in zip(names, parts)})
        done = eng.run()
    assert len(done) == n_req
    got = np.array([r.prediction
                    for r in sorted(done, key=lambda r: r.rid)])
    want = glm_lib.GLMS["logistic"].predict(
        local.predict_wx(parties))[:n_req]
    np.testing.assert_allclose(got, want, rtol=1e-12)
