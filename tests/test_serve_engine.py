"""Continuous-batching serve engine: slot reuse, per-slot depths, and
equivalence of the vmapped decode with the plain decode path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import registry as models
from repro.serve import ServeEngine


def _setup(n_slots=3, max_len=64):
    cfg = registry.get_smoke_config("qwen3-4b")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    return cfg, api, params, ServeEngine(api, params, n_slots=n_slots,
                                         max_len=max_len)


def test_continuous_batching_completes_all():
    cfg, api, params, eng = _setup()
    rng = np.random.default_rng(0)
    want = {}
    for i in range(8):                       # 8 requests > 3 slots
        n_new = int(rng.integers(3, 9))      # ragged lengths
        rid = eng.submit(rng.integers(0, cfg.vocab_size, size=12), n_new)
        want[rid] = n_new
    done = eng.run()
    assert len(done) == 8
    for req in done:
        assert len(req.generated) == want[req.rid]
        assert all(0 <= t < cfg.vocab_size for t in req.generated)


def test_engine_matches_plain_decode():
    """A single request through the engine produces the same tokens as a
    manual prefill + greedy decode loop."""
    cfg, api, params, eng = _setup(n_slots=2, max_len=64)
    prompt = np.arange(16, dtype=np.int32) % cfg.vocab_size
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run()
    got = done[0].generated

    logits, cache = api.prefill(params, jnp.asarray(prompt)[None],
                                max_len=64)
    tok = int(jnp.argmax(logits[0]))
    manual = [tok]
    t = jnp.asarray([[tok]], jnp.int32)
    for _ in range(5):
        logits, cache = api.decode_step(params, cache, t)
        tok = int(jnp.argmax(logits[0]))
        manual.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    assert got == manual, (got, manual)
