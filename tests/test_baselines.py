"""Baseline frameworks (Table 1/2 rows) train correctly and their
communication ordering matches the paper: SS > SS-HE > EFMVFL > TP."""
import numpy as np

from repro.baselines import ss_glm, ss_he_lr, tp_glm
from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def _parties(X):
    parts = vertical.split_columns(X, 2)
    return [PartyData("C", parts[0]), PartyData("B1", parts[1])]


def _cfg(**kw):
    base = dict(glm="logistic", lr=0.15, max_iter=10, batch_size=512,
                he_backend="mock", tol=0.0, seed=11)
    base.update(kw)
    return VFLConfig(**base)


def test_tp_lr_quality():
    X, y = synthetic.credit_default(n=3000, seed=3)
    cfg = _cfg()
    res = tp_glm.train_tp(_parties(X), y, cfg)
    w_cent, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=1e-9)
    assert res.meter.total_mb > 0


def test_tp_pr_quality():
    X, y = synthetic.dvisits(n=2000, seed=7)
    cfg = _cfg(glm="poisson", lr=0.1)
    res = tp_glm.train_tp(_parties(X), y, cfg)
    _, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=1e-9)


def test_ss_lr_quality():
    X, y = synthetic.credit_default(n=2000, seed=5)
    cfg = _cfg(max_iter=8, batch_size=256)
    res = ss_glm.train_ss(_parties(X), y, cfg)
    w_cent, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=8e-3)
    fed = np.concatenate([res.weights["C"], res.weights["B1"]])
    np.testing.assert_allclose(fed, w_cent, atol=2e-2)


def test_ss_he_lr_quality():
    X, y = synthetic.credit_default(n=2000, seed=9)
    cfg = _cfg(max_iter=8, batch_size=256)
    res = ss_he_lr.train_ss_he(_parties(X), y, cfg)
    w_cent, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=8e-3)
    fed = np.concatenate([res.weights["C"], res.weights["B1"]])
    np.testing.assert_allclose(fed, w_cent, atol=2e-2)


def test_comm_ordering_matches_paper():
    """Paper Table 1 ordering: SS-LR ≫ SS-HE-LR > EFMVFL > TP-LR."""
    X, y = synthetic.credit_default(n=2000, seed=13)
    cfg = _cfg(max_iter=5, batch_size=512)
    parties = _parties(X)
    mb = {
        "TP": tp_glm.train_tp(parties, y, cfg).meter.total_mb,
        "SS": ss_glm.train_ss(parties, y, cfg).meter.total_mb,
        "SSHE": ss_he_lr.train_ss_he(parties, y, cfg).meter.total_mb,
        "EFMVFL": trainer.train_vfl(parties, y, cfg).meter.total_mb,
    }
    assert mb["SS"] > mb["SSHE"] > mb["EFMVFL"] > mb["TP"], mb
