"""Serving parity gauntlet: the continuous-batching secure scoring
service must be BIT-IDENTICAL to the one-shot scorer —

  * across link functions (logistic/poisson), crypto backends
    (mock/paillier) and party counts k∈{2,3,4};
  * in-process and over the real socket mesh (with measured wire bytes
    == analytic for the `infer.wx_share` tag);
  * under a chaos profile (drops + dups + reorders);
  * across a mid-stream hot model swap — each request is scored by
    exactly ONE model version, and each version's outputs match the
    one-shot scorer for that version's weights.

The one-shot reference is `GLMS[glm].predict(res.predict_wx(parties))`
— same float64 association (roster order, C's own term first), so
equality is exact, not approximate.
"""
import numpy as np
import pytest

from repro.core import glm as glm_lib
from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.runtime import LocalTransport, VFLScheduler
from repro.runtime.chaos import ChaosProfile
from repro.serve import VFLScoringEngine


def _data(glm, n=160, seed=3):
    if glm == "poisson":
        return synthetic.dvisits(n=n, seed=seed)
    return synthetic.credit_default(n=n, d=8, seed=seed)


def _make_parties(X, k):
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    return [PartyData(name=nm, X=p) for nm, p in zip(names, parts)], \
        names, parts


def _cfg(glm, backend, **kw):
    kw.setdefault("key_bits", 256 if backend == "paillier" else 1024)
    kw.setdefault("max_iter", 2)
    return VFLConfig(glm=glm, lr=0.1, batch_size=64,
                     he_backend=backend, tol=0.0, seed=11, **kw)


def _rows(names, parts, i):
    return {nm: part[i] for nm, part in zip(names, parts)}


def _wx_reference(weights, names, parts, rows):
    """One-shot wx with the engine's exact association: C first, then
    roster order, per-party shares via the batch-size-invariant
    `matvec_rowwise` — bitwise-reproducible float64 sums."""
    wx = glm_lib.matvec_rowwise(parts[0][rows], weights[names[0]])
    for nm, part in zip(names[1:], parts[1:]):
        wx = wx + glm_lib.matvec_rowwise(part[rows], weights[nm])
    return wx


# ---------------------------------------------------------------------------
# 1. in-process parity grid
# ---------------------------------------------------------------------------

GRID_FAST = [("logistic", "mock", 2), ("logistic", "mock", 3),
             ("logistic", "mock", 4), ("poisson", "mock", 2),
             ("poisson", "mock", 3), ("poisson", "mock", 4),
             ("logistic", "paillier", 2)]
GRID_SLOW = [("logistic", "paillier", 3), ("logistic", "paillier", 4),
             ("poisson", "paillier", 2), ("poisson", "paillier", 3),
             ("poisson", "paillier", 4)]


def _parity_inprocess(glm, backend, k):
    X, y = _data(glm, n=96)
    parties, names, parts = _make_parties(X, k)
    cfg = _cfg(glm, backend)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()
    want = glm_lib.GLMS[glm].predict(res.predict_wx(parties))

    eng = VFLScoringEngine(sched.parties, max_batch=7)   # ragged batches
    n_req = 20
    for i in range(n_req):
        eng.submit(_rows(names, parts, i))
    done = sorted(eng.run(), key=lambda r: r.rid)
    got = np.array([r.prediction for r in done])
    np.testing.assert_array_equal(got, want[:n_req])     # BIT-identical
    assert all(r.model_version == 0 for r in done)
    assert eng.transport.meter.by_tag["infer.wx_share"] \
        == n_req * (k - 1) * 8


@pytest.mark.parametrize("glm,backend,k", GRID_FAST)
def test_served_equals_one_shot_inprocess(glm, backend, k):
    _parity_inprocess(glm, backend, k)


@pytest.mark.slow
@pytest.mark.parametrize("glm,backend,k", GRID_SLOW)
def test_served_equals_one_shot_inprocess_slow(glm, backend, k):
    _parity_inprocess(glm, backend, k)


# ---------------------------------------------------------------------------
# 2. socket parity + measured wire bytes == analytic per tag
# ---------------------------------------------------------------------------

def _parity_socket(glm, backend, k, chaos=None):
    from repro.launch.cluster import SocketCluster

    X, y = _data(glm, n=96)
    parties, names, parts = _make_parties(X, k)
    cfg = _cfg(glm, backend)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    want = glm_lib.GLMS[glm].predict(local.predict_wx(parties))

    n_req = 24
    with SocketCluster(parties, y, cfg, chaos=chaos) as cl:
        cl.train()
        eng = VFLScoringEngine(cluster=cl, max_batch=10)
        for i in range(n_req):
            eng.submit(_rows(names, parts, i))
        done = sorted(eng.run(), key=lambda r: r.rid)
        meters = cl.fetch_meters()
        chaos_stats = getattr(cl.tp, "chaos_stats", None)
    got = np.array([r.prediction for r in done])
    np.testing.assert_array_equal(got, want[:n_req])     # BIT-identical
    # wire invariant: measured serving bytes == analytic, == n·(k-1)·8
    analytic = meters["meter"].by_tag["infer.wx_share"]
    measured = meters["measured"].by_tag["infer.wx_share"]
    assert analytic == measured == n_req * (k - 1) * 8
    return chaos_stats


@pytest.mark.parametrize("glm,k", [("logistic", 3), ("poisson", 2)])
def test_served_equals_one_shot_socket(glm, k):
    _parity_socket(glm, "mock", k)


@pytest.mark.slow
def test_served_equals_one_shot_socket_paillier():
    _parity_socket("logistic", "paillier", 3)


#: drops + dups + reorders on every link, timings scaled for CI — the
#: serving path must come through bit-identical anyway (reliable
#: delivery below the codec, same floats above it)
CHAOS = ChaosProfile(seed=29, latency_s=0.001, jitter_s=0.0005,
                     drop_p=0.10, dup_p=0.05, reorder_p=0.12)


def test_served_equals_one_shot_under_chaos():
    stats = _parity_socket("logistic", "mock", 3, chaos=CHAOS).to_dict()
    assert stats["drops"] + stats["reorders"] > 0     # chaos actually bit


# ---------------------------------------------------------------------------
# 3. mid-stream hot model swap — one version per request, both exact
# ---------------------------------------------------------------------------

def _swap_reference(tmp_path, names, step):
    """Per-party weights of checkpoint `step` (what the swap installs)."""
    from repro.checkpoint import load_checkpoint, party_checkpoint_dir
    from repro.runtime import session as session_lib

    weights = {}
    for nm in names:
        got = load_checkpoint(party_checkpoint_dir(str(tmp_path), nm),
                              session_lib.TrainState.tree_template([nm]),
                              step=step)
        assert got is not None, f"no step-{step} checkpoint for {nm}"
        _, tree, extra = got
        st = session_lib.TrainState.from_checkpoint(tree, extra)
        weights[nm] = st.weights[nm]
    return weights


def test_hot_swap_socket_one_version_per_request(tmp_path):
    from repro.launch.cluster import SocketCluster

    glm, k, swap_step = "logistic", 3, 2
    X, y = _data(glm, n=96)
    parties, names, parts = _make_parties(X, k)
    cfg = _cfg(glm, "mock", max_iter=4, checkpoint_every=1)
    with SocketCluster(parties, y, cfg,
                       checkpoint_dir=str(tmp_path)) as cl:
        res = cl.train()
        eng = VFLScoringEngine(cluster=cl, max_batch=4)
        for i in range(8):                       # wave A: final weights (v0)
            eng.submit(_rows(names, parts, i))
        eng.run()
        eng.swap_model(step=swap_step)           # barrier: applied at the
        for i in range(8, 16):                   # next batch boundary
            eng.submit(_rows(names, parts, i))
        done = sorted(eng.run(), key=lambda r: r.rid)

    # every request was scored by exactly ONE version, and every batch
    # is version-homogeneous — the swap barrier
    assert all(r.model_version in (0, 1) for r in done)
    by_batch = {}
    for r in done:
        by_batch.setdefault(r.batch_seq, set()).add(r.model_version)
    assert all(len(vs) == 1 for vs in by_batch.values()), by_batch
    a = [r for r in done if r.rid < 8]
    b = [r for r in done if r.rid >= 8]
    assert {r.model_version for r in a} == {0}
    assert {r.model_version for r in b} == {1}

    # each version's outputs are BIT-identical to the one-shot scorer
    # run against that version's weights
    rows_a, rows_b = np.arange(0, 8), np.arange(8, 16)
    want_a = glm_lib.GLMS[glm].predict(
        _wx_reference(res.weights, names, parts, rows_a))
    w_step = _swap_reference(tmp_path, names, swap_step)
    want_b = glm_lib.GLMS[glm].predict(
        _wx_reference(w_step, names, parts, rows_b))
    np.testing.assert_array_equal(
        np.array([r.prediction for r in a]), want_a)
    np.testing.assert_array_equal(
        np.array([r.prediction for r in b]), want_b)


def test_hot_swap_inprocess_with_pending_queue(tmp_path):
    """In-process swap with requests STILL QUEUED when the swap lands:
    batches closed before the swap score at v0, everything after at v1
    — no batch mixes."""
    from repro.launch.cluster import train_vfl_socket

    glm, k = "logistic", 2
    X, y = _data(glm, n=96)
    parties, names, parts = _make_parties(X, k)
    cfg = _cfg(glm, "mock", max_iter=3, checkpoint_every=1)
    # the socket run writes the party checkpoints the swap will load
    train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path))

    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()
    eng = VFLScoringEngine(sched.parties, max_batch=5,
                           checkpoint_dir=str(tmp_path))
    for i in range(12):
        eng.submit(_rows(names, parts, i))
    assert eng.step() == 5                       # one batch at v0 ...
    v = eng.swap_model(step=1)                   # ... swap lands with 7
    assert v == 1                                # requests still pending
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert [r.model_version for r in done] == [0] * 5 + [1] * 7
    by_batch = {}
    for r in done:
        by_batch.setdefault(r.batch_seq, set()).add(r.model_version)
    assert all(len(vs) == 1 for vs in by_batch.values())

    want_v0 = glm_lib.GLMS[glm].predict(
        _wx_reference(res.weights, names, parts, np.arange(0, 5)))
    w1 = _swap_reference(tmp_path, names, 1)
    want_v1 = glm_lib.GLMS[glm].predict(
        _wx_reference(w1, names, parts, np.arange(5, 12)))
    np.testing.assert_array_equal(
        np.array([r.prediction for r in done[:5]]), want_v0)
    np.testing.assert_array_equal(
        np.array([r.prediction for r in done[5:]]), want_v1)
