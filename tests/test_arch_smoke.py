"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPE_CELLS
from repro.models import registry as models
from repro.optim import make_optimizer

ARCHS = registry.list_archs()
B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.where(jnp.arange(S) % 7 == 0, -1, tokens)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    api = models.build(cfg)
    key = jax.random.key(0)
    params = api.init_params(key)
    batch = _batch(cfg, jax.random.key(1))
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)

    loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    new_params, _ = opt.update(grads, opt_state, params, 1e-3)
    flat = jax.tree.leaves(new_params)
    assert all(jnp.isfinite(x.astype(jnp.float32)).all() for x in flat), \
        f"{arch}: NaN/Inf after update"
    # loss decreases after a few SGD steps on the same batch (sanity)
    p = params
    for _ in range(4):
        l, g = jax.value_and_grad(api.train_loss)(p, batch)
        p = jax.tree.map(lambda pi, gi: (pi.astype(jnp.float32) - 0.5
                                         * gi.astype(jnp.float32)
                                         ).astype(pi.dtype), p, g)
    l_end = api.train_loss(p, batch)
    assert float(l_end) < float(loss), f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = registry.get_smoke_config(arch)
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    state = api.init_decode_state(B, S)
    token = jnp.zeros((B, 1), jnp.int32)
    extras = {}
    if cfg.family == "audio":
        extras["enc_out"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model),
            jnp.bfloat16)
    logits, new_state = api.decode_step(params, state, token, **extras)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: NaN"
    # a second step advances the state
    logits2, _ = api.decode_step(params, new_state, token, **extras)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ["minitron-4b", "qwen3-4b", "gemma3-4b"])
def test_prefill_decode_consistency(arch):
    """prefill(t_0..t_{n-1}) + decode(t_n) ≡ full forward logits."""
    cfg = registry.get_smoke_config(arch)
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (B, 8), 0, cfg.vocab_size)
    from repro.models import transformer
    # full forward logits at position 7
    logits_full, _, _ = transformer.forward(params, cfg, toks,
                                            kv_block=None)
    # prefill 7 then decode token 7
    last, cache = api.prefill(params, toks[:, :7], max_len=16)
    logits_dec, _ = api.decode_step(params, cache, toks[:, 7:8])
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, 7], np.float32), atol=0.75, rtol=0.15)


def test_param_counts_full_configs():
    """Analytic N for the 6ND roofline: spot-check magnitudes."""
    n = registry.get_config("kimi-k2-1t-a32b").param_count()
    assert 0.8e12 < n < 1.3e12, f"kimi param count {n/1e12:.2f}T"
    na = registry.get_config("kimi-k2-1t-a32b").active_param_count()
    assert 20e9 < na < 45e9, f"kimi active {na/1e9:.1f}B"
    n15 = registry.get_config("starcoder2-15b").param_count()
    assert 12e9 < n15 < 18e9, f"starcoder2 {n15/1e9:.1f}B"
    n4 = registry.get_config("qwen3-4b").param_count()
    assert 3e9 < n4 < 5.5e9, f"qwen3 {n4/1e9:.1f}B"
    nr = registry.get_config("rwkv6-1.6b").param_count()
    assert 1.2e9 < nr < 2.2e9, f"rwkv6 {nr/1e9:.2f}B"
    nz = registry.get_config("zamba2-7b").param_count()
    assert 5e9 < nz < 9e9, f"zamba2 {nz/1e9:.2f}B"


def test_input_specs_all_cells():
    for arch in ARCHS:
        cfg = registry.get_config(arch)
        for cell in SHAPE_CELLS.values():
            specs = models.input_specs(cfg, cell)
            assert "tokens" in specs or "token" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
