"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape sweeps
and hypothesis value sweeps."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.crypto import bigint, ring
from repro.crypto.bigint import Modulus
from repro.kernels import ops, ref

RNG = np.random.default_rng(41)

MODS = [
    (1 << 61) - 1,                                   # 61-bit prime
    int("0x" + "b" * 64, 16) | 1,                    # 256-bit odd
    int("0x" + "7" * 128, 16) | 1,                   # 512-bit odd
]


def rand_residues(n_mod, size):
    nbytes = (n_mod.bit_length() + 7) // 8
    return [int.from_bytes(RNG.bytes(nbytes), "little") % n_mod
            for _ in range(size)]


@pytest.mark.parametrize("n", MODS)
@pytest.mark.parametrize("batch", [1, 7, 128, 300])
def test_montmul_kernel_vs_ref(n, batch):
    mod = Modulus.make(n)
    a = rand_residues(n, batch)
    b = rand_residues(n, batch)
    A = jnp.asarray(bigint.ints_to_limbs(a, mod.L))
    B = jnp.asarray(bigint.ints_to_limbs(b, mod.L))
    got = np.asarray(ops.montmul(A, B, mod, tile_b=128))
    want = np.asarray(ref.montmul_ref(A, B, mod))
    np.testing.assert_array_equal(got, want)
    # and against python ints
    R = 1 << (12 * mod.L)
    rinv = pow(R, -1, n)
    got_ints = [bigint.limbs_to_int(g) for g in got]
    assert got_ints == [(x * y * rinv) % n for x, y in zip(a, b)]


def test_montmul_kernel_batch_shapes():
    n = MODS[0]
    mod = Modulus.make(n)
    a = rand_residues(n, 12)
    A = jnp.asarray(bigint.ints_to_limbs(a, mod.L)).reshape(3, 4, mod.L)
    got = ops.montmul(A, A, mod, tile_b=8)
    assert got.shape == (3, 4, mod.L)
    want = ref.montmul_ref(A, A, mod)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mont_exp_bits_kernel():
    n = MODS[0]
    mod = Modulus.make(n)
    base = rand_residues(n, 4)
    exps = rand_residues(1 << 24, 4)
    B = bigint.to_mont(jnp.asarray(bigint.ints_to_limbs(base, mod.L)), mod)
    bits = jnp.asarray(np.stack([bigint.int_to_bits(e, 24) for e in exps]))
    got = bigint.from_mont(ops.mont_exp_bits(B, bits, mod), mod)
    ints = [bigint.limbs_to_int(x) for x in np.asarray(got)]
    assert ints == [pow(x, e, n) for x, e in zip(base, exps)]


@pytest.mark.parametrize("shape", [(4, 8, 4), (128, 64, 128), (100, 33, 50),
                                   (1, 1, 1), (130, 40000, 10)])
def test_ring_matmul_kernel_vs_ref(shape):
    M, K, N = shape
    a = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (M, K), dtype=np.uint64))
    b = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (K, N), dtype=np.uint64))
    got = ops.ring_matmul(a, b, tm=32, tn=32)
    want = ref.ring_matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got.hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(got.lo), np.asarray(want.lo))
    # spot-check a cell against python ints
    av = ring.to_numpy_u64(a).astype(object)
    bv = ring.to_numpy_u64(b).astype(object)
    want00 = sum(int(av[0, k]) * int(bv[k, 0]) for k in range(K)) % (1 << 64)
    assert int(ring.to_numpy_u64(got)[0, 0]) == want00


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=3, max_value=(1 << 128) - 1),
       st.integers(min_value=0), st.integers(min_value=0))
def test_hypothesis_montmul_kernel(n, a, b):
    n |= 1
    a %= n
    b %= n
    mod = Modulus.make(n)
    A = jnp.asarray(bigint.int_to_limbs(a, mod.L))[None]
    B = jnp.asarray(bigint.int_to_limbs(b, mod.L))[None]
    got = bigint.limbs_to_int(np.asarray(ops.montmul(A, B, mod, tile_b=8))[0])
    R = 1 << (12 * mod.L)
    assert got == (a * b * pow(R, -1, n)) % n


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=(1 << 64) - 1),
                min_size=4, max_size=4),
       st.lists(st.integers(min_value=0, max_value=(1 << 64) - 1),
                min_size=4, max_size=4))
def test_hypothesis_ring_matmul(avals, bvals):
    a = ring.from_numpy_u64(np.array(avals, np.uint64).reshape(2, 2))
    b = ring.from_numpy_u64(np.array(bvals, np.uint64).reshape(2, 2))
    got = ring.to_numpy_u64(ops.ring_matmul(a, b, tm=8, tn=8))
    for i in range(2):
        for j in range(2):
            want = sum(avals[2 * i + k] * bvals[2 * k + j]
                       for k in range(2)) % (1 << 64)
            assert int(got[i, j]) == want
