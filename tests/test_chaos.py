"""Chaos link layer (runtime/chaos.py) + retry policy (runtime/policy.py).

Four layers of coverage:

1. the seeded fault schedule — every decision is a pure hash of
   (seed, link, seq, attempt, channel), so schedules replay exactly
   (property-tested via _hypothesis_compat) and the backoff trace of a
   chaos run is itself deterministic;
2. the link envelope + ARQ machinery — corrupt/truncated envelopes are
   rejected (never silently delivered), duplicates and reorders are
   never double-applied (exactly-once in-order delivery), retry-budget
   exhaustion surfaces as a peer loss, and the pump/reader threads are
   joined on close (no leaks);
3. lossless wire compression — frame round-trip, the deterministic
   worth-it probe, and config-time REFUSAL of the lossy int8 scheme
   on the wire;
4. the chaos gauntlet — k ∈ {2,3,4} × logistic/poisson socket training
   under seeded drops/dups/reorders/resets + a guaranteed partition
   (and, separately, a real SIGKILL mid-run) finishing bit-identical
   to the fault-free run: losses, weights, per-tag analytic AND
   measured bytes.
"""
import os
import sys
import threading

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import trainer  # noqa: E402
from repro.core.trainer import PartyData, VFLConfig  # noqa: E402
from repro.data import synthetic, vertical  # noqa: E402
from repro.distributed import compression as comp_lib  # noqa: E402
from repro.runtime import LocalTransport  # noqa: E402
from repro.runtime import session  # noqa: E402
from repro.runtime.chaos import (CH_DATA, ENVELOPE, MAGIC,  # noqa: E402
                                 ChaosProfile, FaultSchedule,
                                 FaultyTransport, LinkError, PROFILES,
                                 link_seed, read_envelope, resolve_profile)
from repro.runtime.codec import Codec  # noqa: E402
from repro.runtime.policy import RetryPolicy, _unit_hash  # noqa: E402
from repro.runtime.transport import PeerClosed  # noqa: E402

#: the gauntlet profile: every fault kind enabled, partition GUARANTEED
#: (p=1 → every directed link blackholes once), timings scaled for CI
GAUNTLET = ChaosProfile(seed=42, latency_s=0.001, jitter_s=0.0005,
                        drop_p=0.06, dup_p=0.04, reorder_p=0.08,
                        reset_p=0.01, partition_p=1.0, partition_at=3,
                        partition_s=0.15)


def _make_parties(X, k):
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    return [PartyData(name=nm, X=p) for nm, p in zip(names, parts)]


def _data(glm, n=160, seed=3):
    if glm == "poisson":
        return synthetic.dvisits(n=n, seed=seed)
    return synthetic.credit_default(n=n, d=8, seed=seed)


def _assert_socket_exact(res, ref):
    assert res.losses == ref.losses
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert dict(res.measured_meter.by_tag) == dict(ref.meter.by_tag)
    assert res.n_iter == ref.n_iter


# ---------------------------------------------------------------------------
# 1. seeded schedule + policy determinism
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31),   # profile seed
       st.integers(min_value=0, max_value=10_000),    # seq
       st.integers(min_value=0, max_value=30))        # attempt
def test_fault_schedule_replays_exactly(seed, seq, attempt):
    """Two schedules built from equal profiles agree on every decision;
    the decisions depend only on their integer inputs."""
    p = ChaosProfile(seed=seed, drop_p=0.3, dup_p=0.3, reorder_p=0.3,
                     reset_p=0.3, jitter_s=0.004,
                     partition_p=0.5, partition_s=0.1)
    a, b = FaultSchedule(p), FaultSchedule(ChaosProfile(**p.to_dict()))
    ls = link_seed(seed, "C", "B1")
    for chan in range(3):
        assert a.drop(ls, seq, attempt, chan) == b.drop(ls, seq, attempt,
                                                        chan)
        assert a.reorder(ls, seq, attempt, chan) == b.reorder(
            ls, seq, attempt, chan)
        assert a.jitter(ls, seq, attempt, chan) == b.jitter(
            ls, seq, attempt, chan)
        assert 0.0 <= a.jitter(ls, seq, attempt, chan) <= p.jitter_s
    assert a.dup(ls, seq) == b.dup(ls, seq)
    assert a.reset(ls, seq, attempt) == b.reset(ls, seq, attempt)
    assert a.partition_point(ls) == b.partition_point(ls)


def test_link_seed_is_directed_and_keyed():
    """A→B and B→A are independent links; the profile seed matters."""
    assert link_seed(0, "C", "B1") != link_seed(0, "B1", "C")
    assert link_seed(0, "C", "B1") != link_seed(1, "C", "B1")
    assert link_seed(7, "C", "B1") == link_seed(7, "C", "B1")


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31),
       st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=30))
def test_backoff_deterministic_and_bounded(ls, seq, attempt):
    pol = RetryPolicy()
    d = pol.backoff(ls, seq, attempt)
    assert d == pol.backoff(ls, seq, attempt)            # replayable
    assert 0.5 * pol.rto(attempt) <= d < 1.5 * pol.rto(attempt)
    assert pol.rto(attempt) <= pol.rto_max_s


def test_policy_roundtrip_and_derived():
    pol = RetryPolicy(io_timeout_s=42.0, rto_initial_s=0.125,
                      retry_budget=5, frame_deadlines=(("bye", 3.0),))
    back = RetryPolicy.from_dict(pol.to_dict())
    assert back == pol
    assert back.deadline_for("bye") == 3.0
    assert back.deadline_for("iterate") == 42.0
    assert back.connect_timeout() == 42.0
    assert back.heartbeat_interval() == 14.0             # io/3 < 30
    # budget × capped backoff bounds the survivable outage — the chaos
    # profiles' partitions must sit well under the default bound
    assert RetryPolicy().max_outage_s() > 10 * PROFILES[
        "chaos"].partition_s
    assert 0.0 <= _unit_hash(1, 2, 3) < 1.0


def test_resolve_profile_forms():
    assert resolve_profile(None) is None
    assert resolve_profile("wan20") is PROFILES["wan20"]
    p = resolve_profile({"seed": 3, "drop_p": 0.5})
    assert p.seed == 3 and p.drop_p == 0.5 and p.faulty()
    assert resolve_profile(p) is p
    with pytest.raises(ValueError, match="unknown chaos profile"):
        resolve_profile("tsunami")
    assert not PROFILES["off"].active()
    assert PROFILES["wan20"].shaped() and not PROFILES["wan20"].faulty()


# ---------------------------------------------------------------------------
# 2. envelope + ARQ machinery
# ---------------------------------------------------------------------------

def _sock_pair():
    import socket as socket_lib
    srv = socket_lib.create_server(("127.0.0.1", 0))
    cli = socket_lib.create_connection(srv.getsockname())
    conn, _ = srv.accept()
    srv.close()
    return cli, conn


def test_read_envelope_rejects_corruption():
    """Bad magic, crc mismatch, oversize, truncation: all raise, none
    silently deliver."""
    import zlib
    tx, rx = _sock_pair()
    try:
        body = b"payload-bytes"
        tx.sendall(ENVELOPE.pack(MAGIC, 1, 7, zlib.crc32(body), len(body))
                   + body)
        assert read_envelope(rx) == (1, 7, body)
        tx.sendall(ENVELOPE.pack(b"NOPE", 1, 0, 0, 0))
        with pytest.raises(LinkError, match="magic"):
            read_envelope(rx)
        tx.sendall(ENVELOPE.pack(MAGIC, 1, 1, zlib.crc32(body) ^ 0xFF,
                                 len(body)) + body)
        with pytest.raises(LinkError, match="crc"):
            read_envelope(rx)
        tx.sendall(ENVELOPE.pack(MAGIC, 1, 2, 0, 1 << 31))
        with pytest.raises(LinkError, match="too large"):
            read_envelope(rx)
        tx.sendall(ENVELOPE.pack(MAGIC, 1, 3, 0, 64)[:10])
        tx.close()                                   # truncated header
        with pytest.raises(PeerClosed):
            read_envelope(rx)
    finally:
        tx.close()
        rx.close()


def test_rx_ingest_exactly_once_in_order():
    """Duplicates discarded, early arrivals buffered, delivery strictly
    in seq order — no frame is ever applied twice."""
    tp = FaultyTransport("X", Codec())
    try:
        assert tp._rx_ingest("P", 0, "m0") == ["m0"]
        assert tp._rx_ingest("P", 0, "m0") == []     # dup of delivered
        assert tp._rx_ingest("P", 3, "m3") == []     # early — buffered
        assert tp._rx_ingest("P", 3, "m3") == []     # dup of buffered
        assert tp._rx_ingest("P", 2, "m2") == []
        assert tp._rx_ingest("P", 1, "m1") == ["m1", "m2", "m3"]
        assert tp._rx_ingest("P", 2, "m2") == []     # late dup
        st = tp.chaos_stats
        assert st.rx_dups == 3 and st.rx_buffered == 2
    finally:
        tp.close()


def test_faulty_pair_delivers_under_faults():
    """Two FaultyTransports over real sockets under a drop/dup/reorder
    profile: every control arrives exactly once, in order."""
    from repro.runtime import messages as msg_lib
    prof = ChaosProfile(seed=5, latency_s=0.001, drop_p=0.15, dup_p=0.1,
                        reorder_p=0.2)
    pol = RetryPolicy(rto_initial_s=0.05, rto_max_s=0.2)
    a = FaultyTransport("A", Codec(), profile=prof, policy=pol)
    b = FaultyTransport("B", Codec(), profile=prof, policy=pol)
    s_ab, s_ba = _sock_pair()
    a.attach("B", s_ab)
    b.attach("A", s_ba)
    try:
        n = 30
        for i in range(n):
            a.send_control(msg_lib.Control("A", "B", kind=f"seq{i}"))
        got = [b.inbound.get(timeout=30) for _ in range(n)]
        assert [m.kind for m in got] == [f"seq{i}" for i in range(n)]
        assert a.flush(timeout=30)                   # all acked
        total = a.chaos_stats.injected() + b.chaos_stats.injected()
        assert total > 0, "profile injected nothing — test is vacuous"
    finally:
        a.close()
        b.close()


def test_retry_budget_exhaustion_surfaces_peer_loss():
    """drop_p=1 blackhole + tiny budget: the sender declares the link
    dead with a __closed__ event instead of retrying forever."""
    from repro.runtime import messages as msg_lib
    prof = ChaosProfile(seed=1, drop_p=1.0)
    pol = RetryPolicy(rto_initial_s=0.01, rto_max_s=0.02, retry_budget=3)
    a = FaultyTransport("A", Codec(), profile=prof, policy=pol)
    b = FaultyTransport("B", Codec(), profile=prof, policy=pol)
    s_ab, s_ba = _sock_pair()
    a.attach("B", s_ab)
    b.attach("A", s_ba)
    try:
        a.send_control(msg_lib.Control("A", "B", kind="doomed"))
        m = a.inbound.get(timeout=10)
        assert m.kind == "__closed__"
        assert "retry budget" in m.payload["error"]
        assert a.chaos_stats.budget_deaths == 1
        assert a.chaos_stats.retransmits == pol.retry_budget
    finally:
        a.close()
        b.close()


def test_chaos_threads_joined_on_close():
    """detach + close leave no pump/reader/heartbeat threads behind."""
    from repro.runtime import messages as msg_lib
    before = {t.name for t in threading.enumerate()}
    a = FaultyTransport("A", Codec(), profile=PROFILES["lossy"])
    b = FaultyTransport("B", Codec(), profile=PROFILES["lossy"])
    s_ab, s_ba = _sock_pair()
    a.attach("B", s_ab)
    b.attach("A", s_ba)
    a.start_heartbeat("B", 0.02)
    a.send_control(msg_lib.Control("A", "B", kind="ping"))
    assert b.inbound.get(timeout=10).kind == "ping"
    b.detach("A")
    a.close()
    b.close()
    leaked = {t.name for t in threading.enumerate()} - before
    assert not leaked, f"threads leaked past close: {leaked}"


# ---------------------------------------------------------------------------
# 3. lossless wire compression
# ---------------------------------------------------------------------------

def test_wire_scheme_validation_refuses_lossy():
    assert comp_lib.validate_wire_scheme("none") == "none"
    assert comp_lib.validate_wire_scheme("zlib") == "zlib"
    with pytest.raises(ValueError, match="(?i)lossy"):
        comp_lib.validate_wire_scheme("int8")
    with pytest.raises(ValueError):
        comp_lib.validate_wire_scheme("brotli")


def test_deflate_roundtrip_and_probe():
    compressible = b"\x00" * 4096 + b"abc" * 1000
    assert comp_lib.worth_deflating(compressible)
    wire = comp_lib.deflate_frame(compressible)
    assert len(wire) < len(compressible)
    assert comp_lib.inflate_frame(wire) == compressible
    assert not comp_lib.worth_deflating(b"x")            # tiny: skipped
    rnd = np.random.default_rng(0).bytes(8192)           # dense: probe
    assert not comp_lib.worth_deflating(rnd)             # says no


def test_wire_compression_is_non_semantic_for_resume():
    cfg_a = VFLConfig(glm="logistic", seed=1)
    cfg_b = VFLConfig(glm="logistic", seed=1, wire_compression="zlib")
    assert session.config_hash(cfg_a) == session.config_hash(cfg_b)


def test_compressed_socket_run_bit_identical():
    """wire_compression=zlib below the metering boundary: identical
    losses/weights/meters, and the stats show frames were deflated."""
    from repro.launch.cluster import train_vfl_socket
    X, y = _data("logistic")
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=11,
                    wire_compression="zlib")
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, ref)
    total = res.chaos_report["total"]
    assert total["deflated_frames"] > 0
    assert total["deflate_saved_bytes"] > 0


# ---------------------------------------------------------------------------
# 4. the gauntlet: chaos training bit-identical to fault-free
# ---------------------------------------------------------------------------

def _gauntlet(glm, k, tmp_path=None, kill_plan=None):
    from repro.launch.cluster import (train_vfl_socket,
                                      train_vfl_socket_resilient)
    X, y = _data(glm)
    cfg = VFLConfig(glm=glm, lr=0.1, max_iter=3, batch_size=48,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1 if kill_plan else 0)
    parties = _make_parties(X, k)
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    if kill_plan:
        res = train_vfl_socket_resilient(
            parties, y, cfg, checkpoint_dir=str(tmp_path),
            kill_plan=kill_plan, chaos=GAUNTLET)
    else:
        res = train_vfl_socket(parties, y, cfg, chaos=GAUNTLET)
    _assert_socket_exact(res, ref)
    total = res.chaos_report["total"]
    assert total["drops"] > 0 and total["retransmits"] > 0
    assert total["partitions"] >= 1                      # p=1 guarantees
    return res, total


@pytest.mark.parametrize("glm,k", [("logistic", 2), ("logistic", 3),
                                   ("poisson", 3)])
def test_chaos_gauntlet_bit_identical(glm, k):
    """Seeded drops/dups/reorders/resets + a guaranteed partition on
    every link: training finishes bit-identical to the fault-free run
    (losses, weights, per-tag analytic AND measured bytes)."""
    res, total = _gauntlet(glm, k)
    assert total["dups"] > 0 or total["reorders"] > 0
    assert total["budget_deaths"] == 0                   # ARQ absorbed all


@pytest.mark.slow
@pytest.mark.parametrize("glm,k", [("logistic", 4), ("poisson", 2),
                                   ("poisson", 4)])
def test_chaos_gauntlet_bit_identical_slow(glm, k):
    _gauntlet(glm, k)


def test_chaos_gauntlet_with_sigkill(tmp_path):
    """The full storm: faults + partition + a real SIGKILL of B1 — the
    supervisor resumes from party-local checkpoints and the finished
    run is still bit-identical."""
    res, total = _gauntlet("logistic", 3, tmp_path=tmp_path,
                           kill_plan={2: "B1"})
    assert res.restarts == 1
    assert res.resume_report["step"] >= 1


def test_flapping_party_quarantined_and_standby_admitted(tmp_path):
    """Elastic epochs: B1 is SIGKILLed twice (flap_threshold) — the
    supervisor quarantines it, admits the standby replica of the same
    role at the restart boundary, records the checkpoint handoff plan,
    and the finished run is STILL bit-identical (the replica holds the
    same feature shard)."""
    from repro.launch.cluster import train_vfl_socket_resilient
    X, y = _data("logistic")
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=4, batch_size=48,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1)
    parties = _make_parties(X, 3)
    replica = PartyData("B1", np.array(parties[1].X, copy=True))
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket_resilient(
        parties, y, cfg, checkpoint_dir=str(tmp_path),
        kill_plan={1: "B1", 2: "B1"}, standby={"B1": replica},
        flap_threshold=2)
    _assert_socket_exact(res, ref)
    assert res.restarts == 2
    assert res.failures == {"B1": 2}
    plan = res.quarantined["B1"]
    assert plan["party"] == "B1" and plan["step"] >= 1
    assert plan["files"] and all("sha256" in f for f in plan["files"])
