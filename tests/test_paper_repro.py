"""Paper-claim regression tests (reduced scale; benchmarks/ reproduce the
full tables)."""
import numpy as np
import pytest


def test_fig2_comm_linear_in_parties():
    """Paper Fig 2 (lower): communication grows linearly with parties,
    and the concurrent-leg transport meters the identical bytes."""
    from benchmarks import fig2_scaling
    report = fig2_scaling.run(ks=(2, 3, 4, 5), glms=("logistic",),
                              iters=4, batch=512, n_samples=2000,
                              smoke=True)
    fit = report["linear_fits"][0]
    rows = report["rows"]
    comm = [r["comm_mb"] for r in rows if r["transport"] == "pipelined"]
    assert fit["slope_mb_per_party"] > 0
    assert fit["max_residual_mb"] < 0.05 * max(comm), \
        "comm growth should be ~linear (paper Fig 2)"
    for k in (2, 3, 4, 5):
        by_tp = {r["transport"]: r["comm_mb"] for r in rows
                 if r["parties"] == k}
        assert by_tp["pipelined"] == by_tp["local"]


def test_fig1_losses_match_centralized():
    """Paper Fig 1: EFMVFL loss curve ≈ non-private training."""
    from benchmarks import fig1_losses
    curves = fig1_losses.run(iters=8)
    for glm in ("logistic", "poisson"):
        c = curves[glm]
        gap = max(abs(a - b) for a, b in zip(c["efmvfl"], c["centralized"]))
        assert gap < 5e-3, f"{glm}: federated diverges from centralized"


def test_vfl_lm_head_trains():
    """DESIGN §4: the paper's protocol as an LM-framework feature."""
    import jax
    from repro.configs import registry
    from repro.core import vfl_lm
    from repro.core.trainer import VFLConfig
    from repro.core.vfl_lm import BackboneParty, identity_backbone
    from repro.models import registry as models

    rng = np.random.default_rng(1)
    n = 192
    X = rng.normal(size=(n, 6))
    w = rng.normal(size=6)
    y = np.where(X @ w > 0, 1.0, -1.0)
    cfg_lm = registry.get_smoke_config("gpt-100m")
    api = models.build(cfg_lm)
    params = api.init_params(jax.random.key(0))
    toks = np.where(y[:, None] > 0,
                    rng.integers(0, 512, (n, 12)),
                    rng.integers(512, 1024, (n, 12))).astype(np.int32)
    parties = [BackboneParty("C", identity_backbone, X),
               BackboneParty("B1", vfl_lm.make_lm_backbone(api, params), toks)]
    cfg = VFLConfig(glm="logistic", lr=0.3, max_iter=12, batch_size=64,
                    he_backend="mock", tol=0.0, seed=2)
    res, quality = vfl_lm.train_federated_head(parties, y, cfg)
    assert quality["train_auc"] > 0.8
    assert res.meter.total_mb > 0
