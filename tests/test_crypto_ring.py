"""Z_2^64 (hi,lo)-pair arithmetic vs numpy uint64 oracles."""
import numpy as np
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.crypto import fixed_point, ring

RNG = np.random.default_rng(5)
U64 = st.integers(min_value=0, max_value=(1 << 64) - 1)


def rand_u64(shape):
    return RNG.integers(0, 1 << 64, size=shape, dtype=np.uint64)


def test_roundtrip():
    x = rand_u64((4, 3))
    assert (ring.to_numpy_u64(ring.from_numpy_u64(x)) == x).all()


@settings(max_examples=60, deadline=None)
@given(U64, U64)
def test_add_sub_mul_hypothesis(a, b):
    A = ring.from_numpy_u64(np.array([a], np.uint64))
    B = ring.from_numpy_u64(np.array([b], np.uint64))
    m = (1 << 64) - 1
    assert int(ring.to_numpy_u64(ring.add(A, B))[0]) == (a + b) & m
    assert int(ring.to_numpy_u64(ring.sub(A, B))[0]) == (a - b) & m
    assert int(ring.to_numpy_u64(ring.mul(A, B))[0]) == (a * b) & m
    assert int(ring.to_numpy_u64(ring.neg(A))[0]) == (-a) & m


def test_mul_pub_and_shifts():
    x = rand_u64((16,))
    X = ring.from_numpy_u64(x)
    for k in [0, 1, 3, -5, 1 << 40]:
        got = ring.to_numpy_u64(ring.mul_pub_int(X, k))
        want = x * np.uint64(k % (1 << 64))
        assert (got == want).all()
    for s in [0, 1, 12, 31, 32, 33, 63]:
        assert (ring.to_numpy_u64(ring.shift_left(X, s)) == (x << np.uint64(s))).all()
        assert (ring.to_numpy_u64(ring.shift_right_logical(X, s))
                == (x >> np.uint64(s))).all()


def test_fixed_point_roundtrip():
    x = RNG.normal(size=(32,)) * 100
    enc = fixed_point.encode(x, 20)
    dec = fixed_point.decode(enc, 20)
    np.testing.assert_allclose(dec, x, atol=2 ** -20)


def test_sum_axis():
    x = rand_u64((7, 5))
    got = ring.to_numpy_u64(ring.sum_axis(ring.from_numpy_u64(x), 0))
    want = x.sum(axis=0)  # numpy uint64 wraps mod 2^64
    assert (got == want).all()


def test_matmul_public_by_ring():
    xs = RNG.integers(-1000, 1000, size=(4, 6)).astype(np.int32)
    a = rand_u64((6, 3))
    got = ring.to_numpy_u64(ring.matmul(jnp.asarray(xs), ring.from_numpy_u64(a)))
    want = (xs.astype(np.int64).astype(np.uint64)[:, :, None] * a[None]).sum(1)
    assert (got == want).all()
