"""Validates the analytic cost model against XLA's cost_analysis on
FULLY-UNROLLED small configs — the regime where XLA's numbers are exact
(no while loops).  This is the ground-truth anchor for §Roofline."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch import costmodel
from repro.models import transformer


class _Mesh1:
    axis_names = ("data", "model")

    class devices:
        size = 1
        shape = (1, 1)


MESH1 = _Mesh1()


def _val_cfg(**kw):
    base = dict(name="val", family="dense", n_layers=2, d_model=256,
                n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=2048,
                head_dim=64, remat=False, debug_unroll=True, act="silu")
    base.update(kw)
    return ModelConfig(**base)


def _xla_flops(fn, *args) -> float:
    from repro.launch.costmodel import xla_cost_analysis
    compiled = jax.jit(fn).lower(*args).compile()
    return float(xla_cost_analysis(compiled)["flops"])


@pytest.mark.parametrize("S,B", [(128, 2), (256, 1)])
def test_forward_flops_dense(S, B):
    cfg = _val_cfg()
    cell = ShapeCell("t", S, B, "prefill")
    params = transformer.init_params(jax.random.key(0), cfg)
    tokens = jnp.zeros((B, S), jnp.int32)

    def fwd(p, t):
        logits, _, _ = transformer.forward(p, cfg, t, kv_block=None)
        return logits

    got = _xla_flops(fwd, params, tokens)
    want = costmodel.forward_flops_total(cfg, cell, costmodel.CostKnobs())
    assert abs(got - want) / want < 0.25, (got, want, got / want)


def test_train_flops_multiplier():
    """fwd+bwd ≈ 3× fwd (no remat): the analytic multiplier is right."""
    cfg = _val_cfg()
    B, S = 2, 128
    params = transformer.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}

    def fwd(p, b):
        return transformer.train_loss(p, cfg, b)

    f_fwd = _xla_flops(fwd, params, batch)
    f_train = _xla_flops(
        lambda p, b: jax.value_and_grad(fwd)(p, b), params, batch)
    ratio = f_train / f_fwd
    assert 2.4 < ratio < 3.6, ratio


def test_moe_flops():
    cfg = _val_cfg(family="moe", n_experts=8, experts_per_token=2,
                   moe_d_ff=256, capacity_factor=1.25)
    B, S = 2, 128
    cell = ShapeCell("t", S, B, "prefill")
    params = transformer.init_params(jax.random.key(0), cfg)
    tokens = jnp.zeros((B, S), jnp.int32)

    def fwd(p, t):
        logits, _, _ = transformer.forward(p, cfg, t, kv_block=None)
        return logits

    got = _xla_flops(fwd, params, tokens)
    want = costmodel.forward_flops_total(cfg, cell, costmodel.CostKnobs())
    # sorted dispatch adds gather/scatter overhead; model counts GEMMs
    assert abs(got - want) / want < 0.35, (got, want, got / want)


def test_decode_flops():
    cfg = _val_cfg()
    B, S_ctx = 4, 256
    cell = ShapeCell("t", S_ctx, B, "decode")
    params = transformer.init_params(jax.random.key(0), cfg)
    cache = transformer.init_cache(cfg, B, S_ctx)
    cache = cache._replace(length=jnp.asarray(S_ctx - 1, jnp.int32))
    token = jnp.zeros((B, 1), jnp.int32)

    def step(p, c, t):
        return transformer.decode_step(p, cfg, c, t)[0]

    got = _xla_flops(step, params, cache, token)
    want = costmodel.forward_flops_total(cfg, cell, costmodel.CostKnobs())
    assert abs(got - want) / want < 0.35, (got, want, got / want)


def test_cell_costs_sane_at_scale():
    """Full-size sanity: useful-flops ratio ≤ 1ish and memory > params."""
    from repro.configs import registry
    from repro.configs.base import SHAPE_CELLS
    for arch in ("qwen3-4b", "kimi-k2-1t-a32b", "rwkv6-1.6b"):
        cfg = registry.get_config(arch)
        cell = SHAPE_CELLS["train_4k"]

        class M:
            axis_names = ("data", "model")

            class devices:
                size = 256
                shape = (16, 16)

        costs = costmodel.cell_costs(cfg, cell, M)
        model_flops = 6 * cfg.active_param_count() * cell.seq_len \
            * cell.global_batch
        total = costs["flops_per_dev"] * 256
        assert total >= model_flops * 0.8, (arch, total / model_flops)
        assert total <= model_flops * 4.0, (arch, total / model_flops)
