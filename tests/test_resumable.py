"""Resumable training sessions (runtime/session.py).

Three layers of coverage:

1. the step-state machine — `VFLScheduler.run()` as a fold over
   `step(state)` is bit-exact vs the one-shot run, including a
   checkpoint → FRESH scheduler → restore → continue split mid-run
   (losses, weights, per-tag bytes) for k ∈ {2,3,4} × logistic/poisson;
2. `TrainState` (de)serialization — hypothesis round-trips through the
   `CheckpointManager` (tree + manifest extra) across GLMs/backends/k,
   plus the hardened manager's torn-manifest skip and config/codec
   mismatch REFUSAL;
3. crash recovery on the real wire — kill -9 of a party process mid-run,
   supervisor relaunch, resume from party-local checkpoints, final run
   bit-identical to an uninterrupted one (mock fast here; the Paillier
   variant is slow-marked).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.checkpoint import (CheckpointManager, CheckpointMismatch,  # noqa: E402
                              save_checkpoint, valid_steps)
from repro.core import trainer  # noqa: E402
from repro.core.trainer import PartyData, VFLConfig  # noqa: E402
from repro.data import synthetic, vertical  # noqa: E402
from repro.runtime import (LocalTransport, PipelinedTransport,  # noqa: E402
                           VFLScheduler)
from repro.runtime import seeds, session  # noqa: E402
from repro.runtime.session import TrainState  # noqa: E402


def _make_parties(X, k):
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    return [PartyData(name=nm, X=p) for nm, p in zip(names, parts)]


def _data(glm, n=200, seed=3):
    if glm == "poisson":
        return synthetic.dvisits(n=n, seed=seed)
    return synthetic.credit_default(n=n, d=8, seed=seed)


def _assert_exact(res, ref):
    assert res.losses == ref.losses
    assert set(res.weights) == set(ref.weights)
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert res.meter.total_bytes == ref.meter.total_bytes
    assert res.n_iter == ref.n_iter


# ---------------------------------------------------------------------------
# 1. step-state machine: fold ≡ one-shot, checkpoint/restore mid-run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("glm,k", [("logistic", 2), ("logistic", 3),
                                   ("logistic", 4), ("poisson", 3)])
def test_checkpoint_midrun_fresh_scheduler_bit_identical(glm, k, tmp_path):
    """Save TrainState after 2 iterations, load it into a FRESH
    scheduler (new actors, new backend, new transport), continue — the
    spliced run must equal the uninterrupted one bit-for-bit."""
    X, y = _data(glm)
    cfg = VFLConfig(glm=glm, lr=0.1, max_iter=4, batch_size=64,
                    he_backend="mock", tol=0.0, seed=11)
    parties = _make_parties(X, k)
    ref = trainer.train_vfl(parties, y, cfg)

    sched_a = VFLScheduler(parties, y, cfg)
    state = sched_a.init_state()
    for _ in range(2):
        state = sched_a.step(state)
    mgr = CheckpointManager(str(tmp_path), config_hash=session.config_hash(cfg),
                            codec_version=session.CODEC_VERSION)
    tree, extra = state.to_checkpoint()
    mgr.save(state.it, tree, extra)

    got = CheckpointManager(
        str(tmp_path), config_hash=session.config_hash(cfg),
        codec_version=session.CODEC_VERSION).restore(
            TrainState.tree_template([p.name for p in parties]))
    assert got is not None and got[0] == 2
    restored = TrainState.from_checkpoint(got[1], got[2])
    assert restored.equals(state)

    sched_b = VFLScheduler(parties, y, cfg)      # fresh everything
    res = sched_b.run(state=restored)
    _assert_exact(res, ref)


def test_step_counters_and_random_cp_pipelined_resume():
    """The dedicated CP-selection stream and the drawn counters survive
    a mid-run state splice under PipelinedTransport + random CP."""
    X, y = _data("logistic", n=300, seed=2)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=4, batch_size=128,
                    he_backend="mock", tol=0.0, seed=6,
                    cp_selection="random")
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg,
                            transport=PipelinedTransport())
    sched_a = VFLScheduler(parties, y, cfg, transport=PipelinedTransport())
    state = sched_a.init_state()
    state = sched_a.step(state)
    assert state.select_rng is not None          # dedicated stream captured
    assert state.select_rng["drawn"] == 1        # one choice per iteration
    assert state.dealer["drawn"] > 0             # loss-product triples drawn
    round_tripped = TrainState.from_checkpoint(*state.to_checkpoint())
    assert round_tripped.equals(state)
    sched_b = VFLScheduler(parties, y, cfg, transport=PipelinedTransport())
    res = sched_b.run(state=round_tripped)
    _assert_exact(res, ref)


def test_early_stop_state_is_terminal():
    """A state captured at the stop flag folds to itself: run() from it
    performs no further iterations."""
    X, y = _data("logistic", n=300, seed=15)
    cfg = VFLConfig(glm="logistic", lr=0.0, max_iter=10, batch_size=128,
                    he_backend="mock", tol=1e-3, seed=5)
    parties = _make_parties(X, 2)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()
    assert res.n_iter == 2
    state = sched._capture(it=res.n_iter, order=np.arange(len(X)), cursor=0,
                           runtime_s=0.0)
    assert state.stop
    res2 = VFLScheduler(parties, y, cfg).run(state=state)
    assert res2.n_iter == res.n_iter and res2.losses == res.losses


def test_counted_rng_drawn_and_locked_passthrough():
    """seeds.CountedGenerator counts draw calls, serializes its exact
    position, and stays counting under transport.LockedRNG."""
    from repro.runtime.transport import LockedRNG
    rng = seeds.protocol_rng(7)
    assert rng.drawn() == 0
    a = rng.integers(2 ** 31)
    rng.random(4)
    assert rng.drawn() == 2
    snap = rng.state()
    b = rng.integers(2 ** 31)
    rng.set_state(snap)
    assert rng.drawn() == 2
    assert rng.integers(2 ** 31) == b            # exact position restored
    locked = LockedRNG(seeds.protocol_rng(7))
    assert int(locked.integers(2 ** 31)) == int(a)   # same stream replica
    assert locked.drawn() == 1
    st2 = locked.state()
    assert st2["drawn"] == 1
    # a counted state transplants across instances: position + counter
    fresh = seeds.protocol_rng(0)
    fresh.set_state(st2)
    ref = seeds.protocol_rng(7)
    ref.integers(2 ** 31)
    assert int(fresh.integers(2 ** 31)) == int(ref.integers(2 ** 31))
    # replica equality: same seed, same draw count -> same next value
    r1, r2 = seeds.party_rng(3, 1), seeds.party_rng(3, 1)
    r1.integers(10)
    r2.set_state(r1.state())
    assert int(r1.integers(2 ** 31)) == int(r2.integers(2 ** 31))


# ---------------------------------------------------------------------------
# 2. TrainState serialization (hypothesis) + hardened manager
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=1),     # glm index
       st.integers(min_value=2, max_value=4),     # k
       st.integers(min_value=0, max_value=2),     # steps before capture
       st.integers(min_value=0, max_value=10_000))  # run seed
def test_trainstate_roundtrip_hypothesis(glm_i, k, n_steps, seed):
    glm = ("logistic", "poisson")[glm_i]
    X, y = _data(glm, n=120, seed=3)
    cfg = VFLConfig(glm=glm, lr=0.1, max_iter=3, batch_size=32,
                    he_backend="mock", tol=0.0, seed=seed)
    sched = VFLScheduler(_make_parties(X, k), y, cfg)
    state = sched.init_state()
    for _ in range(n_steps):
        state = sched.step(state)
    tree, extra = state.to_checkpoint()
    # manifest extra must be JSON-able exactly as the manager writes it
    import json
    extra = json.loads(json.dumps(extra))
    back = TrainState.from_checkpoint(tree, extra)
    assert back.equals(state)
    assert state.equals(back)
    assert back.it == n_steps
    assert back.protocol_rng["drawn"] == state.protocol_rng["drawn"]


@pytest.mark.slow
def test_trainstate_roundtrip_paillier_backend(tmp_path):
    """Same round-trip with the real Paillier backend in the loop (the
    protocol stream has consumed keygen + noise draws)."""
    X, y = _data("logistic", n=100, seed=5)
    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=2, batch_size=32,
                    he_backend="paillier", key_bits=192, tol=0.0, seed=1)
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg)
    sched = VFLScheduler(parties, y, cfg)
    state = sched.step(sched.init_state())
    mgr = CheckpointManager(str(tmp_path))
    tree, extra = state.to_checkpoint()
    mgr.save(state.it, tree, extra)
    s, t2, e2 = mgr.restore(TrainState.tree_template([p.name
                                                      for p in parties]))
    back = TrainState.from_checkpoint(t2, e2)
    assert back.equals(state)
    res = VFLScheduler(parties, y, cfg).run(state=back)
    _assert_exact(res, ref)


def test_manager_refuses_config_and_codec_mismatch(tmp_path):
    tree = {"a": np.arange(3)}
    save_checkpoint(str(tmp_path), 1, tree, config_hash="aaaa",
                    codec_version=1)
    ok = CheckpointManager(str(tmp_path), config_hash="aaaa",
                           codec_version=1)
    assert ok.steps() == [1]
    assert ok.restore({"a": 0})[0] == 1
    bad_cfg = CheckpointManager(str(tmp_path), config_hash="bbbb",
                                codec_version=1)
    with pytest.raises(CheckpointMismatch, match="config hash"):
        bad_cfg.restore({"a": 0})
    with pytest.raises(CheckpointMismatch, match="config hash"):
        bad_cfg.steps()
    bad_codec = CheckpointManager(str(tmp_path), config_hash="aaaa",
                                  codec_version=2)
    with pytest.raises(CheckpointMismatch, match="codec version"):
        bad_codec.restore({"a": 0})
    # unstamped legacy checkpoint + expectation -> also refused
    save_checkpoint(str(tmp_path), 2, tree)
    with pytest.raises(CheckpointMismatch):
        ok.restore({"a": 0})


def test_manager_skips_torn_manifest_and_archive(tmp_path):
    tree = {"a": np.arange(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    save_checkpoint(str(tmp_path), 3, tree)
    # torn manifest: truncated JSON (crash mid-manifest-write)
    with open(tmp_path / "step_3.json", "w") as f:
        f.write('{"step": 3, "n_leav')
    # torn archive: manifest fine, npz corrupted
    with open(tmp_path / "step_2.npz", "r+b") as f:
        f.write(b"garbage")
    assert valid_steps(str(tmp_path)) == [1]
    got = CheckpointManager(str(tmp_path)).restore({"a": 0})
    assert got is not None and got[0] == 1


def test_config_hash_semantics():
    cfg_a = VFLConfig(glm="logistic", seed=1)
    cfg_b = VFLConfig(glm="logistic", seed=1, checkpoint_every=5)
    cfg_c = VFLConfig(glm="logistic", seed=2)
    assert session.config_hash(cfg_a) == session.config_hash(cfg_b)
    assert session.config_hash(cfg_a) != session.config_hash(cfg_c)


# ---------------------------------------------------------------------------
# 3. crash recovery on the real wire (kill -9 + supervised resume)
# ---------------------------------------------------------------------------

def _assert_socket_exact(res, ref):
    assert res.losses == ref.losses
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert res.meter.total_bytes == ref.meter.total_bytes
    assert res.n_iter == ref.n_iter
    assert dict(res.measured_meter.by_tag) == dict(res.meter.by_tag)


def test_kill_and_resume_socket_parity_mock(tmp_path):
    """kill -9 one party mid-run -> supervisor relaunch -> resume from
    party-local checkpoints: losses, weights, and per-tag analytic AND
    measured byte accounting bit-identical to an uninterrupted run."""
    from repro.launch.cluster import train_vfl_socket_resilient
    X, y = _data("logistic", n=200, seed=3)
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=4, batch_size=64,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1)
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket_resilient(
        parties, y, cfg, checkpoint_dir=str(tmp_path),
        kill_plan={2: "B1"})
    _assert_socket_exact(res, ref)
    assert res.restarts == 1
    assert res.resume_report["step"] >= 1        # rolled back, not replayed
    # the audited replicated counters agreed across all 3 parties
    assert set(res.resume_report["rng_drawn"]) == {"C", "B1", "B2"}


def test_kill_and_resume_socket_parity_poisson_mock(tmp_path):
    """Same invariant under the order-sensitive e^z chaining and a
    kill of the label holder C itself."""
    from repro.launch.cluster import train_vfl_socket_resilient
    X, y = _data("poisson", n=200, seed=7)
    cfg = VFLConfig(glm="poisson", lr=0.05, max_iter=3, batch_size=48,
                    he_backend="mock", tol=0.0, seed=5,
                    checkpoint_every=1)
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket_resilient(
        parties, y, cfg, checkpoint_dir=str(tmp_path),
        kill_plan={1: "C"})
    _assert_socket_exact(res, ref)
    assert res.restarts == 1


@pytest.mark.slow
def test_kill_and_resume_socket_parity_paillier(tmp_path):
    """Real Paillier over the wire: the killed party's private key is
    re-derived (never read from disk), mask/noise streams roll back to
    the checkpointed positions, and the run stays bit-identical."""
    from repro.launch.cluster import train_vfl_socket_resilient
    X, y = _data("poisson", n=90, seed=19)
    cfg = VFLConfig(glm="poisson", lr=0.05, max_iter=3, batch_size=24,
                    he_backend="paillier", key_bits=192, tol=0.0, seed=17,
                    checkpoint_every=1)
    parties = _make_parties(X, 3)
    ref = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket_resilient(
        parties, y, cfg, checkpoint_dir=str(tmp_path),
        kill_plan={1: "B1"})
    _assert_socket_exact(res, ref)
    assert res.restarts == 1


def test_resume_refused_on_config_mismatch(tmp_path):
    """A checkpoint directory written under one config must refuse a
    resume under another, with the mismatch spelled out."""
    from repro.launch.cluster import ClusterError, train_vfl_socket
    X, y = _data("logistic", n=120, seed=3)
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=32,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1)
    parties = _make_parties(X, 2)
    train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path))
    other = VFLConfig(glm="logistic", lr=0.2, max_iter=2, batch_size=32,
                      he_backend="mock", tol=0.0, seed=11,
                      checkpoint_every=1)
    with pytest.raises(ClusterError, match="config hash"):
        train_vfl_socket(parties, y, other, checkpoint_dir=str(tmp_path),
                         resume=True)


def test_completed_run_resume_is_noop(tmp_path):
    """Resuming a directory whose newest common step is the final
    iteration performs zero additional iterations and reproduces the
    same result (idempotent recovery)."""
    from repro.launch.cluster import train_vfl_socket
    X, y = _data("logistic", n=120, seed=3)
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=32,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1)
    parties = _make_parties(X, 2)
    first = train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path))
    again = train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path),
                             resume=True)
    assert again.resume_report["step"] == 2
    _assert_socket_exact(again, first)


def test_resume_handshake_survives_partition(tmp_path):
    """A link partition firing during the resume handshake itself: the
    chaos ARQ layer (runtime/chaos.py) retransmits the handshake frames
    across the outage, the max-common-step election completes, and the
    resumed run stays bit-identical — regression for the resume frames
    being single-shot reads with no retry path."""
    from repro.launch.cluster import train_vfl_socket
    from repro.runtime.chaos import ChaosProfile
    X, y = _data("logistic", n=120, seed=3)
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=32,
                    he_backend="mock", tol=0.0, seed=11,
                    checkpoint_every=1)
    parties = _make_parties(X, 2)
    first = train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path))
    # partition_p=1 + partition_at=2: every directed link blackholes at
    # its 2nd reliable first-send — i.e. mid-handshake, before iterate
    storm = ChaosProfile(seed=9, latency_s=0.001, drop_p=0.05,
                         partition_p=1.0, partition_at=2,
                         partition_s=0.2)
    again = train_vfl_socket(parties, y, cfg, checkpoint_dir=str(tmp_path),
                             resume=True, chaos=storm)
    assert again.resume_report["step"] == 2
    _assert_socket_exact(again, first)
    assert again.chaos_report["total"]["partitions"] >= 1


# ---------------------------------------------------------------------------
# transport-level liveness plumbing
# ---------------------------------------------------------------------------

def test_socket_transport_reconnect_and_heartbeat():
    """attach() replaces a dropped link without a spurious peer-loss
    event; heartbeat frames flow and are plain `hb` controls."""
    import queue
    import socket as socket_lib

    from repro.runtime import messages as msg_lib
    from repro.runtime.codec import Codec
    from repro.runtime.transport import SocketTransport

    def pair():
        srv = socket_lib.create_server(("127.0.0.1", 0))
        cli = socket_lib.create_connection(srv.getsockname())
        conn, _ = srv.accept()
        srv.close()
        return cli, conn

    a = SocketTransport("A", Codec())
    b = SocketTransport("B", Codec())
    s_ab, s_ba = pair()
    a.attach("B", s_ab)
    b.attach("A", s_ba)
    a.send_control(msg_lib.Control("A", "B", kind="ping"))
    assert b.inbound.get(timeout=5).kind == "ping"

    # reconnect: B deliberately drops the stale link (detach — silenced),
    # both ends re-attach a fresh connection, traffic continues, and
    # neither stale reader posts a spurious __closed__ event
    b.detach("A")
    s2_ab, s2_ba = pair()
    a.attach("B", s2_ab)          # attach-replace: closes A's stale socket
    b.attach("A", s2_ba)
    a.send_control(msg_lib.Control("A", "B", kind="ping2"))
    got = b.inbound.get(timeout=5)
    assert isinstance(got, msg_lib.Control) and got.kind == "ping2"

    # heartbeats: periodic `hb` frames arrive on the receiver
    a.start_heartbeat("B", 0.05)
    hb = b.inbound.get(timeout=5)
    assert isinstance(hb, msg_lib.Control) and hb.kind == "hb"

    # no spurious peer-loss surfaced by the reconnect (checked BEFORE
    # close — the close() pair itself legitimately races a final
    # __closed__ on whichever side closes second)
    leftovers = []
    try:
        while True:
            leftovers.append(b.inbound.get_nowait())
    except queue.Empty:
        pass
    assert all(m.kind != "__closed__" for m in leftovers
               if isinstance(m, msg_lib.Control))
    a.close()
    b.close()
