"""`hypothesis` if installed, else a deterministic fallback.

The seed suite hard-imported hypothesis, so 4 of 15 test modules failed
at *collection* on a clean interpreter.  This shim keeps the
property-based tests meaningful everywhere: with hypothesis installed
(declared in pyproject's `test` extra) you get real shrinking sweeps;
without it, each `@given` test runs a fixed number of deterministically
seeded samples drawn from the same strategy shapes (boundaries + a
log-uniform interior spread, which is what matters for limb/ring
arithmetic), so the suite still collects and smoke-covers the
properties.

Usage (drop-in for the subset these tests need):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import zlib

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # deterministic fallback
    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample                      # rng -> value

    class _St:
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = 0 if min_value is None else int(min_value)

            def sample(rng):
                if max_value is None:
                    # unbounded above: log-uniform magnitude up to 256 bits
                    bits = int(rng.integers(1, 257))
                    return lo + int.from_bytes(
                        rng.bytes((bits + 7) // 8), "little") % (1 << bits)
                hi = int(max_value)
                span = hi - lo + 1
                r = rng.random()
                if r < 0.15:
                    return lo
                if r < 0.30:
                    return hi
                # log-uniform interior: exercise all magnitudes
                k = int(rng.integers(1, max(span.bit_length(), 1) + 1))
                return lo + int.from_bytes(
                    rng.bytes((k + 7) // 8), "little") % span

            return _Strategy(sample)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            lo, hi = float(min_value), float(max_value)

            def sample(rng):
                r = rng.random()
                if r < 0.1:
                    return lo
                if r < 0.2:
                    return hi
                return lo + (hi - lo) * rng.random()

            return _Strategy(sample)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def sample(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elem.sample(rng) for _ in range(size)]

            return _Strategy(sample)

    st = _St()

    def settings(max_examples=None, deadline=None, **_):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # NOTE: plain zero-arg wrapper, no functools.wraps — pytest
            # would follow __wrapped__ and demand the strategy params as
            # fixtures.  These tests take strategy-supplied args only.
            def wrapper():
                limit = getattr(wrapper, "_compat_max_examples", None)
                n = min(limit or FALLBACK_EXAMPLES, FALLBACK_EXAMPLES)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*(s.sample(rng) for s in strats))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
