"""EFMVFL protocol correctness: Protocol 2/3/4 vs plaintext oracles, and
mock-HE ≡ real-Paillier equivalence."""
import jax
import numpy as np

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.core.comm import CommMeter
from repro.crypto import fixed_point, paillier, ring
from repro.mpc import beaver, sharing

RNG = np.random.default_rng(23)
F = 18
FX = 12
W = 18   # exponent width for tests (small features)


def _shares(x, key, f=F):
    return sharing.share(fixed_point.encode(x, f), jax.random.key(key))


def test_gradient_operator_lr():
    n = 256
    z = RNG.normal(size=n) * 2
    y = np.where(RNG.uniform(size=n) > 0.5, 1.0, -1.0)
    ctx = glm_lib.ShareCtx(z=_shares(z, 1), y=_shares(y, 2), ez=None, f=F,
                           dealer=beaver.DealerTripleSource(3))
    d0, d1 = glm_lib.LOGISTIC.gradient_operator(ctx)
    got = fixed_point.decode(sharing.reconstruct(d0, d1), F)
    np.testing.assert_allclose(got, 0.25 * z - 0.5 * y, atol=2 ** -F * 8)


def test_gradient_operator_pr():
    n = 128
    z = RNG.normal(size=n)
    ez = np.exp(z)
    y = RNG.poisson(0.5, size=n).astype(np.float64)
    ctx = glm_lib.ShareCtx(z=_shares(z, 4), y=_shares(y, 5),
                           ez=_shares(ez, 6), f=F,
                           dealer=beaver.DealerTripleSource(7))
    d0, d1 = glm_lib.POISSON.gradient_operator(ctx)
    got = fixed_point.decode(sharing.reconstruct(d0, d1), F)
    np.testing.assert_allclose(got, ez - y, atol=2 ** -F * 8)


def test_loss_lr_matches_float_oracle():
    n = 512
    z = RNG.normal(size=n)
    y = np.where(RNG.uniform(size=n) > 0.6, 1.0, -1.0)
    ctx = glm_lib.ShareCtx(z=_shares(z, 8), y=_shares(y, 9), ez=None, f=F,
                           dealer=beaver.DealerTripleSource(10))
    l0, l1 = glm_lib.LOGISTIC.loss_shares(ctx)
    revealed = float(fixed_point.decode(sharing.reconstruct(l0, l1), F))
    got = glm_lib.LOGISTIC.finalize_loss(revealed, y, n)
    want = glm_lib.LOGISTIC.loss_float(z, y)
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_loss_pr_matches_float_oracle():
    n = 256
    z = RNG.normal(size=n) * 0.5
    ez = np.exp(z)
    y = RNG.poisson(0.4, size=n).astype(np.float64)
    ctx = glm_lib.ShareCtx(z=_shares(z, 11), y=_shares(y, 12),
                           ez=_shares(ez, 13), f=F,
                           dealer=beaver.DealerTripleSource(14))
    l0, l1 = glm_lib.POISSON.loss_shares(ctx)
    revealed = float(fixed_point.decode(sharing.reconstruct(l0, l1), F))
    got = glm_lib.POISSON.finalize_loss(revealed, y, n)
    want = glm_lib.POISSON.loss_float(z, y)
    np.testing.assert_allclose(got, want, atol=1e-3)


def _p3_setup(n, m, seed):
    X = RNG.normal(size=(n, m))
    d = RNG.normal(size=n) * 0.5
    feats = protocols.EncodedFeatures.make(X, FX, W)
    d_enc = fixed_point.encode(d, F)
    d0, d1 = sharing.share(d_enc, jax.random.key(seed))
    return X, d, feats, d0, d1


def test_he_matvec_integer_exactness():
    key = paillier.keygen(256, seed=31)
    pub = key.pub
    n, m = 12, 3
    u = RNG.integers(0, 1 << 64, size=n, dtype=np.uint64)
    exps = RNG.integers(0, 1 << W, size=(n, m), dtype=np.uint32)
    cts = paillier.encrypt(
        pub, fixed_point.r64_to_limbs(ring.from_numpy_u64(u), pub.Ln),
        rng=np.random.default_rng(1))
    want = [int(sum(int(exps[i, j]) * int(u[i]) for i in range(n)))
            for j in range(m)]
    for window in (1, 2, 4):   # bit-serial == fixed-window (§Perf variant)
        out = protocols.he_matvec(pub, cts, exps, W, window=window)
        dec = paillier.decode_ints(np.asarray(paillier.decrypt(key, out)))
        assert dec == want, f"window={window}" 


def test_protocol3_cp_matches_oracle_mock():
    n, m = 200, 6
    X, d, feats, d0, d1 = _p3_setup(n, m, 15)
    backend = protocols.MockHEBackend(1024)
    ct1 = backend.encrypt_share("B1", d1)
    g = protocols.secure_gradient_cp(
        backend, p0="C", p1="B1", feats=feats,
        d_self=d0, d_other_ct=ct1, d_other_share=d1,
        mask_bound_bits=64 + W + 9, rng=np.random.default_rng(5))
    got = fixed_point.decode(g, FX + F)
    np.testing.assert_allclose(got, X.T @ d, rtol=0, atol=2 ** -FX * n * 2)


def test_protocol3_mock_equals_paillier_bitwise():
    """The mock backend must produce the *identical* ring result as real
    Paillier (given identical masks) — validates the DESIGN §7 semantics."""
    n, m = 24, 4
    X, d, feats, d0, d1 = _p3_setup(n, m, 16)
    key = paillier.keygen(256, seed=33)
    pbackend = protocols.PaillierBackend({"C": key, "B1": key},
                                         np.random.default_rng(9))
    mbackend = protocols.MockHEBackend(256)
    outs = {}
    for name, backend in [("paillier", pbackend), ("mock", mbackend)]:
        ct1 = backend.encrypt_share("B1", d1)
        g = protocols.secure_gradient_cp(
            backend, p0="C", p1="B1", feats=feats,
            d_self=d0, d_other_ct=ct1, d_other_share=d1,
            mask_bound_bits=64 + W + 6, rng=np.random.default_rng(77))
        outs[name] = ring.to_numpy_u64(g)
    assert (outs["paillier"] == outs["mock"]).all()


def test_protocol3_noncp():
    n, m = 64, 5
    X, d, feats, d0, d1 = _p3_setup(n, m, 17)
    backend = protocols.MockHEBackend(1024)
    cts = {"C": backend.encrypt_share("C", d0),
           "B1": backend.encrypt_share("B1", d1)}
    g = protocols.secure_gradient_noncp(
        backend, party="B2", cps=("C", "B1"), feats=feats,
        d_cts=cts, d_shares={"C": d0, "B1": d1},
        mask_bound_bits=64 + W + 7, rng=np.random.default_rng(6))
    got = fixed_point.decode(g, FX + F)
    np.testing.assert_allclose(got, X.T @ d, rtol=0, atol=2 ** -FX * n * 2)


def test_comm_meter_accounting():
    meter = CommMeter()
    meter.ring("C", "B1", "P1.z_share", 100)
    meter.cipher("B1", "C", "P3.enc_d", 10, 1024)
    assert meter.total_bytes == 100 * 8 + 10 * 256
    assert meter.summary()["TOTAL_MB"] == meter.total_mb


# ---------------------------------------------------------------------------
# Property-based protocol invariants (hypothesis)
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, st


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=-30, max_value=30), min_size=4,
                max_size=16),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_property_p3_gradient_exact(xs, seed):
    """Protocol 3 (mock backend ≡ Paillier, proven elsewhere) recovers
    X^T d within fixed-point tolerance for arbitrary bounded inputs."""
    n = len(xs)
    rng = np.random.default_rng(seed)
    X = np.asarray(xs, np.float64).reshape(n, 1) / 8.0
    d = rng.normal(size=n)
    feats = protocols.EncodedFeatures.make(X, FX, W)
    d0, d1 = sharing.share(fixed_point.encode(d, F),
                           jax.random.key(seed % 1000))
    backend = protocols.MockHEBackend(1024)
    g = protocols.secure_gradient_cp(
        backend, p0="C", p1="B1", feats=feats,
        d_self=d0, d_other_ct=backend.encrypt_share("B1", d1),
        d_other_share=d1, mask_bound_bits=64 + W + 6,
        rng=np.random.default_rng(seed))
    got = fixed_point.decode(g, FX + F)
    np.testing.assert_allclose(got, X.T @ d, atol=2 ** -FX * n * 2 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_property_share_reveal_only_masked(seed):
    """What P1 sees in Protocol 3 (the masked value) is statistically
    independent of the gradient: two different gradients under the SAME
    mask stream differ by exactly their true difference — i.e. the mask
    cancels; and under fresh masks the messages are unpredictable."""
    n, m = 16, 2
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    feats = protocols.EncodedFeatures.make(X, FX, W)
    backend = protocols.MockHEBackend(1024)

    def masked_message(d, mask_seed):
        d0, d1 = sharing.share(fixed_point.encode(d, F), jax.random.key(7))
        enc_g = backend.matvec("B1", backend.encrypt_share("B1", d1),
                               jax.numpy.asarray(feats.exps), feats.width)
        R = protocols.mask_ints(64 + W + 6, m,
                                np.random.default_rng(mask_seed))
        return ring.to_numpy_u64(backend.add_mask("B1", enc_g, R))

    d_a = rng.normal(size=n)
    d_b = rng.normal(size=n)
    msg_a = masked_message(d_a, 1234)
    msg_b = masked_message(d_b, 1234)     # same masks
    diff = (msg_a - msg_b).astype(np.int64)
    # mask cancels: difference equals the unmasked value difference
    da0, da1 = sharing.share(fixed_point.encode(d_a, F), jax.random.key(7))
    db0, db1 = sharing.share(fixed_point.encode(d_b, F), jax.random.key(7))
    va = backend.matvec("B1", da1, jax.numpy.asarray(feats.exps), feats.width)
    vb = backend.matvec("B1", db1, jax.numpy.asarray(feats.exps), feats.width)
    want = (ring.to_numpy_u64(va) - ring.to_numpy_u64(vb)).astype(np.int64)
    assert (diff == want).all()
