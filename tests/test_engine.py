"""Crypto compute-engine parity: the fused Pallas kernels (interpret
mode — same IR as the TPU path) must be bit-exact vs the pure-jnp
`bigint` oracles on every hot-path op, across key sizes and both GLMs,
and the runtime's noise-pool prefetch must leave the trained model
bit-identical."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.crypto import bigint, paillier
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import Modulus
from repro.kernels import ops

RNG = np.random.default_rng(17)

MODS = [
    (1 << 61) - 1,                                   # 61-bit prime
    int("0x" + "b" * 64, 16) | 1,                    # 256-bit odd
    int("0x" + "7" * 128, 16) | 1,                   # 512-bit odd
]

INTERP = engine_mod.CryptoEngine(backend="pallas-interpret")


def rand_residues(n_mod, size):
    nbytes = (n_mod.bit_length() + 7) // 8
    return [int.from_bytes(RNG.bytes(nbytes), "little") % n_mod
            for _ in range(size)]


# ---------------------------------------------------------------------------
# Engine plumbing
# ---------------------------------------------------------------------------

def test_engine_resolution_and_context():
    assert engine_mod.resolve_backend("jnp") == "jnp"
    assert engine_mod.resolve_backend("pallas-interpret") == "pallas-interpret"
    with pytest.raises(ValueError):
        engine_mod.resolve_backend("cuda")
    base = engine_mod.get_engine()
    with engine_mod.use_engine("pallas-interpret") as eng:
        assert eng.uses_kernels and eng.interpret
        assert engine_mod.get_engine() is eng
    assert engine_mod.get_engine() == base


def test_engine_jnp_is_library():
    mod = Modulus.make(MODS[0])
    a = jnp.asarray(bigint.ints_to_limbs(rand_residues(MODS[0], 3), mod.L))
    eng = engine_mod.CryptoEngine(backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(eng.mont_mul(a, a, mod)),
        np.asarray(bigint.mont_mul(a, a, mod)))


# ---------------------------------------------------------------------------
# Fused mont_exp ≡ bigint ladder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", MODS)
@pytest.mark.parametrize("batch", [1, 5, 8])
def test_mont_exp_fused_vs_oracle(n, batch):
    mod = Modulus.make(n)
    base = rand_residues(n, batch)
    exps = rand_residues(1 << 24, batch)
    B = bigint.to_mont(jnp.asarray(bigint.ints_to_limbs(base, mod.L)), mod)
    bits = jnp.asarray(np.stack([bigint.int_to_bits(e, 24) for e in exps]))
    want = np.asarray(bigint.mont_exp_bits(B, bits, mod))
    got = np.asarray(ops.mont_exp_fused(B, bits, mod, tile_b=4))
    np.testing.assert_array_equal(got, want)
    # python-int ground truth
    ints = [bigint.limbs_to_int(x)
            for x in np.asarray(bigint.from_mont(jnp.asarray(got), mod))]
    assert ints == [pow(x, e, n) for x, e in zip(base, exps)]


def test_mont_exp_fused_broadcast_bits():
    """Single shared exponent vector (the decrypt lam_bits pattern)."""
    n = MODS[1]
    mod = Modulus.make(n)
    B = bigint.to_mont(
        jnp.asarray(bigint.ints_to_limbs(rand_residues(n, 6), mod.L)), mod)
    bits = jnp.asarray(bigint.int_to_bits(0xDEADBEEF, 32))
    np.testing.assert_array_equal(
        np.asarray(ops.mont_exp_fused(B, bits, mod)),
        np.asarray(bigint.mont_exp_bits(B, bits, mod)))


def test_engine_mont_exp_const_cached_bits():
    n = MODS[0]
    mod = Modulus.make(n)
    B = bigint.to_mont(
        jnp.asarray(bigint.ints_to_limbs(rand_residues(n, 2), mod.L)), mod)
    for e in (0, 1, 12345, 0xFFFF):
        np.testing.assert_array_equal(
            np.asarray(INTERP.mont_exp_const(B, e, mod)),
            np.asarray(bigint.mont_exp_const(B, e, mod)))


# ---------------------------------------------------------------------------
# Fused he_matvec ≡ library ladders (both paths, chunking, precompute)
# ---------------------------------------------------------------------------

def _matvec_case(key_bits, n_rows, m, width, seed):
    from repro.core import protocols
    key = paillier.keygen(key_bits, seed=seed)
    pub = key.pub
    rng = np.random.default_rng(seed + 1)
    msgs = [int(v) for v in rng.integers(0, 1 << 16, size=n_rows)]
    cts = paillier.encrypt(pub, paillier.encode_ints(pub, msgs), rng=rng)
    exps = rng.integers(0, 1 << width, size=(n_rows, m), dtype=np.uint32)
    return protocols, key, pub, cts, jnp.asarray(exps), msgs, exps


@pytest.mark.parametrize("key_bits", [128, 256])
def test_he_matvec_fused_vs_library(key_bits):
    protocols, key, pub, cts, exps, msgs, exps_np = _matvec_case(
        key_bits, n_rows=7, m=3, width=22, seed=key_bits)
    want = protocols.he_matvec(pub, cts, exps, 22)
    # fused engine path, with n-chunking and m-tiling exercised
    eng = engine_mod.CryptoEngine(backend="pallas-interpret",
                                  chunk_n=3, tile_m=2)
    got = protocols.he_matvec(pub, cts, exps, 22, engine=eng)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # decrypted integers match the plaintext matvec
    dec = paillier.decode_ints(np.asarray(paillier.decrypt(key, got)))
    assert dec == [sum(int(exps_np[i, j]) * msgs[i]
                       for i in range(len(msgs)))
                   for j in range(exps_np.shape[1])]


def test_he_matvec_fused_bitserial_window():
    protocols, key, pub, cts, exps, msgs, _ = _matvec_case(
        128, n_rows=5, m=2, width=10, seed=3)
    want = protocols.he_matvec(pub, cts, exps, 10, window=1)
    got = protocols.he_matvec(pub, cts, exps, 10, window=1, engine=INTERP)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_he_matvec_precomputed_digits_match():
    from repro.core import protocols
    protocols_, key, pub, cts, exps, msgs, exps_np = _matvec_case(
        128, n_rows=6, m=3, width=22, seed=9)
    digits = protocols.window_digits(exps_np, 22, protocols.DEFAULT_WINDOW)
    want = protocols.he_matvec(pub, cts, exps, 22)
    got = protocols.he_matvec(pub, cts, exps, 22,
                              digits=digits.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # stale digits (wrong level count for the requested window) re-derive
    got2 = protocols.he_matvec(pub, cts, exps, 22, window=6,
                               digits=digits.astype(np.uint32))
    want2 = protocols.he_matvec(pub, cts, exps, 22, window=6)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))


def test_encoded_features_digits_sliced():
    from repro.core import protocols
    X = RNG.normal(size=(20, 3))
    feats = protocols.EncodedFeatures.make(X, fx=10)
    assert feats.digits is not None
    levels = -(-feats.width // protocols.DEFAULT_WINDOW)
    assert feats.digits.shape == feats.exps.shape + (levels,)
    sl = feats.slice(np.array([3, 1, 7]))
    np.testing.assert_array_equal(
        sl.digits,
        protocols.window_digits(sl.exps, feats.width,
                                protocols.DEFAULT_WINDOW))


_PROP_KEY = None


def _prop_key():
    global _PROP_KEY
    if _PROP_KEY is None:
        _PROP_KEY = paillier.keygen(128, seed=41)
    return _PROP_KEY


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=24),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0))
def test_hypothesis_windowed_equals_bitserial(width, window, seed):
    """Property (satellite): windowed ≡ bit-serial he_matvec for random
    widths/windows.  (Fused ≡ library is covered at fixed sizes above —
    keeping the sweep on the library path bounds kernel compile count.)"""
    from repro.core import protocols
    rng = np.random.default_rng(seed % (1 << 32))
    key = _prop_key()
    pub = key.pub
    n_rows, m = 4, 2
    msgs = [int(v) for v in rng.integers(0, 1 << 16, size=n_rows)]
    cts = paillier.encrypt(pub, paillier.encode_ints(pub, msgs), rng=rng)
    exps = jnp.asarray(rng.integers(0, 1 << width, size=(n_rows, m),
                                    dtype=np.uint32))
    bit_serial = protocols.he_matvec(pub, cts, exps, width, window=1)
    windowed = protocols.he_matvec(pub, cts, exps, width, window=window)
    np.testing.assert_array_equal(np.asarray(windowed),
                                  np.asarray(bit_serial))


# ---------------------------------------------------------------------------
# Whole-cryptosystem parity under the engine switch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key_bits", [128, 256])
def test_paillier_roundtrip_engine_parity(key_bits):
    """encrypt / decrypt / decrypt_crt / smul / hom_sum: kernel engine ≡
    jnp engine bit-for-bit (same noise stream ⇒ same ciphertexts)."""
    key = paillier.keygen(key_bits, seed=key_bits + 1)
    pub = key.pub
    msgs = [int(v) for v in RNG.integers(0, 1 << 20, size=6)]
    m = paillier.encode_ints(pub, msgs)
    c_jnp = paillier.encrypt(pub, m, rng=np.random.default_rng(7))
    c_eng = paillier.encrypt(pub, m, rng=np.random.default_rng(7),
                             engine=INTERP)
    np.testing.assert_array_equal(np.asarray(c_eng), np.asarray(c_jnp))
    np.testing.assert_array_equal(
        np.asarray(paillier.decrypt(key, c_jnp, engine=INTERP)),
        np.asarray(paillier.decrypt(key, c_jnp)))
    np.testing.assert_array_equal(
        np.asarray(paillier.decrypt_crt(key, c_jnp, engine=INTERP)),
        np.asarray(paillier.decrypt_crt(key, c_jnp)))
    np.testing.assert_array_equal(
        np.asarray(paillier.smul_const(pub, c_jnp, 997, engine=INTERP)),
        np.asarray(paillier.smul_const(pub, c_jnp, 997)))
    np.testing.assert_array_equal(
        np.asarray(paillier.hom_sum(pub, c_jnp, engine=INTERP)),
        np.asarray(paillier.hom_sum(pub, c_jnp)))


@pytest.mark.slow
@pytest.mark.parametrize("glm", ["logistic", "poisson"])
def test_train_engine_parity_both_glms(glm):
    """End-to-end Algorithm 1 with real Paillier: the pallas-interpret
    engine trains the bit-identical model to the jnp engine."""
    from repro.core import trainer
    from repro.data import synthetic, vertical
    if glm == "poisson":
        X, y = synthetic.dvisits(n=60, seed=7)
    else:
        X, y = synthetic.credit_default(n=60, d=4, seed=3)
    parts = vertical.split_columns(X, 2)
    parties = [trainer.PartyData(name=nm, X=p)
               for nm, p in zip(["C", "B1"], parts)]
    cfg_jnp = trainer.VFLConfig(glm=glm, lr=0.1, max_iter=1, batch_size=16,
                                he_backend="paillier", key_bits=256,
                                tol=0.0, seed=2, crypto_engine="jnp")
    cfg_eng = trainer.VFLConfig(glm=glm, lr=0.1, max_iter=1, batch_size=16,
                                he_backend="paillier", key_bits=256,
                                tol=0.0, seed=2,
                                crypto_engine="pallas-interpret")
    ref = trainer.train_vfl(parties, y, cfg_jnp)
    res = trainer.train_vfl(parties, y, cfg_eng)
    assert res.losses == ref.losses
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])


# ---------------------------------------------------------------------------
# Noise-pool prefetch
# ---------------------------------------------------------------------------

def test_noise_pool_prefetch_and_fallback():
    from concurrent.futures import ThreadPoolExecutor

    from repro.core import protocols
    from repro.crypto import fixed_point, ring
    key = paillier.keygen(128, seed=5)
    backend = protocols.PaillierBackend({"C": key},
                                        np.random.default_rng(3))
    d = ring.from_numpy_u64(
        RNG.integers(0, 1 << 64, size=4, dtype=np.uint64))
    # no executor: prefetch is a no-op, encrypt falls back to sync
    backend.prefetch_noise("C", 4)
    assert not backend._noise["C"]
    c_sync = backend.encrypt_share("C", d)
    assert paillier.decode_ints(np.asarray(paillier.decrypt(key, c_sync))) \
        == [int(v) for v in np.asarray(ring.to_numpy_u64(d))]
    # with executor: pooled noise is consumed, decryption unchanged
    with ThreadPoolExecutor(max_workers=2) as ex:
        backend.attach_noise_executor(ex)
        backend.prefetch_noise("C", 4)
        assert len(backend._noise["C"]) == 1
        c_pool = backend.encrypt_share("C", d)
        assert not backend._noise["C"]          # consumed
        # count mismatch falls back without touching the pool
        backend.prefetch_noise("C", 2)
        c_other = backend.encrypt_share("C", d)
        assert len(backend._noise["C"]) == 1
    for c in (c_pool, c_other):
        assert paillier.decode_ints(np.asarray(paillier.decrypt(key, c))) \
            == [int(v) for v in np.asarray(ring.to_numpy_u64(d))]


def test_pipelined_paillier_prefetch_model_parity():
    """PipelinedTransport + Paillier: the noise pool reorders only the
    entropy stream for r and masks — masks cancel and noise never reaches
    a decrypted value, so the model is bit-identical to LocalTransport."""
    from repro.core import trainer
    from repro.data import synthetic, vertical
    from repro.runtime import LocalTransport, PipelinedTransport
    X, y = synthetic.credit_default(n=45, d=6, seed=5)
    parts = vertical.split_columns(X, 3)   # k=3: exercises the non-CP
    parties = [trainer.PartyData(name=nm, X=p)   # two-key masking legs
               for nm, p in zip(["C", "B1", "B2"], parts)]
    cfg = trainer.VFLConfig(glm="logistic", lr=0.2, max_iter=1,
                            batch_size=16, he_backend="paillier",
                            key_bits=192, tol=0.0, seed=1)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    piped = trainer.train_vfl(parties, y, cfg,
                              transport=PipelinedTransport())
    assert piped.losses == local.losses
    for name in local.weights:
        np.testing.assert_array_equal(piped.weights[name],
                                      local.weights[name])
    assert dict(piped.meter.by_tag) == dict(local.meter.by_tag)


# ---------------------------------------------------------------------------
# Vectorized host helpers (satellites)
# ---------------------------------------------------------------------------

def test_int_to_bits_vectorized():
    for e, nbits in [(0, 1), (1, 1), (5, 3), (0xDEAD, 16),
                     ((1 << 200) - 3, 200)]:
        got = bigint.int_to_bits(e, nbits)
        want = np.array([(e >> (nbits - 1 - i)) & 1 for i in range(nbits)],
                        dtype=np.uint32)
        np.testing.assert_array_equal(got, want)
        assert got.dtype == np.uint32
    with pytest.raises(ValueError):
        bigint.int_to_bits(4, 2)


def test_cached_bits_identity_and_immutability():
    a = bigint.cached_bits(12345, 14)
    b = bigint.cached_bits(12345, 14)
    assert a is b
    with pytest.raises(ValueError):
        a[0] = 1
    np.testing.assert_array_equal(a, bigint.int_to_bits(12345, 14))


def test_decode_ints_vectorized():
    key = paillier.keygen(128, seed=11)
    vals = [0, 1, (1 << 60) + 12345, (1 << 100) - 1]
    limbs = bigint.ints_to_limbs(vals, key.pub.Ln)
    assert paillier.decode_ints(limbs) == vals
    assert paillier.decode_ints(limbs[0]) == [0]
    # nested batch keeps its structure
    nested = limbs.reshape(2, 2, -1)
    assert paillier.decode_ints(nested) == [vals[:2], vals[2:]]
