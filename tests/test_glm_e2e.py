"""End-to-end Algorithm 1: federated GLM quality vs centralized oracle
(paper Table 1/2 + Figure 1 semantics)."""
import numpy as np
import pytest

from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def _make_parties(X, n_parties=2):
    parts = vertical.split_columns(X, n_parties)
    names = ["C"] + [f"B{i}" for i in range(1, n_parties)]
    return [PartyData(name=nm, X=p) for nm, p in zip(names, parts)]


def test_lr_two_party_matches_centralized():
    X, y = synthetic.credit_default(n=3000, seed=3)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=15, batch_size=512,
                    he_backend="mock", tol=0.0, seed=11)
    parties = _make_parties(Xtr)
    res = trainer.train_vfl(parties, ytr, cfg)
    w_cent, losses_cent = trainer.train_centralized(Xtr, ytr, cfg)

    # loss curves nearly identical (paper Fig 1: red ≈ blue)
    np.testing.assert_allclose(res.losses, losses_cent, atol=5e-3)
    # test AUC within noise of the centralized model
    test_parties = _make_parties(Xte)
    wx_fed = res.predict_wx(test_parties)
    auc_fed = metrics.auc(yte, wx_fed)
    auc_cent = metrics.auc(yte, Xte @ w_cent)
    assert abs(auc_fed - auc_cent) < 0.01
    # small-n slice of the Bayes-limited task (full 30k run lands ≈0.71,
    # benchmarks/table1_lr.py reproduces the paper number)
    assert auc_fed > 0.58
    assert res.meter.total_mb > 0


@pytest.mark.slow
def test_lr_real_paillier_small():
    """Full Algorithm 1 with genuine Paillier (small but secure-shaped)."""
    X, y = synthetic.credit_default(n=200, d=8, seed=5)
    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=3, batch_size=64,
                    he_backend="paillier", key_bits=256, tol=0.0, seed=1)
    parties = _make_parties(X)
    res = trainer.train_vfl(parties, y, cfg)
    cfg_mock = VFLConfig(**{**cfg.__dict__, "he_backend": "mock"})
    res_mock = trainer.train_vfl(parties, y, cfg_mock)
    # identical protocol → identical losses up to shared randomness
    np.testing.assert_allclose(res.losses, res_mock.losses, atol=1e-6)


def test_pr_two_party_matches_centralized():
    X, y = synthetic.dvisits(n=2000, seed=7)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y)
    cfg = VFLConfig(glm="poisson", lr=0.1, max_iter=15, batch_size=512,
                    he_backend="mock", tol=0.0, seed=2)
    parties = _make_parties(Xtr)
    res = trainer.train_vfl(parties, ytr, cfg)
    w_cent, losses_cent = trainer.train_centralized(Xtr, ytr, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=5e-3)
    pred_fed = np.exp(res.predict_wx(_make_parties(Xte)))
    pred_cent = np.exp(Xte @ w_cent)
    assert abs(metrics.mae(yte, pred_fed) - metrics.mae(yte, pred_cent)) < 0.01
    assert abs(metrics.rmse(yte, pred_fed) - metrics.rmse(yte, pred_cent)) < 0.02


def test_multiparty_four_parties():
    """§4.3: >2 parties; non-CP parties go through the broadcast path."""
    X, y = synthetic.credit_default(n=1200, seed=9)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=8, batch_size=256,
                    he_backend="mock", tol=0.0, seed=3)
    parties = _make_parties(X, n_parties=4)
    res = trainer.train_vfl(parties, y, cfg)
    w_cent, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=5e-3)
    # comm grows with parties: broadcast tags must be present
    assert "P3.enc_d_bcast" in res.meter.by_tag


def test_multiparty_random_cp_selection():
    X, y = synthetic.credit_default(n=800, seed=13)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=6, batch_size=256,
                    he_backend="mock", tol=0.0, seed=4, cp_selection="random")
    parties = _make_parties(X, n_parties=3)
    res = trainer.train_vfl(parties, y, cfg)
    w_cent, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=5e-3)


def test_early_stop_flag():
    X, y = synthetic.credit_default(n=600, seed=15)
    cfg = VFLConfig(glm="logistic", lr=0.0, max_iter=10, batch_size=128,
                    he_backend="mock", tol=1e-3, seed=5)
    res = trainer.train_vfl(_make_parties(X), y, cfg)
    assert res.n_iter == 2          # zero lr → Δloss = 0 → stop after iter 2


def test_linear_glm_bonus():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(1500, 10)) * 0.5
    w_true = rng.normal(size=10)
    y = X @ w_true + 0.05 * rng.normal(size=1500)
    cfg = VFLConfig(glm="linear", lr=0.3, max_iter=25, batch_size=512,
                    he_backend="mock", tol=0.0, seed=6)
    res = trainer.train_vfl(_make_parties(X), y, cfg)
    w_cent, _ = trainer.train_centralized(X, y, cfg)
    fed = np.concatenate([res.weights["C"], res.weights["B1"]])
    np.testing.assert_allclose(fed, w_cent, atol=5e-3)


def test_gamma_glm_bonus():
    """Paper §4.2: 'also suitable for … Gamma' — log-link Gamma GLM."""
    rng = np.random.default_rng(3)
    n, d = 1500, 10
    X = rng.normal(size=(n, d)) * 0.3
    w_true = rng.normal(size=d) * 0.4
    mu = np.exp(X @ w_true)
    y = rng.gamma(shape=2.0, scale=mu / 2.0)
    cfg = VFLConfig(glm="gamma", lr=0.15, max_iter=15, batch_size=512,
                    he_backend="mock", tol=0.0, seed=7)
    res = trainer.train_vfl(_make_parties(X), y, cfg)
    _, losses_cent = trainer.train_centralized(X, y, cfg)
    np.testing.assert_allclose(res.losses, losses_cent, atol=5e-3)
    fed = np.concatenate([res.weights["C"], res.weights["B1"]])
    assert np.corrcoef(fed, w_true)[0, 1] > 0.9
