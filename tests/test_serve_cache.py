"""Serving-cache correctness: encrypted-constant and windowed-digit
caches are keyed by (model version, key fingerprint) and INVALIDATE by
refusal — a stale cache must raise `StaleCacheError`, never silently
score against the wrong model or a dead key (the PR-6
`TableMismatchError` contract applied to serving)."""
import numpy as np
import pytest

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.core.trainer import PartyData, VFLConfig
from repro.crypto import fixed_point
from repro.data import synthetic, vertical
from repro.runtime import VFLScheduler
from repro.serve import PartyServingCache, StaleCacheError, key_fingerprint_of


def _trained_scheduler(he_backend="mock", key_bits=1024):
    X, y = synthetic.credit_default(n=80, d=6, seed=9)
    parts = vertical.split_columns(X, 2)
    parties = [PartyData(nm, p) for nm, p in zip(["C", "B1"], parts)]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=2, batch_size=64,
                    he_backend=he_backend, key_bits=key_bits, tol=0.0,
                    seed=3)
    sched = VFLScheduler(parties, y, cfg)
    sched.run()
    return sched, parts


def test_publish_builds_version_pinned_caches():
    sched, _ = _trained_scheduler()
    for p in sched.parties:
        p.publish_version(0)
        cache = p.serving_cache
        assert cache.version == 0
        assert cache.key_fp == key_fingerprint_of(p.backend, p.name)
        np.testing.assert_array_equal(cache.W, p.W)
        # the windowed-digit precompute is exactly what he_matvec consumes
        want = protocols.EncodedFeatures.make(
            np.asarray(p.W, np.float64)[None, :], p.cfg.fx, p.cfg.exp_width)
        np.testing.assert_array_equal(cache.w_feats.digits, want.digits)
        # the encrypted constant is [[w]] under the party's own key
        np.testing.assert_array_equal(
            np.asarray(cache.enc_w),
            np.asarray(p.backend.encrypt_share(
                p.name, fixed_point.encode(cache.W, p.cfg.f))))


def test_version_mismatch_refuses():
    sched, parts = _trained_scheduler()
    p = sched.parties[1]
    p.publish_version(0)
    rows = parts[1][:4]
    np.testing.assert_array_equal(
        p.predict_share(rows, version=0),
        glm_lib.matvec_rowwise(rows, p.serving_cache.W))
    with pytest.raises(StaleCacheError, match="holds model version 0"):
        p.predict_share(rows, version=1)      # never published
    with pytest.raises(StaleCacheError, match="republish"):
        p.serving_cache.ensure(7, p.serving_cache.key_fp, party=p.name)


def test_unpublished_party_refuses_versioned_scoring():
    sched, parts = _trained_scheduler()
    p = sched.parties[1]
    assert p.serving_cache is None
    with pytest.raises(StaleCacheError, match="no published model version"):
        p.predict_share(parts[1][:2], version=0)
    # the legacy unversioned path (training-time predict_wx) still works
    np.testing.assert_array_equal(
        p.predict_share(parts[1][:2]),
        glm_lib.matvec_rowwise(parts[1][:2], p.W))


def test_key_fingerprint_mismatch_refuses():
    sched, _ = _trained_scheduler()
    p = sched.parties[0]
    p.publish_version(0)
    cache = p.serving_cache
    with pytest.raises(StaleCacheError, match="dead key"):
        cache.ensure(0, "mock:2048", party=p.name)   # rotated key identity


def test_paillier_fingerprint_tracks_modulus():
    sched, _ = _trained_scheduler(he_backend="paillier", key_bits=256)
    a, b = sched.parties
    fa = key_fingerprint_of(a.backend, a.name)
    fb = key_fingerprint_of(b.backend, b.name)
    assert fa != fb                          # per-party keys, per-party fps
    a.publish_version(0)
    assert a.serving_cache.key_fp == fa
    with pytest.raises(StaleCacheError):
        a.serving_cache.ensure(0, fb, party=a.name)


def test_swap_pins_old_version_and_refuses_it_after():
    """Hot swap installs new weights as a NEW version; the old version's
    pinned snapshot is untouched while it lives, and once the party has
    moved on, requests stamped with the old version refuse — they can
    no longer be silently scored by the new model."""
    sched, parts = _trained_scheduler()
    p = sched.parties[1]
    p.publish_version(0)
    w0 = np.array(p.serving_cache.W)

    new_w = w0 + 0.25
    p.set_weights(new_w, version=1)
    np.testing.assert_array_equal(p.serving_cache.W, new_w)
    assert p.serving_cache.version == 1
    assert p.model_version == 1

    rows = parts[1][:3]
    np.testing.assert_array_equal(
        p.predict_share(rows, version=1),
        glm_lib.matvec_rowwise(rows, new_w))
    with pytest.raises(StaleCacheError, match="wants 0|holds model version"):
        p.predict_share(rows, version=0)     # stale stamp: refuse, not score
