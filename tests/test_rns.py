"""RNS pipeline parity: pallas kernels ≡ pallas-interpret ≡ bigint oracle.

Sweeps key sizes × window widths × batch shapes for every RNS op —
montmul, the constant-time ladder, fixed-base exponentiation and the
windowed HE matvec.  Interpret-mode rows always run (they are the CI
guarantee that the compiled IR computes the right thing — interpret
executes the same traced kernel body); compiled rows run only on a TPU
host and skip elsewhere.  `crypto.bigint` is the bit-exactness oracle
throughout, itself oracle-tested against python ints in
test_crypto_bigint.py.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.crypto import bigint, rns
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import Modulus
from repro.kernels import ops

RNG = np.random.default_rng(29)

ON_TPU = jax.default_backend() == "tpu"
compiled = pytest.mark.skipif(not ON_TPU,
                              reason="compiled pallas rows need a TPU")

# moduli spanning the auto-routing threshold (RNS_MIN_BITS = 512):
# below it, at it, and the paper's 1024-bit ciphertext size is covered
# by the slow-marked rows and benchmarks.
MODS = [
    (1 << 61) - 1,                                   # 61-bit prime
    (1 << 256) - 159,                                # 256-bit odd
    (1 << 512) - 569,                                # 512-bit odd (≥ thresh)
]
MODS_SLOW = [(1 << 1024) - 105]                      # paper-scale


def rand_residues(n_mod, size):
    nbytes = (n_mod.bit_length() + 7) // 8
    return [int.from_bytes(RNG.bytes(nbytes), "little") % n_mod
            for _ in range(size)]


def limbs(ints, L):
    return jnp.asarray(bigint.ints_to_limbs(ints, L))


# ---------------------------------------------------------------------------
# montmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", MODS)
@pytest.mark.parametrize("batch", [1, 5, 64])
def test_rns_montmul_vs_oracle(n, batch):
    mod = Modulus.make(n)
    a, b = rand_residues(n, batch), rand_residues(n, batch)
    A, B = limbs(a, mod.L), limbs(b, mod.L)
    want = np.asarray(bigint.mont_mul(A, B, mod))
    # jnp library pipeline
    ctx = rns.for_modulus(mod)
    np.testing.assert_array_equal(np.asarray(rns.mont_mul(ctx, A, B)), want)
    # kernel, interpret mode
    got = np.asarray(ops.rns_montmul(A, B, mod, tile_b=32, interpret=True))
    np.testing.assert_array_equal(got, want)


@compiled
@pytest.mark.parametrize("n", MODS)
def test_rns_montmul_compiled(n):
    mod = Modulus.make(n)
    a, b = rand_residues(n, 64), rand_residues(n, 64)
    A, B = limbs(a, mod.L), limbs(b, mod.L)
    want = np.asarray(bigint.mont_mul(A, B, mod))
    got = np.asarray(ops.rns_montmul(A, B, mod, interpret=False))
    np.testing.assert_array_equal(got, want)


def test_rns_montmul_batch_shapes():
    n = MODS[0]
    mod = Modulus.make(n)
    A = limbs(rand_residues(n, 12), mod.L).reshape(3, 4, mod.L)
    got = ops.rns_montmul(A, A, mod, tile_b=8, interpret=True)
    assert got.shape == (3, 4, mod.L)
    want = bigint.mont_mul(A, A, mod)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=(1 << 256) - 160),
       st.integers(min_value=0, max_value=(1 << 256) - 160))
def test_hypothesis_rns_montmul(a, b):
    mod = Modulus.make((1 << 256) - 159)
    ctx = rns.for_modulus(mod)
    A, B = limbs([a], mod.L), limbs([b], mod.L)
    want = np.asarray(bigint.mont_mul(A, B, mod))
    np.testing.assert_array_equal(np.asarray(rns.mont_mul(ctx, A, B)), want)


# ---------------------------------------------------------------------------
# constant-time ladder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", MODS)
@pytest.mark.parametrize("ebits", [1, 16, 61])
def test_rns_ladder_vs_oracle(n, ebits):
    mod = Modulus.make(n)
    batch = 4
    base = rand_residues(n, batch)
    e = int.from_bytes(RNG.bytes((ebits + 7) // 8), "little") % (1 << ebits)
    e |= 1 << (ebits - 1)
    bits = jnp.asarray(bigint.int_to_bits(e, ebits))
    B = limbs(base, mod.L)
    want = np.asarray(bigint.mont_exp_bits(B, bits, mod))
    ctx = rns.for_modulus(mod)
    np.testing.assert_array_equal(
        np.asarray(rns.mont_exp_bits(ctx, B, bits)), want)
    got = np.asarray(ops.rns_mont_exp_fused(B, bits, mod, tile_b=4,
                                            interpret=True))
    np.testing.assert_array_equal(got, want)


@compiled
def test_rns_ladder_compiled():
    n = MODS[1]
    mod = Modulus.make(n)
    B = limbs(rand_residues(n, 8), mod.L)
    bits = jnp.asarray(bigint.int_to_bits(0xC0FFEE, 24))
    want = np.asarray(bigint.mont_exp_bits(B, bits, mod))
    got = np.asarray(ops.rns_mont_exp_fused(B, bits, mod, interpret=False))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# fixed-base exponentiation (persistent-table form)
# ---------------------------------------------------------------------------

def _noise_table(mod, window, exp_bits=32):
    from repro.crypto import fixed_base
    n_fake = mod.value          # fingerprint input only; base is explicit
    return fixed_base.build_noise_table(n_fake, mod, window=window,
                                        rho_bits=exp_bits, x=0xDEADBEEF)


@pytest.mark.parametrize("n", MODS)
@pytest.mark.parametrize("window", [1, 2, 4])
def test_rns_fixed_base_vs_oracle(n, window):
    from repro.crypto import fixed_base
    mod = Modulus.make(n)
    table = _noise_table(mod, window)
    batch = 5
    exps = [int(RNG.integers(0, 1 << 31)) for _ in range(batch)]
    digits = fixed_base.exp_digits(exps, table.levels, window)
    ctx = rns.for_modulus(mod)
    R = 1 << (12 * mod.L)
    want = np.asarray(limbs(
        [(pow(table.base, e, n) * R) % n for e in exps], mod.L))
    got_jnp = np.asarray(rns.fixed_base_exp(
        ctx, jnp.asarray(table.table_rns), jnp.asarray(digits)))
    np.testing.assert_array_equal(got_jnp, want)
    got_k = np.asarray(ops.rns_fixed_base_fused(
        jnp.asarray(table.table_rns), jnp.asarray(digits), mod,
        window=window, tile_b=4, interpret=True))
    np.testing.assert_array_equal(got_k, want)


@compiled
def test_rns_fixed_base_compiled():
    from repro.crypto import fixed_base
    mod = Modulus.make(MODS[1])
    table = _noise_table(mod, 4)
    exps = [int(RNG.integers(0, 1 << 31)) for _ in range(8)]
    digits = fixed_base.exp_digits(exps, table.levels, 4)
    want = np.asarray(ops.rns_fixed_base_fused(
        jnp.asarray(table.table_rns), jnp.asarray(digits), mod,
        window=4, interpret=True))
    got = np.asarray(ops.rns_fixed_base_fused(
        jnp.asarray(table.table_rns), jnp.asarray(digits), mod,
        window=4, interpret=False))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# windowed HE matvec
# ---------------------------------------------------------------------------

# tier-1 runs one bit-serial and one windowed row (≈20 s of interpret
# time); the full key-size × window × shape cross-product is slow-marked
# — interpret-mode he_matvec costs ~5 s per digit level on CPU.
_MV_FAST = [(1, 2, (4, 3), MODS[0]), (4, 8, (3, 2), MODS[2])]
_MV_FULL = [(w, wd, sh, n) for (w, wd) in [(1, 8), (3, 9), (4, 22)]
            for sh in [(4, 3), (9, 2)] for n in MODS]


@pytest.mark.parametrize(
    "window,width,shape,n",
    _MV_FAST + [pytest.param(*p, marks=pytest.mark.slow)
                for p in _MV_FULL])
def test_rns_he_matvec_vs_oracle(window, width, shape, n):
    from repro.core.protocols import window_digits
    mod = Modulus.make(n)
    rows, cols = shape
    cts = limbs(rand_residues(n, rows), mod.L)
    exps = RNG.integers(0, 1 << width, size=shape).astype(np.uint32)
    digits = jnp.asarray(window_digits(exps, width, window))
    # oracle: per-column ladder over the bigint library
    want = []
    for j in range(cols):
        acc = bigint.mont_one(mod)[None, :]
        for i in range(rows):
            bits = jnp.asarray(bigint.int_to_bits(int(exps[i, j]), width))
            term = bigint.mont_exp_bits(cts[i:i + 1], bits, mod)
            acc = bigint.mont_mul(acc, term, mod)
        want.append(np.asarray(acc[0]))
    want = np.stack(want)
    ctx = rns.for_modulus(mod)
    got_jnp = np.asarray(rns.he_matvec(ctx, cts, digits, window))
    np.testing.assert_array_equal(got_jnp, want)
    got_k = np.asarray(ops.rns_he_matvec_fused(
        cts, digits, mod, window=window, tile_m=2, chunk_n=4,
        interpret=True))
    np.testing.assert_array_equal(got_k, want)


@compiled
def test_rns_he_matvec_compiled():
    from repro.core.protocols import window_digits
    mod = Modulus.make(MODS[1])
    cts = limbs(rand_residues(mod.value, 8), mod.L)
    exps = RNG.integers(0, 1 << 22, size=(8, 4)).astype(np.uint32)
    digits = jnp.asarray(window_digits(exps, 22, 4))
    want = np.asarray(ops.rns_he_matvec_fused(cts, digits, mod, window=4,
                                              interpret=True))
    got = np.asarray(ops.rns_he_matvec_fused(cts, digits, mod, window=4,
                                             interpret=False))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# engine routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("pipeline", ["auto", "cios", "rns"])
@pytest.mark.parametrize("n", [MODS[0], MODS[2]])
def test_engine_pipeline_parity(backend, pipeline, n):
    """Every (backend, pipeline) cell is bit-exact vs the library — the
    pipeline field is purely a performance knob."""
    mod = Modulus.make(n)
    A = limbs(rand_residues(n, 6), mod.L)
    B = limbs(rand_residues(n, 6), mod.L)
    want = np.asarray(bigint.mont_mul(A, B, mod))
    eng = engine_mod.CryptoEngine(backend=backend, pipeline=pipeline,
                                  tile_b=8)
    np.testing.assert_array_equal(np.asarray(eng.mont_mul(A, B, mod)), want)
    bits = jnp.asarray(bigint.int_to_bits(0xBEEF, 16))
    want_e = np.asarray(bigint.mont_exp_bits(A, bits, mod))
    np.testing.assert_array_equal(
        np.asarray(eng.mont_exp_bits(A, bits, mod)), want_e)


def test_engine_auto_threshold_routing():
    """auto routes by RNS_MIN_BITS, and interpret-mode small-modulus ops
    go to the library (never-slower-than-library guarantee)."""
    small = Modulus.make(MODS[0])
    large = Modulus.make(MODS[2])
    jnp_eng = engine_mod.CryptoEngine(backend="jnp")
    interp = engine_mod.CryptoEngine(backend="pallas-interpret")
    tpu = engine_mod.CryptoEngine(backend="pallas")
    assert jnp_eng._route(small) == "lib"
    assert jnp_eng._route(large) == "rns-jnp"
    assert interp._route(small) == "lib"      # kernel would only add
    assert interp._route(large) == "rns"      # interpreter overhead
    assert tpu._route(small) == "cios"
    assert tpu._route(large) == "rns"
    # explicit pipelines pin the arithmetic
    assert engine_mod.CryptoEngine(backend="pallas-interpret",
                                   pipeline="cios")._route(large) == "cios"
    assert engine_mod.CryptoEngine(backend="jnp",
                                   pipeline="rns")._route(small) == "rns-jnp"


def test_engine_pipeline_env_and_single_device(monkeypatch):
    monkeypatch.setenv(engine_mod.PIPELINE_ENV_VAR, "rns")
    eng = engine_mod.CryptoEngine(backend="jnp")
    assert eng._route(Modulus.make(MODS[0])) == "rns-jnp"
    monkeypatch.delenv(engine_mod.PIPELINE_ENV_VAR)
    with pytest.raises(ValueError):
        engine_mod.resolve_pipeline("turbo")
    # single_device carries the pipeline through
    eng2 = engine_mod.CryptoEngine(backend="jnp", pipeline="rns",
                                   mesh=None)
    assert eng2.single_device().pipeline == "rns"
