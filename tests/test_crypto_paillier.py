"""Paillier correctness: roundtrip, homomorphism, protocol encodings."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.crypto import bigint, fixed_point, paillier, ring

KEY = paillier.keygen(128, seed=7)        # small key: fast CPU tests
PUB = KEY.pub
RNG = np.random.default_rng(3)


def rand_msgs(k, bits=100):
    return [int.from_bytes(RNG.bytes(bits // 8), "little") for _ in range(k)]


def test_keygen_sane():
    assert PUB.n.bit_length() == 128
    assert (KEY.lam * pow(KEY.lam, -1, PUB.n)) % PUB.n == 1


def test_enc_dec_roundtrip():
    msgs = rand_msgs(6) + [0, 1, PUB.n - 1]
    m = paillier.encode_ints(PUB, msgs)
    c = paillier.encrypt(PUB, m, rng=RNG)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, c)))
    assert got == msgs


def test_homomorphic_add():
    a, b = rand_msgs(5), rand_msgs(5)
    ca = paillier.encrypt(PUB, paillier.encode_ints(PUB, a), rng=RNG)
    cb = paillier.encrypt(PUB, paillier.encode_ints(PUB, b), rng=RNG)
    cs = paillier.add_ct(PUB, ca, cb)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, cs)))
    assert got == [(x + y) % PUB.n for x, y in zip(a, b)]


def test_scalar_mul_const():
    a = rand_msgs(4)
    k = 123457
    ca = paillier.encrypt(PUB, paillier.encode_ints(PUB, a), rng=RNG)
    ck = paillier.smul_const(PUB, ca, k)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, ck)))
    assert got == [(x * k) % PUB.n for x in a]


def test_scalar_mul_traced_bits():
    a = rand_msgs(4)
    ks = [3, 9999, (1 << 22) - 1, 1]
    ca = paillier.encrypt(PUB, paillier.encode_ints(PUB, a), rng=RNG)
    bits = jnp.asarray(np.stack([bigint.int_to_bits(k, 22) for k in ks]))
    ck = paillier.smul_bits(PUB, ca, bits)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, ck)))
    assert got == [(x * k) % PUB.n for x, k in zip(a, ks)]


def test_hom_sum_tree():
    a = rand_msgs(9)
    ca = paillier.encrypt(PUB, paillier.encode_ints(PUB, a), rng=RNG)
    cs = paillier.hom_sum(PUB, ca, axis=0)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, cs[None])))
    assert got == [sum(a) % PUB.n]


def test_noise_precompute_matches_fresh():
    msgs = rand_msgs(3)
    m = paillier.encode_ints(PUB, msgs)
    r = paillier.raw_noise(PUB, 3, rng=np.random.default_rng(11))
    rn = paillier.noise_to_mont(PUB, r)
    c = paillier.encrypt_with_noise(PUB, m, rn)
    got = paillier.decode_ints(np.asarray(paillier.decrypt(KEY, c)))
    assert got == msgs


def test_ring64_residue_protocol_semantics():
    """The DESIGN §7 convention: decrypt(…) mod 2^64 == ring result, with
    multipliers lifted to non-negative residues mod 2^64."""
    key = paillier.keygen(256, seed=9)  # big enough for exact 128-bit values
    pub = key.pub
    vals = np.array([123456789, 2 ** 63 + 17], np.uint64)   # ring residues
    mult = -7  # signed multiplier, lifted
    m = np.stack([bigint.int_to_limbs(int(v), pub.Ln) for v in vals])
    c = paillier.encrypt(pub, m, rng=RNG)
    k = (mult) % (1 << 64)
    ck = paillier.smul_const(pub, c, k)
    dec = np.asarray(paillier.decrypt(key, ck))
    got = [x % (1 << 64) for x in paillier.decode_ints(dec)]
    want = [int((v * np.uint64(k)) & np.uint64(0xFFFFFFFFFFFFFFFF)) for v in vals]
    assert got == want


def test_r64_limb_bridge():
    vals = np.array([0, 1, 2 ** 40 + 3, 2 ** 64 - 1, 0xDEADBEEFCAFEBABE],
                    np.uint64)
    a = ring.from_numpy_u64(vals)
    limbs = fixed_point.r64_to_limbs(a, 10)
    ints = [bigint.limbs_to_int(x) for x in np.asarray(limbs)]
    assert ints == [int(v) for v in vals]
    back = fixed_point.limbs_to_r64(limbs)
    assert (ring.to_numpy_u64(back) == vals).all()


def test_u64_bits_msb():
    vals = np.array([0xDEADBEEFCAFEBABE, 1, 2 ** 63], np.uint64)
    a = ring.from_numpy_u64(vals)
    bits = np.asarray(fixed_point.u64_bits_msb(a))
    for i, v in enumerate(vals):
        want = bigint.int_to_bits(int(v), 64)
        assert (bits[i] == want).all()


@pytest.mark.slow
def test_crt_decrypt_equals_plain():
    """CRT decryption (≈4× cheaper) is bit-identical to plain decryption."""
    key = paillier.keygen(192, seed=13)
    pub = key.pub
    rng = np.random.default_rng(5)
    msgs = [int.from_bytes(rng.bytes(20), "little") % pub.n
            for _ in range(8)] + [0, 1, pub.n - 1]
    c = paillier.encrypt(pub, paillier.encode_ints(pub, msgs), rng=rng)
    plain = paillier.decode_ints(np.asarray(paillier.decrypt(key, c)))
    crt = paillier.decode_ints(np.asarray(paillier.decrypt_crt(key, c)))
    assert plain == crt == msgs
