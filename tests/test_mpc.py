"""MPC layer: sharing, Beaver multiplication, truncation statistics."""
import jax
import numpy as np
import pytest

from repro.crypto import fixed_point, paillier, ring
from repro.mpc import beaver, sharing, truncation

RNG = np.random.default_rng(17)
M = (1 << 64) - 1


def rand_u64(shape):
    return RNG.integers(0, 1 << 64, size=shape, dtype=np.uint64)


def test_share_reconstruct():
    x = ring.from_numpy_u64(rand_u64((8, 3)))
    s0, s1 = sharing.share(x, jax.random.key(0))
    got = ring.to_numpy_u64(sharing.reconstruct(s0, s1))
    assert (got == ring.to_numpy_u64(x)).all()
    # shares individually != x (overwhelming probability)
    assert not (ring.to_numpy_u64(s0) == ring.to_numpy_u64(x)).all()


def test_share_zero():
    s0, s1 = sharing.share_zero((16,), jax.random.key(1))
    got = ring.to_numpy_u64(sharing.reconstruct(s0, s1))
    assert (got == 0).all()


def test_shares_look_uniform():
    """Statistical sanity for Theorem 2: share bytes are ~uniform."""
    x = ring.from_numpy_u64(np.zeros(4096, np.uint64))  # worst case: all-zero
    s0, _ = sharing.share(x, jax.random.key(2))
    bits = np.unpackbits(np.asarray(s0.lo).view(np.uint8))
    # mean of 131072 fair bits: std ≈ 0.0014 — allow 5 sigma
    assert abs(bits.mean() - 0.5) < 0.007


def test_beaver_mul_dealer():
    dealer = beaver.DealerTripleSource(seed=3)
    x = rand_u64((6, 4))
    y = rand_u64((6, 4))
    xs = sharing.share(ring.from_numpy_u64(x), jax.random.key(4))
    ys = sharing.share(ring.from_numpy_u64(y), jax.random.key(5))
    t0, t1 = dealer.elementwise((6, 4))
    z0, z1 = beaver.mul(xs, ys, t0, t1)
    got = ring.to_numpy_u64(sharing.reconstruct(z0, z1))
    assert (got == x * y).all()


def test_beaver_dot():
    dealer = beaver.DealerTripleSource(seed=6)
    x = rand_u64((32,))
    y = rand_u64((32,))
    xs = sharing.share(ring.from_numpy_u64(x), jax.random.key(7))
    ys = sharing.share(ring.from_numpy_u64(y), jax.random.key(8))
    t0, t1 = dealer.elementwise((32,))
    z0, z1 = beaver.dot(xs, ys, t0, t1)
    got = ring.to_numpy_u64(sharing.reconstruct(z0, z1))
    assert int(got) == int((x * y).sum())


@pytest.mark.slow
def test_paillier_triples():
    key = paillier.keygen(256, seed=21)
    t0, t1 = beaver.paillier_triple((5,), key, np.random.default_rng(2),
                                    jax.random.key(9))
    a = ring.to_numpy_u64(sharing.reconstruct(t0.a, t1.a))
    b = ring.to_numpy_u64(sharing.reconstruct(t0.b, t1.b))
    c = ring.to_numpy_u64(sharing.reconstruct(t0.c, t1.c))
    assert (c == a * b).all()


def test_truncation_accuracy():
    f = 20
    x = RNG.normal(size=(4096,)) * 50
    enc = fixed_point.encode(x, 2 * f)          # value with 2f frac bits
    s0, s1 = sharing.share(enc, jax.random.key(10))
    t0, t1 = truncation.trunc_pair(s0, s1, f)
    got = fixed_point.decode(sharing.reconstruct(t0, t1), f)
    np.testing.assert_allclose(got, x, atol=2 ** -f * 4 + 1e-9)


def test_fixed_point_product_pipeline():
    """share -> beaver mul -> truncate == float product."""
    f = 18
    dealer = beaver.DealerTripleSource(seed=11)
    x = RNG.normal(size=(512,)) * 5
    y = RNG.normal(size=(512,)) * 5
    xs = sharing.share(fixed_point.encode(x, f), jax.random.key(12))
    ys = sharing.share(fixed_point.encode(y, f), jax.random.key(13))
    t0, t1 = dealer.elementwise((512,))
    z = beaver.mul(xs, ys, t0, t1)
    z = truncation.trunc_pair(z[0], z[1], f)
    got = fixed_point.decode(sharing.reconstruct(*z), f)
    np.testing.assert_allclose(got, x * y, atol=2 ** -f * 8 + 1e-6)
