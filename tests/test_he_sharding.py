"""Mesh-sharded HE engine: `ShardedCryptoEngine` must be bit-exact
against the single-device engine on every hot-path op, on a real
multi-device CPU mesh (forced host devices — subprocess, so the device
count can't leak into other tests' jax state)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.crypto import engine as engine_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_sharded_engine_requires_mesh():
    from repro.distributed.he_sharding import ShardedCryptoEngine
    with pytest.raises(ValueError):
        ShardedCryptoEngine(backend="jnp")


def test_unsharded_engine_mesh_knob_inert():
    """mesh=None (and shard_batch=False) keep the single-device routing;
    `sharded` only flips on a real multi-device axis."""
    eng = engine_mod.CryptoEngine(backend="jnp")
    assert not eng.sharded
    assert eng.single_device() is eng

    class OneDevMesh:
        shape = {"data": 1}

    assert not engine_mod.CryptoEngine(backend="jnp",
                                       mesh=OneDevMesh()).sharded

    class TwoDevMesh:
        shape = {"data": 2}

    assert engine_mod.CryptoEngine(backend="jnp", mesh=TwoDevMesh()).sharded
    assert not engine_mod.CryptoEngine(backend="jnp", mesh=TwoDevMesh(),
                                       shard_batch=False).sharded

    class WrongAxisMesh:
        shape = {"batch": 2}

    with pytest.raises(ValueError, match="no axis"):
        engine_mod.CryptoEngine(backend="jnp", mesh=WrongAxisMesh()).sharded
    with pytest.raises(ValueError, match="no axis"):
        from repro.distributed.he_sharding import ShardedCryptoEngine
        ShardedCryptoEngine(backend="jnp", mesh=WrongAxisMesh())


def test_sharded_engine_bit_exact_multidevice():
    """All sharded ops ≡ single-device engine on a 4-device CPU mesh:
    mont_mul, the constant-time ladder (incl. the shared-exponent
    decrypt pattern), the windowed HE matvec via `protocols.he_matvec`
    for both jnp and pallas-interpret backends (odd row counts exercise
    the pad path; `modmul_reduce` ⊕-combines the partials), and a full
    Paillier encrypt → matvec → CRT-decrypt roundtrip."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.crypto import bigint, paillier
from repro.crypto.bigint import Modulus
from repro.crypto import engine as engine_mod
from repro.distributed.he_sharding import (ShardedCryptoEngine,
                                           make_sharded_engine)
from repro.core import protocols

mesh = jax.make_mesh((4,), ("data",))
n = (1 << 61) - 1
mod = Modulus.make(n)
rng = np.random.default_rng(0)
vals = [int(v) % n for v in rng.integers(1, 1 << 60, size=7)]
A = jnp.asarray(bigint.ints_to_limbs(vals, mod.L))
eng1 = engine_mod.CryptoEngine(backend="jnp")
engS = ShardedCryptoEngine(backend="jnp", mesh=mesh)
np.testing.assert_array_equal(np.asarray(engS.mont_mul(A, A, mod)),
                              np.asarray(eng1.mont_mul(A, A, mod)))
Am = bigint.to_mont(A, mod)
bits = jnp.asarray(np.stack(
    [bigint.int_to_bits(int(e), 16)
     for e in rng.integers(0, 1 << 16, size=7)]))
np.testing.assert_array_equal(
    np.asarray(engS.mont_exp_bits(Am, bits, mod)),
    np.asarray(eng1.mont_exp_bits(Am, bits, mod)))
shared = jnp.asarray(bigint.int_to_bits(0xBEEF, 16))
np.testing.assert_array_equal(
    np.asarray(engS.mont_exp_bits(Am, shared, mod)),
    np.asarray(eng1.mont_exp_bits(Am, shared, mod)))

key = paillier.keygen(128, seed=1)
pub = key.pub
msgs = [int(v) for v in rng.integers(0, 1 << 16, size=6)]
cts = paillier.encrypt(pub, paillier.encode_ints(pub, msgs), rng=rng)
exps = jnp.asarray(rng.integers(0, 1 << 22, size=(6, 3), dtype=np.uint32))
want = protocols.he_matvec(pub, cts, exps, 22)
got_jnp = protocols.he_matvec(pub, cts, exps, 22, engine=engS)
np.testing.assert_array_equal(np.asarray(got_jnp), np.asarray(want))
engK = make_sharded_engine(mesh, "pallas-interpret", chunk_n=2, tile_m=2)
got_pal = protocols.he_matvec(pub, cts, exps, 22, engine=engK)
np.testing.assert_array_equal(np.asarray(got_pal), np.asarray(want))
w1 = protocols.he_matvec(pub, cts, exps[:, :2] & 0x3FF, 10, window=1)
g1 = protocols.he_matvec(pub, cts, exps[:, :2] & 0x3FF, 10, window=1,
                         engine=engS)
np.testing.assert_array_equal(np.asarray(g1), np.asarray(w1))

m = paillier.encode_ints(pub, msgs)
c_s = paillier.encrypt(pub, m, rng=np.random.default_rng(7), engine=engS)
c_1 = paillier.encrypt(pub, m, rng=np.random.default_rng(7))
np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_1))
np.testing.assert_array_equal(
    np.asarray(paillier.decrypt_crt(key, c_1, engine=engS)),
    np.asarray(paillier.decrypt_crt(key, c_1)))
assert paillier.decode_ints(
    np.asarray(paillier.decrypt_crt(key, got_jnp, engine=engS))) == \
    paillier.decode_ints(np.asarray(paillier.decrypt_crt(key, want)))
print("HE_SHARDING_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=REPO)
    assert "HE_SHARDING_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])


@pytest.mark.slow
def test_sharded_engine_end_to_end_training():
    """Algorithm 1 end-to-end with a mesh-sharded Paillier backend (2
    fake devices): bit-identical losses and weights vs the single-device
    engine."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from repro.core import trainer, protocols
from repro.crypto import paillier
from repro.data import synthetic, vertical
from repro.distributed.he_sharding import ShardedCryptoEngine

X, y = synthetic.credit_default(n=40, d=4, seed=3)
parts = vertical.split_columns(X, 2)
parties = [trainer.PartyData(name=nm, X=p)
           for nm, p in zip(["C", "B1"], parts)]
cfg = trainer.VFLConfig(glm="logistic", lr=0.1, max_iter=1, batch_size=16,
                        he_backend="paillier", key_bits=192, tol=0.0,
                        seed=2)

def backend_with(engine):
    rng = np.random.default_rng(cfg.seed + 90001)
    keys = {p: paillier.keygen(cfg.key_bits,
                               seed=int(rng.integers(2**31)))
            for p in ["C", "B1"]}
    return protocols.PaillierBackend(keys, rng, engine=engine), rng

mesh = jax.make_mesh((2,), ("data",))
b1, _ = backend_with(None)
ref = trainer.train_vfl(parties, y, cfg, backend=b1)
b2, _ = backend_with(ShardedCryptoEngine(backend="jnp", mesh=mesh))
res = trainer.train_vfl(parties, y, cfg, backend=b2)
assert res.losses == ref.losses, (res.losses, ref.losses)
for name in ref.weights:
    np.testing.assert_array_equal(res.weights[name], ref.weights[name])
print("HE_SHARDING_E2E_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=REPO)
    assert "HE_SHARDING_E2E_OK" in r.stdout, (r.stdout[-1500:],
                                              r.stderr[-3000:])
