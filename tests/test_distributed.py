"""Distribution layer: sharding rules, gradient compression, secure
collectives (single-device semantics + subprocess multi-device)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed import compression

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_compression_error_feedback_converges():
    """EF-SGD on a quadratic ≈ exact SGD (<1% param error)."""
    rng = np.random.default_rng(0)
    A = rng.normal(size=(16, 16))
    A = A @ A.T / 16 + np.eye(16)
    b = rng.normal(size=16)
    x_exact = np.zeros(16)
    x_comp = np.zeros(16)
    err = {"g": jnp.zeros(16)}
    for _ in range(300):
        g_e = A @ x_exact - b
        x_exact -= 0.05 * g_e
        g_c = A @ x_comp - b
        q, s, new_e = compression.compress({"g": jnp.asarray(g_c)}, err)
        err = new_e
        g_deq = np.asarray(compression.decompress(q, s)["g"])
        x_comp -= 0.05 * g_deq
    sol = np.linalg.solve(A, b)
    assert np.linalg.norm(x_comp - sol) / np.linalg.norm(sol) < 0.01
    # 4x wire reduction
    q, s, _ = compression.compress({"g": jnp.zeros(1024)},
                                   {"g": jnp.zeros(1024)})
    assert compression.wire_bytes(q) == 1024          # int8


def test_param_specs_consistency_all_archs():
    """Every arch's full-config param tree gets guarded, divisible specs
    on the production mesh shape (checked without building 256 devices —
    specs are pure functions of shapes)."""
    from repro.distributed.sharding import param_spec_for

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    for arch in registry.list_archs():
        cfg = registry.get_config(arch)
        from repro.models import registry as models
        api = models.build(cfg)
        shapes = jax.eval_shape(api.init_params, jax.random.key(0))

        def check(path, leaf):
            name = ""
            for e in reversed(path):
                if isinstance(e, jax.tree_util.DictKey):
                    name = str(e.key)
                    break
            spec = param_spec_for(name, tuple(leaf.shape), mesh)
            assert len(spec) <= len(leaf.shape)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    size = mesh.shape[ax] if isinstance(ax, str) else \
                        int(np.prod([mesh.shape[a] for a in ax]))
                    assert dim % size == 0, (arch, name, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(check, shapes)


@pytest.mark.slow
def test_modmul_reduce_multidevice():
    """The homomorphic tree collective on 8 fake devices (subprocess so
    the forced device count can't leak into other tests)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.crypto import bigint
from repro.crypto.bigint import Modulus
from repro.distributed.secure_ops import make_modmul_reduce_shardmap

n = (1 << 61) - 1
mod = Modulus.make(n)
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(1)
vals = [int(rng.integers(1, 1 << 60)) for _ in range(8)]
R = 1 << (12 * mod.L)
mont = [(v * R) % n for v in vals]
x = jnp.asarray(np.stack([bigint.int_to_limbs(m, mod.L)[None]
                          for m in mont]))   # (8, 1, L)
fn = make_modmul_reduce_shardmap(mesh, mod, "data")
out = jax.jit(fn)(x)
got_mont = bigint.limbs_to_int(np.asarray(out)[0, 0])
Rinv = pow(R, -1, n)
got = (got_mont * Rinv) % n
want = 1
for v in vals:
    want = (want * v) % n
assert got == want, (got, want)
print("MODMUL_REDUCE_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=REPO)
    assert "MODMUL_REDUCE_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    """The dry-run entry point succeeds on reduced configs for a sample of
    archs on both debug meshes (8 fake devices)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "olmoe-1b-7b", "--out", "/tmp/dryrun_test_out"],
        env=ENV, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "FAIL" not in r.stdout


@pytest.mark.slow
def test_elastic_reshard_subprocess():
    """Grow the data axis 2→4 (simulated elastic resize): the resharded
    model must produce identical outputs, and the shard plan must halve
    per-device bytes."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.distributed import elastic
from repro.models import registry as models

cfg = registry.get_smoke_config("qwen3-4b")
api = models.build(cfg)
params_host = jax.tree.map(np.asarray, api.init_params(jax.random.key(0)))
toks = np.zeros((4, 8), np.int32)
outs = {}
plans = {}
for tag, shape in [("small", (2, 4)), ("big", (4, 2))]:
    mesh = jax.make_mesh(shape, ("data", "model"))
    params = elastic.replace_onto_mesh(params_host, mesh)
    logits, _ = jax.jit(lambda p, t: api.prefill(p, t, max_len=16))(
        params, jnp.asarray(toks))
    outs[tag] = np.asarray(logits, np.float32)
    plans[tag] = elastic.shard_plan(
        jax.eval_shape(lambda: params_host), mesh)
# bf16 psum order differs across shardings — allow bf16-scale noise
np.testing.assert_allclose(outs["small"], outs["big"], atol=8e-2, rtol=3e-2)
# the plan re-derives shard SHAPES for the new mesh (2x4 vs 4x2)
k = [k for k in plans["small"] if k.endswith("/wq")][0]
assert plans["big"][k]["shard_shape"] != plans["small"][k]["shard_shape"]
assert plans["big"][k]["global_shape"] == plans["small"][k]["global_shape"]
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=REPO)
    assert "ELASTIC_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-2500:])


def test_gradient_accumulation_matches_full_batch():
    """microbatch=k scan-accumulated grads == full-batch grads (mean-loss
    linearity over equal chunks)."""
    from repro.configs import registry
    from repro.configs.base import TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import registry as models

    cfg = registry.get_smoke_config("gpt-100m")
    api = models.build(cfg)
    params = api.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    opt_f, step_f = make_train_step(api, TrainConfig(microbatch=None))
    opt_m, step_m = make_train_step(api, TrainConfig(microbatch=2))
    lf, gf, pf, _ = step_f(params, opt_f.init(params), batch)
    lm, gm, pm, _ = step_m(params, opt_m.init(params), batch)
    np.testing.assert_allclose(float(lf), float(lm), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pm)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-2, rtol=2e-2)


@pytest.mark.slow
def test_secure_dryrun_subprocess():
    """The EFMVFL multi-pod secure step (pod = party) lowers + compiles
    end-to-end at reduced size (guards the §Dry-run deliverable)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.secure_dryrun",
         "--samples", "512", "--features", "32", "--key-bits", "128",
         "--window", "4", "--shard-mode", "sample2d",
         "--out", "/tmp/secure_dryrun_test.json"],
        env=ENV, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2500:])
    import json
    with open("/tmp/secure_dryrun_test.json") as f:
        d = json.load(f)
    assert d["ok"] and d["montmuls_per_dev"] > 0
    # the homomorphic ⊕-ladder must appear as collective-permutes
    assert d["collectives"]["op_counts"].get("collective-permute", 0) >= 4
