"""The refactor's invariant: the actor runtime (`repro.runtime`) must
reproduce the pre-refactor monolithic `train_vfl` simulation exactly —
losses, final weights, and per-tag CommMeter byte totals — for a fixed
seed.  The oracle below is a frozen copy of the seed trainer's loop
(hand-placed meter calls and all), kept here as a test fixture so the
live code path can stay message-routed."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.core import glm as glm_lib
from repro.core import metrics, protocols, trainer
from repro.core.comm import CommMeter
from repro.core.trainer import PartyData, VFLConfig
from repro.crypto import fixed_point, ring
from repro.crypto.ring import R64
from repro.data import synthetic, vertical
from repro.mpc import beaver, sharing, truncation
from repro.runtime import LocalTransport, PipelinedTransport, VFLScheduler


# ---------------------------------------------------------------------------
# Frozen seed trainer (pre-refactor simulation, verbatim message flow)
# ---------------------------------------------------------------------------

class _MeteredDealer:
    def __init__(self, dealer, meter, a, b):
        self._dealer = dealer
        self._meter = meter
        self._a, self._b = a, b

    def elementwise(self, shape):
        n = int(np.prod(shape))
        self._meter.ring(self._a, self._b, "beaver_open", 2 * n)
        self._meter.ring(self._b, self._a, "beaver_open", 2 * n)
        return self._dealer.elementwise(shape)


def _share_to_cps(val, owner, cps, meter, key, tag):
    s0, s1 = sharing.share(val, key)
    n = int(np.prod(val.lo.shape))
    if owner == cps[0]:
        meter.ring(owner, cps[1], tag, n)
    elif owner == cps[1]:
        meter.ring(owner, cps[0], tag, n)
    else:
        meter.ring(owner, cps[0], tag, n)
        meter.ring(owner, cps[1], tag, n)
    return s0, s1


def _seed_train_vfl(parties, y, cfg, backend=None):
    """Frozen copy of the seed `train_vfl` (the pre-runtime monolith)."""
    assert parties[0].name == "C"
    model = glm_lib.GLMS[cfg.glm]
    names = [p.name for p in parties]
    rng = np.random.default_rng(cfg.seed + 90001)
    batch_rng = np.random.default_rng(cfg.seed)
    jkey = jax.random.key(cfg.seed)
    meter = CommMeter()
    if backend is None:
        backend = trainer.make_backend(cfg, names, rng)
    dealer = beaver.DealerTripleSource(seed=cfg.seed + 1)

    n_total = parties[0].X.shape[0]
    W = {p.name: np.zeros(p.X.shape[1]) for p in parties}
    feats = {p.name: protocols.EncodedFeatures.make(p.X, cfg.fx,
                                                    cfg.exp_width)
             for p in parties}
    mask_bound = 64 + cfg.exp_width + int(np.ceil(np.log2(cfg.batch_size))) + 1

    losses = []
    flag = False
    order = batch_rng.permutation(n_total)
    cursor = 0
    it = 0
    while it < cfg.max_iter and not flag:
        if cursor + cfg.batch_size > n_total:
            order = batch_rng.permutation(n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        nb = len(idx)
        if cfg.cp_selection == "random":
            cp_idx = rng.choice(len(names), size=2, replace=False)
            cps = (names[cp_idx[0]], names[cp_idx[1]])
        else:
            cps = (names[0], names[1])
        jkey, *subkeys = jax.random.split(jkey, len(names) * 2 + 3)

        z_shares = [None, None]
        ez_shares = None
        for i, p in enumerate(parties):
            zp = p.X[idx] @ W[p.name]
            s0, s1 = _share_to_cps(fixed_point.encode(zp, cfg.f), p.name,
                                   cps, meter, subkeys[i], "P1.z_share")
            z_shares[0] = s0 if z_shares[0] is None else ring.add(z_shares[0], s0)
            z_shares[1] = s1 if z_shares[1] is None else ring.add(z_shares[1], s1)
        y_shares = _share_to_cps(fixed_point.encode(y[idx], cfg.f), "C",
                                 cps, meter, subkeys[len(names)], "P1.y_share")
        mdealer = _MeteredDealer(dealer, meter, cps[0], cps[1])
        if model.needs_exp:
            for i, p in enumerate(parties):
                ezp = np.exp(np.clip(model.exp_sign * (p.X[idx] @ W[p.name]),
                                     -30, 8))
                es = _share_to_cps(fixed_point.encode(ezp, cfg.f), p.name,
                                   cps, meter,
                                   subkeys[len(names) + 1 + i], "P1.ez_share")
                if ez_shares is None:
                    ez_shares = es
                else:
                    prod = beaver.mul(ez_shares, es, *mdealer.elementwise((nb,)))
                    ez_shares = truncation.trunc_pair(prod[0], prod[1], cfg.f)

        ctx = glm_lib.ShareCtx(z=tuple(z_shares), y=y_shares, ez=ez_shares,
                               f=cfg.f, dealer=mdealer)
        d0, d1 = model.gradient_operator(ctx)

        ct0 = backend.encrypt_share(cps[0], d0)
        ct1 = backend.encrypt_share(cps[1], d1)
        meter.cipher(cps[1], cps[0], "P3.enc_d", nb, backend.key_bits(cps[1]))
        meter.cipher(cps[0], cps[1], "P3.enc_d", nb, backend.key_bits(cps[0]))
        grads = {}
        for p0, p1, dS, dO, ctO in ((cps[0], cps[1], d0, d1, ct1),
                                    (cps[1], cps[0], d1, d0, ct0)):
            m = feats[p0].x_int.shape[1]
            grads[p0] = protocols.secure_gradient_cp(
                backend, p0=p0, p1=p1, feats=feats[p0].slice(idx),
                d_self=dS, d_other_ct=ctO, d_other_share=dO,
                mask_bound_bits=mask_bound, rng=rng)
            meter.cipher(p0, p1, "P3.masked_grad", m, backend.key_bits(p1))
            meter.ring(p1, p0, "P3.unmasked_share", m)
        for p in parties:
            if p.name in cps:
                continue
            m = p.X.shape[1]
            meter.cipher(cps[0], p.name, "P3.enc_d_bcast", nb,
                         backend.key_bits(cps[0]))
            meter.cipher(cps[1], p.name, "P3.enc_d_bcast", nb,
                         backend.key_bits(cps[1]))
            grads[p.name] = protocols.secure_gradient_noncp(
                backend, party=p.name, cps=cps,
                feats=feats[p.name].slice(idx),
                d_cts={cps[0]: ct0, cps[1]: ct1},
                d_shares={cps[0]: d0, cps[1]: d1},
                mask_bound_bits=mask_bound, rng=rng)
            for cp in cps:
                meter.cipher(p.name, cp, "P3.masked_grad", m,
                             backend.key_bits(cp))
                meter.ring(cp, p.name, "P3.unmasked_share", m)

        for p in parties:
            g = fixed_point.decode(grads[p.name], cfg.fx + cfg.f) / nb
            W[p.name] = W[p.name] - cfg.lr * g

        l0, l1 = model.loss_shares(ctx)
        meter.ring(cps[1], cps[0], "P4.loss_share", 1)
        if cps[0] != "C":
            meter.ring(cps[0], "C", "P4.loss_share", 1)
        revealed = float(fixed_point.decode(sharing.reconstruct(l0, l1),
                                            cfg.f))
        losses.append(model.finalize_loss(revealed, y[idx], nb))

        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            flag = True
        for p in names[1:]:
            meter.add("C", p, "flag", 1)
        it += 1

    return trainer.TrainResult(weights=W, losses=losses, meter=meter,
                               runtime_s=0.0, n_iter=it)


# ---------------------------------------------------------------------------
# Parity assertions
# ---------------------------------------------------------------------------

def _make_parties(X, k):
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    return [PartyData(name=nm, X=p) for nm, p in zip(names, parts)]


def _assert_exact(res, ref):
    assert res.losses == ref.losses
    assert set(res.weights) == set(ref.weights)
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert res.meter.total_bytes == ref.meter.total_bytes
    assert res.n_iter == ref.n_iter


@pytest.mark.parametrize("glm", ["logistic", "poisson"])
@pytest.mark.parametrize("cp_selection", ["fixed", "random"])
@pytest.mark.parametrize("k", [2, 4])
def test_runtime_matches_seed_trainer(glm, cp_selection, k):
    if glm == "poisson":
        X, y = synthetic.dvisits(n=400, seed=7)
    else:
        X, y = synthetic.credit_default(n=400, d=12, seed=3)
    cfg = VFLConfig(glm=glm, lr=0.1, max_iter=4, batch_size=128,
                    he_backend="mock", tol=0.0, seed=11,
                    cp_selection=cp_selection)
    parties = _make_parties(X, k)
    ref = _seed_train_vfl(parties, y, cfg)
    res = trainer.train_vfl(parties, y, cfg)
    _assert_exact(res, ref)
    assert res.rounds > 0


def test_runtime_matches_seed_trainer_paillier():
    """Both HE backends: real Paillier (small but secure-shaped keys)."""
    X, y = synthetic.credit_default(n=150, d=6, seed=5)
    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=2, batch_size=64,
                    he_backend="paillier", key_bits=256, tol=0.0, seed=1,
                    cp_selection="fixed")
    parties = _make_parties(X, 3)
    ref = _seed_train_vfl(parties, y, cfg)
    res = trainer.train_vfl(parties, y, cfg)
    _assert_exact(res, ref)


def test_early_stop_flag_parity():
    X, y = synthetic.credit_default(n=300, seed=15)
    cfg = VFLConfig(glm="logistic", lr=0.0, max_iter=10, batch_size=128,
                    he_backend="mock", tol=1e-3, seed=5)
    parties = _make_parties(X, 2)
    ref = _seed_train_vfl(parties, y, cfg)
    res = trainer.train_vfl(parties, y, cfg)
    _assert_exact(res, ref)
    assert res.n_iter == 2


def test_pipelined_transport_equivalent_and_fewer_rounds():
    """PipelinedTransport overlaps the data-independent Protocol-3 legs:
    identical model + identical per-tag bytes, strictly fewer rounds."""
    X, y = synthetic.credit_default(n=400, d=12, seed=9)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=3, batch_size=128,
                    he_backend="mock", tol=0.0, seed=4)
    parties = _make_parties(X, 4)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    piped = trainer.train_vfl(parties, y, cfg,
                              transport=PipelinedTransport())
    assert piped.losses == local.losses
    for name in local.weights:
        np.testing.assert_array_equal(piped.weights[name],
                                      local.weights[name])
    assert dict(piped.meter.by_tag) == dict(local.meter.by_tag)
    assert piped.rounds < local.rounds


def test_concurrent_legs_parity_k8():
    """Tentpole invariant at k=8: the concurrent-leg schedule (every
    Protocol-1 share computation and Protocol-3 masked-matvec/decrypt
    leg an independent pool future, join barrier before Protocol 4) is
    bit-identical to the sequential LocalTransport run — losses, final
    weights, per-tag byte totals — and to the barrier-sweep pipelined
    schedule it supersedes."""
    X, y = synthetic.credit_default(n=600, d=24, seed=21)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=3, batch_size=128,
                    he_backend="mock", tol=0.0, seed=13)
    parties = _make_parties(X, 8)
    seq = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    conc = trainer.train_vfl(
        parties, y, cfg, transport=PipelinedTransport())
    sweep = trainer.train_vfl(
        parties, y, cfg,
        transport=PipelinedTransport(concurrent_legs=False))
    for res in (conc, sweep):
        assert res.losses == seq.losses
        for name in seq.weights:
            np.testing.assert_array_equal(res.weights[name],
                                          seq.weights[name])
        assert dict(res.meter.by_tag) == dict(seq.meter.by_tag)
        assert res.rounds < seq.rounds
    # the async drain must not change the round (latency-step) count of
    # the merged Protocol-3 phase
    assert conc.rounds == sweep.rounds


@pytest.mark.slow
def test_concurrent_legs_parity_k8_poisson_paillier():
    """Same invariant under the order-sensitive ez chaining (Poisson)
    and a real Paillier backend with the noise pool active."""
    X, y = synthetic.dvisits(n=120, seed=19)
    cfg = VFLConfig(glm="poisson", lr=0.05, max_iter=2, batch_size=32,
                    he_backend="paillier", key_bits=192, tol=0.0, seed=17)
    parties = _make_parties(X, 8)
    seq = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    conc = trainer.train_vfl(parties, y, cfg,
                             transport=PipelinedTransport())
    assert conc.losses == seq.losses
    for name in seq.weights:
        np.testing.assert_array_equal(conc.weights[name],
                                      seq.weights[name])
    assert dict(conc.meter.by_tag) == dict(seq.meter.by_tag)


def test_pipelined_random_cp_deterministic():
    """Thread interleaving must not shift the CP-selection trajectory."""
    X, y = synthetic.credit_default(n=300, d=8, seed=2)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=3, batch_size=128,
                    he_backend="mock", tol=0.0, seed=6,
                    cp_selection="random")
    parties = _make_parties(X, 3)
    a = trainer.train_vfl(parties, y, cfg, transport=PipelinedTransport())
    b = trainer.train_vfl(parties, y, cfg, transport=PipelinedTransport())
    assert a.losses == b.losses
    for name in a.weights:
        np.testing.assert_array_equal(a.weights[name], b.weights[name])


# ---------------------------------------------------------------------------
# Socket transport: real OS processes over TCP, bit-identical to local
# ---------------------------------------------------------------------------

def _assert_socket_exact(res, ref):
    """Socket run vs in-process reference: losses, weights, per-tag
    analytic bytes — AND the measured (actually framed) payload bytes
    must equal the analytic accounting tag-for-tag."""
    assert res.losses == ref.losses
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
    assert dict(res.meter.by_tag) == dict(ref.meter.by_tag)
    assert res.meter.total_bytes == ref.meter.total_bytes
    assert res.n_iter == ref.n_iter
    assert dict(res.measured_meter.by_tag) == dict(res.meter.by_tag)
    assert res.wire_overhead_bytes > 0          # headers exist, unmetered


def test_socket_parity_k2_mock():
    """Tentpole invariant: k=2 training across real OS processes over
    SocketTransport is bit-identical to LocalTransport."""
    from repro.launch.cluster import train_vfl_socket
    X, y = synthetic.credit_default(n=200, d=8, seed=3)
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=3, batch_size=64,
                    he_backend="mock", tol=0.0, seed=11)
    parties = _make_parties(X, 2)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, local)


def test_socket_parity_k4_poisson_mock():
    """k=4 with the order-sensitive e^z chaining: the chained Beaver
    products run as per-CP legs with real `beaver_open` frames and must
    still match the local pair evaluation bit-for-bit."""
    from repro.launch.cluster import train_vfl_socket
    X, y = synthetic.dvisits(n=200, seed=7)
    cfg = VFLConfig(glm="poisson", lr=0.05, max_iter=2, batch_size=48,
                    he_backend="mock", tol=0.0, seed=5)
    parties = _make_parties(X, 4)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, local)


def test_socket_early_stop_and_random_cp():
    """The conductor's stop decision mirrors C's flag (early-stop parity)
    and random CP selection follows the dedicated-stream trajectory the
    PipelinedTransport established."""
    from repro.launch.cluster import train_vfl_socket
    X, y = synthetic.credit_default(n=300, seed=15)
    cfg = VFLConfig(glm="logistic", lr=0.0, max_iter=10, batch_size=128,
                    he_backend="mock", tol=1e-3, seed=5)
    parties = _make_parties(X, 2)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, local)
    assert res.n_iter == 2
    # random CP: same trajectory as the pipelined transport (seed+90002)
    X, y = synthetic.credit_default(n=200, d=8, seed=2)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=6,
                    cp_selection="random")
    parties = _make_parties(X, 3)
    piped = trainer.train_vfl(parties, y, cfg,
                              transport=PipelinedTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, piped)


@pytest.mark.slow
def test_socket_parity_k4_paillier_poisson():
    """Real Paillier over the wire: ciphertexts cross process boundaries
    in canonical Z_{n²} packing (Montgomery → canonical → Montgomery),
    each party holds only its own private key, and the model is still
    bit-identical to the single-process run."""
    from repro.launch.cluster import train_vfl_socket
    X, y = synthetic.dvisits(n=120, seed=19)
    cfg = VFLConfig(glm="poisson", lr=0.05, max_iter=2, batch_size=32,
                    he_backend="paillier", key_bits=192, tol=0.0, seed=17)
    parties = _make_parties(X, 4)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    res = train_vfl_socket(parties, y, cfg)
    _assert_socket_exact(res, local)


def test_socket_scoring_matches_local_serving():
    """The serving path over sockets (score shares as `infer.wx_share`
    frames through the party mesh) matches TrainResult.predict_wx."""
    from repro.core import glm as glm_lib
    from repro.launch.cluster import SocketCluster
    X, y = synthetic.credit_default(n=200, d=9, seed=5)
    cfg = VFLConfig(glm="logistic", lr=0.2, max_iter=2, batch_size=64,
                    he_backend="mock", tol=0.0, seed=1)
    parties = _make_parties(X, 3)
    local = trainer.train_vfl(parties, y, cfg, transport=LocalTransport())
    with SocketCluster(parties, y, cfg) as cl:
        cl.train()
        preds = cl.score({p.name: p.X[:10] for p in parties})
    wx = sum(p.X[:10] @ local.weights[p.name] for p in parties)
    np.testing.assert_allclose(preds, glm_lib.GLMS["logistic"].predict(wx))


def test_runtime_predict_share_matches_trainresult():
    """The actor inference path (Party.predict_share) reproduces
    TrainResult.predict_wx."""
    X, y = synthetic.credit_default(n=300, d=8, seed=8)
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=3, batch_size=128,
                    he_backend="mock", tol=0.0, seed=3)
    parties = _make_parties(X, 3)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()
    wx_actor = sum(p.predict_share() for p in sched.parties)
    np.testing.assert_allclose(wx_actor, res.predict_wx(parties))
