"""Persistent fixed-base tables: build/eval correctness and persistence
hardening (torn writes, key mismatches, stale layouts)."""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.crypto import bigint, fixed_base, paillier
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import Modulus

KEY_BITS = 128
ENG = engine_mod.CryptoEngine(backend="jnp")


@pytest.fixture(scope="module")
def keypair():
    return paillier.keygen(KEY_BITS, seed=11)


@pytest.fixture(scope="module")
def table(keypair):
    return fixed_base.build_noise_table(
        keypair.pub.n, keypair.pub.mod_n2, window=4, rho_bits=64,
        rng=np.random.default_rng(5))


# ---------------------------------------------------------------------------
# build + eval
# ---------------------------------------------------------------------------

def test_build_shapes_and_header(keypair, table):
    assert table.purpose == "noise"
    assert table.exp_bits == 64 and table.levels == 16
    assert table.table_rns.shape[:2] == (16, 16)
    h = table.header()
    assert h["fingerprint"] == fixed_base.key_fingerprint(keypair.pub.n)
    assert h["limb_bits"] == 12 and h["L"] == keypair.pub.mod_n2.L


def test_eval_matches_pow_oracle(keypair, table):
    pub = keypair.pub
    n2 = pub.mod_n2.value
    R = 1 << (12 * pub.mod_n2.L)
    exps = [0, 1, (1 << 64) - 1, 0x1234ABCD]
    digits = fixed_base.exp_digits(exps, table.levels, table.window)
    out = np.asarray(ENG.fixed_base_exp(table, digits, pub.mod_n2))
    for e, row in zip(exps, out):
        want = (pow(table.base, e, n2) * R) % n2
        assert paillier.decode_ints(row)[0] == want


def test_draw_digits_uniform_shape(table):
    d = fixed_base.draw_exponent_digits(table, 7, np.random.default_rng(1))
    assert d.shape == (7, table.levels) and d.dtype == np.uint32
    assert d.max() < 1 << table.window


def test_table_noise_decrypts(keypair, table):
    """h^ρ is valid encryption noise: Enc(m; table-noise) decrypts to m."""
    pub = keypair.pub
    digits = fixed_base.draw_exponent_digits(table, 3,
                                             np.random.default_rng(2))
    rn = paillier.noise_from_table(pub, table, digits, ENG)
    m = paillier.encode_ints(pub, [0, 42, pub.n - 1])
    ct = paillier.encrypt_with_noise(pub, m, rn, ENG)
    dec = paillier.decode_ints(np.asarray(paillier.decrypt_crt(
        keypair, ct, engine=ENG)))
    assert dec == [0, 42, pub.n - 1]


def test_generator_table(keypair):
    pub = keypair.pub
    g = 1 + pub.n
    t = fixed_base.build_generator_table(pub.n, g, pub.mod_n2,
                                         window=4, msg_bits=16)
    n2 = pub.mod_n2.value
    R = 1 << (12 * pub.mod_n2.L)
    digits = fixed_base.exp_digits([777], t.levels, 4)
    out = np.asarray(ENG.fixed_base_exp(t, digits, pub.mod_n2))
    assert paillier.decode_ints(out[0])[0] == (pow(g, 777, n2) * R) % n2


# ---------------------------------------------------------------------------
# persistence hardening
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(keypair, table, tmp_path):
    path = str(tmp_path / "noise.npz")
    fixed_base.save_table(table, path)
    assert not any(".tmp." in f for f in os.listdir(tmp_path))  # atomic
    back = fixed_base.load_table(path, n=keypair.pub.n,
                                 mod=keypair.pub.mod_n2, window=4)
    assert back.base == table.base and back.levels == table.levels
    np.testing.assert_array_equal(back.table_rns, table.table_rns)


def test_load_rejects_other_key(keypair, table, tmp_path):
    path = str(tmp_path / "noise.npz")
    fixed_base.save_table(table, path)
    other = paillier.keygen(KEY_BITS, seed=99)
    with pytest.raises(fixed_base.TableMismatchError, match="fingerprint"):
        fixed_base.load_table(path, n=other.pub.n, mod=other.pub.mod_n2)


def test_load_rejects_stale_layout(keypair, table, tmp_path):
    """A table whose window/layout no longer matches the requested
    configuration is a MISMATCH (stale file), not corruption."""
    path = str(tmp_path / "noise.npz")
    fixed_base.save_table(table, path)
    with pytest.raises(fixed_base.TableMismatchError, match="window"):
        fixed_base.load_table(path, n=keypair.pub.n,
                              mod=keypair.pub.mod_n2, window=8)


def test_load_rejects_torn_file(keypair, table, tmp_path):
    """Truncation anywhere in the file → TableCorruptError, never a
    silently wrong table (and never TableMismatchError)."""
    path = str(tmp_path / "noise.npz")
    fixed_base.save_table(table, path)
    blob = open(path, "rb").read()
    for cut in (10, len(blob) // 2, len(blob) - 7):
        with open(path, "wb") as f:
            f.write(blob[:cut])
        with pytest.raises(fixed_base.TableCorruptError):
            fixed_base.load_table(path, n=keypair.pub.n,
                                  mod=keypair.pub.mod_n2)


def test_load_rejects_bit_rot(keypair, table, tmp_path):
    """Payload digest catches content damage an intact zip would hide."""
    import io, zipfile
    path = str(tmp_path / "noise.npz")
    fixed_base.save_table(table, path)
    # rewrite the npz with one payload byte flipped but valid zip structure
    src = zipfile.ZipFile(path)
    buf = io.BytesIO()
    with zipfile.ZipFile(buf, "w") as dst:
        for name in src.namelist():
            data = src.read(name)
            if name == "table_rns.npy":
                data = data[:-1] + bytes([data[-1] ^ 1])
            dst.writestr(name, data)
    with open(path, "wb") as f:
        f.write(buf.getvalue())
    with pytest.raises(fixed_base.TableCorruptError, match="digest"):
        fixed_base.load_table(path, n=keypair.pub.n,
                              mod=keypair.pub.mod_n2)


def test_ensure_table_builds_loads_rebuilds(keypair, tmp_path):
    pub = keypair.pub
    path = str(tmp_path / "noise.npz")
    t1, built1 = fixed_base.ensure_table(pub.n, pub.mod_n2, path,
                                         rho_bits=64,
                                         rng=np.random.default_rng(3))
    t2, built2 = fixed_base.ensure_table(pub.n, pub.mod_n2, path,
                                         rho_bits=64)
    assert built1 and not built2
    np.testing.assert_array_equal(t1.table_rns, t2.table_rns)
    # corrupt the file: ensure_table rebuilds instead of failing
    with open(path, "wb") as f:
        f.write(b"garbage")
    t3, built3 = fixed_base.ensure_table(pub.n, pub.mod_n2, path,
                                         rho_bits=64,
                                         rng=np.random.default_rng(4))
    assert built3
    fixed_base.load_table(path, n=pub.n, mod=pub.mod_n2)  # now valid again


def test_keygen_table_path_attach(tmp_path):
    path = str(tmp_path / "noise.npz")
    priv = paillier.keygen(KEY_BITS, seed=21, table_path=path)
    assert priv.noise_table is not None
    assert os.path.exists(path)
    # backend auto-attaches and the mismatch guard works
    from repro.core import protocols
    backend = protocols.PaillierBackend({"A": priv},
                                        np.random.default_rng(1), ENG)
    assert "A" in backend.tables
    other = paillier.keygen(KEY_BITS, seed=22)
    backend2 = protocols.PaillierBackend({"B": other},
                                         np.random.default_rng(1), ENG)
    with pytest.raises(fixed_base.TableMismatchError):
        backend2.attach_table("B", priv.noise_table)


# ---------------------------------------------------------------------------
# End-to-end: table noise trains the bit-identical model
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_table_noise_parity(tmp_path):
    """End-to-end Algorithm 1 with real Paillier: routing encryption
    noise through persistent fixed-base tables trains the bit-identical
    model to the r^n ladder — masks cancel exactly and noise never
    reaches a decrypted value, so the noise *source* is model-invisible."""
    from repro.core import trainer
    from repro.data import synthetic, vertical

    X, y = synthetic.credit_default(n=60, d=4, seed=3)
    parts = vertical.split_columns(X, 2)
    parties = [trainer.PartyData(name=nm, X=p)
               for nm, p in zip(["C", "B1"], parts)]
    cfg = trainer.VFLConfig(glm="logistic", lr=0.1, max_iter=1,
                            batch_size=16, he_backend="paillier",
                            key_bits=256, tol=0.0, seed=2,
                            crypto_engine="jnp")
    names = [p.name for p in parties]
    ref_backend = trainer.make_backend(cfg, names, np.random.default_rng(9))
    ref = trainer.train_vfl(parties, y, cfg, backend=ref_backend)

    tab_backend = trainer.make_backend(cfg, names, np.random.default_rng(9))
    for nm in names:                       # same keys (same rng seed)
        assert tab_backend.keys[nm].pub.n == ref_backend.keys[nm].pub.n
        pub = tab_backend.keys[nm].pub
        tbl, built = fixed_base.ensure_table(
            pub.n, pub.mod_n2, str(tmp_path / f"noise_{nm}.npz"),
            rho_bits=96, rng=np.random.default_rng(31))
        assert built
        tab_backend.attach_table(nm, tbl)
    res = trainer.train_vfl(parties, y, cfg, backend=tab_backend)

    assert set(tab_backend.tables) == set(names)   # table path was live
    assert res.losses == ref.losses
    for name in ref.weights:
        np.testing.assert_array_equal(res.weights[name], ref.weights[name])
