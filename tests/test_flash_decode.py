"""Flash-decode (sharded partial attention + LSE merge) ≡ plain decode
attention — exactness on 1 device, collectives on 8 fake devices."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import flash_decode
from repro.models import attention
from repro.models.attention import AttnSpec, KVCache

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_partial_merge_equals_full_softmax():
    """Chunked local partials + LSE merge == one global softmax."""
    rng = np.random.default_rng(0)
    B, G, Hg, hd, S = 2, 4, 2, 16, 64
    q = jnp.asarray(rng.normal(size=(B, G, Hg, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, G, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, G, hd)), jnp.float32)
    length = 50

    # oracle: plain decode attention
    spec = AttnSpec(n_heads=G * Hg, n_kv_heads=G, head_dim=hd,
                    rope_theta=None)
    cache = KVCache(k, v, jnp.asarray(length))
    want = attention.decode_attention(
        q.reshape(B, 1, G * Hg, hd), cache, spec)[:, 0]

    # 4 chunks, merged manually with the flash_decode primitives
    accs, ms, dens = [], [], []
    Sc = S // 4
    for c in range(4):
        kpos = c * Sc + np.arange(Sc)
        valid = jnp.asarray(kpos < length)
        a, m, d = flash_decode.local_partial_attention(
            q, k[:, c * Sc:(c + 1) * Sc], v[:, c * Sc:(c + 1) * Sc], valid)
        accs.append(a)
        ms.append(m)
        dens.append(d)
    m_glob = jnp.max(jnp.stack(ms), 0)
    num = sum(a * jnp.exp(m - m_glob)[..., None]
              for a, m in zip(accs, ms))
    den = sum(d * jnp.exp(m - m_glob) for d, m in zip(dens, ms))
    got = (num / den[..., None]).reshape(B, G * Hg, hd)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32).reshape(
                                   B, G * Hg, hd),
                               atol=1e-5)


@pytest.mark.slow
def test_flash_decode_shardmap_8dev():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import flash_decode
from repro.models import attention
from repro.models.attention import AttnSpec, KVCache

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(1)
B, G, Hg, hd, S = 2, 2, 2, 8, 64
q = jnp.asarray(rng.normal(size=(B, G, Hg, hd)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, S, G, hd)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, S, G, hd)), jnp.float32)
length = jnp.asarray(41)
flash = flash_decode.make_flash_decode(mesh, "data", B, S, G, Hg, hd)
got = jax.jit(flash)(q, k, v, length)
spec = AttnSpec(n_heads=G*Hg, n_kv_heads=G, head_dim=hd, rope_theta=None)
want = attention.decode_attention(q.reshape(B,1,G*Hg,hd),
                                  KVCache(k, v, length), spec)[:, 0]
np.testing.assert_allclose(np.asarray(got).reshape(B, G*Hg, hd),
                           np.asarray(want, np.float32).reshape(B, G*Hg, hd),
                           atol=1e-5)
# the lowered HLO must NOT gather the cache: no all-gather of (B,S,G,hd)
txt = jax.jit(flash).lower(q, k, v, length).compile().as_text()
assert "all-reduce" in txt
cache_elems = B * S * G * hd
import re
for m in re.finditer(r"f32\[([\d,]+)\][^ ]* all-gather", txt):
    n = 1
    for d in m.group(1).split(","):
        n *= int(d)
    assert n < cache_elems, f"cache-sized all-gather found: {m.group(0)}"
print("FLASH_DECODE_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=REPO)
    assert "FLASH_DECODE_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-2500:])
