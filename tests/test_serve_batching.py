"""Property suite for the serving admission controller (MicroBatcher).

Invariants under arbitrary (adversarial) arrival/poll schedules:
  * a closed batch never exceeds max_batch;
  * per-client FIFO order is preserved end to end;
  * no starvation — every submitted item eventually leaves once polling
    continues past the deadline;
  * the deadline trigger always closes a NON-EMPTY batch (it can only
    fire when something has been waiting).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.serve.batching import MicroBatcher  # noqa: E402


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def drive(max_batch, max_wait_s, schedule):
    """Run an arrival/advance/poll schedule; return (batches, submitted).

    `schedule` is a list of ints: value v encodes one of three moves —
      v % 3 == 0: submit v // 3 + 1 items,
      v % 3 == 1: advance the clock by (v % 7) * max_wait_s / 4,
      v % 3 == 2: poll once (deadline-triggered only, no flush).
    Adversarial in the sense that arrivals, time and polls interleave
    arbitrarily; determinism comes from the strategy sampler.
    """
    clock = FakeClock()
    b = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s,
                     clock=clock)
    submitted, batches, seq = [], [], 0
    for v in schedule:
        move = v % 3
        if move == 0:
            for _ in range(v // 3 % 4 + 1):
                item = ("cl%d" % (seq % 3), seq)     # (client, seq)
                b.submit(item)
                submitted.append(item)
                seq += 1
        elif move == 1:
            clock.t += (v % 7) * (max_wait_s / 4 if max_wait_s else 0.25)
        else:
            out = b.poll()
            if out:
                batches.append(out)
    # drain: time passes and polling continues — nothing may starve
    for _ in range(len(submitted) + 1):
        clock.t += max(max_wait_s, 1.0)
        out = b.poll()
        if out:
            batches.append(out)
    return batches, submitted


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=7),
       st.floats(min_value=0.0, max_value=2.0),
       st.lists(st.integers(min_value=0, max_value=1000),
                min_size=0, max_size=40))
def test_batcher_invariants(max_batch, max_wait_s, schedule):
    batches, submitted = drive(max_batch, max_wait_s, schedule)

    # 1. admission never exceeds max_batch
    for batch in batches:
        assert len(batch) <= max_batch

    # 2. no starvation: everything submitted eventually left, exactly once
    served = [it for batch in batches for it in batch]
    assert sorted(served, key=lambda x: x[1]) == submitted

    # 3. per-client FIFO: each client's seqs leave in submit order
    by_client = {}
    for client, s in served:
        by_client.setdefault(client, []).append(s)
    for client, seqs in by_client.items():
        assert seqs == sorted(seqs), f"client {client} reordered: {seqs}"


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.lists(st.integers(min_value=0, max_value=100),
                min_size=1, max_size=20))
def test_deadline_trigger_closes_nonempty_batch(max_batch, arrivals):
    """Whenever the deadline trigger fires, the batch it closes is
    non-empty — an empty deadline batch would spin the service loop."""
    clock = FakeClock()
    b = MicroBatcher(max_batch=max_batch, max_wait_s=1.0, clock=clock)
    assert b.poll() == []                 # nothing pending, nothing fires
    for i, gap in enumerate(arrivals):
        b.submit(i)
        clock.t += gap / 50.0
        out = b.poll()
        if out:
            assert len(out) > 0           # trigger fired => non-empty
            assert len(out) <= max_batch
    clock.t += 2.0
    while b.pending:
        out = b.poll()
        assert out, "deadline passed with items pending but poll was empty"


def test_size_trigger_exact():
    """Size trigger fires the moment pending reaches max_batch, taking
    exactly the oldest max_batch items — independent of the clock."""
    b = MicroBatcher(max_batch=3, max_wait_s=1e9, clock=lambda: 0.0)
    for i in range(7):
        b.submit(i)
    assert b.poll() == [0, 1, 2]
    assert b.poll() == [3, 4, 5]
    assert b.poll() == []                 # 1 < max_batch, deadline far off
    assert b.poll(flush=True) == [6]
    assert b.pending == 0


def test_flush_ignores_deadline():
    b = MicroBatcher(max_batch=8, max_wait_s=1e9, clock=lambda: 0.0)
    for i in range(5):
        b.submit(i)
    assert b.poll() == []
    assert b.poll(flush=True) == [0, 1, 2, 3, 4]


def test_zero_wait_degenerates_to_synchronous():
    """max_wait_s=0 means every poll drains whatever is pending — the
    legacy synchronous engine behavior."""
    clock = FakeClock()
    b = MicroBatcher(max_batch=64, max_wait_s=0.0, clock=clock)
    b.submit("a")
    b.submit("b")
    assert b.poll() == ["a", "b"]
    assert b.poll() == []
