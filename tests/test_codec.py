"""Round-trip and rejection suite for the binary wire codec.

Invariants:
  * decode(encode(m)) == m for every message type, across key sizes,
    payload dtypes, and empty/edge shapes;
  * the encoded payload length equals `wire_bytes()` for every
    data-plane frame (analytic comm accounting == the wire, enforced by
    the encoder itself — these tests also measure it independently);
  * truncated and corrupted frames are rejected with `CodecError`.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.crypto import fixed_point, paillier, ring
from repro.crypto.ring import R64
from repro.runtime import messages as msg
from repro.runtime.codec import (Codec, CodecError, PRELUDE,
                                 frame_overhead_bytes)

CODEC = Codec()


def _rand_r64(shape, seed):
    rng = np.random.default_rng(seed)
    return ring.from_numpy_u64(
        rng.integers(0, 1 << 64, size=shape, dtype=np.uint64))


def _payload_len(frame: bytes) -> int:
    return len(frame) - frame_overhead_bytes(frame)


def _assert_ring_equal(a: R64, b: R64):
    np.testing.assert_array_equal(ring.to_numpy_u64(a), ring.to_numpy_u64(b))


# ---------------------------------------------------------------------------
# ring-payload messages
# ---------------------------------------------------------------------------

RING_TYPES = [msg.ZShare, msg.YShare, msg.EzShare, msg.BeaverOpen,
              msg.UnmaskedShare, msg.LossShare]


@pytest.mark.parametrize("cls", RING_TYPES)
@pytest.mark.parametrize("shape", [(), (1,), (5,), (0,), (2, 3), (2, 0)])
def test_ring_roundtrip_shapes(cls, shape):
    v = _rand_r64(shape, seed=hash((cls.__name__, shape)) % (1 << 31))
    m = cls("B1", "C", v)
    frame = CODEC.encode(m)
    out = CODEC.decode(frame)
    assert type(out) is cls and out.src == "B1" and out.dst == "C"
    assert out.payload.lo.shape == shape
    _assert_ring_equal(out.payload, v)
    n = int(np.prod(shape)) if shape else 1
    assert _payload_len(frame) == m.wire_bytes() == n * 8


def test_ring_synthetic_traffic_roundtrip():
    """payload=None + n_elems — dry-run traffic synthesis frames."""
    m = msg.ZShare("B2", "C", None, n_elems=17)
    out = CODEC.decode(CODEC.encode(m))
    assert out.payload is None and out.n_elems == 17
    assert out.wire_bytes() == m.wire_bytes() == 17 * 8


def test_ring_n_elems_consistency_enforced():
    v = _rand_r64((4,), seed=3)
    with pytest.raises(CodecError):
        CODEC.encode(msg.ZShare("B1", "C", v, n_elems=5))


def test_ring_empty_payload_with_zero_n_elems():
    """n_elems=0 with a genuinely empty tensor is consistent, not an
    error (0 must not be coerced to 1)."""
    v = _rand_r64((0,), seed=3)
    out = CODEC.decode(CODEC.encode(msg.ZShare("B1", "C", v, n_elems=0)))
    assert out.payload.lo.shape == (0,) and out.n_elems == 0
    assert out.wire_bytes() == 0


@given(st.integers(min_value=0, max_value=200),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_ring_roundtrip_random(n, seed):
    v = _rand_r64((n,), seed)
    m = msg.UnmaskedShare("C", "B3", v)
    out = CODEC.decode(CODEC.encode(m))
    _assert_ring_equal(out.payload, v)


def test_float_scores_roundtrip():
    rng = np.random.default_rng(9)
    wx = rng.normal(size=23)
    m = msg.WxShare("B1", "C", wx, n_elems=23)
    frame = CODEC.encode(m)
    out = CODEC.decode(frame)
    np.testing.assert_array_equal(out.payload, wx)   # bit-exact float64
    assert _payload_len(frame) == m.wire_bytes() == 23 * 8


def test_beaver_open_stacked_pair():
    """The distributed runtime ships (d, e) halves as one stacked frame:
    2 ring elements per product element, matching the analytic 2·n."""
    d, e = _rand_r64((6,), 1), _rand_r64((6,), 2)
    import jax.numpy as jnp
    both = R64(jnp.stack([d.hi, e.hi]), jnp.stack([d.lo, e.lo]))
    m = msg.BeaverOpen("C", "B1", both, n_elems=12)
    frame = CODEC.encode(m)
    assert _payload_len(frame) == m.wire_bytes() == 12 * 8
    out = CODEC.decode(frame)
    _assert_ring_equal(R64(out.payload.hi[0], out.payload.lo[0]), d)
    _assert_ring_equal(R64(out.payload.hi[1], out.payload.lo[1]), e)


# ---------------------------------------------------------------------------
# flags + control
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stop", [False, True])
def test_flag_roundtrip(stop):
    frame = CODEC.encode(msg.Flag("C", "B7", stop=stop))
    out = CODEC.decode(frame)
    assert isinstance(out, msg.Flag) and out.stop is stop
    assert _payload_len(frame) == 1


def test_control_roundtrip():
    payload = {"roster": [["C", "127.0.0.1", 4242]], "cfg": {"seed": 3},
               "loss": 0.6931471805599453}
    m = msg.Control("conductor", "C", payload=payload, kind="handshake")
    out = CODEC.decode(CODEC.encode(m))
    assert out.kind == "handshake" and out.payload == payload
    assert out.payload["loss"] == payload["loss"]    # float64 round-trip


# ---------------------------------------------------------------------------
# ciphertexts — mock padding and real canonical packing
# ---------------------------------------------------------------------------

CT_TYPES = [msg.EncD, msg.EncDBroadcast, msg.MaskedGrad]


@pytest.mark.parametrize("cls", CT_TYPES)
@pytest.mark.parametrize("key_bits", [192, 256, 1024])
def test_mock_ciphertext_roundtrip(cls, key_bits):
    v = _rand_r64((5,), seed=key_bits)
    m = cls("C", "B1", v, n_cts=5, key_bits=key_bits, key_owner="C")
    frame = CODEC.encode(m)
    assert _payload_len(frame) == m.wire_bytes() == 5 * (2 * key_bits // 8)
    out = CODEC.decode(frame)
    assert type(out) is cls
    assert (out.n_cts, out.key_bits, out.key_owner) == (5, key_bits, "C")
    _assert_ring_equal(out.payload, v)


def test_mock_ciphertext_rejects_dirty_padding():
    v = _rand_r64((2,), seed=1)
    frame = bytearray(CODEC.encode(
        msg.EncD("C", "B1", v, n_cts=2, key_bits=256, key_owner="C")))
    # poke a byte inside the zero padding of the first ciphertext and
    # re-seal the CRC so only the semantic check can catch it
    import zlib
    overhead = frame_overhead_bytes(bytes(frame))
    frame[overhead + 20] = 0xAB
    _, _, hlen, plen, _ = PRELUDE.unpack_from(bytes(frame))
    crc = zlib.crc32(bytes(frame[PRELUDE.size:])) & 0xFFFFFFFF
    frame[:PRELUDE.size] = PRELUDE.pack(b"EFM", 1, hlen, plen, crc)
    with pytest.raises(CodecError):
        CODEC.decode(bytes(frame))


@pytest.mark.parametrize("key_bits", [192, 256])
def test_paillier_ciphertext_roundtrip(key_bits):
    """Canonical 2·key_bits-bit packing is bit-exact through the
    Montgomery domain (reduced representatives are unique), and the
    re-encoded batch decrypts to the original plaintexts."""
    key = paillier.keygen(key_bits, seed=11)
    pub = key.pub
    rng = np.random.default_rng(4)
    vals = ring.from_numpy_u64(
        rng.integers(0, 1 << 64, size=6, dtype=np.uint64))
    cts = paillier.encrypt(pub, fixed_point.r64_to_limbs(vals, pub.Ln),
                           rng=rng)
    codec = Codec(lambda owner: pub.mod_n2 if owner == "B2" else None)
    m = msg.MaskedGrad("C", "B2", cts, n_cts=6, key_bits=key_bits,
                       key_owner="B2")
    frame = codec.encode(m)
    assert _payload_len(frame) == m.wire_bytes() \
        == 6 * ((2 * key_bits + 7) // 8)
    out = codec.decode(frame)
    np.testing.assert_array_equal(np.asarray(out.payload), np.asarray(cts))
    dec = fixed_point.limbs_to_r64(paillier.decrypt_crt(key, out.payload))
    _assert_ring_equal(dec, vals)


def test_paillier_ciphertext_needs_key_provider():
    key = paillier.keygen(192, seed=2)
    rng = np.random.default_rng(1)
    cts = paillier.encrypt(
        key.pub, fixed_point.r64_to_limbs(_rand_r64((2,), 0), key.pub.Ln),
        rng=rng)
    m = msg.EncD("C", "B1", cts, n_cts=2, key_bits=192, key_owner="C")
    with pytest.raises(CodecError):
        Codec().encode(m)


def test_paillier_out_of_range_residue_rejected():
    """A residue >= n² cannot be a ciphertext — reject before to_mont."""
    key = paillier.keygen(192, seed=5)
    pub = key.pub
    rng = np.random.default_rng(2)
    cts = paillier.encrypt(
        pub, fixed_point.r64_to_limbs(_rand_r64((1,), 7), pub.Ln), rng=rng)
    codec = Codec(lambda owner: pub.mod_n2)
    frame = bytearray(codec.encode(
        msg.EncD("C", "B1", cts, n_cts=1, key_bits=192, key_owner="C")))
    overhead = frame_overhead_bytes(bytes(frame))
    frame[overhead:] = b"\xff" * (len(frame) - overhead)   # ≥ n² for sure
    import zlib
    _, _, hlen, plen, _ = PRELUDE.unpack_from(bytes(frame))
    crc = zlib.crc32(bytes(frame[PRELUDE.size:])) & 0xFFFFFFFF
    frame[:PRELUDE.size] = PRELUDE.pack(b"EFM", 1, hlen, plen, crc)
    with pytest.raises(CodecError):
        codec.decode(bytes(frame))


# ---------------------------------------------------------------------------
# malformed frames
# ---------------------------------------------------------------------------

def _sample_frame() -> bytes:
    return CODEC.encode(msg.ZShare("B1", "C", _rand_r64((9,), 42)))


@given(st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=20, deadline=None)
def test_truncated_frames_rejected(frac):
    frame = _sample_frame()
    cut = min(int(len(frame) * frac), len(frame) - 1)
    with pytest.raises(CodecError):
        CODEC.decode(frame[:cut])


@given(st.integers(min_value=0, max_value=10 ** 9))
@settings(max_examples=30, deadline=None)
def test_corrupted_frames_rejected(pos_seed):
    frame = bytearray(_sample_frame())
    pos = pos_seed % len(frame)
    frame[pos] ^= 0x5A
    with pytest.raises(CodecError):
        CODEC.decode(bytes(frame))


def test_trailing_garbage_rejected():
    with pytest.raises(CodecError):
        CODEC.decode(_sample_frame() + b"\x00")


def test_bad_magic_and_version_rejected():
    frame = bytearray(_sample_frame())
    bad = bytearray(frame)
    bad[0] = 0x00
    with pytest.raises(CodecError):
        CODEC.decode(bytes(bad))
    bad = bytearray(frame)
    bad[3] = 99                                   # future codec version
    with pytest.raises(CodecError):
        CODEC.decode(bytes(bad))


def test_unknown_type_id_rejected():
    import zlib
    frame = bytearray(_sample_frame())
    body = bytearray(frame[PRELUDE.size:])
    body[0] = 200                                 # unregistered type id
    _, _, hlen, plen, _ = PRELUDE.unpack_from(bytes(frame))
    crc = zlib.crc32(bytes(body)) & 0xFFFFFFFF
    with pytest.raises(CodecError):
        CODEC.decode(PRELUDE.pack(b"EFM", 1, hlen, plen, crc) + bytes(body))


def test_drift_guard_matches_meter_for_every_tag():
    """One live frame per data-plane tag: encoded payload length ==
    wire_bytes() == what a CommMeter would account."""
    frames = [
        msg.ZShare("B1", "C", _rand_r64((8,), 0)),
        msg.YShare("C", "B1", _rand_r64((8,), 1)),
        msg.EzShare("B2", "C", _rand_r64((8,), 2)),
        msg.BeaverOpen("C", "B1", _rand_r64((2, 8), 3), n_elems=16),
        msg.UnmaskedShare("C", "B1", _rand_r64((3,), 4)),
        msg.LossShare("B1", "C", _rand_r64((), 5), n_elems=1),
        msg.WxShare("B1", "C", np.ones(4), n_elems=4),
        msg.EncD("C", "B1", _rand_r64((8,), 6), n_cts=8, key_bits=256,
                 key_owner="C"),
        msg.EncDBroadcast("C", "B2", _rand_r64((8,), 7), n_cts=8,
                          key_bits=256, key_owner="C"),
        msg.MaskedGrad("B2", "C", _rand_r64((3,), 8), n_cts=3,
                       key_bits=256, key_owner="C"),
        msg.Flag("C", "B1", stop=False),
    ]
    seen = set()
    for m in frames:
        f = CODEC.encode(m)
        assert _payload_len(f) == m.wire_bytes(), m.tag
        seen.add(m.tag)
    assert seen == set(msg.TAG_PROTOCOL)
