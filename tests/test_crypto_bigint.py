"""bigint limb arithmetic vs python-int oracles (incl. hypothesis sweeps)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.crypto import bigint
from repro.crypto.bigint import (Modulus, carry_sweep, big_lt, big_mul_full,
                                 from_mont, int_to_limbs, limbs_to_int,
                                 mod_add, mod_sub, mont_exp_bits,
                                 mont_exp_const, mont_mul, mul_low, to_mont,
                                 int_to_bits, limbs_to_bits, nlimbs)

RNG = np.random.default_rng(0)

# A few fixed odd moduli of assorted sizes (incl. a real 1024-bit-style one)
MODULI = [
    97,
    (1 << 61) - 1,
    0xF123_4567_89AB_CDEF_0123_4567_89AB_CD0F_FFFF_FFFF_FFFF_FFC5,
    int("0x" + "d" * 128, 16) | 1,      # 512-bit odd
]


def rand_below(n, size):
    return [int(RNG.integers(0, 1 << 62)) % n if n < (1 << 62)
            else int.from_bytes(RNG.bytes((n.bit_length() + 7) // 8), "little") % n
            for _ in range(size)]


def test_roundtrip_int_limbs():
    for x in [0, 1, 4095, 4096, (1 << 200) - 12345]:
        L = nlimbs(max(1, x.bit_length()))
        assert limbs_to_int(int_to_limbs(x, L)) == x


def test_carry_sweep_exact():
    raw = jnp.asarray(np.array([[4095, 4095, 4095, 0],
                                [70000, 123456, 999999, 1]], np.uint32))
    out = np.asarray(carry_sweep(raw))
    for i in range(2):
        want = sum(int(v) << (12 * j) for j, v in enumerate(np.asarray(raw)[i]))
        got = limbs_to_int(out[i])
        assert got == want % (1 << 48)
        assert (out[i] <= 0xFFF).all()


@pytest.mark.parametrize("n", MODULI)
def test_mod_add_sub(n):
    L = nlimbs(n.bit_length())
    mod = Modulus.make(n)
    a = rand_below(n, 8)
    b = rand_below(n, 8)
    A = jnp.asarray(bigint.ints_to_limbs(a, L))
    B = jnp.asarray(bigint.ints_to_limbs(b, L))
    s = [limbs_to_int(x) for x in np.asarray(mod_add(A, B, mod))]
    d = [limbs_to_int(x) for x in np.asarray(mod_sub(A, B, mod))]
    assert s == [(x + y) % n for x, y in zip(a, b)]
    assert d == [(x - y) % n for x, y in zip(a, b)]


@pytest.mark.parametrize("n", MODULI)
def test_mont_mul_matches_python(n):
    L = nlimbs(n.bit_length())
    mod = Modulus.make(n)
    R = 1 << (12 * L)
    Rinv = pow(R, -1, n)
    a = rand_below(n, 16)
    b = rand_below(n, 16)
    A = jnp.asarray(bigint.ints_to_limbs(a, L))
    B = jnp.asarray(bigint.ints_to_limbs(b, L))
    got = [limbs_to_int(x) for x in np.asarray(mont_mul(A, B, mod))]
    want = [(x * y * Rinv) % n for x, y in zip(a, b)]
    assert got == want


@pytest.mark.parametrize("n", MODULI)
def test_to_from_mont_roundtrip(n):
    L = nlimbs(n.bit_length())
    mod = Modulus.make(n)
    a = rand_below(n, 8)
    A = jnp.asarray(bigint.ints_to_limbs(a, L))
    back = [limbs_to_int(x) for x in np.asarray(from_mont(to_mont(A, mod), mod))]
    assert back == a


@pytest.mark.parametrize("n", MODULI[:3])
def test_mont_exp(n):
    mod = Modulus.make(n)
    base = rand_below(n, 4)
    exps = [0, 1, 2, 65537]
    B = to_mont(jnp.asarray(bigint.ints_to_limbs(base, mod.L)), mod)
    for e in exps:
        got = [limbs_to_int(x) for x in
               np.asarray(from_mont(mont_exp_const(B, e, mod), mod))]
        assert got == [pow(x, e, n) for x in base]


def test_mont_exp_bits_traced():
    n = MODULI[1]
    mod = Modulus.make(n)
    base = rand_below(n, 5)
    exps = rand_below(1 << 48, 5)
    B = to_mont(jnp.asarray(bigint.ints_to_limbs(base, mod.L)), mod)
    bits = jnp.asarray(np.stack([int_to_bits(e, 48) for e in exps]))
    got = [limbs_to_int(x) for x in
           np.asarray(from_mont(mont_exp_bits(B, bits, mod), mod))]
    assert got == [pow(x, e, n) for x, e in zip(base, exps)]


def test_big_mul_full_and_low():
    a = [(1 << 200) - 3, 12345, 1]
    b = [(1 << 150) + 7, (1 << 100) - 1, 0]
    La, Lb = nlimbs(201), nlimbs(151)
    A = jnp.asarray(bigint.ints_to_limbs(a, La))
    B = jnp.asarray(bigint.ints_to_limbs(b, Lb))
    out = nlimbs(360)
    got = [limbs_to_int(x) for x in np.asarray(big_mul_full(A, B, out))]
    assert got == [(x * y) % (1 << (12 * out)) for x, y in zip(a, b)]
    lowL = 10
    gotl = [limbs_to_int(x) for x in np.asarray(mul_low(A, B[..., :lowL], lowL))]
    assert gotl == [(x * y) % (1 << (12 * lowL)) for x, y in zip(a, b)]


def test_big_lt():
    L = 8
    a = [5, 100, (1 << 90) - 1]
    b = [6, 100, 1 << 89]
    A = jnp.asarray(bigint.ints_to_limbs(a, L))
    B = jnp.asarray(bigint.ints_to_limbs(b, L))
    assert list(np.asarray(big_lt(A, B))) == [x < y for x, y in zip(a, b)]


def test_limbs_to_bits():
    x = 0b1011_0000_1111_0101
    arr = jnp.asarray(int_to_limbs(x, 4))
    bits = np.asarray(limbs_to_bits(arr, 16))
    want = int_to_bits(x, 16)
    assert (bits == want).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=3, max_value=(1 << 256) - 1),
       st.integers(min_value=0), st.integers(min_value=0))
def test_hypothesis_montmul(n, a, b):
    n |= 1
    a %= n
    b %= n
    mod = Modulus.make(n)
    R = 1 << (12 * mod.L)
    A = jnp.asarray(int_to_limbs(a, mod.L))
    B = jnp.asarray(int_to_limbs(b, mod.L))
    got = limbs_to_int(np.asarray(mont_mul(A, B, mod)))
    assert got == (a * b * pow(R, -1, n)) % n
