"""Paper Figure 2 — EFMVFL comm + runtime vs number of participants
(paper: both grow ~linearly; runtime jumps 2→3 because non-CP parties do
two cipher products)."""
from __future__ import annotations

import numpy as np

from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def run(max_parties: int = 6, iters: int = 8) -> list[dict]:
    X, y = synthetic.credit_default(n=4000, d=24, seed=4)
    base = vertical.split_columns(X, 2)
    rows = []
    for k in range(2, max_parties + 1):
        parts = vertical.replicate_provider(base, k)
        names = ["C"] + [f"B{i}" for i in range(1, k)]
        parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
        cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=iters,
                        batch_size=512, he_backend="mock", tol=0.0, seed=5)
        res = trainer.train_vfl(parties, y, cfg)
        rows.append({"parties": k,
                     "comm_mb": round(res.meter.total_mb, 2),
                     "runtime_s": round(res.runtime_s, 2)})
    # linearity check (paper fits a straight line)
    comm = np.array([r["comm_mb"] for r in rows])
    slope = np.polyfit(np.arange(len(comm)), comm, 1)[0]
    resid = comm - np.polyval(np.polyfit(np.arange(len(comm)), comm, 1),
                              np.arange(len(comm)))
    rows.append({"fit": "linear", "slope_mb_per_party": round(float(slope), 2),
                 "max_residual_mb": round(float(np.max(np.abs(resid))), 3)})
    return rows
