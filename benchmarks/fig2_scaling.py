"""Paper Figure 2, upgraded to the k-scaling benchmark — EFMVFL comm +
per-iteration wall-clock vs number of participants k, sequential
(`LocalTransport`) vs concurrent-leg (`PipelinedTransport`) schedules.

The paper's claim (§5.1, Fig. 2): communication grows ~linearly in k and
the runtime jump from 2→3 parties reflects non-CP parties doing two
cipher products.  The runtime claim this repo adds on top: with the
concurrent-leg schedule the k−2 non-CP Protocol-3 legs are independent
pool futures, so per-iteration wall-clock stays below k× the k=2 cost
(the sub-k gauge below) while comm (a transport-metered invariant)
stays identical to the sequential run.

Full mode writes machine-readable ``BENCH_scaling.json`` at the repo
root (schema ``bench_scaling/v1``): mock-backend rows for
k ∈ {2, 4, 8, 16} × both GLMs × both transports — the comm-scaling
curve and the scheduler-concurrency acceptance gauge (t_k < k·t_2 per
iteration, steady-state) — plus a real-Paillier timing section
(logistic, k ∈ {2, 4, 8}, small key/batch) where wall-clock is
genuinely HE-bound, kept as the honest single-host reference (with its
CPU-contention caveat recorded in the JSON).  ``--smoke`` shrinks
everything and skips the JSON write (CI drift check).

  PYTHONPATH=src python -m benchmarks.fig2_scaling [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.runtime import LocalTransport, PipelinedTransport

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_SCALING_PATH = REPO_ROOT / "BENCH_scaling.json"

KS = (2, 4, 8, 16)
GLMS = ("logistic", "poisson")


def _dataset(glm: str, n: int):
    if glm == "poisson":
        return synthetic.dvisits(n=n, seed=4)
    return synthetic.credit_default(n=n, d=24, seed=4)


def _parties(X: np.ndarray, k: int) -> list[PartyData]:
    base = vertical.split_columns(X, 2)
    parts = vertical.replicate_provider(base, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    return [PartyData(nm, p) for nm, p in zip(names, parts)]


def _transports():
    return (("local", lambda: LocalTransport()),
            ("pipelined", lambda: PipelinedTransport()))


def _row(glm, k, he, tname, res) -> dict:
    return {
        "glm": glm, "parties": k, "he_backend": he, "transport": tname,
        "comm_mb": round(res.meter.total_mb, 3),
        "rounds_per_iter": round(res.rounds / max(res.n_iter, 1), 1),
        "runtime_s": round(res.runtime_s, 3),
        "per_iter_s": round(res.runtime_s / max(res.n_iter, 1), 4),
        "n_iter": res.n_iter,
    }


def _linear_fit(rows, glm) -> dict:
    """Comm vs k straight-line fit over the pipelined mock rows (the
    paper fits Fig. 2 to a line; residuals gauge the linearity claim)."""
    pts = sorted((r["parties"], r["comm_mb"]) for r in rows
                 if r["glm"] == glm and r["transport"] == "pipelined"
                 and r["he_backend"] == "mock")
    ks = np.array([p[0] for p in pts], float)
    comm = np.array([p[1] for p in pts], float)
    coef = np.polyfit(ks, comm, 1)
    resid = comm - np.polyval(coef, ks)
    return {"glm": glm, "fit": "comm_mb ~ a*k + b",
            "slope_mb_per_party": round(float(coef[0]), 3),
            "max_residual_mb": round(float(np.max(np.abs(resid))), 3)}


def run(ks=KS, glms=GLMS, iters: int = 6, batch: int = 512,
        n_samples: int = 4000, smoke: bool = False,
        warmup: bool = True) -> dict:
    """Returns the full report dict (rows + fits + concurrency summary).
    The mock rows keep comm/rounds honest at every k (the backend meters
    identical bytes to Paillier) and gauge the scheduler's own k-scaling;
    the Paillier section times the real HE-bound iteration.  `warmup`
    runs one untimed iteration per (glm, backend) first so every row is
    steady-state (jit caches warm) — shapes are k-independent, so one
    k=2 warmup covers all ks."""
    t_start = time.perf_counter()
    rows = []
    for glm in glms:
        X, y = _dataset(glm, n_samples)
        if warmup:
            wcfg = VFLConfig(glm=glm, lr=0.1, max_iter=1,
                             batch_size=batch, he_backend="mock",
                             tol=0.0, seed=5)
            for _, make_tp in _transports():
                trainer.train_vfl(_parties(X, 2), y, wcfg,
                                  transport=make_tp())
        for k in ks:
            parties = _parties(X, k)
            cfg = VFLConfig(glm=glm, lr=0.1, max_iter=iters,
                            batch_size=batch, he_backend="mock", tol=0.0,
                            seed=5)
            for tname, make_tp in _transports():
                res = trainer.train_vfl(parties, y, cfg,
                                        transport=make_tp())
                rows.append(_row(glm, k, "mock", tname, res))

    # real-Paillier reference rows: small key/batch so a CPU full run
    # stays in minutes, but the per-leg cost is genuinely HE-dominated.
    # Caveat recorded in the JSON: on a single CPU host the legs contend
    # for the same cores/GIL, so thread-level concurrency shows as
    # sub-k-linear growth at best here — the acceptance gauge is the
    # mock section (scheduler scaling); real deployments run each
    # party's legs on its own hardware.
    pk = tuple(k for k in ks if k <= 8) or ks[:1]
    if not smoke:
        Xp, yp = _dataset("logistic", 512)
        pcfg = dict(glm="logistic", lr=0.1, batch_size=16,
                    he_backend="paillier", key_bits=144, tol=0.0, seed=5)
        if warmup:
            for _, make_tp in _transports():
                trainer.train_vfl(_parties(Xp, 2), yp,
                                  VFLConfig(max_iter=1, **pcfg),
                                  transport=make_tp())
        for k in pk:
            parties = _parties(Xp, k)
            cfg = VFLConfig(max_iter=2, **pcfg)
            for tname, make_tp in _transports():
                res = trainer.train_vfl(parties, y=yp, cfg=cfg,
                                        transport=make_tp())
                rows.append(_row("logistic", k, "paillier", tname, res))

    fits = [_linear_fit(rows, glm) for glm in glms]

    def per_iter(he, k, tname):
        sel = [r["per_iter_s"] for r in rows
               if r["he_backend"] == he and r["parties"] == k
               and r["transport"] == tname and r["glm"] == "logistic"]
        return sel[0] if sel else None

    def section(he, kset):
        """Per-backend k-scaling summary: pipelined per-iteration cost
        at every k against the acceptance bound t_k < k · t_{kmin}."""
        kmin = min(kset)
        t0 = per_iter(he, kmin, "pipelined")
        ratios = {}
        for k in sorted(kset):
            tk = per_iter(he, k, "pipelined")
            if t0 and tk:
                ratios[str(k)] = round(tk / t0, 2)
        out = {
            "k_min": kmin,
            "per_iter_s_pipelined": {
                str(k): per_iter(he, k, "pipelined") for k in sorted(kset)},
            "per_iter_s_local": {
                str(k): per_iter(he, k, "local") for k in sorted(kset)},
            "ratio_vs_kmin_pipelined": ratios,
            # acceptance: concurrent k-party iteration < k × the k=2 cost
            "sub_k_times_kmin": bool(ratios) and all(
                v < int(k) for k, v in ratios.items() if int(k) > kmin),
        }
        return out

    summary = {"gauge": "mock", "mock": section("mock", ks)}
    if not smoke:
        summary["paillier"] = section("paillier", pk)
        summary["paillier"]["note"] = (
            "single-host CPU: the HE legs contend for the same cores and "
            "GIL, so leg concurrency shows as sub-k-linear growth at "
            "best here; on per-party hardware the legs overlap for real "
            "(each party computes on its own machine)")
    return {"schema": "bench_scaling/v1", "ks": list(ks),
            "glms": list(glms), "rows": rows, "linear_fits": fits,
            "concurrency": summary,
            "wall_s": round(time.perf_counter() - t_start, 1)}


def write_report(report: dict, out=None) -> pathlib.Path:
    """Single writer for BENCH_scaling.json (used by both this module's
    CLI and `benchmarks.run --paper`, so the committed artifact can't
    drift between the two)."""
    path = pathlib.Path(out) if out else BENCH_SCALING_PATH
    path.write_text(json.dumps(report, indent=1) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, mock only, no JSON write (CI)")
    ap.add_argument("--out", default=str(BENCH_SCALING_PATH))
    args = ap.parse_args()
    if args.smoke:
        report = run(ks=(2, 4), glms=("logistic",), iters=2, batch=64,
                     n_samples=512, smoke=True)
    else:
        report = run()
    print(json.dumps(report["concurrency"], indent=1))
    for f in report["linear_fits"]:
        print(f"# {f['glm']}: slope={f['slope_mb_per_party']} MB/party, "
              f"max_residual={f['max_residual_mb']} MB")
    if args.smoke:
        print(f"# smoke mode: {pathlib.Path(args.out).name} not written")
        return
    print(f"# wrote {write_report(report, args.out)}")


if __name__ == "__main__":
    main()
