"""Paper Table 2 — Poisson regression on the dvisits task.
Paper reference: TP-PR 0.571/0.834/4.27 MB/12.44 s;
                 EFMVFL-PR 0.571/0.834/5.60 MB/10.78 s."""
from __future__ import annotations

import numpy as np

from repro.baselines import tp_glm
from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical

PAPER_REF = {"TP-PR": (0.571, 0.834, 4.27, 12.44),
             "EFMVFL-PR": (0.571, 0.834, 5.60, 10.78)}


def run(paper_scale: bool = False) -> list[dict]:
    n = 5190 if paper_scale else 2600
    iters = 30 if paper_scale else 12
    X, y = synthetic.dvisits(n=n, seed=1)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y, 0.7)
    parts = vertical.split_columns(Xtr, 2)
    parties = [PartyData("C", parts[0]), PartyData("B1", parts[1])]
    te_parts = vertical.split_columns(Xte, 2)
    te_parties = [PartyData("C", te_parts[0]), PartyData("B1", te_parts[1])]
    cfg = VFLConfig(glm="poisson", lr=0.1, max_iter=iters, batch_size=512,
                    he_backend="mock", key_bits=1024, tol=1e-4, seed=0)

    rows = []
    for name, fn in [("TP-PR", tp_glm.train_tp),
                     ("EFMVFL-PR", trainer.train_vfl)]:
        res = fn(parties, ytr, cfg)
        pred = np.exp(np.clip(res.predict_wx(te_parties), -20, 10))
        rows.append({
            "framework": name,
            "mae": round(metrics.mae(yte, pred), 3),
            "rmse": round(metrics.rmse(yte, pred), 3),
            "comm_mb": round(res.meter.total_mb, 2),
            "runtime_s": round(res.runtime_s, 2),
            "iters": res.n_iter,
            "paper_ref": PAPER_REF[name],
        })
    return rows
