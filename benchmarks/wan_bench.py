"""WAN latency economics: measured socket training vs rounds × RTT.

The protocol's communication-round count (`TrainResult.rounds` — one
sequential transport latency step per round) predicts how wall-clock
scales with link latency: a shaped run should cost roughly

    base_s  +  rounds × (latency_s + jitter_s / 2)

on top of the fault-free compute.  This bench trains the same k=3 mock
run under `runtime.chaos.PROFILES` shaping (`wan20` = 20 ms one-way,
`wan100` = 100 ms — pure shaping, no faults) plus an unshaped baseline,
and reports the measured wall-clock next to that analytic model — the
deployment-economics view of docs/transports.md §WAN, and the guard
that round-count regressions show up as *seconds* at WAN latencies.

  PYTHONPATH=src python -m benchmarks.wan_bench [--smoke]

writes BENCH_wan.json at the repo root (committed, like BENCH_crypto);
`benchmarks/run.py --only wan` prints the same rows as CSV (`--smoke`
for the CI-sized variant, which never overwrites the committed file).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_WAN_PATH = REPO_ROOT / "BENCH_wan.json"

#: shaped-only profiles measured against the unshaped baseline
WAN_PROFILES = ("wan20", "wan100")


def _mock_run(chaos, iters: int, nb: int, k: int = 3):
    """One k-party mock-HE socket training run; returns TrainResult."""
    from repro.core.trainer import PartyData, VFLConfig
    from repro.launch.cluster import train_vfl_socket

    m = 4
    rng = np.random.default_rng(7)
    X = rng.normal(size=(nb, k * m)) * 0.3
    y = (rng.random(nb) < 0.5).astype(np.float64) * 2 - 1
    parties = [PartyData("C", X[:, :m])] + [
        PartyData(f"B{i}", X[:, i * m:(i + 1) * m]) for i in range(1, k)]
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=iters, batch_size=nb,
                    he_backend="mock", key_bits=256, tol=0.0, seed=0)
    return train_vfl_socket(parties, y, cfg, chaos=chaos)


def run(smoke: bool = False) -> dict:
    from repro.runtime.chaos import PROFILES

    iters = 2 if smoke else 4
    nb = 64 if smoke else 128
    profiles = WAN_PROFILES[:1] if smoke else WAN_PROFILES

    # `runtime_s` is the conductor's training-loop wall clock (post-
    # handshake, pre-teardown) — process spawn + jax import would drown
    # the rounds × RTT signal if we timed the whole launch instead
    base = _mock_run(None, iters, nb)          # plain SocketTransport
    base_s = base.runtime_s
    rows = [{
        "name": "wan.base", "profile": "none", "latency_ms": 0.0,
        "iters": base.n_iter, "rounds": base.rounds,
        "analytic_comm_s": 0.0, "measured_s": round(base_s, 3),
        "wan_extra_s": 0.0, "us": base_s * 1e6, "derived": "",
    }]
    for name in profiles:
        p = PROFILES[name]
        res = _mock_run(name, iters, nb)
        wall = res.runtime_s
        # the protocol must be UNCHANGED by shaping — only slower
        assert res.losses == base.losses, f"{name}: shaping changed losses"
        assert dict(res.meter.by_tag) == dict(base.meter.by_tag), \
            f"{name}: shaping changed the analytic meter"
        assert res.rounds == base.rounds, f"{name}: round count changed"
        analytic = res.rounds * (p.latency_s + p.jitter_s / 2)
        extra = wall - base_s
        rows.append({
            "name": f"wan.{name}",
            "profile": name,
            "latency_ms": p.latency_s * 1e3,
            "iters": res.n_iter,
            "rounds": res.rounds,
            "analytic_comm_s": round(analytic, 3),
            "measured_s": round(wall, 3),
            "wan_extra_s": round(extra, 3),
            "us": wall * 1e6,
            "derived": (f"rounds={res.rounds};"
                        f"analytic_comm_s={analytic:.3f};"
                        f"wan_extra_s={extra:.3f}"),
        })
    return {"schema": "bench_wan/v1", "parties": 3, "iters": iters,
            "batch": nb, "he_backend": "mock", "rows": rows}


def write_report(report: dict) -> pathlib.Path:
    out = dict(report)
    out["rows"] = [{k: v for k, v in r.items() if k not in ("us", "derived")}
                   for r in report["rows"]]
    BENCH_WAN_PATH.write_text(json.dumps(out, indent=1) + "\n")
    return BENCH_WAN_PATH


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, wan20 only, no file written")
    args = ap.parse_args()
    report = run(smoke=args.smoke)
    for r in report["rows"]:
        print(f"{r['name']}: rounds={r['rounds']} "
              f"latency={r['latency_ms']:.0f}ms "
              f"analytic_comm={r['analytic_comm_s']:.3f}s "
              f"measured={r['measured_s']:.3f}s "
              f"wan_extra={r['wan_extra_s']:.3f}s")
    if not args.smoke:
        print(f"# wrote {write_report(report)}")


if __name__ == "__main__":
    main()
