"""Serving bench: p50/p99 request latency and throughput of the
continuous-batching secure scoring service vs micro-batch size x party
count x crypto backend.

Every row scores the SAME request stream through `VFLScoringEngine`
(admission -> per-version serving caches -> `infer.wx_share` shares ->
inverse link at C), varying only the batch-close size; the guard rows
assert that batching pays: throughput at the largest batch must be at
least that of singleton batches.  Full mode adds one socket row (real
party processes over TCP) and records the wire invariant measured ==
analytic bytes for the `infer.wx_share` tag.

  PYTHONPATH=src python -m benchmarks.run --only serve [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.runtime import VFLScheduler
from repro.serve import VFLScoringEngine

#: (k, he_backend) grid; smoke keeps one mock row so CI proves the
#: path end-to-end without paying Paillier
GRID_FULL = [(2, "mock"), (3, "mock"), (4, "mock"), (3, "paillier")]
GRID_SMOKE = [(3, "mock")]
BATCHES_FULL = (1, 8, 32)
BATCHES_SMOKE = (1, 8)


def _setup(k: int, backend: str, n: int = 256):
    X, y = synthetic.credit_default(n=n, d=8, seed=17)
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=128,
                    he_backend=backend,
                    key_bits=256 if backend == "paillier" else 1024,
                    tol=0.0, seed=7)
    return parties, y, cfg, names, parts


def _requests(names, parts, n_req):
    return [{nm: part[i % part.shape[0]]
             for nm, part in zip(names, parts)} for i in range(n_req)]


def _drive(eng, reqs, batch):
    """Submit in waves of `batch` and close each wave as one micro-batch
    — per-request latency is submit->scored against the engine's own
    clock, throughput is the wall clock over the whole stream."""
    t0 = time.perf_counter()
    for i in range(0, len(reqs), batch):
        for r in reqs[i:i + batch]:
            eng.submit(r)
        while eng.batcher.pending:
            eng.step(flush=True)
    wall = time.perf_counter() - t0
    lat = eng.latencies()
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "throughput_rps": len(reqs) / wall}


def _row(name, stats, k, backend, batch, mode, n_req, guard_vs=None,
         **extra):
    r = {"name": name, "k": k, "backend": backend, "batch": batch,
         "mode": mode, "n_req": n_req,
         "p50_ms": round(stats["p50_ms"], 4),
         "p99_ms": round(stats["p99_ms"], 4),
         "throughput_rps": round(stats["throughput_rps"], 1),
         "guard_vs": guard_vs,
         "us": stats["p50_ms"] * 1e3,
         "derived": (f"p50_ms={stats['p50_ms']:.3f};"
                     f"p99_ms={stats['p99_ms']:.3f};"
                     f"rps={stats['throughput_rps']:.0f}")}
    r.update(extra)
    return r


def run(smoke: bool = False) -> list[dict]:
    grid = GRID_SMOKE if smoke else GRID_FULL
    batches = BATCHES_SMOKE if smoke else BATCHES_FULL
    n_req = 32 if smoke else 96
    rows = []
    for k, backend in grid:
        parties, y, cfg, names, parts = _setup(k, backend)
        sched = VFLScheduler(parties, y, cfg)
        sched.run()
        reqs = _requests(names, parts, n_req)
        base = f"serve.inproc.k{k}.{backend}"
        for b in batches:
            eng = VFLScoringEngine(sched.parties, max_batch=b)
            stats = _drive(eng, reqs, b)
            guard = f"{base}.b{batches[0]}" if b == batches[-1] else None
            rows.append(_row(f"{base}.b{b}", stats, k, backend, b,
                             "inproc", n_req, guard_vs=guard))
    if not smoke:
        rows.append(_socket_row(n_req=48, batch=16))
    return rows


def _socket_row(n_req: int, batch: int) -> dict:
    """One distributed row: real party processes over TCP, plus the wire
    invariant (measured frame bytes == analytic meter) for the serving
    tag — the same per-tag identity training asserts."""
    from repro.launch.cluster import SocketCluster
    k, backend = 3, "mock"
    parties, y, cfg, names, parts = _setup(k, backend)
    with SocketCluster(parties, y, cfg) as cl:
        cl.train()
        eng = VFLScoringEngine(cluster=cl, max_batch=batch)
        stats = _drive(eng, _requests(names, parts, n_req), batch)
        meters = cl.fetch_meters()
    analytic = meters["meter"].by_tag["infer.wx_share"]
    measured = meters["measured"].by_tag["infer.wx_share"]
    return _row(f"serve.socket.k{k}.{backend}.b{batch}", stats, k,
                backend, batch, "socket", n_req,
                wx_bytes_analytic=int(analytic),
                wx_bytes_measured=int(measured),
                wire_ok=bool(analytic == measured
                             == n_req * (k - 1) * 8))


def check_guards(rows: list[dict]) -> list[str]:
    """Guard rows: the largest batch's throughput must not fall below
    singleton batching (batching must amortize, or the admission
    controller is broken); socket rows must hold the wire identity."""
    by_name = {r["name"]: r for r in rows}
    failures = []
    for r in rows:
        ref = r.get("guard_vs")
        if ref:
            other = by_name.get(ref)
            if other is None:
                failures.append(f"{r['name']}: guard target {ref} missing")
            elif r["throughput_rps"] < other["throughput_rps"]:
                failures.append(
                    f"{r['name']}: {r['throughput_rps']} rps < "
                    f"{other['throughput_rps']} rps ({ref}) — batching "
                    "no longer amortizes")
        if "wire_ok" in r and not r["wire_ok"]:
            failures.append(
                f"{r['name']}: measured infer.wx_share bytes "
                f"{r['wx_bytes_measured']} != analytic "
                f"{r['wx_bytes_analytic']}")
    return failures


if __name__ == "__main__":
    for row in run(smoke=True):
        print(row)
