"""Paper Table 1 — LR on the credit-default task: four frameworks
(TP-LR, SS-LR, SS-HE-LR, EFMVFL-LR) × {auc, ks, comm, runtime}.

Default profile is reduced for the CPU container (n=6000, 12 iters);
``--paper`` runs the full 30k×24, 30-iteration configuration.  Paper
reference (1024-bit keys, 16-core Xeon, 1 Gbps):
    TP-LR     0.712 / 0.371 / 14.20 MB / 34.79 s
    SS-LR     0.719 / 0.363 / 181.8 MB / 71.05 s
    SS-HE-LR  0.702 / 0.367 / 85.30 MB / 37.6 s
    EFMVFL-LR 0.712 / 0.372 / 26.45 MB / 23.29 s
"""
from __future__ import annotations

from repro.baselines import ss_glm, ss_he_lr, tp_glm
from repro.core import metrics, trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical

PAPER_REF = {
    "TP-LR": (0.712, 0.371, 14.20, 34.79),
    "SS-LR": (0.719, 0.363, 181.8, 71.05),
    "SS-HE-LR": (0.702, 0.367, 85.30, 37.6),
    "EFMVFL-LR": (0.712, 0.372, 26.45, 23.29),
}


def run(paper_scale: bool = False) -> list[dict]:
    n = 30000 if paper_scale else 6000
    iters = 30 if paper_scale else 12
    X, y = synthetic.credit_default(n=n, d=24, seed=0)
    (Xtr, ytr), (Xte, yte) = synthetic.train_test_split(X, y, 0.7)
    parts = vertical.split_columns(Xtr, 2)
    parties = [PartyData("C", parts[0]), PartyData("B1", parts[1])]
    te_parts = vertical.split_columns(Xte, 2)
    te_parties = [PartyData("C", te_parts[0]), PartyData("B1", te_parts[1])]
    cfg = VFLConfig(glm="logistic", lr=0.15, max_iter=iters,
                    batch_size=2048, he_backend="mock", key_bits=1024,
                    tol=1e-4, seed=0)

    rows = []
    for name, fn in [("TP-LR", tp_glm.train_tp),
                     ("SS-LR", ss_glm.train_ss),
                     ("SS-HE-LR", ss_he_lr.train_ss_he),
                     ("EFMVFL-LR", trainer.train_vfl)]:
        res = fn(parties, ytr, cfg)
        wx = res.predict_wx(te_parties)
        rows.append({
            "framework": name,
            "auc": round(metrics.auc(yte, wx), 3),
            "ks": round(metrics.ks(yte, wx), 3),
            "comm_mb": round(res.meter.total_mb, 2),
            "runtime_s": round(res.runtime_s, 2),
            "iters": res.n_iter,
            "paper_ref": PAPER_REF[name],
        })
    return rows
