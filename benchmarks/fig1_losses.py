"""Paper Figure 1 — training loss curves: EFMVFL vs third-party baselines
(the curves should be nearly identical; TP-LR differs by its Taylor loss).
Emits CSV rows: iter, efmvfl_lr, tp_lr, efmvfl_pr, tp_pr."""
from __future__ import annotations

from repro.baselines import tp_glm
from repro.core import trainer
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical


def run(iters: int = 15) -> dict:
    out = {}
    for glm, make_data, lr in [("logistic", synthetic.credit_default, 0.15),
                               ("poisson", synthetic.dvisits, 0.1)]:
        X, y = make_data(n=4000, seed=2)
        parts = vertical.split_columns(X, 2)
        parties = [PartyData("C", parts[0]), PartyData("B1", parts[1])]
        cfg = VFLConfig(glm=glm, lr=lr, max_iter=iters, batch_size=512,
                        he_backend="mock", tol=0.0, seed=3)
        fed = trainer.train_vfl(parties, y, cfg)
        tp = tp_glm.train_tp(parties, y, cfg)
        cent = trainer.train_centralized(X, y, cfg)[1]
        out[glm] = {"efmvfl": fed.losses, "tp": tp.losses,
                    "centralized": cent}
    return out
