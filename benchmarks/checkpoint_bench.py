"""Checkpoint bench: TrainState save/restore latency and on-disk bytes
vs party count and key size.

Measures the resumable-session hot path (`runtime/session.py` +
`checkpoint/manager.py`): capture a live `VFLScheduler` TrainState after
one iteration, then time

  * `save`    — serialize + fsync + atomic rename + manifest (durable),
  * `restore` — manifest parse + sha256 verify + npz load + rebuild,

and record the archive + manifest bytes.  Rows sweep k ∈ {2,4,8} (mock
backend — state size is key-independent there) and key size for the
wire-relevant sizes (state size is key-INdependent by design: no
ciphertext, share, or key material is ever checkpointed — the row pair
proves it).  `benchmarks.run --only checkpoint` prints CSV rows and
(full mode) writes `BENCH_checkpoint.json`; `--smoke` runs tiny shapes
in CI.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.trainer import PartyData, VFLConfig
from repro.data import synthetic, vertical
from repro.runtime import VFLScheduler
from repro.runtime import session
from repro.runtime.session import TrainState


def _time(fn, reps: int) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _disk_bytes(directory: str, step: int) -> int:
    return sum(os.path.getsize(os.path.join(directory, f"step_{step}{ext}"))
               for ext in (".npz", ".json"))


def _state_for(k: int, key_bits: int, he: str, n: int, batch: int,
               iters: int) -> tuple[TrainState, list[str], VFLConfig]:
    X, y = synthetic.credit_default(n=n, d=4 * k, seed=3)
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=iters,
                    batch_size=batch, he_backend=he, key_bits=key_bits,
                    tol=0.0, seed=7)
    sched = VFLScheduler(parties, y, cfg)
    state = sched.init_state()
    for _ in range(iters):
        state = sched.step(state)
    return state, names, cfg


def run(smoke: bool = False) -> list[dict]:
    n = 96 if smoke else 512
    batch = 32 if smoke else 128
    iters = 1 if smoke else 2
    reps = 2 if smoke else 10
    ks = (2, 4) if smoke else (2, 4, 8)
    key_sweeps = ((2, 192),) if smoke else ((2, 192), (2, 512), (2, 1024))
    rows: list[dict] = []

    def bench(state: TrainState, names: list[str], cfg: VFLConfig,
              label: str) -> None:
        tree, extra = state.to_checkpoint()
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=reps + 2,
                                    config_hash=session.config_hash(cfg),
                                    codec_version=session.CODEC_VERSION)
            step_box = [0]

            def save():
                step_box[0] += 1
                mgr.save(step_box[0], tree, extra)

            save_us = _time(save, reps)
            nbytes = _disk_bytes(d, step_box[0])
            template = TrainState.tree_template(names)

            def restore():
                got = mgr.restore(template)
                assert got is not None
                TrainState.from_checkpoint(got[1], got[2])

            restore_us = _time(restore, reps)
        rows.append({
            "name": f"checkpoint.{label}",
            "us": round(save_us, 1),
            "save_us": round(save_us, 1),
            "restore_us": round(restore_us, 1),
            "bytes_on_disk": nbytes,
            "parties": len(names),
            "key_bits": cfg.key_bits,
            "he_backend": cfg.he_backend,
            "reps": reps,
            "derived": f"restore_us={restore_us:.1f};bytes={nbytes};"
                       f"k={len(names)};key_bits={cfg.key_bits}",
        })

    for k in ks:                                   # state size vs k
        state, names, cfg = _state_for(k, 256, "mock", n, batch, iters)
        bench(state, names, cfg, f"mock.k{k}")
    for k, kb in key_sweeps:                       # state size vs key size
        # mock backend at varying key_bits: proves the checkpoint carries
        # no ciphertext/key material (bytes must NOT scale with the key)
        state, names, cfg = _state_for(k, kb, "mock", n, batch, iters)
        bench(state, names, cfg, f"mock.k{k}.kb{kb}")
    if not smoke:                                  # real-backend reference
        state, names, cfg = _state_for(2, 192, "paillier", 128, 32, 1)
        bench(state, names, cfg, "paillier.k2.kb192")
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write BENCH_checkpoint.json here (full mode)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    if args.out and not args.smoke:
        import jax
        report = {
            "schema": "bench_checkpoint/v1",
            "jax": jax.__version__,
            "rows": [{k: v for k, v in r.items() if k != "derived"}
                     for r in rows],
        }
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
