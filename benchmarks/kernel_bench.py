"""Crypto kernel microbenchmarks (CPU wall-clock; the Pallas path runs in
interpret mode here — on TPU it is the deployment path).

Rows are dicts {name, us, derived, montmuls?, backend} so `run.py` can
emit both the CSV lines and the machine-readable ``BENCH_crypto.json``
perf-trajectory file.  The library-vs-engine pairs (`montmul`,
`mont_exp`, `he_matvec`) are the acceptance gauge for the fused kernels:
`mont_exp_fused` must beat the per-step `ops.mont_exp_bits` ladder
(2×nbits separate pallas_calls) by ≥2× at batch ≥128.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint, paillier, ring
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import Modulus
from repro.kernels import ops

RNG = np.random.default_rng(7)


def _time(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6    # µs


def _row(name: str, us: float, derived: str = "", *,
         backend: str = "jnp", montmuls: int | None = None) -> dict:
    r = {"name": name, "us": us, "derived": derived, "backend": backend}
    if montmuls is not None:
        r["montmuls"] = montmuls
    return r


def run(smoke: bool = False) -> list[dict]:
    """smoke=True shrinks every size so CI can run this as a drift check
    in seconds; full mode is the perf-trajectory measurement."""
    rows = []
    mod_bits = (256,) if smoke else (256, 1024)
    batch = 64 if smoke else 256
    # --- Montgomery product: library vs Pallas(interpret) ----------------
    for bits in mod_bits:
        n = (1 << bits) - 159
        mod = Modulus.make(n)
        vals = RNG.integers(0, 1 << 62, size=batch).astype(object)
        A = jnp.asarray(bigint.ints_to_limbs([int(v) % n for v in vals],
                                             mod.L))
        jit_lib = jax.jit(lambda a, b: bigint.mont_mul(a, b, mod))
        us = _time(jit_lib, A, A)
        rows.append(_row(f"montmul_lib_{bits}b_x{batch}", us,
                         f"{batch/us:.2f}mul_per_us", montmuls=batch))
        us = _time(lambda a, b: ops.montmul(a, b, mod, interpret=True), A, A)
        rows.append(_row(f"montmul_pallas_interp_{bits}b_x{batch}", us,
                         f"{batch/us:.2f}mul_per_us",
                         backend="pallas-interpret", montmuls=batch))

    # --- mont_exp: per-step kernel ladder vs fused single pallas_call ----
    # (the tentpole acceptance row: fused ≥2× at batch ≥128)
    exp_mod = Modulus.make((1 << 256) - 159)
    exp_batch = 128
    exp_bits_n = 8 if smoke else 16
    base_ints = [int.from_bytes(RNG.bytes(30), "little") % exp_mod.value
                 for _ in range(exp_batch)]
    Bm = bigint.to_mont(
        jnp.asarray(bigint.ints_to_limbs(base_ints, exp_mod.L)), exp_mod)
    ebits = jnp.asarray(np.stack(
        [bigint.int_to_bits(int(e), exp_bits_n)
         for e in RNG.integers(0, 1 << exp_bits_n, size=exp_batch)]))
    exp_mm = 2 * exp_bits_n * exp_batch
    us_lib = _time(jax.jit(lambda b, e: bigint.mont_exp_bits(b, e, exp_mod)),
                   Bm, ebits)
    rows.append(_row(f"mont_exp_lib_256b_x{exp_batch}_e{exp_bits_n}", us_lib,
                     "", montmuls=exp_mm))
    us_step = _time(lambda b, e: ops.mont_exp_bits(b, e, exp_mod,
                                                   interpret=True), Bm, ebits)
    rows.append(_row(f"mont_exp_perstep_256b_x{exp_batch}_e{exp_bits_n}",
                     us_step, f"pallas_calls={2*exp_bits_n}",
                     backend="pallas-interpret", montmuls=exp_mm))
    us_fused = _time(lambda b, e: ops.mont_exp_fused(b, e, exp_mod,
                                                     interpret=True),
                     Bm, ebits)
    rows.append(_row(f"mont_exp_fused_256b_x{exp_batch}_e{exp_bits_n}",
                     us_fused,
                     f"pallas_calls=1;speedup_vs_perstep={us_step/us_fused:.2f}x",
                     backend="pallas-interpret", montmuls=exp_mm))

    # --- Paillier primitive ops ------------------------------------------
    key = paillier.keygen(128 if smoke else 256, seed=1)
    pub = key.pub
    kb = pub.key_bits
    enc_batch = 16 if smoke else 64
    m = paillier.encode_ints(pub, [123456] * enc_batch)
    rng = np.random.default_rng(2)
    noise = paillier.noise_to_mont(pub, paillier.raw_noise(pub, enc_batch,
                                                           rng))
    us = _time(jax.jit(lambda mm: paillier.encrypt_with_noise(
        pub, mm, noise)), m)
    rows.append(_row(f"paillier_enc_precomp_noise_x{enc_batch}_{kb}b", us))
    c = paillier.encrypt_with_noise(pub, m, noise)
    us = _time(jax.jit(lambda cc: paillier.decrypt(key, cc)), c)
    rows.append(_row(f"paillier_dec_x{enc_batch}_{kb}b", us))
    us_crt = _time(jax.jit(lambda cc: paillier.decrypt_crt(key, cc)), c)
    rows.append(_row(f"paillier_dec_crt_x{enc_batch}_{kb}b", us_crt,
                     f"speedup={us/us_crt:.2f}x"))
    us = _time(jax.jit(lambda cc: paillier.add_ct(pub, cc, cc)), c)
    rows.append(_row(f"paillier_hom_add_x{enc_batch}_{kb}b", us,
                     montmuls=enc_batch))

    # --- HE matvec (Protocol 3 hot path): library vs fused engine --------
    from repro.core import protocols
    mv_m = 4 if smoke else 8
    width = 22
    window = protocols.DEFAULT_WINDOW
    exps = jnp.asarray(RNG.integers(0, 1 << width,
                                    size=(enc_batch, mv_m),
                                    dtype=np.uint32))
    levels = -(-width // window)
    mv_mm = (enc_batch * ((1 << window) - 2)
             + levels * (enc_batch * mv_m + (window + 1) * mv_m))
    if not smoke:
        us_b = _time(lambda cc, ee: protocols.he_matvec(
            pub, cc, ee, width, window=1), c, exps)
        rows.append(_row(f"he_matvec_bitserial_{enc_batch}x{mv_m}_w{width}_{kb}b",
                         us_b, f"{enc_batch*mv_m/us_b:.3f}cells_per_us",
                         montmuls=width * (enc_batch * mv_m + 2 * mv_m)))
    us_w = _time(lambda cc, ee: protocols.he_matvec(
        pub, cc, ee, width, window=window), c, exps)
    rows.append(_row(f"he_matvec_lib_window{window}_{enc_batch}x{mv_m}"
                     f"_w{width}_{kb}b", us_w,
                     f"{enc_batch*mv_m/us_w:.3f}cells_per_us",
                     montmuls=mv_mm))
    eng = engine_mod.CryptoEngine(backend="pallas-interpret")
    us_e = _time(lambda cc, ee: protocols.he_matvec(
        pub, cc, ee, width, window=window, engine=eng), c, exps)
    rows.append(_row(f"he_matvec_fused_window{window}_{enc_batch}x{mv_m}"
                     f"_w{width}_{kb}b", us_e,
                     f"pallas_calls=1;lib_vs_fused={us_w/us_e:.2f}x",
                     backend="pallas-interpret", montmuls=mv_mm))

    # --- ring64 matmul: jnp reference vs Pallas(interpret) ---------------
    M, K, N = (32, 64, 16) if smoke else (128, 256, 64)
    a = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (M, K), dtype=np.uint64))
    b = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (K, N), dtype=np.uint64))
    us = _time(lambda x, y: ops.ring_matmul(x, y, tm=min(M, 64),
                                            tn=min(N, 64)), a, b)
    rows.append(_row(f"ring64_matmul_pallas_{M}x{K}x{N}", us,
                     f"{2*M*K*N/us/1e6:.2f}Gmac_per_s",
                     backend="pallas-interpret"))
    return rows
