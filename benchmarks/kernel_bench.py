"""Crypto kernel microbenchmarks (CPU wall-clock; the Pallas path runs in
interpret mode here — on TPU it is the deployment path)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint, paillier, ring
from repro.crypto.bigint import Modulus
from repro.kernels import ops

RNG = np.random.default_rng(7)


def _time(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6    # µs


def run() -> list[tuple[str, float, str]]:
    rows = []
    # --- Montgomery product: library vs Pallas(interpret) ----------------
    for bits in (256, 1024):
        n = (1 << bits) - 159
        mod = Modulus.make(n)
        batch = 256
        vals = RNG.integers(0, 1 << 62, size=batch).astype(object)
        A = jnp.asarray(bigint.ints_to_limbs([int(v) % n for v in vals],
                                             mod.L))
        jit_lib = jax.jit(lambda a, b: bigint.mont_mul(a, b, mod))
        us = _time(jit_lib, A, A)
        rows.append((f"montmul_lib_{bits}b_x{batch}", us,
                     f"{batch/us:.2f}mul_per_us"))
        us = _time(lambda a, b: ops.montmul(a, b, mod, interpret=True), A, A)
        rows.append((f"montmul_pallas_interp_{bits}b_x{batch}", us,
                     f"{batch/us:.2f}mul_per_us"))

    # --- Paillier primitive ops ------------------------------------------
    key = paillier.keygen(256, seed=1)
    pub = key.pub
    m = paillier.encode_ints(pub, [123456] * 64)
    rng = np.random.default_rng(2)
    noise = paillier.noise_to_mont(pub, paillier.raw_noise(pub, 64, rng))
    us = _time(jax.jit(lambda mm: paillier.encrypt_with_noise(
        pub, mm, noise)), m)
    rows.append(("paillier_enc_precomp_noise_x64_256b", us, ""))
    c = paillier.encrypt_with_noise(pub, m, noise)
    us = _time(jax.jit(lambda cc: paillier.decrypt(key, cc)), c)
    rows.append(("paillier_dec_x64_256b", us, ""))
    us_crt = _time(jax.jit(lambda cc: paillier.decrypt_crt(key, cc)), c)
    rows.append(("paillier_dec_crt_x64_256b", us_crt,
                 f"speedup={us/us_crt:.2f}x"))
    us = _time(jax.jit(lambda cc: paillier.add_ct(pub, cc, cc)), c)
    rows.append(("paillier_hom_add_x64_256b", us, ""))

    # --- HE matvec (Protocol 3 hot path): bit-serial vs windowed ---------
    from repro.core import protocols
    exps = jnp.asarray(RNG.integers(0, 1 << 22, size=(64, 8),
                                    dtype=np.uint32))
    us_b = _time(lambda cc, ee: protocols.he_matvec(pub, cc, ee, 22,
                                                    window=1), c, exps)
    rows.append(("he_matvec_bitserial_64x8_w22_256b", us_b,
                 f"{64*8/us_b:.3f}cells_per_us"))
    us_w = _time(lambda cc, ee: protocols.he_matvec(pub, cc, ee, 22,
                                                    window=4), c, exps)
    rows.append(("he_matvec_window4_64x8_w22_256b", us_w,
                 f"{64*8/us_w:.3f}cells_per_us;speedup={us_b/us_w:.2f}x"))

    # --- ring64 matmul: jnp reference vs Pallas(interpret) ---------------
    M, K, N = 128, 256, 64
    a = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (M, K), dtype=np.uint64))
    b = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (K, N), dtype=np.uint64))
    us = _time(lambda x, y: ops.ring_matmul(x, y, tm=64, tn=64), a, b)
    rows.append((f"ring64_matmul_pallas_{M}x{K}x{N}", us,
                 f"{2*M*K*N/us/1e6:.2f}Gmac_per_s"))
    return rows
