"""Crypto kernel microbenchmarks (CPU wall-clock; the Pallas path runs in
interpret mode here — on TPU it is the deployment path).

Rows are dicts {name, us, derived, montmuls?, backend} so `run.py` can
emit both the CSV lines and the machine-readable ``BENCH_crypto.json``
perf-trajectory file.  The library-vs-engine pairs (`montmul`,
`mont_exp`, `he_matvec`) are the acceptance gauge for the fused kernels:
`mont_exp_fused` must beat the per-step `ops.mont_exp_bits` ladder
(2×nbits separate pallas_calls) by ≥2× at batch ≥128.

Guard rows: every ``*_engine_auto_*`` row carries ``guard_vs`` naming
its library counterpart plus ``guard_max_ratio`` — `check_guards`
asserts engine-routed interpret mode never regresses below the library
at any committed size (small moduli route to the library, large ones to
the RNS pipeline which WINS there; docs/engine.md §amortization).  The
``fixed_base`` guard additionally encodes the ≥10× table-vs-ladder
acceptance bound.  `run.py --guards` re-checks the committed
BENCH_crypto.json; the smoke run in scripts/ci.sh checks fresh numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint, paillier, ring, rns
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import Modulus
from repro.kernels import ops

RNG = np.random.default_rng(7)

# engine-routed interpret mode may not exceed library µs by more than
# this factor (CPU wall-clock jitter allowance)
GUARD_TOLERANCE = 1.15


def check_guards(rows: list[dict]) -> list[str]:
    """Validate every guard-carrying row against its library reference.
    Returns a list of human-readable failures (empty == all pass)."""
    by_name = {r["name"]: r for r in rows}
    failures = []
    for r in rows:
        ref_name = r.get("guard_vs")
        if not ref_name:
            continue
        ref = by_name.get(ref_name)
        if ref is None:
            failures.append(f"{r['name']}: guard reference {ref_name!r} "
                            "missing from the row set")
            continue
        limit = float(r.get("guard_max_ratio", GUARD_TOLERANCE))
        ratio = r["us"] / ref["us"]
        if ratio > limit:
            failures.append(
                f"{r['name']}: {r['us']:.0f}us is {ratio:.2f}x the library "
                f"row {ref_name} ({ref['us']:.0f}us); limit {limit:.2f}x")
    return failures


def _time(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6    # µs


def _row(name: str, us: float, derived: str = "", *,
         backend: str = "jnp", montmuls: int | None = None) -> dict:
    r = {"name": name, "us": us, "derived": derived, "backend": backend}
    if montmuls is not None:
        r["montmuls"] = montmuls
    return r


def run(smoke: bool = False) -> list[dict]:
    """smoke=True shrinks every size so CI can run this as a drift check
    in seconds; full mode is the perf-trajectory measurement."""
    rows = []
    mod_bits = (256,) if smoke else (256, 1024)
    batch = 64 if smoke else 256
    # --- Montgomery product: library vs CIOS kernel vs RNS pipeline ------
    eng_auto = engine_mod.CryptoEngine(backend="pallas-interpret",
                                       pipeline="auto")
    for bits in mod_bits:
        n = (1 << bits) - 159
        mod = Modulus.make(n)
        vals = RNG.integers(0, 1 << 62, size=batch).astype(object)
        A = jnp.asarray(bigint.ints_to_limbs([int(v) % n for v in vals],
                                             mod.L))
        jit_lib = jax.jit(lambda a, b: bigint.mont_mul(a, b, mod))
        lib_name = f"montmul_lib_{bits}b_x{batch}"
        us_lib = _time(jit_lib, A, A)
        rows.append(_row(lib_name, us_lib,
                         f"{batch/us_lib:.2f}mul_per_us", montmuls=batch))
        # full-batch tile: one grid program (interpret overhead is per
        # program, so the honest interpret tiling is the biggest tile)
        us = _time(lambda a, b: ops.montmul(a, b, mod, tile_b=batch,
                                            interpret=True), A, A)
        rows.append(_row(f"montmul_pallas_interp_{bits}b_x{batch}", us,
                         f"{batch/us:.2f}mul_per_us",
                         backend="pallas-interpret", montmuls=batch))
        ctx = rns.for_modulus(mod)
        us = _time(lambda a, b: rns.mont_mul(ctx, a, b), A, A)
        rows.append(_row(f"montmul_rns_jnp_{bits}b_x{batch}", us,
                         f"{batch/us:.2f}mul_per_us;lib_vs_rns="
                         f"{us_lib/us:.2f}x", montmuls=batch))
        us = _time(lambda a, b: ops.rns_montmul(a, b, mod, tile_b=batch,
                                                interpret=True), A, A)
        rows.append(_row(f"montmul_rns_interp_{bits}b_x{batch}", us,
                         f"{batch/us:.2f}mul_per_us;lib_vs_rns="
                         f"{us_lib/us:.2f}x",
                         backend="pallas-interpret", montmuls=batch))
        # engine-routed (auto pipeline): the never-slower-than-library row
        # (jitted like the lib row — engine calls sit inside jitted
        # protocol legs in training)
        us = _time(jax.jit(lambda a, b: eng_auto.mont_mul(a, b, mod)), A, A)
        guard = _row(f"montmul_engine_auto_{bits}b_x{batch}", us,
                     f"route={eng_auto._route(mod)};lib_vs_engine="
                     f"{us_lib/us:.2f}x",
                     backend="pallas-interpret", montmuls=batch)
        guard["guard_vs"] = lib_name
        rows.append(guard)

    # --- mont_exp: per-step kernel ladder vs fused single pallas_call ----
    # (the tentpole acceptance row: fused ≥2× at batch ≥128)
    exp_mod = Modulus.make((1 << 256) - 159)
    exp_batch = 128
    exp_bits_n = 8 if smoke else 16
    base_ints = [int.from_bytes(RNG.bytes(30), "little") % exp_mod.value
                 for _ in range(exp_batch)]
    Bm = bigint.to_mont(
        jnp.asarray(bigint.ints_to_limbs(base_ints, exp_mod.L)), exp_mod)
    ebits = jnp.asarray(np.stack(
        [bigint.int_to_bits(int(e), exp_bits_n)
         for e in RNG.integers(0, 1 << exp_bits_n, size=exp_batch)]))
    exp_mm = 2 * exp_bits_n * exp_batch
    us_lib = _time(jax.jit(lambda b, e: bigint.mont_exp_bits(b, e, exp_mod)),
                   Bm, ebits)
    rows.append(_row(f"mont_exp_lib_256b_x{exp_batch}_e{exp_bits_n}", us_lib,
                     "", montmuls=exp_mm))
    us_step = _time(lambda b, e: ops.mont_exp_bits(b, e, exp_mod,
                                                   interpret=True), Bm, ebits)
    rows.append(_row(f"mont_exp_perstep_256b_x{exp_batch}_e{exp_bits_n}",
                     us_step, f"pallas_calls={2*exp_bits_n}",
                     backend="pallas-interpret", montmuls=exp_mm))
    us_fused = _time(lambda b, e: ops.mont_exp_fused(b, e, exp_mod,
                                                     interpret=True),
                     Bm, ebits)
    rows.append(_row(f"mont_exp_fused_256b_x{exp_batch}_e{exp_bits_n}",
                     us_fused,
                     f"pallas_calls=1;speedup_vs_perstep={us_step/us_fused:.2f}x",
                     backend="pallas-interpret", montmuls=exp_mm))
    guard = _row(f"mont_exp_engine_auto_256b_x{exp_batch}_e{exp_bits_n}",
                 _time(jax.jit(lambda b, e: eng_auto.mont_exp_bits(
                     b, e, exp_mod)), Bm, ebits),
                 f"route={eng_auto._route(exp_mod)}",
                 backend="pallas-interpret", montmuls=exp_mm)
    guard["guard_vs"] = f"mont_exp_lib_256b_x{exp_batch}_e{exp_bits_n}"
    rows.append(guard)

    # --- mont_exp at the paper's 1024-bit ciphertext modulus: the RNS
    # pipeline is where the fused ladder finally beats the library ------
    if not smoke:
        big_mod = Modulus.make((1 << 1024) - 105)
        big_batch, big_eb = 64, 16
        base_ints = [int.from_bytes(RNG.bytes(127), "little")
                     % big_mod.value for _ in range(big_batch)]
        Bb = bigint.to_mont(
            jnp.asarray(bigint.ints_to_limbs(base_ints, big_mod.L)),
            big_mod)
        eb_big = jnp.asarray(np.stack(
            [bigint.int_to_bits(int(e), big_eb)
             for e in RNG.integers(0, 1 << big_eb, size=big_batch)]))
        big_mm = 2 * big_eb * big_batch
        us_lib = _time(jax.jit(lambda b, e: bigint.mont_exp_bits(
            b, e, big_mod)), Bb, eb_big)
        rows.append(_row(f"mont_exp_lib_1024b_x{big_batch}_e{big_eb}",
                         us_lib, "", montmuls=big_mm))
        us_rns = _time(lambda b, e: ops.rns_mont_exp_fused(
            b, e, big_mod, interpret=True), Bb, eb_big)
        rows.append(_row(f"mont_exp_rns_interp_1024b_x{big_batch}_e{big_eb}",
                         us_rns, f"lib_vs_rns={us_lib/us_rns:.2f}x",
                         backend="pallas-interpret", montmuls=big_mm))
        guard = _row(f"mont_exp_engine_auto_1024b_x{big_batch}_e{big_eb}",
                     _time(jax.jit(lambda b, e: eng_auto.mont_exp_bits(
                         b, e, big_mod)), Bb, eb_big),
                     f"route={eng_auto._route(big_mod)}",
                     backend="pallas-interpret", montmuls=big_mm)
        guard["guard_vs"] = f"mont_exp_lib_1024b_x{big_batch}_e{big_eb}"
        rows.append(guard)

    # --- Paillier primitive ops ------------------------------------------
    key = paillier.keygen(128 if smoke else 256, seed=1)
    pub = key.pub
    kb = pub.key_bits
    enc_batch = 16 if smoke else 64
    m = paillier.encode_ints(pub, [123456] * enc_batch)
    rng = np.random.default_rng(2)
    noise = paillier.noise_to_mont(pub, paillier.raw_noise(pub, enc_batch,
                                                           rng))
    us = _time(jax.jit(lambda mm: paillier.encrypt_with_noise(
        pub, mm, noise)), m)
    rows.append(_row(f"paillier_enc_precomp_noise_x{enc_batch}_{kb}b", us))
    c = paillier.encrypt_with_noise(pub, m, noise)
    us = _time(jax.jit(lambda cc: paillier.decrypt(key, cc)), c)
    rows.append(_row(f"paillier_dec_x{enc_batch}_{kb}b", us))
    us_crt = _time(jax.jit(lambda cc: paillier.decrypt_crt(key, cc)), c)
    rows.append(_row(f"paillier_dec_crt_x{enc_batch}_{kb}b", us_crt,
                     f"speedup={us/us_crt:.2f}x"))
    us = _time(jax.jit(lambda cc: paillier.add_ct(pub, cc, cc)), c)
    rows.append(_row(f"paillier_hom_add_x{enc_batch}_{kb}b", us,
                     montmuls=enc_batch))

    # --- HE matvec (Protocol 3 hot path): library vs fused engine --------
    from repro.core import protocols
    mv_m = 4 if smoke else 8
    width = 22
    window = protocols.DEFAULT_WINDOW
    exps = jnp.asarray(RNG.integers(0, 1 << width,
                                    size=(enc_batch, mv_m),
                                    dtype=np.uint32))
    levels = -(-width // window)
    mv_mm = (enc_batch * ((1 << window) - 2)
             + levels * (enc_batch * mv_m + (window + 1) * mv_m))
    if not smoke:
        us_b = _time(lambda cc, ee: protocols.he_matvec(
            pub, cc, ee, width, window=1), c, exps)
        rows.append(_row(f"he_matvec_bitserial_{enc_batch}x{mv_m}_w{width}_{kb}b",
                         us_b, f"{enc_batch*mv_m/us_b:.3f}cells_per_us",
                         montmuls=width * (enc_batch * mv_m + 2 * mv_m)))
    # digits precomputed once, as the trainer's EncodedFeatures does —
    # every windowed row then measures one dispatch into its (jitted)
    # ladder instead of a per-call eager digit decomposition
    dig = jnp.asarray(protocols.window_digits(np.asarray(exps), width,
                                              window))
    us_w = _time(lambda cc, dd: protocols.he_matvec(
        pub, cc, exps, width, window=window, digits=dd), c, dig)
    rows.append(_row(f"he_matvec_lib_window{window}_{enc_batch}x{mv_m}"
                     f"_w{width}_{kb}b", us_w,
                     f"{enc_batch*mv_m/us_w:.3f}cells_per_us",
                     montmuls=mv_mm))
    # guard row measured back-to-back with its reference so the ratio
    # compares like cache/allocator state, not bench-run drift
    guard = _row(f"he_matvec_engine_auto_{enc_batch}x{mv_m}_w{width}_{kb}b",
                 _time(lambda cc, dd: protocols.he_matvec(
                     pub, cc, exps, width, window=window, digits=dd,
                     engine=eng_auto), c, dig),
                 f"route={eng_auto._route(pub.mod_n2)}",
                 backend="pallas-interpret", montmuls=mv_mm)
    guard["guard_vs"] = (f"he_matvec_lib_window{window}_{enc_batch}x{mv_m}"
                         f"_w{width}_{kb}b")
    rows.append(guard)
    eng = engine_mod.CryptoEngine(backend="pallas-interpret",
                                  pipeline="cios")
    us_e = _time(lambda cc, dd: protocols.he_matvec(
        pub, cc, exps, width, window=window, digits=dd, engine=eng), c, dig)
    rows.append(_row(f"he_matvec_fused_window{window}_{enc_batch}x{mv_m}"
                     f"_w{width}_{kb}b", us_e,
                     f"pallas_calls=1;lib_vs_fused={us_w/us_e:.2f}x",
                     backend="pallas-interpret", montmuls=mv_mm))
    eng_r = engine_mod.CryptoEngine(backend="pallas-interpret",
                                    pipeline="rns")
    us_r = _time(lambda cc, dd: protocols.he_matvec(
        pub, cc, exps, width, window=window, digits=dd, engine=eng_r),
        c, dig)
    rows.append(_row(f"he_matvec_rns_window{window}_{enc_batch}x{mv_m}"
                     f"_w{width}_{kb}b", us_r,
                     f"lib_vs_rns={us_w/us_r:.2f}x",
                     backend="pallas-interpret", montmuls=mv_mm))

    # --- fixed-base exponentiation: persistent table vs library ladder ---
    # (the tentpole acceptance row: the encryption-noise modexp h^ρ from
    # a persistent table must beat the r^n library ladder by ≥10× at the
    # paper's 1024-bit ciphertext modulus — guard_max_ratio = 0.1)
    from repro.crypto import fixed_base
    fb_key = paillier.keygen(128 if smoke else 512, seed=3)
    fb_pub = fb_key.pub
    fb_bits = fb_pub.mod_n2.value.bit_length()
    fb_batch = 8 if smoke else 64
    t0 = time.perf_counter()
    table = fixed_base.build_noise_table(fb_pub.n, fb_pub.mod_n2,
                                         rng=np.random.default_rng(4))
    build_us = (time.perf_counter() - t0) * 1e6
    fb_rng = np.random.default_rng(5)
    eng_lib = engine_mod.CryptoEngine(backend="jnp", pipeline="cios")
    raw = paillier.raw_noise(fb_pub, fb_batch, fb_rng)
    lib_name = f"noise_ladder_lib_{fb_bits}b_x{fb_batch}"
    us_nl = _time(jax.jit(lambda rr: paillier.noise_to_mont(
        fb_pub, rr, eng_lib)), jnp.asarray(raw), reps=1)
    rows.append(_row(lib_name, us_nl,
                     f"exp_bits={fb_pub.n.bit_length()}",
                     montmuls=2 * fb_pub.n.bit_length() * fb_batch))
    digits = fixed_base.draw_exponent_digits(table, fb_batch, fb_rng)
    eng_fb = engine_mod.CryptoEngine(backend="pallas-interpret")
    us_fb = _time(lambda dd: paillier.noise_from_table(fb_pub, table, dd,
                                                       eng_fb),
                  jnp.asarray(digits))
    guard = _row(f"fixed_base_table_{fb_bits}b_x{fb_batch}", us_fb,
                 f"window={table.window};levels={table.levels};"
                 f"table_kb={table.nbytes()//1024};"
                 f"build_us={build_us:.0f};"
                 f"speedup_vs_ladder={us_nl/us_fb:.1f}x",
                 backend="pallas-interpret",
                 montmuls=(table.levels + 1) * fb_batch)
    guard["guard_vs"] = lib_name
    # the ≥10× acceptance bound holds at the full 1024-bit measurement;
    # smoke shrinks the modulus to 256 bits, BELOW the RNS amortization
    # threshold (docs/engine.md) where the table walk legitimately loses
    # to the cheap short-limb ladder — there the guard is only a drift
    # tripwire (2×), not a win assertion
    guard["guard_max_ratio"] = 2.0 if smoke else 0.1
    rows.append(guard)

    # --- ring64 matmul: jnp reference vs Pallas(interpret) ---------------
    M, K, N = (32, 64, 16) if smoke else (128, 256, 64)
    a = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (M, K), dtype=np.uint64))
    b = ring.from_numpy_u64(RNG.integers(0, 1 << 64, (K, N), dtype=np.uint64))
    us = _time(lambda x, y: ops.ring_matmul(x, y, tm=min(M, 64),
                                            tn=min(N, 64)), a, b)
    rows.append(_row(f"ring64_matmul_pallas_{M}x{K}x{N}", us,
                     f"{2*M*K*N/us/1e6:.2f}Gmac_per_s",
                     backend="pallas-interpret"))
    return rows
