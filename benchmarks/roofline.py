"""Roofline table from the dry-run JSONs (EXPERIMENTS.md §Roofline feeder).

Reads results/dryrun/*.json, prints per (arch × cell × mesh):
compute/memory/collective seconds, dominant term, MODEL_FLOPS/HLO ratio.
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun import PEAK_FLOPS  # noqa: F401 (doc cross-ref)


def load(dirpath: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def summarize(rows: list[dict]) -> list[dict]:
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append({"arch": r["arch"], "cell": r["cell"],
                        "mesh": r.get("mesh"), "status": "FAIL",
                        "error": r.get("error", "")[:120]})
            continue
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = r["compute_s"] / bound if bound else 0.0
        out.append({
            "arch": r["arch"], "cell": r["cell"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": round(r["compute_s"] * 1e3, 3),
            "memory_ms": round(r["memory_s"] * 1e3, 3),
            "collective_ms": round(r["collective_s"] * 1e3, 3),
            "dominant": dom,
            "roofline_frac": round(frac, 3),
            "useful_flops_ratio": round(r.get("useful_flops_ratio", 0), 3),
            "peak_gib": round(r["peak_bytes_per_dev"] / 2**30, 2),
        })
    return out


def run() -> list[dict]:
    return summarize(load())
