"""Benchmark harness — one entry per paper table/figure plus kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV lines.

The kernels bench additionally writes ``BENCH_crypto.json`` at the repo
root (per-kernel µs, analytic Montgomery-product counts, backend, jax
metadata) — the machine-readable perf trajectory; commit it so speedups
and regressions accumulate in history.

  PYTHONPATH=src python -m benchmarks.run [--paper] [--only table1_lr]
      [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_CRYPTO_PATH = REPO_ROOT / "BENCH_crypto.json"
BENCH_WIRE_PATH = REPO_ROOT / "BENCH_wire.json"
BENCH_CHECKPOINT_PATH = REPO_ROOT / "BENCH_checkpoint.json"
BENCH_WAN_PATH = REPO_ROOT / "BENCH_wan.json"
BENCH_SERVE_PATH = REPO_ROOT / "BENCH_serve.json"


def _csv(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_table1(paper_scale: bool) -> None:
    from benchmarks import table1_lr
    t0 = time.perf_counter()
    rows = table1_lr.run(paper_scale)
    total = (time.perf_counter() - t0) * 1e6
    for r in rows:
        ref = r.pop("paper_ref")
        _csv(f"table1.{r['framework']}", r["runtime_s"] * 1e6,
             f"auc={r['auc']};ks={r['ks']};comm_mb={r['comm_mb']};"
             f"paper_auc={ref[0]};paper_comm_mb={ref[2]}")
    _csv("table1.total", total)


def bench_table2(paper_scale: bool) -> None:
    from benchmarks import table2_pr
    rows = table2_pr.run(paper_scale)
    for r in rows:
        ref = r.pop("paper_ref")
        _csv(f"table2.{r['framework']}", r["runtime_s"] * 1e6,
             f"mae={r['mae']};rmse={r['rmse']};comm_mb={r['comm_mb']};"
             f"paper_mae={ref[0]};paper_comm_mb={ref[2]}")


def bench_fig1(_: bool) -> None:
    from benchmarks import fig1_losses
    curves = fig1_losses.run()
    for glm, c in curves.items():
        gap = max(abs(a - b) for a, b in zip(c["efmvfl"], c["centralized"]))
        _csv(f"fig1.{glm}", 0.0,
             f"iters={len(c['efmvfl'])};max_gap_vs_centralized={gap:.4f}")
        print(f"# fig1.{glm}.efmvfl="
              + ";".join(f"{v:.4f}" for v in c["efmvfl"]))
        print(f"# fig1.{glm}.tp="
              + ";".join(f"{v:.4f}" for v in c["tp"]))


def bench_fig2(paper: bool) -> None:
    """k-scaling rows; full measurement + BENCH_scaling.json come from
    `python -m benchmarks.fig2_scaling` (or --paper here)."""
    from benchmarks import fig2_scaling
    if paper:
        report = fig2_scaling.run()
        print(f"# wrote {fig2_scaling.write_report(report)}")
    else:
        report = fig2_scaling.run(ks=(2, 4, 8), glms=("logistic",),
                                  iters=3, batch=128, n_samples=1000,
                                  smoke=True)
    for r in report["rows"]:
        _csv(f"fig2.{r['glm']}.k{r['parties']}.{r['transport']}",
             r["per_iter_s"] * 1e6,
             f"comm_mb={r['comm_mb']};he={r['he_backend']}")
    for f in report["linear_fits"]:
        _csv(f"fig2.linear_fit.{f['glm']}", 0.0,
             f"slope_mb_per_party={f['slope_mb_per_party']};"
             f"max_residual_mb={f['max_residual_mb']}")


def check_committed_guards() -> None:
    """Re-validate the guard rows of the committed BENCH_crypto.json and
    BENCH_serve.json (structure + ratios), without re-measuring.  Exits
    non-zero on any violation so CI fails if a regressing measurement is
    committed."""
    from benchmarks import kernel_bench, serve_bench
    report = json.loads(BENCH_CRYPTO_PATH.read_text())
    rows = report["kernels"]
    guarded = [r["name"] for r in rows if r.get("guard_vs")]
    if not guarded:
        raise SystemExit(f"{BENCH_CRYPTO_PATH.name}: no guard rows found "
                         "— regenerate with python -m benchmarks.run "
                         "--only kernels")
    failures = kernel_bench.check_guards(rows)
    if failures:
        raise SystemExit(f"{BENCH_CRYPTO_PATH.name} guard violations:\n  "
                         + "\n  ".join(failures))
    print(f"# {BENCH_CRYPTO_PATH.name}: {len(guarded)} guard rows ok "
          f"({', '.join(guarded)})")
    serve_report = json.loads(BENCH_SERVE_PATH.read_text())
    srows = serve_report["rows"]
    sguarded = [r["name"] for r in srows
                if r.get("guard_vs") or "wire_ok" in r]
    if not sguarded:
        raise SystemExit(f"{BENCH_SERVE_PATH.name}: no guard rows found "
                         "— regenerate with python -m benchmarks.run "
                         "--only serve")
    failures = serve_bench.check_guards(srows)
    if failures:
        raise SystemExit(f"{BENCH_SERVE_PATH.name} guard violations:\n  "
                         + "\n  ".join(failures))
    print(f"# {BENCH_SERVE_PATH.name}: {len(sguarded)} guard rows ok "
          f"({', '.join(sguarded)})")


def bench_kernels(_: bool, smoke: bool = False) -> None:
    import jax

    from benchmarks import kernel_bench
    from repro.crypto import engine as engine_mod
    rows = kernel_bench.run(smoke=smoke)
    for r in rows:
        _csv(f"kernel.{r['name']}", r["us"], r["derived"])
    failures = kernel_bench.check_guards(rows)
    if failures:
        # SystemExit (not Exception) so main()'s report-and-continue
        # wrapper does NOT swallow it — the CI smoke run must go red
        raise SystemExit("kernel guard violations (engine-routed "
                         "interpret mode slower than the library):\n  "
                         + "\n  ".join(failures))
    if smoke:
        # drift check only — never clobber the committed full-measurement
        # perf trajectory with tiny smoke numbers
        print(f"# smoke mode: {BENCH_CRYPTO_PATH.name} not written")
        return
    report = {
        "schema": "bench_crypto/v1",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "default_crypto_engine": engine_mod.resolve_backend(),
        "kernels": [
            {k: v for k, v in r.items()} for r in rows
        ],
    }
    BENCH_CRYPTO_PATH.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {BENCH_CRYPTO_PATH}")


def bench_wire(_: bool, smoke: bool = False) -> None:
    """Codec throughput (encode/decode of the training frame classes);
    full mode writes BENCH_wire.json."""
    import jax

    from benchmarks import wire_bench
    rows = wire_bench.run(smoke=smoke)
    for r in rows:
        _csv(r["name"], r["us"], r["derived"])
    if smoke:
        print(f"# smoke mode: {BENCH_WIRE_PATH.name} not written")
        return
    report = {
        "schema": "bench_wire/v1",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "rows": [{k: v for k, v in r.items() if k != "derived"}
                 for r in rows],
    }
    BENCH_WIRE_PATH.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {BENCH_WIRE_PATH}")


def bench_checkpoint(_: bool, smoke: bool = False) -> None:
    """TrainState save/restore latency + bytes vs k and key size; full
    mode writes BENCH_checkpoint.json."""
    import jax

    from benchmarks import checkpoint_bench
    rows = checkpoint_bench.run(smoke=smoke)
    for r in rows:
        _csv(r["name"], r["us"], r["derived"])
    if smoke:
        print(f"# smoke mode: {BENCH_CHECKPOINT_PATH.name} not written")
        return
    report = {
        "schema": "bench_checkpoint/v1",
        "jax": jax.__version__,
        "rows": [{k: v for k, v in r.items() if k != "derived"}
                 for r in rows],
    }
    BENCH_CHECKPOINT_PATH.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {BENCH_CHECKPOINT_PATH}")


def bench_wan(_: bool, smoke: bool = False) -> None:
    """Measured socket training under shaped WAN profiles vs the
    analytic rounds × RTT model; full mode writes BENCH_wan.json."""
    from benchmarks import wan_bench
    report = wan_bench.run(smoke=smoke)
    for r in report["rows"]:
        _csv(r["name"], r["us"], r["derived"])
    if smoke:
        print(f"# smoke mode: {BENCH_WAN_PATH.name} not written")
        return
    print(f"# wrote {wan_bench.write_report(report)}")


def bench_serve(_: bool, smoke: bool = False) -> None:
    """Secure scoring service: p50/p99 latency + throughput vs batch
    size x k x crypto backend; full mode writes BENCH_serve.json."""
    import jax

    from benchmarks import serve_bench
    rows = serve_bench.run(smoke=smoke)
    for r in rows:
        _csv(r["name"], r["us"], r["derived"])
    failures = serve_bench.check_guards(rows)
    if failures:
        # SystemExit so the CI smoke gate goes red (see bench_kernels)
        raise SystemExit("serve guard violations:\n  "
                         + "\n  ".join(failures))
    if smoke:
        print(f"# smoke mode: {BENCH_SERVE_PATH.name} not written")
        return
    report = {
        "schema": "bench_serve/v1",
        "jax": jax.__version__,
        "rows": [{k: v for k, v in r.items() if k not in ("us", "derived")}
                 for r in rows],
    }
    BENCH_SERVE_PATH.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {BENCH_SERVE_PATH}")


def bench_roofline(_: bool) -> None:
    from benchmarks import roofline
    rows = roofline.run()
    if not rows:
        print("# roofline: no dry-run results found "
              "(run python -m repro.launch.dryrun --all first)")
        return
    for r in rows:
        if r["status"] != "ok":
            _csv(f"roofline.{r['arch']}.{r['cell']}.{r['mesh']}", 0.0,
                 f"FAIL:{r['error']}")
            continue
        _csv(f"roofline.{r['arch']}.{r['cell']}.{r['mesh']}",
             max(r["compute_ms"], r["memory_ms"], r["collective_ms"]) * 1e3,
             f"dom={r['dominant']};frac={r['roofline_frac']};"
             f"useful={r['useful_flops_ratio']};peak_gib={r['peak_gib']}")


BENCHES = {
    "table1_lr": bench_table1,
    "table2_pr": bench_table2,
    "fig1_losses": bench_fig1,
    "fig2_scaling": bench_fig2,
    "kernels": bench_kernels,
    "wire": bench_wire,
    "checkpoint": bench_checkpoint,
    "wan": bench_wan,
    "serve": bench_serve,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full paper-scale configurations (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI kernel-drift check; kernels only)")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--guards", action="store_true",
                    help="validate the committed BENCH_crypto.json guard "
                         "rows and exit (no measurement)")
    args = ap.parse_args()
    if args.guards:
        check_committed_guards()
        return
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            if name in ("kernels", "wire", "checkpoint", "wan", "serve"):
                fn(args.paper, smoke=args.smoke)
            else:
                fn(args.paper)
        except Exception as e:   # noqa: BLE001 — report and continue
            _csv(f"{name}.ERROR", 0.0, f"{type(e).__name__}:{e}")
            import traceback
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
