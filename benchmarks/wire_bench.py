"""Codec throughput microbench: encode/decode rates of the wire formats.

Rows cover the hot frame classes of a training iteration: ring-share
frames (8-byte LE elements), mock ciphertext frames (canonical-width
padding), and real Paillier ciphertext frames (Montgomery → canonical
→ Montgomery, the expensive direction).  `benchmarks.run --only wire`
prints CSV rows and (full mode) writes `BENCH_wire.json`.
"""
from __future__ import annotations

import time

import numpy as np

from repro.crypto import fixed_point, paillier, ring
from repro.runtime import messages as msg
from repro.runtime.codec import Codec


def _time(fn, reps: int) -> float:
    fn()                                       # warm-up / trace
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _row(name: str, us: float, payload_bytes: int, reps: int) -> dict:
    return {
        "name": name,
        "us": round(us, 1),
        "payload_bytes": payload_bytes,
        "mb_per_s": round(payload_bytes / max(us, 1e-9), 1),
        "reps": reps,
        "derived": f"payload_b={payload_bytes};"
                   f"mbps={payload_bytes / max(us, 1e-9):.1f}",
    }


def run(smoke: bool = False) -> list[dict]:
    rng = np.random.default_rng(0)
    nb = 128 if smoke else 2048
    m = 8 if smoke else 64
    key_bits = 192 if smoke else 256
    reps = 3 if smoke else 20
    rows: list[dict] = []
    codec = Codec()

    # -- ring share frames (Protocol 1 / Beaver openings) -----------------
    v = ring.from_numpy_u64(rng.integers(0, 1 << 64, nb, dtype=np.uint64))
    m_ring = msg.ZShare("B1", "C", v)
    frame = codec.encode(m_ring)
    rows.append(_row(f"wire.ring_encode.n{nb}",
                     _time(lambda: codec.encode(m_ring), reps),
                     int(m_ring.wire_bytes()), reps))
    rows.append(_row(f"wire.ring_decode.n{nb}",
                     _time(lambda: codec.decode(frame), reps),
                     int(m_ring.wire_bytes()), reps))

    # -- mock ciphertext frames (canonical-width padding) -----------------
    m_mock = msg.EncD("C", "B1", v, n_cts=nb, key_bits=key_bits,
                      key_owner="C")
    frame = codec.encode(m_mock)
    rows.append(_row(f"wire.mock_ct_encode.n{nb}.k{key_bits}",
                     _time(lambda: codec.encode(m_mock), reps),
                     int(m_mock.wire_bytes()), reps))
    rows.append(_row(f"wire.mock_ct_decode.n{nb}.k{key_bits}",
                     _time(lambda: codec.decode(frame), reps),
                     int(m_mock.wire_bytes()), reps))

    # -- real Paillier ciphertext frames ----------------------------------
    key = paillier.keygen(key_bits, seed=7)
    pub = key.pub
    vals = ring.from_numpy_u64(rng.integers(0, 1 << 64, m, dtype=np.uint64))
    cts = paillier.encrypt(pub, fixed_point.r64_to_limbs(vals, pub.Ln),
                           rng=rng)
    pcodec = Codec(lambda owner: pub.mod_n2)
    m_ct = msg.MaskedGrad("B1", "C", cts, n_cts=m, key_bits=key_bits,
                          key_owner="C")
    frame = pcodec.encode(m_ct)
    ct_reps = max(2, reps // 4)
    rows.append(_row(f"wire.paillier_ct_encode.n{m}.k{key_bits}",
                     _time(lambda: pcodec.encode(m_ct), ct_reps),
                     int(m_ct.wire_bytes()), ct_reps))
    rows.append(_row(f"wire.paillier_ct_decode.n{m}.k{key_bits}",
                     _time(lambda: pcodec.decode(frame), ct_reps),
                     int(m_ct.wire_bytes()), ct_reps))
    return rows
