#!/usr/bin/env python
"""Docs consistency gate (CI):

1. every relative markdown link in docs/*.md and README.md resolves to
   an existing file;
2. every repo file path named in backticks in those documents exists;
3. every message tag named in docs/protocols.md exists in
   `repro.runtime.messages` (and every tag the runtime defines is
   documented there) — the paper↔code map must not drift from the code.

Run from anywhere:  python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

DOCS = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
PATH_RE = re.compile(
    r"`((?:src|scripts|benchmarks|tests|docs|examples)/[\w./-]+\."
    r"(?:py|md|sh|json|yml))`")
TAG_RE = re.compile(r"`(P\d\.[a-z_]+|beaver_open|flag|infer\.wx_share)`")


def check_links() -> list[str]:
    errors = []
    for doc in DOCS:
        text = doc.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = (doc.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{doc.relative_to(REPO)}: broken link "
                              f"-> {target}")
        for m in PATH_RE.finditer(text):
            if not (REPO / m.group(1)).exists():
                errors.append(f"{doc.relative_to(REPO)}: named file "
                              f"missing -> {m.group(1)}")
    return errors


def check_tags() -> list[str]:
    from repro.runtime import messages

    def subclass_tags(cls):
        out = set()
        for sub in cls.__subclasses__():
            if sub.tag != "?":
                out.add(sub.tag)
            out |= subclass_tags(sub)
        return out

    code_tags = subclass_tags(messages.Message)
    code_tags |= set(messages.TAG_PROTOCOL)
    proto_doc = REPO / "docs" / "protocols.md"
    doc_tags = set(TAG_RE.findall(proto_doc.read_text()))
    errors = [f"docs/protocols.md names unknown tag `{t}` "
              f"(not in runtime/messages.py)"
              for t in sorted(doc_tags - code_tags)]
    errors += [f"runtime tag `{t}` is undocumented in docs/protocols.md"
               for t in sorted(set(messages.TAG_PROTOCOL) - doc_tags)]
    return errors


def main() -> int:
    errors = check_links() + check_tags()
    for e in errors:
        print(f"DOCS-CHECK FAIL: {e}")
    if not errors:
        docs = ", ".join(str(d.relative_to(REPO)) for d in DOCS)
        print(f"docs check ok ({docs})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
