#!/usr/bin/env bash
# Tier-1 CI: fast test selection with explicit PYTHONPATH so collection
# regressions (e.g. a hard dependency creeping into a test module) fail
# loudly rather than silently skipping modules.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Collection must be clean before anything runs (4 modules failed to
# import at seed; this guards the fix).
python -m pytest -q --collect-only >/dev/null

# Docs consistency gate: markdown cross-references resolve and every
# message tag named in docs/protocols.md exists in runtime/messages.py.
python scripts/check_docs.py

# Crypto-kernel drift smoke (CPU, tiny sizes): the kernel microbench
# must run end-to-end AND its guard rows must hold — engine-routed
# interpret-mode ops may never be slower than the library path (the
# bench exits non-zero on a guard violation).  Engine bit-exactness
# parity itself lives in tests/test_engine.py + tests/test_rns.py,
# collected by the tier-1 sweep below.
python -m benchmarks.run --only kernels --smoke >/dev/null

# The committed perf trajectory must also satisfy its own guards
# (catches committing a regressing full measurement).
python -m benchmarks.run --guards >/dev/null

# k-scaling smoke: the concurrent-leg scheduler must survive the
# fig2 benchmark path end-to-end (full curves: benchmarks.fig2_scaling).
python -m benchmarks.fig2_scaling --smoke >/dev/null

# Wire smoke: codec throughput rows must produce end-to-end, and a k=3
# mock training across REAL OS processes over SocketTransport must stay
# bit-identical to LocalTransport with measured == analytic bytes
# (examples/distributed_training.py asserts all three).  Codec
# round-trip/rejection coverage itself is tests/test_codec.py in the
# tier-1 sweep below.
python -m benchmarks.run --only wire --smoke >/dev/null
python examples/distributed_training.py --smoke >/dev/null

# Resume smoke: checkpoint save/restore latency rows must produce, and
# a mock socket run killed mid-iteration (SIGKILL) must resume from
# party-local checkpoints bit-identically (examples/resumable_training
# asserts losses/weights/analytic+measured bytes).  The full coverage
# is tests/test_resumable.py in the tier-1 sweep below.
python -m benchmarks.run --only checkpoint --smoke >/dev/null
python examples/resumable_training.py --smoke >/dev/null

# Chaos smoke: a k=3 socket training run through the shaped chaos link
# layer (FaultyTransport, runtime/chaos.py) must finish with identical
# losses/meters/rounds to the unshaped baseline — the bench asserts all
# three.  The full fault gauntlet (drops/dups/reorders/resets/partition
# + SIGKILL, bit-identical) is tests/test_chaos.py in the sweep below.
python -m benchmarks.run --only wan --smoke >/dev/null

# Serving smoke: the continuous-batching scoring service must run
# end-to-end (admission -> version-pinned caches -> infer.wx_share ->
# inverse link) AND its guard rows must hold — batching must amortize
# (largest-batch throughput >= singleton).  The committed BENCH_serve
# .json is re-validated by the --guards gate above; the full parity
# gauntlet (bit-identity, chaos, hot swap) is tests/test_serve_* in
# the tier-1 sweep below.
python -m benchmarks.run --only serve --smoke >/dev/null

exec python -m pytest -x -q "$@"
