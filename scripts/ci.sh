#!/usr/bin/env bash
# Tier-1 CI: fast test selection with explicit PYTHONPATH so collection
# regressions (e.g. a hard dependency creeping into a test module) fail
# loudly rather than silently skipping modules.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Collection must be clean before anything runs (4 modules failed to
# import at seed; this guards the fix).
python -m pytest -q --collect-only >/dev/null

exec python -m pytest -x -q "$@"
