"""Continuous batching: requests enter/leave the decode batch at any step.

Implementation: per-slot KV caches are stacked on a leading slot axis and
decoded with `jax.vmap` over slots (params broadcast) — each slot carries
its own `length`, so sequences at different depths batch together, the
property fixed-batch decode lacks.  Prefill runs per admitted request
(B=1, prompt padded to a bucket to bound compile count) and its cache is
written into a free slot.

This wraps the same `api.prefill` / `api.decode_step` the dry-run lowers,
so the engine works unchanged for any decoder-only architecture config.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(16, 32, 64, 128, 256)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServeEngine:
    def __init__(self, api, params, n_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        assert api.cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid"), \
            "engine supports decoder-style families"
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        slot_cache = api.init_decode_state(1, max_len)
        self.caches = jax.tree.map(
            lambda x: jnp.stack([x] * n_slots), slot_cache)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_tok = np.zeros((n_slots, 1, 1), np.int32)  # (slot, B=1, 1)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._next_rid = 0
        self._prefill = jax.jit(
            lambda p, t: api.prefill(p, t, max_len=max_len),
            static_argnums=())
        self._decode_v = jax.jit(jax.vmap(
            lambda p, c, t: api.decode_step(p, c, t),
            in_axes=(None, 0, 0)))

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    # -- scheduler ---------------------------------------------------------
    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            b = _bucket(len(req.prompt))
            padded = np.full((1, b), 0, np.int32)
            padded[0, b - len(req.prompt):] = req.prompt   # left-pad
            logits, cache = self._prefill(self.params, jnp.asarray(padded))
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.slot_req[s] = req
            self.slot_tok[s, 0, 0] = tok
            self.caches = jax.tree.map(
                lambda c, new: c.at[s].set(new), self.caches, cache)

    def step(self) -> int:
        """Admit + one batched decode step.  Returns #active slots."""
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        logits, self.caches = self._decode_v(
            self.params, self.caches, jnp.asarray(self.slot_tok))
        toks = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
        for s in active:
            req = self.slot_req[s]
            tok = int(toks[s])
            req.generated.append(tok)
            self.slot_tok[s, 0, 0] = tok
            if (len(req.generated) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.busy:
                break
            self.step()
        return self.finished


# ---------------------------------------------------------------------------
# Federated GLM scoring (EFMVFL runtime-backed serving path)
# ---------------------------------------------------------------------------

class FeatureKeyError(ValueError):
    """A submitted feature dict's keys disagree with the party roster —
    refused at `submit` time with both sides spelled out (previously a
    bare KeyError deep inside np.stack during `step`)."""

    def __init__(self, missing, unexpected, roster):
        self.missing = sorted(missing)
        self.unexpected = sorted(unexpected)
        super().__init__(
            f"feature dict keys do not match the party roster "
            f"{sorted(roster)}: missing {self.missing}, "
            f"unexpected {self.unexpected}")


@dataclasses.dataclass
class ScoreRequest:
    rid: int
    features: dict[str, np.ndarray]   # party name -> (m_p,) feature slice
    prediction: Optional[float] = None
    client: Optional[str] = None      # submitter identity (FIFO per client)
    model_version: Optional[int] = None   # the ONE version that scored it
    batch_seq: Optional[int] = None   # micro-batch ordinal it rode in
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class VFLScoringEngine:
    """Long-lived secure scoring service on the trainer's actor/message/
    transport stack.

    Requests carry vertically-split feature rows (one slice per party).
    An admission controller (`serve.batching.MicroBatcher`) closes
    micro-batches on a size trigger (`max_batch`) or a deadline trigger
    (`max_wait_s` since the oldest pending request); each party computes
    its local score share X_p W_p against a PUBLISHED model version's
    pinned weights (`serve.cache.PartyServingCache` — windowed-digit
    precompute and encrypted constants amortized per version, not per
    request) and ships it to C as an `infer.wx_share` message through
    the transport (metered + round-counted like training traffic); C
    sums the shares in roster order and applies the inverse link.  Raw
    features and per-party weights never move.

    Hot model swap: `swap_model(step)` loads every party's OWN slice of
    a PR-5 versioned checkpoint and republishes it as version v+1 — the
    swap is applied only at a batch boundary with nothing in flight,
    and every score request carries the version it is to be scored at
    (a straggler party refuses with `StaleCacheError`), so no batch is
    ever scored by mixed versions.

    Two hosting modes:
      * in-process (`parties=` actors + a local transport) — the
        trainer's actors serve directly;
      * distributed (`cluster=` a started `launch.cluster.SocketCluster`)
        — every micro-batch is scored by the real party *processes*:
        the conductor fans the feature slices out as control frames and
        the score shares travel party→C over the TCP mesh as encoded
        `infer.wx_share` frames.

    Service mode: `start()` runs the admission/scoring loop on a worker
    thread (deadline batches close without client calls); `stop()`
    drains and joins.  Synchronous use (`run()`) drains inline.
    """

    def __init__(self, parties=None, transport=None, max_batch: int = 64,
                 cluster=None, max_wait_s: float = 0.0,
                 clock=time.monotonic, checkpoint_dir: Optional[str] = None,
                 version: int = 0):
        assert (parties is None) != (cluster is None), \
            "pass either in-process actors (parties=) or a SocketCluster"
        from repro.serve.batching import MicroBatcher
        self.cluster = cluster
        if parties is not None:
            from repro.runtime import LocalTransport
            from repro.runtime.party import LabelParty
            assert isinstance(parties[0], LabelParty), \
                "parties[0] must be the label party C " \
                "(e.g. from a VFLScheduler)"
            self.parties = list(parties)
            self.label = self.parties[0]
            self.names = [p.name for p in self.parties]
            self.transport = transport if transport is not None \
                else LocalTransport()
            self.transport.bind(self.parties)
            for p in self.parties:
                p.publish_version(version)
        else:
            self.parties = None
            self.label = None
            self.names = list(cluster.names)
            self.transport = cluster.tp
            cluster.publish_model(version)
        self.max_batch = max_batch
        self.model_version = int(version)
        self.checkpoint_dir = checkpoint_dir   # in-process hot-swap source
        self.clock = clock
        self.batcher = MicroBatcher(max_batch=max_batch,
                                    max_wait_s=max_wait_s, clock=clock)
        self.finished: list[ScoreRequest] = []
        self._next_rid = 0
        self._batch_seq = 0
        self._in_flight = 0
        self._pending_swap: Optional[tuple[int, int]] = None
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()

    # -- client API --------------------------------------------------------
    def submit(self, features: dict[str, np.ndarray],
               client: Optional[str] = None) -> int:
        """Enqueue one scoring request.  The feature dict must carry
        exactly the party roster's keys — anything else is refused HERE
        (`FeatureKeyError`), not half-way through a batch."""
        roster, got = set(self.names), set(features)
        if got != roster:
            raise FeatureKeyError(roster - got, got - roster, self.names)
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        req = ScoreRequest(rid, features, client=client,
                           t_submit=self.clock())
        self.batcher.submit(req, now=req.t_submit)
        return rid

    @property
    def busy(self) -> bool:
        """True while anything is pending OR in flight — `run()`/`stop()`
        cannot return early while a cluster-mode batch is still being
        scored (the old queue-only check did exactly that)."""
        with self._lock:
            in_flight = self._in_flight
        return self.batcher.pending > 0 or in_flight > 0

    def swap_model(self, step: int) -> int:
        """Request a hot swap to checkpoint `step`; applied at the next
        batch boundary (never while a batch is in flight — the version
        barrier).  Returns the version the swapped model will serve as."""
        with self._lock:
            base = self._pending_swap[1] if self._pending_swap \
                else self.model_version
            v = base + 1
            self._pending_swap = (int(step), v)
        return v

    def latencies(self) -> np.ndarray:
        """Per-request latency (seconds) of every finished request."""
        return np.array([r.latency_s for r in self.finished], np.float64)

    # -- scheduler ---------------------------------------------------------
    def step(self, flush: bool = True) -> int:
        """Apply any pending swap at this batch boundary, then close and
        score one micro-batch (`flush=True` ignores the deadline — the
        synchronous drain; the worker thread polls with flush=False).
        Returns the number of requests served."""
        self._apply_pending_swap()
        batch = self.batcher.poll(flush=flush)
        if not batch:
            return 0
        with self._lock:
            self._in_flight += len(batch)
            version = self.model_version
            seq = self._batch_seq
            self._batch_seq += 1
        try:
            preds = self._score_batch(batch, version)
            t_done = self.clock()
            for r, pred in zip(batch, preds):
                r.prediction = float(pred)
                r.model_version = version
                r.batch_seq = seq
                r.t_done = t_done
                self.finished.append(r)
        finally:
            with self._lock:
                self._in_flight -= len(batch)
        return len(batch)

    def _score_batch(self, batch: list, version: int) -> np.ndarray:
        X = {name: np.stack([r.features[name] for r in batch])
             for name in self.names}
        if self.cluster is not None:
            return self.cluster.score(X, version=version)
        senders = [n for n in self.names if n != self.label.name]
        self.label.begin_inference(len(batch), senders)
        for p in self.parties:
            if p.name != self.label.name:
                self.transport.post(p.wx_share_msg(
                    X[p.name], dst=self.label.name, version=version))
        self.transport.pump(order=[self.label.name])
        return self.label.finish_inference(X[self.label.name],
                                           version=version)

    def _apply_pending_swap(self) -> None:
        with self._lock:
            pend = self._pending_swap
            if pend is None:
                return
            assert self._in_flight == 0, \
                "swap at a batch boundary only — a batch is in flight"
            self._pending_swap = None
        step, v = pend
        if self.cluster is not None:
            self.cluster.swap_model(step, version=v)
        else:
            from repro.checkpoint import (load_checkpoint,
                                          party_checkpoint_dir)
            from repro.runtime import session as session_lib
            assert self.checkpoint_dir is not None, \
                "in-process hot swap needs checkpoint_dir="
            for p in self.parties:
                pdir = party_checkpoint_dir(self.checkpoint_dir, p.name)
                got = load_checkpoint(
                    pdir, session_lib.TrainState.tree_template([p.name]),
                    step=step,
                    expect_config_hash=session_lib.config_hash(p.cfg),
                    expect_codec_version=session_lib.CODEC_VERSION)
                if got is None:
                    raise RuntimeError(f"hot swap: step {step} is missing "
                                       f"or invalid in {pdir}")
                _, tree, extra = got
                st = session_lib.TrainState.from_checkpoint(tree, extra)
                p.set_weights(st.weights[p.name], version=v)
        with self._lock:
            self.model_version = v

    # -- drive modes -------------------------------------------------------
    def run(self) -> list[ScoreRequest]:
        """Synchronous drain: score everything pending and return."""
        while self.batcher.pending:
            self.step(flush=True)
        return self.finished

    def start(self, poll_interval_s: float = 0.002) -> None:
        """Service mode: run the admission/scoring loop on a worker
        thread.  Deadline-triggered batches close without any client
        call; clients just `submit` and read `finished`."""
        assert self._worker is None, "service already started"
        self._stop_evt.clear()

        def loop() -> None:
            while not self._stop_evt.is_set():
                if self.step(flush=False) == 0:
                    self._stop_evt.wait(poll_interval_s)

        self._worker = threading.Thread(target=loop, name="vfl-serve",
                                        daemon=True)
        self._worker.start()

    def stop(self, drain: bool = True) -> list[ScoreRequest]:
        """Stop the worker; with `drain` (default) flush every request
        still pending before returning the finished list."""
        if self._worker is not None:
            self._stop_evt.set()
            self._worker.join()
            self._worker = None
        if drain:
            while self.batcher.pending:
                self.step(flush=True)
        return self.finished
