"""Continuous batching: requests enter/leave the decode batch at any step.

Implementation: per-slot KV caches are stacked on a leading slot axis and
decoded with `jax.vmap` over slots (params broadcast) — each slot carries
its own `length`, so sequences at different depths batch together, the
property fixed-batch decode lacks.  Prefill runs per admitted request
(B=1, prompt padded to a bucket to bound compile count) and its cache is
written into a free slot.

This wraps the same `api.prefill` / `api.decode_step` the dry-run lowers,
so the engine works unchanged for any decoder-only architecture config.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(16, 32, 64, 128, 256)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServeEngine:
    def __init__(self, api, params, n_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        assert api.cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid"), \
            "engine supports decoder-style families"
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        slot_cache = api.init_decode_state(1, max_len)
        self.caches = jax.tree.map(
            lambda x: jnp.stack([x] * n_slots), slot_cache)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_tok = np.zeros((n_slots, 1, 1), np.int32)  # (slot, B=1, 1)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._next_rid = 0
        self._prefill = jax.jit(
            lambda p, t: api.prefill(p, t, max_len=max_len),
            static_argnums=())
        self._decode_v = jax.jit(jax.vmap(
            lambda p, c, t: api.decode_step(p, c, t),
            in_axes=(None, 0, 0)))

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    # -- scheduler ---------------------------------------------------------
    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            b = _bucket(len(req.prompt))
            padded = np.full((1, b), 0, np.int32)
            padded[0, b - len(req.prompt):] = req.prompt   # left-pad
            logits, cache = self._prefill(self.params, jnp.asarray(padded))
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.slot_req[s] = req
            self.slot_tok[s, 0, 0] = tok
            self.caches = jax.tree.map(
                lambda c, new: c.at[s].set(new), self.caches, cache)

    def step(self) -> int:
        """Admit + one batched decode step.  Returns #active slots."""
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        logits, self.caches = self._decode_v(
            self.params, self.caches, jnp.asarray(self.slot_tok))
        toks = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
        for s in active:
            req = self.slot_req[s]
            tok = int(toks[s])
            req.generated.append(tok)
            self.slot_tok[s, 0, 0] = tok
            if (len(req.generated) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.busy:
                break
            self.step()
        return self.finished


# ---------------------------------------------------------------------------
# Federated GLM scoring (EFMVFL runtime-backed serving path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScoreRequest:
    rid: int
    features: dict[str, np.ndarray]   # party name -> (m_p,) feature slice
    prediction: Optional[float] = None


class VFLScoringEngine:
    """Serves a trained federated GLM with the same actor/message/transport
    stack the trainer runs on.

    Requests carry vertically-split feature rows (one slice per party).
    The engine micro-batches them; each party computes its local score
    share X_p W_p via `Party.predict_share` and ships it to C as an
    `infer.wx_share` message through the transport (metered + round-
    counted like training traffic); C sums the shares and applies the
    inverse link.  Raw features and per-party weights never move.

    Two hosting modes:
      * in-process (`parties=` actors + a local transport) — the
        trainer's actors serve directly;
      * distributed (`cluster=` a started `launch.cluster.SocketCluster`)
        — every micro-batch is scored by the real party *processes*:
        the conductor fans the feature slices out as control frames and
        the score shares travel party→C over the TCP mesh as encoded
        `infer.wx_share` frames.
    """

    def __init__(self, parties=None, transport=None, max_batch: int = 64,
                 cluster=None):
        assert (parties is None) != (cluster is None), \
            "pass either in-process actors (parties=) or a SocketCluster"
        self.cluster = cluster
        if parties is not None:
            from repro.runtime import LocalTransport
            from repro.runtime.party import LabelParty
            assert isinstance(parties[0], LabelParty), \
                "parties[0] must be the label party C " \
                "(e.g. from a VFLScheduler)"
            self.parties = list(parties)
            self.label = self.parties[0]
            self.transport = transport if transport is not None \
                else LocalTransport()
            self.transport.bind(self.parties)
        else:
            self.parties = None
            self.label = None
            self.transport = cluster.tp
        self.max_batch = max_batch
        self.queue: deque[ScoreRequest] = deque()
        self.finished: list[ScoreRequest] = []
        self._next_rid = 0

    def submit(self, features: dict[str, np.ndarray]) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(ScoreRequest(rid, features))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue)

    def step(self) -> int:
        """Score one micro-batch.  Returns the number of requests served."""
        batch = [self.queue.popleft()
                 for _ in range(min(self.max_batch, len(self.queue)))]
        if not batch:
            return 0
        if self.cluster is not None:
            X = {name: np.stack([r.features[name] for r in batch])
                 for name in self.cluster.names}
            preds = self.cluster.score(X)
        else:
            X = {p.name: np.stack([r.features[p.name] for r in batch])
                 for p in self.parties}
            self.label.begin_inference(len(batch), len(self.parties))
            for p in self.parties:
                if p.name != self.label.name:
                    self.transport.post(p.wx_share_msg(X[p.name],
                                                       dst=self.label.name))
            self.transport.pump(order=[self.label.name])
            preds = self.label.finish_inference(X[self.label.name])
        for r, pred in zip(batch, preds):
            r.prediction = float(pred)
            self.finished.append(r)
        return len(batch)

    def run(self) -> list[ScoreRequest]:
        while self.busy:
            self.step()
        return self.finished
