"""Serving runtimes: continuous batching over slot-stacked KV caches
(LM decode) and micro-batched federated GLM scoring (EFMVFL actors)."""
from repro.serve.engine import (Request, ScoreRequest, ServeEngine,
                                VFLScoringEngine)

__all__ = ["ServeEngine", "Request", "VFLScoringEngine", "ScoreRequest"]
