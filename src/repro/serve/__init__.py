"""Serving runtime: continuous batching over slot-stacked KV caches."""
from repro.serve.engine import Request, ServeEngine

__all__ = ["ServeEngine", "Request"]
