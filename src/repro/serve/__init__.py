"""Serving runtimes: continuous batching over slot-stacked KV caches
(LM decode) and a micro-batched secure scoring service for the
federated GLM (EFMVFL actors: admission control, per-version serving
caches, hot model swap)."""
from repro.serve.batching import MicroBatcher
from repro.serve.cache import (PartyServingCache, StaleCacheError,
                               key_fingerprint_of)
from repro.serve.engine import (FeatureKeyError, Request, ScoreRequest,
                                ServeEngine, VFLScoringEngine)

__all__ = ["ServeEngine", "Request", "VFLScoringEngine", "ScoreRequest",
           "FeatureKeyError", "MicroBatcher", "PartyServingCache",
           "StaleCacheError", "key_fingerprint_of"]
