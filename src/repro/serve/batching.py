"""Admission control for the scoring service: micro-batch formation.

`MicroBatcher` is the serving-side admission controller — the
continuous-batching idiom of `examples/continuous_batching.py` (requests
enter/leave the working set at any step) specialized for one-shot
scoring requests: there are no slots to reuse, so the controller's whole
job is deciding WHEN to close the next micro-batch.

Two triggers close a batch:

  * size    — `max_batch` requests are pending (the oldest `max_batch`
              leave immediately; the rest wait for the next batch);
  * deadline — the OLDEST pending request has waited `max_wait_s`
              (everything pending leaves, capped at `max_batch`).

`max_wait_s = 0` degenerates to "any pending request closes a batch",
which is the synchronous drain the one-shot scorer used.

Invariants (property-tested in tests/test_serve_batching.py):

  * a closed batch never exceeds `max_batch`;
  * requests leave in global submission order (FIFO), which implies
    per-client FIFO for any interleaving of clients;
  * no starvation: the oldest pending request is in EVERY next closed
    batch, so no arrival pattern can delay it past one batch boundary
    beyond its deadline;
  * the deadline trigger never fires on an empty queue.

The clock is injectable (`clock=`) so the properties are tested against
a simulated clock; `submit`/`poll` also accept an explicit `now` for the
same reason.  All public methods are thread-safe — the service mode of
`VFLScoringEngine` polls from a worker thread while clients submit.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, List, Optional


class MicroBatcher:
    """Deadline- and size-triggered micro-batch admission queue."""

    def __init__(self, max_batch: int = 64, max_wait_s: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self._q: collections.deque[tuple[float, Any]] = collections.deque()
        self._lock = threading.Lock()

    def submit(self, item: Any, now: Optional[float] = None) -> None:
        """Enqueue one request (timestamped for the deadline trigger)."""
        t = self.clock() if now is None else float(now)
        with self._lock:
            self._q.append((t, item))

    @property
    def pending(self) -> int:
        return len(self._q)

    def oldest_age(self, now: Optional[float] = None) -> float:
        """Seconds the oldest pending request has waited (0 if empty)."""
        t = self.clock() if now is None else float(now)
        with self._lock:
            return 0.0 if not self._q else max(t - self._q[0][0], 0.0)

    def poll(self, now: Optional[float] = None,
             flush: bool = False) -> List[Any]:
        """Close and return the next micro-batch, or [] if no trigger
        fired.  `flush=True` forces the deadline trigger (drain mode)."""
        t = self.clock() if now is None else float(now)
        with self._lock:
            n = len(self._q)
            if n == 0:
                return []
            if n >= self.max_batch:                       # size trigger
                take = self.max_batch
            elif flush or (t - self._q[0][0]) >= self.max_wait_s:
                take = n                                  # deadline trigger
            else:
                return []
            return [self._q.popleft()[1] for _ in range(take)]
