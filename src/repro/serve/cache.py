"""Per-party serving caches, keyed by (model version, key fingerprint).

Serving amortizes per-request work that training pays per iteration:

  * a PINNED weight snapshot — every batch scored at version v uses the
    same `W` bits, even if the live actor trains on or swaps models
    underneath (this pin is what makes the hot-swap barrier sound: a
    version is immutable once published);
  * the windowed-digit precompute of the weight row
    (`EncodedFeatures.make` — the same MSB-first window decomposition
    `he_matvec` consumes), built once per version instead of per batch;
  * the encrypted constant [[w]] under the party's OWN key
    (`backend.encrypt_share`), the operand any ciphertext-side serving
    protocol starts from — m ciphertexts per model version, not per
    request.

Staleness is a REFUSAL, not a silent rebuild — the same contract as
`crypto.fixed_base.TableMismatchError` (PR 6): a cache whose version or
key fingerprint disagrees with the request is intact but belongs to a
different serving epoch, and scoring with it would silently serve the
wrong model (or a key that no longer exists).  `PartyServingCache
.ensure` raises `StaleCacheError` with both identities spelled out;
callers re-publish explicitly (`Party.publish_version`) — never
implicitly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import protocols
from repro.crypto import fixed_base, fixed_point


class StaleCacheError(ValueError):
    """Serving cache disagrees with the requested model version or the
    live key material — intact, but from a different serving epoch.
    Scoring with it is refused (mirrors `TableMismatchError`: wrong
    configuration, not a damaged artifact)."""


def key_fingerprint_of(backend, party: str) -> str:
    """Stable fingerprint of the party's encryption identity: sha256
    over the public modulus for Paillier (`fixed_base.key_fingerprint`),
    a synthesized `mock:<bits>` tag for the unencrypted mock backend."""
    keys = getattr(backend, "keys", None)
    if keys is None:
        return f"mock:{int(backend.key_bits(party))}"
    return fixed_base.key_fingerprint(keys[party].pub.n)


@dataclasses.dataclass
class PartyServingCache:
    """One published model version of one party (see module docstring)."""
    version: int
    key_fp: str
    W: np.ndarray                          # pinned (m_p,) float64 snapshot
    w_feats: protocols.EncodedFeatures     # windowed-digit precompute of W
    enc_w: object                          # [[w]] under the party's own key

    @staticmethod
    def build(party, version: int) -> "PartyServingCache":
        """Snapshot `party.W` as served model `version` and precompute
        the per-version constants.  Cost: one fixed-point encode + digit
        decomposition + m encryptions — amortized over every request
        scored at this version."""
        W = np.array(party.W, np.float64)
        cfg = party.cfg
        return PartyServingCache(
            version=int(version),
            key_fp=key_fingerprint_of(party.backend, party.name),
            W=W,
            w_feats=protocols.EncodedFeatures.make(W[None, :], cfg.fx,
                                                   cfg.exp_width),
            enc_w=party.backend.encrypt_share(
                party.name, fixed_point.encode(W, cfg.f)))

    def ensure(self, version: int, key_fp: str,
               party: str = "?") -> "PartyServingCache":
        """Refuse unless this cache IS (version, key_fp); returns self."""
        if int(version) != self.version:
            raise StaleCacheError(
                f"{party}: serving cache holds model version "
                f"{self.version}, request wants {int(version)} — "
                "republish (publish_version / swap) before scoring")
        if key_fp != self.key_fp:
            raise StaleCacheError(
                f"{party}: serving cache was built for key {self.key_fp}, "
                f"live backend key is {key_fp} — encrypted constants are "
                "under a dead key; republish before scoring")
        return self
