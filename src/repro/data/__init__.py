"""Data pipeline: synthetic dataset twins, vertical splitting, resumable
token streams for LM training."""
from repro.data import synthetic, vertical

__all__ = ["synthetic", "vertical"]
