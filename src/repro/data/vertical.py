"""Vertical partitioning — split a feature matrix across parties the way
FATE does for its VFL examples (contiguous column blocks, C first)."""
from __future__ import annotations

import numpy as np


def split_columns(X: np.ndarray, n_parties: int) -> list[np.ndarray]:
    """Split features into n_parties column blocks (C gets the first)."""
    cols = np.array_split(np.arange(X.shape[1]), n_parties)
    return [X[:, c] for c in cols]


def replicate_provider(parts: list[np.ndarray], n_parties: int
                       ) -> list[np.ndarray]:
    """Paper §5.1: 'in the multi-party case, we easily copy the data of
    party B1 to the new party'."""
    assert len(parts) == 2
    return [parts[0]] + [parts[1]] * (n_parties - 1)
