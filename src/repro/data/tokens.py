"""Resumable synthetic token pipeline for LM training examples.

Deterministic given (seed, cursor): the stream state is two integers, so
checkpoint/restart reproduces the exact batch sequence — the property the
failure-recovery test asserts bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStreamState:
    seed: int
    cursor: int = 0


class TokenStream:
    """Markov-ish synthetic corpus (not uniform noise: loss can decrease)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.state = TokenStreamState(seed=seed)
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        k = min(64, vocab)
        self._trans = rng.integers(0, vocab, size=(vocab, k))

    def next_batch(self) -> dict:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.cursor) & 0x7FFFFFFF)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        choices = rng.integers(0, self._trans.shape[1],
                               (self.batch, self.seq))
        for t in range(self.seq):
            toks[:, t + 1] = self._trans[toks[:, t], choices[:, t]]
        self.state.cursor += 1
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}

    def save_state(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state(self, d: dict) -> None:
        self.state = TokenStreamState(**d)
