"""Offline synthetic twins of the paper's datasets (no-network container).

* `credit_default` — 30 000 × 24 binary task shaped like the UCI
  default-of-credit-card-clients data: correlated bounded features,
  ~22% positive rate, Bayes-limited so a linear model lands at
  AUC ≈ 0.71–0.72 (paper Table 1 reports 0.712).
* `dvisits` — 5 190 × 19 Poisson count task shaped like the Australian
  Health Survey doctor-visits data: mostly binary/bounded covariates,
  mean count ≈ 0.30, strongly zero-inflated.
"""
from __future__ import annotations

import numpy as np


def credit_default(n: int = 30000, d: int = 24, seed: int = 0):
    rng = np.random.default_rng(seed)
    # correlated latent factors -> bounded features (like bill/pay columns)
    k = 6
    factors = rng.normal(size=(n, k))
    mix = rng.normal(size=(k, d)) / np.sqrt(k)
    X = factors @ mix + 0.6 * rng.normal(size=(n, d))
    X = np.tanh(X)                                   # bounded like scaled data
    w_true = rng.normal(size=d) * 1.1
    w_true[rng.permutation(d)[: d // 3]] = 0.0       # sparse signal
    logits = X @ w_true + 0.3 * rng.normal(size=n)
    noise = rng.logistic(size=n) * 3.1               # Bayes-limits AUC≈0.71
    thresh = np.quantile(logits + noise, 0.78)       # ~22% default rate
    y = np.where(logits + noise > thresh, 1.0, -1.0)
    return X.astype(np.float64), y


def dvisits(n: int = 5190, d: int = 19, seed: int = 1):
    rng = np.random.default_rng(seed)
    X = np.concatenate([
        rng.binomial(1, rng.uniform(0.2, 0.7, size=8), size=(n, 8)),
        np.clip(rng.normal(0.4, 0.3, size=(n, 6)), 0, 1.5),
        rng.uniform(0, 1, size=(n, d - 14)),
    ], axis=1)
    w_true = rng.normal(size=d) * 0.35
    eta = X @ w_true
    eta = eta - eta.mean() + np.log(0.30)            # mean visits ≈ 0.30
    lam = np.exp(np.clip(eta, -6, 2.5))
    y = rng.poisson(lam).astype(np.float64)
    return X.astype(np.float64), y


def train_test_split(X, y, ratio: float = 0.7, seed: int = 42):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(X))
    cut = int(len(X) * ratio)
    tr, te = idx[:cut], idx[cut:]
    return (X[tr], y[tr]), (X[te], y[te])
