"""Optimizers with shardable pytree states (state mirrors the param tree,
so pjit shardings transfer 1:1)."""
from repro.optim.optimizers import (adamw, clip_by_global_norm,
                                    make_optimizer, momentum, sgd)

__all__ = ["sgd", "momentum", "adamw", "make_optimizer",
           "clip_by_global_norm"]
