"""SGD / momentum / AdamW — minimal, pjit-friendly.

`Optimizer` is a pair of pure functions:
  init(params)               -> opt_state (pytree mirroring params)
  update(grads, state, params, lr) -> (new_params, new_state)

State mirrors the param tree leaf-for-leaf so the launcher can reuse the
parameter PartitionSpecs for the optimizer state (ZeRO-style sharding for
free under FSDP specs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def sgd() -> Optimizer:
    return Optimizer(
        name="sgd",
        init=lambda params: (),
        update=lambda grads, state, params, lr: (
            jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                       - lr * g.astype(jnp.float32)
                                       ).astype(p.dtype), params, grads),
            state),
    )


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p), params)

    def update(grads, m, params, lr):
        m = jax.tree.map(lambda mi, g: beta * mi.astype(jnp.float32)
                         + g.astype(jnp.float32), m, grads)
        new_p = jax.tree.map(lambda p, mi: (p.astype(jnp.float32)
                                            - lr * mi).astype(p.dtype),
                             params, m)
        return new_p, jax.tree.map(lambda p, mi: mi.astype(p.dtype),
                                   params, m)

    return Optimizer("momentum", init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mi, vi):
            step = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        return (jax.tree.map(upd, params, m, v),
                {"m": m, "v": v, "t": t})

    return Optimizer("adamw", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](**kw)
