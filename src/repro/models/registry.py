"""Uniform model API over the four families (dense/moe/vlm decoder, rwkv6,
zamba2 hybrid, whisper enc-dec) — what the launcher, dry-run and smoke
tests program against."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import mamba2, rwkv6, transformer, whisper


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    train_loss: Callable[[Any, dict], jnp.ndarray]
    init_decode_state: Callable[[int, int], Any]
    decode_step: Callable[..., tuple]      # (params, state, token, **extras)
    prefill: Optional[Callable] = None


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        return ModelAPI(
            cfg=cfg,
            init_params=lambda k: transformer.init_params(k, cfg),
            train_loss=lambda p, b: transformer.train_loss(p, cfg, b),
            init_decode_state=lambda b, s: transformer.init_cache(cfg, b, s),
            decode_step=lambda p, st, tok, **kw: transformer.decode_step(
                p, cfg, st, tok),
            prefill=lambda p, tok, max_len, **kw: transformer.prefill(
                p, cfg, tok, max_len, **kw),
        )
    if cfg.family == "ssm":
        return ModelAPI(
            cfg=cfg,
            init_params=lambda k: rwkv6.init_params(k, cfg),
            train_loss=lambda p, b: rwkv6.train_loss(p, cfg, b),
            init_decode_state=lambda b, s: rwkv6.init_state(cfg, b, s),
            decode_step=lambda p, st, tok, **kw: rwkv6.decode_step(
                p, cfg, st, tok),
        )
    if cfg.family == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init_params=lambda k: mamba2.init_params(k, cfg),
            train_loss=lambda p, b: mamba2.train_loss(p, cfg, b),
            init_decode_state=lambda b, s: mamba2.init_state(cfg, b, s),
            decode_step=lambda p, st, tok, **kw: mamba2.decode_step(
                p, cfg, st, tok),
        )
    if cfg.family == "audio":
        return ModelAPI(
            cfg=cfg,
            init_params=lambda k: whisper.init_params(k, cfg),
            train_loss=lambda p, b: whisper.train_loss(p, cfg, b),
            init_decode_state=lambda b, s: whisper.init_cache(cfg, b, s),
            decode_step=lambda p, st, tok, enc_out=None, **kw:
                whisper.decode_step(p, cfg, st, tok, enc_out),
        )
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for every model input (dry-run contract)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Returns {name: ShapeDtypeStruct} for the *data* inputs of the cell's
    step function (params/opt/cache specs are built by the launcher from
    jax.eval_shape)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cell.kind == "train":
        batch = {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((B, cfg.vision_patches,
                                           cfg.d_model), bf16)
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), bf16)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": _sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((B, cfg.vision_patches,
                                           cfg.d_model), bf16)
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), bf16)
        return batch
    # decode: one new token against a seq_len-deep state
    batch = {"token": _sds((B, 1), i32)}
    if cfg.family == "audio":
        batch["enc_out"] = _sds((B, cfg.encoder_seq, cfg.d_model), bf16)
    return batch
