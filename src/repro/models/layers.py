"""Shared neural building blocks (pure-functional, init/apply style).

Conventions:
* params are plain nested dicts of jnp arrays (pytree-friendly for pjit),
* compute dtype comes from the config (`bf16` default), params stored in
  the same dtype; softmax/norm statistics and the loss run in f32,
* every init takes an explicit PRNG key chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, fan_in: int, fan_out: int, dtype,
               scale: float | None = None) -> jnp.ndarray:
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, (fan_in, fan_out),
                                        jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d), jnp.float32)
            ).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings (+ simple M-RoPE-compatible section stub)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    """theta may be a python float or a traced scalar (per-layer thetas
    ride through lax.scan in gemma3's 5:1 local:global pattern)."""
    expo = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return jnp.asarray(theta, jnp.float32) ** (-expo)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta
               ) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (llama-style) / plain MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, f, dtype),
         "down": dense_init(ks[1], f, d, dtype)}
    if act == "silu":             # gated variant
        p["gate"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ p["up"]
    if "gate" in p:
        h = h * act_fn(act)(x @ p["gate"])
    else:
        h = act_fn(act)(h)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def lm_head_apply(embed: jnp.ndarray, head: jnp.ndarray | None,
                  x: jnp.ndarray, softcap: float | None) -> jnp.ndarray:
    w = embed.T if head is None else head
    logits = (x @ w).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (…, V) f32, labels (…) int32 — mean NLL (ignore label < 0)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0),
                               axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
