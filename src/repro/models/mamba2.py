"""Mamba2 (SSD) blocks + the Zamba2 hybrid stack (arXiv:2411.15242).

Mamba2 core: per-head scalar decay a_t = exp(dt·A), state (heads, P, N):
    h_t = a_t · h_{t-1} + dt · x_t ⊗ B_t          (outer over state dim N)
    y_t = h_t · C_t + D ⊙ x_t
with a short causal conv on the (x, B, C) stream and a silu(z) output gate.

Zamba2 layout: `n_layers` Mamba2 blocks; every `shared_attn_every` blocks
a *weight-shared* full transformer block (MHA kv=heads + MLP) is applied —
the paper's trick for attention quality at SSM cost.  Two shared blocks
alternate, as in Zamba2-7B.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention, layers
from repro.models.attention import AttnSpec, KVCache

P_HEAD = 64      # mamba2 head channel dim


class MambaState(NamedTuple):
    h: jnp.ndarray         # (L, B, H_m, P, N) ssm state
    conv: jnp.ndarray      # (L, B, conv_w-1, conv_dim) conv tail
    attn_k: jnp.ndarray    # (n_shared, B, S_max, H, hd) shared-attn cache
    attn_v: jnp.ndarray
    length: jnp.ndarray


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    n_heads_m = d_inner // P_HEAD
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    return d_inner, n_heads_m, N, conv_dim


def _mamba_init(cfg: ModelConfig, key) -> dict:
    dt = layers.dtype_of(cfg)
    d = cfg.d_model
    d_inner, hm, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((d,), dt),
        "w_in": layers.dense_init(ks[0], d, d_inner + conv_dim + hm, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "A_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, hm)), jnp.float32),
        "dt_bias": jnp.zeros((hm,), jnp.float32),
        "D": jnp.ones((hm,), jnp.float32),
        "w_out": layers.dense_init(ks[2], d_inner, d, dt),
        "gn": jnp.ones((d_inner,), dt),
    }


def _shared_block_init(cfg: ModelConfig, key) -> dict:
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    spec = _shared_spec(cfg)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": attention.init(ks[0], cfg.d_model, spec, dt),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def _shared_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                    causal=True, norm_eps=cfg.norm_eps)


def init_params(key, cfg: ModelConfig) -> dict:
    dt = layers.dtype_of(cfg)
    k_emb, k_head, k_layers, k_sh = jax.random.split(key, 4)
    stacked = jax.vmap(lambda k: _mamba_init(cfg, k))(
        jax.random.split(k_layers, cfg.n_layers))
    shared = [_shared_block_init(cfg, k) for k in jax.random.split(k_sh, 2)]
    return {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "head": layers.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
        "layers": stacked,
        "shared": shared,
    }


def _causal_conv(x, w, tail):
    """x: (B, T, C); w: (K, C); tail: (B, K-1, C) from previous chunk."""
    K = w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_tail = xp[:, -(K - 1):] if K > 1 else tail
    return out, new_tail


def _mamba_block(cfg, p, x, h0, conv_tail):
    B, T, D = x.shape
    d_inner, hm, N, conv_dim = _dims(cfg)
    hin = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = hin @ p["w_in"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., -hm:]
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], conv_tail)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(B, T, hm, P_HEAD).astype(jnp.float32)
    Bt = xbc[..., d_inner:d_inner + N].astype(jnp.float32)      # (B, T, N)
    Ct = xbc[..., d_inner + N:].astype(jnp.float32)             # (B, T, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"])[None, None] * dt)          # (B, T, hm)

    def step(h, xs_t):
        xt, bt, ct, at, dtt = xs_t
        dx = (dtt[..., None] * xt)                               # (B,hm,P)
        h = at[..., None, None] * h + dx[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs_seq = (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(Bt, 1, 0),
              jnp.moveaxis(Ct, 1, 0), jnp.moveaxis(a, 1, 0),
              jnp.moveaxis(dt, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs_seq)
    y = jnp.moveaxis(ys, 0, 1)                                   # (B,T,hm,P)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = y * p["gn"]
    y = y * jax.nn.silu(z)
    return x + y @ p["w_out"], h, new_tail


def _apply_shared(cfg, p, x, cache: KVCache | None):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attention.apply(p["attn"], h, _shared_spec(cfg),
                                   cache=cache, kv_block=2048)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + layers.mlp_apply(p["mlp"], h, cfg.act), new_cache


def _shared_positions(cfg: ModelConfig) -> list[int]:
    k = cfg.shared_attn_every
    return [] if not k else list(range(k - 1, cfg.n_layers, k))


def forward(params, cfg: ModelConfig, tokens,
            state: MambaState | None = None, max_len: int | None = None):
    """Groups of `shared_attn_every` scanned Mamba blocks interleaved with
    the two alternating shared attention blocks (unrolled: ~13 groups)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, D = x.shape
    d_inner, hm, N, conv_dim = _dims(cfg)
    positions = _shared_positions(cfg)
    n_sh_apps = len(positions)
    decode = state is not None and T == 1
    if state is None:
        state = init_state(cfg, B, max_len or T)

    # scan chunks of mamba layers between shared-attn applications
    bounds = [0] + [p + 1 for p in positions]
    if bounds[-1] != cfg.n_layers:
        bounds.append(cfg.n_layers)
    new_h, new_tails = [], []
    attn_caches = []
    app_i = 0
    for gi in range(len(bounds) - 1):
        lo, hi = bounds[gi], bounds[gi + 1]
        seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
        seg_h = state.h[lo:hi]
        seg_tail = state.conv[lo:hi]

        def body(x, xs):
            p, h0, tail = xs
            x, h, ntail = _mamba_block(cfg, p, x, h0, tail)
            return x, (h, ntail)

        body_fn = jax.checkpoint(body) if (cfg.remat and not decode) else body
        x, (hs, tails) = jax.lax.scan(body_fn, x, (seg, seg_h, seg_tail))
        new_h.append(hs)
        new_tails.append(tails)
        if hi - 1 in positions:      # shared block after this group
            shared_p = params["shared"][app_i % 2]
            if decode:
                lc = KVCache(state.attn_k[app_i], state.attn_v[app_i],
                             state.length)
                x, nc = _apply_shared(cfg, shared_p, x, lc)
                attn_caches.append((nc.k, nc.v))
            else:
                x, kv = _apply_shared(cfg, shared_p, x, None)
                if max_len is not None:
                    k, v = kv
                    pad = [(0, 0), (0, max(max_len - T, 0)), (0, 0), (0, 0)]
                    attn_caches.append((jnp.pad(k, pad).astype(jnp.bfloat16),
                                        jnp.pad(v, pad).astype(jnp.bfloat16)))
            app_i += 1
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_apply(params["embed"], params.get("head"), x,
                                  cfg.logits_softcap)
    h_all = jnp.concatenate(new_h, axis=0)
    tails_all = jnp.concatenate(new_tails, axis=0)
    if attn_caches:
        ak = jnp.stack([c[0] for c in attn_caches])
        av = jnp.stack([c[1] for c in attn_caches])
    else:
        ak, av = state.attn_k, state.attn_v
    return logits, MambaState(h_all, tails_all, ak, av, state.length + T)


def train_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    logits, _ = forward(params, cfg, batch["tokens"])
    return layers.cross_entropy(logits, batch["labels"])


def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> MambaState:
    d_inner, hm, N, conv_dim = _dims(cfg)
    n_sh = len(_shared_positions(cfg))
    return MambaState(
        h=jnp.zeros((cfg.n_layers, batch, hm, P_HEAD, N), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim),
                       dtype),
        attn_k=jnp.zeros((max(n_sh, 1), batch, max_len, cfg.n_kv_heads,
                          cfg.hd), dtype),
        attn_v=jnp.zeros((max(n_sh, 1), batch, max_len, cfg.n_kv_heads,
                          cfg.hd), dtype),
        length=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ModelConfig, state: MambaState, token):
    logits, new_state = forward(params, cfg, token, state)
    return logits[:, 0], new_state
