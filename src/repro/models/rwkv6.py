"""RWKV6 "Finch" — attention-free token mixing with data-dependent decay
(arXiv:2404.05892).

Faithful core: per-channel data-dependent decay w_t produced by a LoRA on
the token-shifted input (THE Finch contribution), matrix-valued recurrent
state per head
    S_t[i,j] = w_t[i]·S_{t-1}[i,j] + k_t[i]·v_t[j]
    y_t[j]   = Σ_i r_t[i]·(S_{t-1}[i,j] + u[i]·k_t[i]·v_t[j])
plus the squared-ReLU channel-mix FFN.  Simplification (DESIGN.md):
receptance/key/value/gate token-shift mixes use static μ interpolation
(the dynamic-mix LoRAs are folded into the decay LoRA only).

Training/prefill run a lax.scan over time (the chunked-parallel form is a
§Perf candidate); decode carries O(1) state — which is why this arch (and
only the SSM/hybrid family) runs the long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers

LORA_R = 64


class RWKVState(NamedTuple):
    """Per-layer stacked decode state."""
    S: jnp.ndarray        # (L, B, H, hd, hd) wkv state
    x_tm: jnp.ndarray     # (L, B, D) previous token (time-mix shift)
    x_cm: jnp.ndarray     # (L, B, D) previous token (channel-mix shift)
    length: jnp.ndarray   # () int32


def _layer_init(cfg: ModelConfig, key) -> dict:
    dt = layers.dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    dec_init = np.linspace(-6.0, -0.5, d).astype(np.float32)
    return {
        "ln1": jnp.zeros((d,), dt), "ln2": jnp.zeros((d,), dt),
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dt),
        "wr": layers.dense_init(ks[1], d, d, dt),
        "wk": layers.dense_init(ks[2], d, d, dt),
        "wv": layers.dense_init(ks[3], d, d, dt),
        "wg": layers.dense_init(ks[4], d, d, dt),
        "wo": layers.dense_init(ks[5], d, d, dt),
        "w0": jnp.asarray(dec_init, dt),                      # decay base
        "wA": layers.dense_init(ks[6], d, LORA_R, dt),        # decay LoRA
        "wB": layers.dense_init(ks[7], LORA_R, d, dt),
        "u": (jax.random.normal(ks[8], (d,), jnp.float32) * 0.1).astype(dt),
        "gn": jnp.ones((d,), dt),                             # group norm
        # channel-mix
        "mu_c": (jax.random.uniform(ks[9], (2, d), jnp.float32)).astype(dt),
        "ck": layers.dense_init(ks[10], d, cfg.d_ff, dt),
        "cv": layers.dense_init(ks[11], cfg.d_ff, d, dt),
        "cr": layers.dense_init(jax.random.fold_in(key, 99), d, d, dt),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    dt = layers.dtype_of(cfg)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(
        jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "head": layers.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
        "layers": stacked,
    }


def _decay(p, xw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent per-channel decay in (0,1): the Finch LoRA."""
    lora = jnp.tanh(xw @ p["wA"]) @ p["wB"]
    return jnp.exp(-jnp.exp((p["w0"] + lora).astype(jnp.float32)))


def wkv_scan(r, k, v, w, u, S0):
    """r/k/v/w: (B, T, H, hd) f32; u: (H, hd); S0: (B, H, hd, hd).
    Returns y (B, T, H, hd) and final state."""
    rt_ = jnp.moveaxis(r, 1, 0)
    kt_ = jnp.moveaxis(k, 1, 0)
    vt_ = jnp.moveaxis(v, 1, 0)
    wt_ = jnp.moveaxis(w, 1, 0)

    def step(S, xs):
        rt, kt, vt, wt = xs                                   # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]              # (B, H, hd, hd)
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    S, ys = jax.lax.scan(step, S0, (rt_, kt_, vt_, wt_))
    return jnp.moveaxis(ys, 0, 1), S


def _time_mix(cfg, p, x, x_prev):
    """x: (B, T, D); x_prev: (B, D) last token of previous chunk."""
    B, T, D = x.shape
    H = cfg.n_heads
    hd = D // H
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    delta = xs - x
    mix = lambda i: x + delta * p["mu"][i]
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, T, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    return r, k, v, g, w, u, x[:, -1]


def _group_norm(y, eps):
    """Per-head normalization of the wkv output (RWKV6 ln_x)."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps)


def _channel_mix(p, x, x_prev):
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    delta = xs - x
    xk = x + delta * p["mu_c"][0]
    xr = x + delta * p["mu_c"][1]
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return (kk @ p["cv"]) * jax.nn.sigmoid(xr @ p["cr"]), x[:, -1]


def _block(cfg, p, x, state_S, x_tm, x_cm):
    B, T, D = x.shape
    H = cfg.n_heads
    hd = D // H
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    r, k, v, g, w, u, new_xtm = _time_mix(cfg, p, h, x_tm)
    y, S = wkv_scan(r, k, v, w, u, state_S)
    y = _group_norm(y, cfg.norm_eps).reshape(B, T, D).astype(x.dtype)
    y = y * p["gn"]
    x = x + ((y * g) @ p["wo"])
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    cm, new_xcm = _channel_mix(p, h2, x_cm)
    return x + cm, S, new_xtm, new_xcm


def forward(params, cfg: ModelConfig, tokens, state: RWKVState | None = None):
    """Training / prefill.  Returns (logits, final RWKVState)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, D = x.shape
    H, hd = cfg.n_heads, D // cfg.n_heads
    if state is None:
        state = RWKVState(
            S=jnp.zeros((cfg.n_layers, B, H, hd, hd), jnp.float32),
            x_tm=jnp.zeros((cfg.n_layers, B, D), x.dtype),
            x_cm=jnp.zeros((cfg.n_layers, B, D), x.dtype),
            length=jnp.zeros((), jnp.int32))

    def body(x, xs):
        p, S0, xtm, xcm = xs
        x, S, ntm, ncm = _block(cfg, p, x, S0, xtm, xcm)
        return x, (S, ntm, ncm)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (S, xtm, xcm) = jax.lax.scan(
        body_fn, x, (params["layers"], state.S, state.x_tm, state.x_cm))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_apply(params["embed"], params.get("head"), x,
                                  cfg.logits_softcap)
    return logits, RWKVState(S, xtm, xcm, state.length + T)


def train_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    logits, _ = forward(params, cfg, batch["tokens"])
    return layers.cross_entropy(logits, batch["labels"])


def init_state(cfg: ModelConfig, batch: int, _max_len: int, dtype=jnp.bfloat16
               ) -> RWKVState:
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    return RWKVState(
        S=jnp.zeros((cfg.n_layers, batch, H, hd, hd), jnp.float32),
        x_tm=jnp.zeros((cfg.n_layers, batch, D), dtype),
        x_cm=jnp.zeros((cfg.n_layers, batch, D), dtype),
        length=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ModelConfig, state: RWKVState, token):
    logits, new_state = forward(params, cfg, token, state)
    return logits[:, 0], new_state
