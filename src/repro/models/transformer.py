"""Decoder-only transformer (dense GQA / MoE / mixed local-global) — covers
minitron, starcoder2, gemma3, qwen3, olmoe, kimi-k2 and the qwen2-vl text
backbone.

Layers are scan-stacked (params have a leading L axis): compile time and
HLO size stay O(1) in depth — essential for the 61–80 layer dry-runs.
Per-layer heterogeneity (gemma3's 5:1 local:global windows and dual rope
thetas) rides through the scan as traced per-layer arrays, not control
flow.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe
from repro.models.attention import AttnSpec, KVCache

FULL_WINDOW = 1 << 30


def attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm, causal=True,
        window=None, softcap=cfg.attn_logit_softcap, norm_eps=cfg.norm_eps,
        kv_repeat=cfg.kv_head_replication)


def layer_meta(cfg: ModelConfig) -> dict[str, np.ndarray]:
    """Per-layer traced metadata: sliding window + rope theta."""
    L = cfg.n_layers
    window = np.full((L,), FULL_WINDOW, np.int32)
    theta = np.full((L,), cfg.rope_theta, np.float32)
    if cfg.local_global_ratio and cfg.sliding_window:
        r = cfg.local_global_ratio
        for i in range(L):
            if (i % (r + 1)) != r:            # local layer
                window[i] = cfg.sliding_window
            else:                             # global layer: long-rope theta
                theta[i] = 1e6
    return {"window": window, "theta": theta}


def _layer_init(cfg: ModelConfig, key) -> dict:
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": attention.init(ks[0], cfg.d_model, attn_spec(cfg), dt),
    }
    if cfg.n_experts:
        p["moe"] = moe.init(ks[1], cfg.d_model, cfg.n_experts,
                            cfg.moe_d_ff or cfg.d_ff, dt)
    else:
        p["mlp"] = layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dt = layers.dtype_of(cfg)
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(lkeys)
    params = {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(
            k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


def _block(cfg: ModelConfig, p, x, positions, window, theta,
           cache: Optional[KVCache], kv_block: Optional[int]):
    spec = dataclasses.replace(attn_spec(cfg), window=window,
                               rope_theta=theta)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, cache_out = attention.apply(p["attn"], h, spec, positions=positions,
                                   cache=cache, kv_block=kv_block)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        mo, aux = moe.apply(p["moe"], h, k=cfg.experts_per_token,
                            impl=cfg.moe_impl,
                            capacity_factor=cfg.capacity_factor)
        x = x + mo
    else:
        x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
    return x, cache_out, aux


def _fit_kv_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (blockwise attention needs
    KV length % block == 0; vision-prefixed sequences aren't powers of 2)."""
    for b in range(min(target, S), 0, -1):
        if S % b == 0:
            return b
    return S


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            vision_embeds: Optional[jnp.ndarray] = None,
            kv_block: Optional[int] = 2048,
            collect_cache: bool = False):
    """Training / prefill forward.  Returns (logits, stacked_cache|None,
    aux_loss)."""
    meta = layer_meta(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if kv_block is not None and S > kv_block:
        kv_block = _fit_kv_block(S, kv_block)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, xs):
        x, aux = carry
        p, window, theta = xs
        x, cache_out, aux_i = _block(cfg, p, x, positions, window, theta,
                                     None, kv_block)
        ys = cache_out if collect_cache else None
        return (x, aux + aux_i), ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), caches = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], jnp.asarray(meta["window"]),
         jnp.asarray(meta["theta"])),
        unroll=cfg.n_layers if cfg.debug_unroll else 1)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_apply(params["embed"], params.get("head"), x,
                                  cfg.logits_softcap)
    return logits, caches, aux


def train_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    logits, _, aux = forward(params, cfg, batch["tokens"],
                             vision_embeds=batch.get("vision_embeds"))
    labels = batch["labels"]
    if batch.get("vision_embeds") is not None:
        pad = -jnp.ones(batch["vision_embeds"].shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return layers.cross_entropy(logits, labels) + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

class StackedCache(NamedTuple):
    k: jnp.ndarray           # (L, B, S_max, K, hd)
    v: jnp.ndarray
    length: jnp.ndarray      # () int32


class StackedCacheQ(NamedTuple):
    """int8 KV cache (§Perf knob): halves decode HBM traffic and the
    seq-sharded cache gather; per-(position, head) bf16 scales."""
    k: jnp.ndarray           # (L, B, S_max, K, hd) int8
    v: jnp.ndarray
    k_scale: jnp.ndarray     # (L, B, S_max, K, 1) bf16
    v_scale: jnp.ndarray
    length: jnp.ndarray


def _quant(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), -1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequant(q, scale):
    return q.astype(jnp.bfloat16) * scale


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len,
             cfg.n_kv_heads * cfg.kv_head_replication, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return StackedCacheQ(jnp.zeros(shape, jnp.int8),
                             jnp.zeros(shape, jnp.int8),
                             jnp.zeros(sshape, jnp.bfloat16),
                             jnp.zeros(sshape, jnp.bfloat16),
                             jnp.zeros((), jnp.int32))
    return StackedCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.zeros((), jnp.int32))


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray, max_len: int,
            vision_embeds=None):
    """Run the prompt, materialize the cache (padded to max_len)."""
    logits, caches, _ = forward(params, cfg, tokens,
                                vision_embeds=vision_embeds,
                                collect_cache=True)
    k, v = caches   # (L, B, S, K, hd)
    S = k.shape[2]  # may exceed max_len when vision patches are prepended
    pad = [(0, 0), (0, 0), (0, max(max_len - S, 0)), (0, 0), (0, 0)]
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant(jnp.pad(k, pad))
        vq, vs = _quant(jnp.pad(v, pad))
        return logits[:, -1], StackedCacheQ(kq, vq, ks, vs,
                                            jnp.asarray(S, jnp.int32))
    cache = StackedCache(jnp.pad(k, pad).astype(jnp.bfloat16),
                         jnp.pad(v, pad).astype(jnp.bfloat16),
                         jnp.asarray(S, jnp.int32))
    return logits[:, -1], cache


def decode_step(params, cfg: ModelConfig, cache, token: jnp.ndarray):
    """token: (B, 1) int32 → (logits (B, V), new cache).  Scans layers,
    threading each layer's cache slice through ys (in-place via donation
    on real hardware).  int8 caches are dequantized inside the layer body
    (HBM reads stay int8; dequant fuses into the attention compute)."""
    meta = layer_meta(cfg)
    x = jnp.take(params["embed"], token, axis=0)
    B = x.shape[0]
    positions = jnp.broadcast_to(cache.length, (B, 1))
    quant = isinstance(cache, StackedCacheQ)

    def body(carry, xs):
        x = carry
        if quant:
            p, window, theta, ck, cv, ks, vs = xs
            lc = KVCache(_dequant(ck, ks), _dequant(cv, vs), cache.length)
        else:
            p, window, theta, ck, cv = xs
            lc = KVCache(ck, cv, cache.length)
        x, new_cache, _ = _block(cfg, p, x, positions, window, theta,
                                 lc, None)
        if quant:
            nk, nks = _quant(new_cache.k)
            nv, nvs = _quant(new_cache.v)
            return x, (nk, nv, nks, nvs)
        return x, (new_cache.k, new_cache.v)

    meta_xs = (params["layers"], jnp.asarray(meta["window"]),
               jnp.asarray(meta["theta"]))
    if quant:
        x, (nk, nv, nks, nvs) = jax.lax.scan(
            body, x, meta_xs + (cache.k, cache.v,
                                cache.k_scale, cache.v_scale))
        new = StackedCacheQ(nk, nv, nks, nvs, cache.length + 1)
    else:
        x, (nk, nv) = jax.lax.scan(body, x, meta_xs + (cache.k, cache.v))
        new = StackedCache(nk, nv, cache.length + 1)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_apply(params["embed"], params.get("head"), x,
                                  cfg.logits_softcap)
    return logits[:, 0], new
