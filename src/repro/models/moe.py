"""Mixture-of-Experts layer (olmoe 64e/top-8, kimi-k2 384e/top-8).

Two dispatch implementations (selectable, compared in §Perf):

* `sorted` (default) — dropless-style: flatten (token, choice) pairs, sort
  by expert id, compute position-in-expert from segment starts, scatter
  into a (E, capacity, D) buffer, batched expert GEMM, scatter-add back.
  HLO FLOPs = true active-expert FLOPs (× capacity slack) — the honest
  cost_analysis accounting for the roofline.
* `dense` — every expert on every token with routing masks.  Partitioning
  is trivially robust but FLOPs inflate by E/k; kept as a fallback and as
  the §Perf baseline comparator.

Experts shard over the `model` mesh axis (EP); tokens stay sharded over
`data`.  The scatter/gather are local because activations are replicated
across `model` at the block boundary (Megatron-style TP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


def init(key, d: int, n_experts: int, d_exp: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    E = n_experts
    s = 1.0 / np.sqrt(d)
    return {
        "router": layers.dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.truncated_normal(
            ks[1], -2, 2, (E, d, d_exp), jnp.float32) * s).astype(dtype),
        "w_up": (jax.random.truncated_normal(
            ks[2], -2, 2, (E, d, d_exp), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.truncated_normal(
            ks[3], -2, 2, (E, d_exp, d), jnp.float32)
            / np.sqrt(d_exp)).astype(dtype),
    }


def _route(p, xf, k: int):
    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary (Switch-style): E · Σ_e f_e · p̄_e
    E = logits.shape[-1]
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        1.0) / (xf.shape[0] * k)
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(dispatch_frac * mean_prob)
    return topv, topi, aux


def apply_sorted(p, x: jnp.ndarray, k: int, capacity_factor: float):
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    topv, topi, aux = _route(p, xf, k)
    E = p["w_down"].shape[0]
    C = int(np.ceil(T * k / E * capacity_factor / 8)) * 8

    flat_e = topi.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    token_of = order // k
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))    # (E,)
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    slot = sorted_e * C + pos_in_e
    slot = jnp.where(pos_in_e < C, slot, E * C)              # drop overflow

    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(
        xf[token_of], mode="drop")[:-1]
    buf = buf.reshape(E, C, D)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    gathered = jnp.take(jnp.concatenate(
        [out_buf, jnp.zeros((1, D), out_buf.dtype)], 0), slot, axis=0)
    weight = topv.reshape(-1)[order].astype(gathered.dtype)
    contrib = gathered * weight[:, None]
    out = jnp.zeros((T, D), x.dtype).at[token_of].add(contrib)
    return out.reshape(B, S, D), aux


def apply_dense(p, x: jnp.ndarray, k: int):
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    topv, topi, aux = _route(p, xf, k)
    E = p["w_down"].shape[0]
    gate_w = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], topi].set(topv)
    h = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("td,edf->tef", xf, p["w_up"])
    y = jnp.einsum("tef,efd->ted", h, p["w_down"])
    out = jnp.einsum("ted,te->td", y, gate_w.astype(y.dtype))
    return out.reshape(B, S, D), aux


def apply(p, x, *, k: int, impl: str, capacity_factor: float = 1.25):
    if impl == "dense":
        return apply_dense(p, x, k)
    return apply_sorted(p, x, k, capacity_factor)
