"""GQA attention: full, sliding-window, blockwise (memory-efficient) and
single-token decode against a KV cache.

Blockwise attention (lax.scan over KV chunks with an online softmax) keeps
the S×S score matrix out of memory for the 32k-prefill cells — the
pure-JAX analogue of flash attention, chosen deliberately so the dry-run's
`cost_analysis()` sees real FLOPs (a Pallas kernel would hide them behind
a custom call).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    qk_norm: bool = False
    causal: bool = True
    window: Optional[int] = None         # sliding window (None = full)
    softcap: Optional[float] = None
    norm_eps: float = 1e-6
    kv_repeat: int = 1                   # KV-head replication (§Perf knob)

    @property
    def kv_eff(self) -> int:
        eff = self.n_kv_heads * self.kv_repeat
        if eff > self.n_heads:
            raise ValueError(
                f"kv_head_replication too large: {eff} KV > {self.n_heads} "
                "query heads (max replication = n_heads // n_kv_heads)")
        return eff


def init(key, d_model: int, spec: AttnSpec, dtype) -> dict:
    ks = jax.random.split(key, 5)
    H, K, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    p = {
        "wq": layers.dense_init(ks[0], d_model, H * hd, dtype),
        "wk": layers.dense_init(ks[1], d_model, K * hd, dtype),
        "wv": layers.dense_init(ks[2], d_model, K * hd, dtype),
        "wo": layers.dense_init(ks[3], H * hd, d_model, dtype),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, x, spec: AttnSpec, positions):
    B, S, _ = x.shape
    H, K, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if spec.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], spec.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], spec.norm_eps)
    if spec.rope_theta is not None:     # theta may be traced (per-layer)
        q = layers.apply_rope(q, positions, spec.rope_theta)
        k = layers.apply_rope(k, positions, spec.rope_theta)
    if spec.kv_repeat > 1:              # duplicate KV heads (exact; lets
        k = jnp.repeat(k, spec.kv_repeat, axis=2)   # the cache shard on
        v = jnp.repeat(v, spec.kv_repeat, axis=2)   # the head dim)
    return q, k, v


def _scores_to_out(scores, v_g, softcap):
    # scores: (B, G, Hg, Sq, Sk) f32; v_g: (B, Sk, G, hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bghqk,bkgd->bqghd", probs,
                      v_g.astype(jnp.float32))


def _grouped(q, k, v, n_kv):
    """Reshape q to (B, S, G, Hg, hd) grouping query heads per KV head."""
    B, S, H, hd = q.shape
    G = n_kv
    return q.reshape(B, S, G, H // G, hd), k, v


def full_attention(q, k, v, spec: AttnSpec, q_offset: int = 0):
    """Materialized-scores GQA (fine for ≤ 8k sequences)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    G = spec.kv_eff
    qg, k, v = _grouped(q, k, v, G)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqghd,bkgd->bghqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if spec.causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if spec.window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - spec.window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    out = _scores_to_out(scores, v, spec.softcap)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def blockwise_attention(q, k, v, spec: AttnSpec, kv_block: int = 1024):
    """Online-softmax attention, scanning KV blocks (O(S·kv_block) memory).
    Causal + optional sliding window."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    assert Sk % kv_block == 0, "pad KV to a block multiple"
    G = spec.kv_eff
    Hg = H // G
    qg = q.reshape(B, Sq, G, Hg, hd).astype(jnp.float32)
    scale = 1.0 / np.sqrt(hd)
    nblk = Sk // kv_block
    kb = jnp.moveaxis(k.reshape(B, nblk, kv_block, G, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, kv_block, G, hd), 1, 0)
    qpos = jnp.arange(Sq)

    def step(carry, xs):
        acc, m, denom, blk = carry
        kblk, vblk = xs
        kpos = blk * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqghd,bkgd->bghqk", qg, kblk.astype(jnp.float32)
                       ) * scale
        if spec.softcap:
            s = jnp.tanh(s / spec.softcap) * spec.softcap
        mask = jnp.ones((Sq, kv_block), bool)
        if spec.causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if spec.window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - spec.window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bghqk,bkgd->bghqd", p, vblk.astype(jnp.float32))
        denom = denom * alpha + p.sum(axis=-1)
        return (acc, m_new, denom, blk + 1), None

    acc0 = jnp.zeros((B, G, Hg, Sq, hd), jnp.float32)
    m0 = jnp.full((B, G, Hg, Sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, G, Hg, Sq), jnp.float32)
    (acc, _, denom, _), _ = jax.lax.scan(step, (acc0, m0, d0, 0), (kb, vb))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_max, K, hd)
    v: jnp.ndarray
    length: jnp.ndarray     # () int32 — filled prefix


def decode_attention(q, cache: KVCache, spec: AttnSpec):
    """One-token query (B, 1, H, hd) against the cache."""
    B, _, H, hd = q.shape
    G = spec.kv_eff
    qg = q.reshape(B, G, H // G, hd).astype(jnp.float32)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bghd,bkgd->bghk", qg, cache.k.astype(jnp.float32)
                   ) * scale
    if spec.softcap:
        s = jnp.tanh(s / spec.softcap) * spec.softcap
    kpos = jnp.arange(cache.k.shape[1])
    valid = kpos < cache.length
    if spec.window is not None:
        valid &= kpos > (cache.length - 1 - spec.window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bghk,bkgd->bghd", p, cache.v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def apply(p, x, spec: AttnSpec, positions=None, cache: KVCache | None = None,
          kv_block: int | None = None, cross_kv=None):
    """Unified entry: training/prefill (cache=None → returns (out, new_kv))
    or decode (cache given → uses cache, returns (out, updated cache)).
    cross_kv: precomputed (k, v) for encoder-decoder cross-attention."""
    B, S, _ = x.shape
    if positions is None:
        base = cache.length if cache is not None else 0
        positions = jnp.broadcast_to(jnp.arange(S) + base, (B, S))
    if cross_kv is not None:
        q = (x @ p["wq"]).reshape(B, S, spec.n_heads, spec.head_dim)
        k, v = cross_kv
        out = full_attention(q, k, v, dataclasses.replace(spec, causal=False),
                             q_offset=0)
        return out.reshape(B, S, -1) @ p["wo"], None
    q, k, v = _project_qkv(p, x, spec, positions)
    if cache is not None:
        if S == 1:   # decode
            newk = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
            newv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
            new_cache = KVCache(newk, newv, cache.length + 1)
            out = decode_attention(q, new_cache, spec)
        else:        # chunked prefill into cache
            newk = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
            newv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
            new_cache = KVCache(newk, newv, cache.length + S)
            out = full_attention(q, newk[:, :], newv[:, :], spec,
                                 q_offset=0)
        return out.reshape(B, S, -1) @ p["wo"], new_cache
    if kv_block and S > kv_block:
        out = blockwise_attention(q, k, v, spec, kv_block)
    else:
        out = full_attention(q, k, v, spec)
    return out.reshape(B, S, -1) @ p["wo"], (k, v)
