"""Whisper backbone (arXiv:2212.04356) — encoder-decoder transformer.

Per the assignment, the conv/mel frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, frames, d_model); the encoder is
the standard bidirectional stack with sinusoidal positions, the decoder a
causal stack with cross-attention (learned positions).  serve_step decodes
one token against (self-KV cache, precomputed cross-KV).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers
from repro.models.attention import AttnSpec, KVCache

MAX_DEC_POS = 1 << 20


def _spec(cfg: ModelConfig, causal: bool) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.hd, rope_theta=None, causal=causal,
                    norm_eps=cfg.norm_eps)


def _enc_layer_init(cfg, key):
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": attention.init(ks[0], cfg.d_model, _spec(cfg, False), dt),
            "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dt)}


def _dec_layer_init(cfg, key):
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "ln_x": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": attention.init(ks[0], cfg.d_model, _spec(cfg, True), dt),
            "xattn": attention.init(ks[1], cfg.d_model, _spec(cfg, False), dt),
            "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dt)}


def init_params(key, cfg: ModelConfig) -> dict:
    dt = layers.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _enc_layer_init(cfg, k))(
        jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(cfg, k))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": layers.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "pos_dec": layers.embed_init(ks[3], 4096, cfg.d_model, dt) * 0.01,
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, d) stub embeddings → encoder states."""
    B, F, D = frames.shape
    pos = jnp.asarray(layers.sinusoid_positions(F, D), frames.dtype)
    x = frames + pos[None]

    def body(x, p):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = attention.apply(p["attn"], h, _spec(cfg, False))
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + layers.mlp_apply(p["mlp"], h, "gelu"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p, enc_out, cfg):
    B, F, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, F, K, hd)
    v = (enc_out @ p["wv"]).reshape(B, F, K, hd)
    return k, v


def decode(params, cfg: ModelConfig, tokens, enc_out,
           cache: "WhisperCache | None" = None, max_len: int | None = None):
    """Teacher-forced decoding (training) or cached single-token decode."""
    B, T = tokens.shape
    base = cache.length if cache is not None else 0
    x = jnp.take(params["embed"], tokens, axis=0)
    posv = jnp.take(params["pos_dec"],
                    (jnp.arange(T) + base) % params["pos_dec"].shape[0],
                    axis=0)
    x = x + posv[None]

    def body(carry, xs):
        x = carry
        if cache is not None:
            p, ck, cv = xs
            lc = KVCache(ck, cv, cache.length)
        else:
            p = xs
            lc = None
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, nc = attention.apply(p["attn"], h, _spec(cfg, True), cache=lc)
        x = x + a
        h = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
        xkv = _cross_kv(p["xattn"], enc_out, cfg)
        a, _ = attention.apply(p["xattn"], h, _spec(cfg, False),
                               cross_kv=xkv)
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        ys = (nc.k, nc.v) if cache is not None else (
            None if max_len is None else nc)
        return x, ys

    if cache is not None:
        body_fn = body
        x, (nk, nv) = jax.lax.scan(
            body_fn, x, (params["dec_layers"], cache.k, cache.v))
        new_cache = WhisperCache(nk, nv, cache.length + T)
    else:
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, kvs = jax.lax.scan(body_fn, x, params["dec_layers"])
        new_cache = None
        if max_len is not None:
            k, v = kvs
            pad = [(0, 0), (0, 0), (0, max_len - T), (0, 0), (0, 0)]
            new_cache = WhisperCache(jnp.pad(k, pad).astype(jnp.bfloat16),
                                     jnp.pad(v, pad).astype(jnp.bfloat16),
                                     jnp.asarray(T, jnp.int32))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_head_apply(params["embed"], None, x, None)
    return logits, new_cache


class WhisperCache(NamedTuple):
    k: jnp.ndarray          # (L, B, S_max, K, hd) decoder self-attn
    v: jnp.ndarray
    length: jnp.ndarray


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> WhisperCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return WhisperCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.zeros((), jnp.int32))


def train_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    enc_out = encode(params, cfg, batch["frames"])
    logits, _ = decode(params, cfg, batch["tokens"], enc_out)
    return layers.cross_entropy(logits, batch["labels"])


def decode_step(params, cfg: ModelConfig, cache: WhisperCache, token,
                enc_out):
    logits, new_cache = decode(params, cfg, token, enc_out, cache=cache)
    return logits[:, 0], new_cache
