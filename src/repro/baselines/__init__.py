"""The paper's comparison frameworks (Table 1 / Table 2 rows).

All baselines run on the byte-exact metered mock-HE/ring backends (the
same wire sizes a Paillier deployment serializes); EFMVFL itself also has
the real-Paillier path (tests assert mock ≡ Paillier).  Quality metrics,
loss curves and communication are therefore directly comparable.
"""
from repro.baselines import ss_glm, ss_he_lr, tp_glm

__all__ = ["tp_glm", "ss_glm", "ss_he_lr"]
