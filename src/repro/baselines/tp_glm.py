"""TP-LR / TP-PR — HE-based VFL *with* a third party (paper's [Kim et al.
2018] / [Hardy et al. 2017] comparators, FATE hetero-GLM shaped).

Roles: C (guest, labels), B1 (host), ARB (arbiter: holds the only HE
keypair, decrypts masked gradients).  Per iteration:

  B1 → C   : [[z_B]]                       (n ciphertexts)
  C  → B1  : [[d]] = ¼([[z_B]]⊕z_C) ⊖ ½y   (n ciphertexts)
  p  → ARB : [[X_p^T d]] ⊕ R_p  (+ [[Σd]]) (m_p + 1 ciphertexts)
  ARB → p  : unmasked-modulo-mask gradient (m_p ring elements)
  C  → ARB : [[Σ loss-terms]], ARB → C: loss   (1 ct + 8 B)

The arbiter sees only masked values but *could* decrypt anything — the
trust gap EFMVFL removes.  Loss here uses the first-order Taylor term
(paper Fig. 1 notes TP-LR's loss is a Taylor approximation of EFMVFL's).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import glm as glm_lib
from repro.core.comm import CommMeter
from repro.core.trainer import PartyData, TrainResult, VFLConfig


def train_tp(parties: list[PartyData], y: np.ndarray, cfg: VFLConfig
             ) -> TrainResult:
    """Third-party HE GLM (logistic or poisson).  Mock-HE compute with
    exact wire accounting; gradient math is float-exact."""
    assert len(parties) == 2, "paper's TP baselines are 2-party"
    model = glm_lib.GLMS[cfg.glm]
    meter = CommMeter()
    rng = np.random.default_rng(cfg.seed)
    n_total = parties[0].X.shape[0]
    W = {p.name: np.zeros(p.X.shape[1]) for p in parties}
    losses: list[float] = []
    t0 = time.perf_counter()
    order = rng.permutation(n_total)
    cursor = 0
    C, B = parties[0], parties[1]

    for it in range(cfg.max_iter):
        if cursor + cfg.batch_size > n_total:
            order = rng.permutation(n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        nb = len(idx)
        z_c = C.X[idx] @ W[C.name]
        z_b = B.X[idx] @ W[B.name]

        if model.needs_exp:
            # TP-PR: B1 sends [[e^{z_B}]]; C forms [[e^{wx}]] = [[e^{z_B}]]⊗e^{z_C}
            meter.cipher(B.name, C.name, "TP.enc_ez", nb, cfg.key_bits)
            wx = z_c + z_b
            d = model.d_float(wx, y[idx])
        else:
            # TP-LR: B1 sends [[z_B]]
            meter.cipher(B.name, C.name, "TP.enc_z", nb, cfg.key_bits)
            wx = z_c + z_b
            d = model.d_float(wx, y[idx])
        # C -> B1: [[d]]
        meter.cipher(C.name, B.name, "TP.enc_d", nb, cfg.key_bits)

        # each party: encrypted masked gradient -> arbiter; plaintext back
        for p in parties:
            m_p = p.X.shape[1]
            meter.cipher(p.name, "ARB", "TP.masked_grad", m_p + 1,
                         cfg.key_bits)
            meter.ring("ARB", p.name, "TP.grad_back", m_p)
            g = p.X[idx].T @ d / nb
            W[p.name] = W[p.name] - cfg.lr * g

        # loss: C aggregates [[Σ t]] (1 ct), arbiter returns the scalar
        meter.cipher(C.name, "ARB", "TP.loss", 1, cfg.key_bits)
        meter.add("ARB", C.name, "TP.loss_back", 8)
        losses.append(model.loss_float(wx, y[idx]))
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            break

    return TrainResult(weights=W, losses=losses, meter=meter,
                       runtime_s=time.perf_counter() - t0, n_iter=len(losses))
