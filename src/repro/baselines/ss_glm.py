"""SS-LR — MPC-only logistic regression (paper's [Wei et al. 2021]
comparator; SecureML-shaped).

Everything — features X, labels y, weights w — is secret-shared over
Z_2^64 and *stays* shared; every product is a Beaver multiplication whose
openings dominate communication (the paper's point: 181.8 MB vs EFMVFL's
26.45 MB).  Runs the genuine ring/Beaver arithmetic (no mock shortcuts).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommMeter
from repro.core.trainer import PartyData, TrainResult, VFLConfig
from repro.crypto import fixed_point, ring
from repro.crypto.ring import R64
from repro.mpc import beaver, sharing, truncation


def _meter_open(meter: CommMeter, shape, tag: str) -> None:
    n = int(np.prod(shape))
    meter.ring("C", "B1", tag, 2 * n)
    meter.ring("B1", "C", tag, 2 * n)


def _bslice(s: tuple[R64, R64], idx) -> tuple[R64, R64]:
    return (R64(s[0].hi[idx], s[0].lo[idx]), R64(s[1].hi[idx], s[1].lo[idx]))


def train_ss(parties: list[PartyData], y: np.ndarray, cfg: VFLConfig
             ) -> TrainResult:
    assert cfg.glm == "logistic", "paper's SS baseline is LR"
    assert len(parties) == 2
    meter = CommMeter()
    rng = np.random.default_rng(cfg.seed)
    jkey = jax.random.key(cfg.seed)
    dealer = beaver.DealerTripleSource(seed=cfg.seed + 1)
    f = cfg.f
    X = np.concatenate([p.X for p in parties], axis=1)
    n_total, m = X.shape
    t0 = time.perf_counter()

    # one-time: share ALL the data (the SS-family overhead EFMVFL avoids)
    jkey, k1, k2, k3 = jax.random.split(jkey, 4)
    Xs = sharing.share(fixed_point.encode(X, f), k1)
    meter.ring("C", "B1", "SS.init_X", parties[0].X.size)
    meter.ring("B1", "C", "SS.init_X", parties[1].X.size)
    ys = sharing.share(fixed_point.encode(y, f), k2)
    meter.ring("C", "B1", "SS.init_y", n_total)
    ws = sharing.share(fixed_point.encode(np.zeros(m), f), k3)

    losses: list[float] = []
    order = rng.permutation(n_total)
    cursor = 0
    # lr/nb is tiny — encode with 12 extra fractional bits, truncate f+12
    extra = 12
    lr_fixed = int(round(cfg.lr / cfg.batch_size * (1 << (f + extra))))

    for it in range(cfg.max_iter):
        if cursor + cfg.batch_size > n_total:
            order = rng.permutation(n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        nb = len(idx)
        Xb = _bslice(Xs, idx)
        yb = _bslice(ys, idx)

        # forward: z = X·w via Beaver ((nb, m) elementwise + row sum)
        wb = tuple(R64(jnp.broadcast_to(s.hi, (nb, m)),
                       jnp.broadcast_to(s.lo, (nb, m))) for s in ws)
        t0_, t1_ = dealer.elementwise((nb, m))
        _meter_open(meter, (nb, m), "SS.fwd_open")
        prod = beaver.mul(Xb, wb, t0_, t1_)
        z = tuple(ring.sum_axis(p, 1) for p in prod)
        z = truncation.trunc_pair(z[0], z[1], f)

        # d = 0.25 z − 0.5 y
        qz = truncation.trunc_pair(z[0], z[1], 2)
        hy = truncation.trunc_pair(yb[0], yb[1], 1)
        d = (ring.sub(qz[0], hy[0]), ring.sub(qz[1], hy[1]))

        # backward: g = X^T d via Beaver ((nb, m) elementwise + col sum)
        db = tuple(R64(jnp.broadcast_to(s.hi[:, None], (nb, m)),
                       jnp.broadcast_to(s.lo[:, None], (nb, m))) for s in d)
        t0_, t1_ = dealer.elementwise((nb, m))
        _meter_open(meter, (nb, m), "SS.bwd_open")
        gprod = beaver.mul(Xb, db, t0_, t1_)
        g = tuple(ring.sum_axis(p, 0) for p in gprod)
        g = truncation.trunc_pair(g[0], g[1], f)

        # update on shares: w -= (lr/nb)·g  (public scalar, local)
        step = tuple(ring.mul_pub_int(s, lr_fixed) for s in g)
        step = truncation.trunc_pair(step[0], step[1], f + extra)
        ws = (ring.sub(ws[0], step[0]), ring.sub(ws[1], step[1]))

        # loss (same MacLaurin as EFMVFL's Protocol 4)
        t_ = beaver.mul(yb, z, *dealer.elementwise((nb,)))
        _meter_open(meter, (nb,), "SS.loss_open")
        t_ = truncation.trunc_pair(t_[0], t_[1], f)
        t2 = beaver.mul(t_, t_, *dealer.elementwise((nb,)))
        _meter_open(meter, (nb,), "SS.loss_open")
        t2 = truncation.trunc_pair(t2[0], t2[1], f)
        ht = truncation.trunc_pair(t_[0], t_[1], 1)
        et2 = truncation.trunc_pair(t2[0], t2[1], 3)
        li = (ring.sub(et2[0], ht[0]), ring.sub(et2[1], ht[1]))
        s0 = ring.sum_axis(li[0], 0)
        s1 = ring.sum_axis(li[1], 0)
        meter.ring("B1", "C", "SS.loss_share", 1)
        revealed = float(fixed_point.decode(sharing.reconstruct(s0, s1), f))
        losses.append(revealed / nb + float(np.log(2.0)))
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            break

    # final: reveal weights to owners
    meter.ring("B1", "C", "SS.final_w", m)
    meter.ring("C", "B1", "SS.final_w", m)
    w = fixed_point.decode(sharing.reconstruct(*ws), f)
    sizes = np.cumsum([0] + [p.X.shape[1] for p in parties])
    weights = {p.name: w[sizes[i]:sizes[i + 1]]
               for i, p in enumerate(parties)}
    return TrainResult(weights=weights, losses=losses, meter=meter,
                       runtime_s=time.perf_counter() - t0, n_iter=len(losses))

