"""SS-HE-LR — [Chen et al., KDD 2021] "When HE marries SS" comparator.

Key structural difference vs EFMVFL: the *model weights* are secret-shared
too (MPC ideology), so every iteration needs HE cross-terms both in the
forward pass (X_p · ⟨w_p⟩_other) and the backward pass (X_p^T · ⟨d⟩_other),
roughly doubling ciphertext traffic and — the paper's point — making
multi-party extension hard.  Features stay local (their sparsity insight).

Real ring/share arithmetic; HE cross-terms on the byte-metered mock
backend (identical mod-2^64 values as real Paillier, see tests).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommMeter
from repro.core.trainer import PartyData, TrainResult, VFLConfig
from repro.crypto import fixed_point, ring
from repro.crypto.ring import R64
from repro.mpc import beaver, sharing, truncation


def _he_cross_matvec(meter: CommMeter, owner: str, keyholder: str,
                     x_int: np.ndarray, v: R64, n_out_tag: str,
                     key_bits: int, rng: np.random.Generator
                     ) -> tuple[R64, R64]:
    """owner holds plaintext x (int fixed-point); keyholder holds ring
    tensor v.  Returns additive shares of  x @ v  (owner's, keyholder's).

    Wire: keyholder → owner: |v| cts; owner → keyholder: rows(x) cts.
    """
    n_in = v.lo.shape[0]
    n_out = x_int.shape[0]
    meter.cipher(keyholder, owner, f"{n_out_tag}.enc_in", n_in, key_bits)
    prod = ring.matmul(jnp.asarray(x_int),
                       R64(v.hi[:, None], v.lo[:, None]))
    prod = R64(prod.hi[:, 0], prod.lo[:, 0])
    # owner masks with uniform R (its share = R; keyholder decrypts x@v − R)
    mask = ring.from_numpy_u64(
        rng.integers(0, 1 << 64, size=n_out, dtype=np.uint64))
    meter.cipher(owner, keyholder, f"{n_out_tag}.masked_out", n_out, key_bits)
    meter_share = ring.sub(prod, mask)
    return mask, meter_share


def train_ss_he(parties: list[PartyData], y: np.ndarray, cfg: VFLConfig
                ) -> TrainResult:
    assert cfg.glm == "logistic" and len(parties) == 2
    meter = CommMeter()
    rng = np.random.default_rng(cfg.seed)
    jkey = jax.random.key(cfg.seed)
    dealer = beaver.DealerTripleSource(seed=cfg.seed + 1)
    f, fx = cfg.f, cfg.fx
    C, B = parties[0], parties[1]
    n_total = C.X.shape[0]
    x_int = {p.name: np.rint(p.X * (1 << fx)).astype(np.int64).astype(np.int32)
             for p in parties}
    mdim = {p.name: p.X.shape[1] for p in parties}
    t0 = time.perf_counter()

    # weights secret-shared between the two parties (the MPC ideology)
    ws = {}
    for p in parties:
        jkey, k = jax.random.split(jkey)
        ws[p.name] = sharing.share(
            fixed_point.encode(np.zeros(mdim[p.name]), f), k)
        meter.ring(p.name, _other(p.name), "SSHE.init_w", mdim[p.name])
    jkey, ky = jax.random.split(jkey)
    ys = sharing.share(fixed_point.encode(y, f), ky)
    meter.ring("C", "B1", "SSHE.init_y", n_total)

    losses: list[float] = []
    order = rng.permutation(n_total)
    cursor = 0

    for it in range(cfg.max_iter):
        if cursor + cfg.batch_size > n_total:
            order = rng.permutation(n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        nb = len(idx)
        yb = (R64(ys[0].hi[idx], ys[0].lo[idx]),
              R64(ys[1].hi[idx], ys[1].lo[idx]))

        # forward: ⟨z⟩ = Σ_p ( X_p·⟨w_p⟩_p local + X_p·⟨w_p⟩_q via HE )
        z = [ring.zeros((nb,)), ring.zeros((nb,))]
        for pi, p in enumerate(parties):
            q = _other(p.name)
            local = ring.matmul(
                jnp.asarray(x_int[p.name][idx]),
                R64(ws[p.name][pi].hi[:, None], ws[p.name][pi].lo[:, None]))
            local = R64(local.hi[:, 0], local.lo[:, 0])
            own_sh, other_sh = _he_cross_matvec(
                meter, p.name, q, x_int[p.name][idx], ws[p.name][1 - pi],
                "SSHE.fwd", cfg.key_bits, rng)
            z[pi] = ring.add(z[pi], ring.add(local, own_sh))
            z[1 - pi] = ring.add(z[1 - pi], other_sh)
        z = truncation.trunc_pair(z[0], z[1], fx)   # X had fx extra bits

        # gradient-operator on shares (identical algebra to EFMVFL P2)
        qz = truncation.trunc_pair(z[0], z[1], 2)
        hy = truncation.trunc_pair(yb[0], yb[1], 1)
        d = (ring.sub(qz[0], hy[0]), ring.sub(qz[1], hy[1]))

        # backward: ⟨g_p⟩ = X_p^T·⟨d⟩_p local + X_p^T·⟨d⟩_q via HE; stays shared
        for pi, p in enumerate(parties):
            q = _other(p.name)
            local = ring.matmul(
                jnp.asarray(x_int[p.name][idx].T),
                R64(d[pi].hi[:, None], d[pi].lo[:, None]))
            local = R64(local.hi[:, 0], local.lo[:, 0])
            own_sh, other_sh = _he_cross_matvec(
                meter, p.name, q, x_int[p.name][idx].T, d[1 - pi],
                "SSHE.bwd", cfg.key_bits, rng)
            gp = (ring.add(local, own_sh), other_sh)
            if pi == 1:
                gp = (gp[1], gp[0])     # order shares as (party0, party1)
            # update shared weights: w -= (lr/nb)·g
            extra = 8
            k = int(round(cfg.lr / nb * (1 << (f + extra))))
            step = tuple(ring.mul_pub_int(s, k) for s in gp)
            # g has fx+f frac, k has f+extra: truncate fx+f+extra -> f frac
            step = truncation.trunc_pair(step[0], step[1], fx + f + extra)
            ws[p.name] = (ring.sub(ws[p.name][0], step[0]),
                          ring.sub(ws[p.name][1], step[1]))

        # loss — same Beaver MacLaurin as EFMVFL's Protocol 4
        t_ = beaver.mul(yb, z, *dealer.elementwise((nb,)))
        meter.ring("C", "B1", "SSHE.loss_open", 4 * nb)
        meter.ring("B1", "C", "SSHE.loss_open", 4 * nb)
        t_ = truncation.trunc_pair(t_[0], t_[1], f)
        t2 = beaver.mul(t_, t_, *dealer.elementwise((nb,)))
        meter.ring("C", "B1", "SSHE.loss_open", 4 * nb)
        meter.ring("B1", "C", "SSHE.loss_open", 4 * nb)
        t2 = truncation.trunc_pair(t2[0], t2[1], f)
        ht = truncation.trunc_pair(t_[0], t_[1], 1)
        et2 = truncation.trunc_pair(t2[0], t2[1], 3)
        li = (ring.sub(et2[0], ht[0]), ring.sub(et2[1], ht[1]))
        meter.ring("B1", "C", "SSHE.loss_share", 1)
        revealed = float(fixed_point.decode(
            sharing.reconstruct(ring.sum_axis(li[0], 0),
                                ring.sum_axis(li[1], 0)), f))
        losses.append(revealed / nb + float(np.log(2.0)))
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            break

    # reveal weights to owners at the end
    weights = {}
    for p in parties:
        meter.ring(_other(p.name), p.name, "SSHE.final_w", mdim[p.name])
        weights[p.name] = fixed_point.decode(
            sharing.reconstruct(*ws[p.name]), f)
    return TrainResult(weights=weights, losses=losses, meter=meter,
                       runtime_s=time.perf_counter() - t0, n_iter=len(losses))


def _other(name: str) -> str:
    return "B1" if name == "C" else "C"
