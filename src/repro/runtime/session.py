"""Resumable training sessions: the step-state machine's state type.

`TrainState` captures *everything one Algorithm-1 iteration consumes*,
so `VFLScheduler.step(state) -> state` is a pure-looking transition and
`run()` is a thin fold over it (bit-exact vs the pre-refactor loop —
tests/test_resumable.py + the frozen seed-trainer oracle).  The same
dataclass doubles as a *party-local slice* in the distributed runtime:
each `netparty.PartyServer` checkpoints only its own fields (own weight
vector, own mask/noise stream, its meter view), never shipping shares or
key material over the wire or into another party's directory.

State inventory (docs/fault_tolerance.md spells out who owns what):

  it                completed-iteration count (checkpoint step number)
  weights           per-party head weights (scheduler: all; slice: own)
  losses / stop     C's public loss trace + stop flag
  order / cursor /  the batch schedule: current epoch permutation,
  batch_rng         position in it, and the generator that draws the
                    next epoch (replicated identically at every party)
  jkey              Protocol-1 share-split jax key ladder position
  protocol_rng      mask/noise stream (`runtime.seeds` counted state:
                    exact bit-generator position + drawn-call counter)
  select_rng        dedicated CP-selection stream (None when shared
                    with the protocol stream — the LocalTransport
                    replay convention)
  dealer            Beaver dealer stream position + drawn counter
                    (`mpc.beaver.DealerTripleSource.state()`)
  noise_pool_fill   prefetched-noise batches alive at capture (always 0
                    at an iteration boundary — the scheduler discards
                    the pool each iteration; recorded so a non-zero
                    value is *visible* if that invariant ever breaks)
  meter_sends /     per-tag byte accounting (analytic, and for socket
  measured_sends    parties the measured-on-the-wire ledger + frame
  / overhead /      overhead), so a resumed run's accounting is
  frames_sent       bit-identical to an uninterrupted one
  rounds / runtime_s  transport latency steps + accumulated wall clock

Serialization: `to_checkpoint()` splits the state into a numpy pytree
(arrays → the `.npz` archive) and a JSON-able `extra` dict (scalars,
rng states, the meter ledger → the manifest), matching
`checkpoint.CheckpointManager`'s (tree, extra) interface.  Manifests
carry `session.config_hash(cfg)` and the wire-codec version so a resume
against a different run configuration or codec build is *refused*
(`checkpoint.CheckpointMismatch`), never silently diverged.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Optional

import numpy as np

from repro.runtime.codec import VERSION as CODEC_VERSION

#: VFLConfig fields that do not change the trained model or any derived
#: randomness stream — excluded from the resume-compatibility hash so a
#: resume may e.g. change the checkpoint cadence or toggle lossless
#: wire compression (below the metering boundary by construction).
_NON_SEMANTIC_CFG_FIELDS = ("checkpoint_every", "wire_compression")


def config_hash(cfg) -> str:
    """Semantic fingerprint of a `VFLConfig`: equal hashes ⇒ identical
    derived streams and model trajectory.  Stamped into every
    checkpoint manifest; resumes with a different hash are refused."""
    d = dataclasses.asdict(cfg)
    for k in _NON_SEMANTIC_CFG_FIELDS:
        d.pop(k, None)
    return hashlib.sha256(
        json.dumps(d, sort_keys=True).encode()).hexdigest()[:16]


@dataclasses.dataclass(eq=False)
class TrainState:
    """Everything one Algorithm-1 iteration consumes.  See the module
    docstring for the field inventory; `it` is the number of COMPLETED
    iterations (so a checkpoint named step s resumes by running
    iteration s as the next one)."""
    it: int
    weights: dict[str, np.ndarray]
    losses: list[float]
    stop: bool
    order: np.ndarray
    cursor: int
    batch_rng: dict
    jkey: np.ndarray
    protocol_rng: dict
    select_rng: Optional[dict]
    dealer: dict
    noise_pool_fill: int
    #: send ledgers: a `LedgerView` (O(1) in-memory snapshot) or
    #: `[src, dst, tag, nbytes]` rows (deserialized) — see `send_rows`
    meter_sends: Any
    rounds: int
    runtime_s: float
    measured_sends: Optional[Any] = None
    overhead_bytes: int = 0
    frames_sent: int = 0

    # -- (de)serialization --------------------------------------------------
    def to_checkpoint(self) -> tuple[dict, dict]:
        """(pytree-of-arrays, JSON extra) for `CheckpointManager.save`."""
        tree = {
            "dealer_key": np.asarray(self.dealer["key"], np.uint32),
            "jkey": np.asarray(self.jkey, np.uint32),
            "order": np.asarray(self.order, np.int64),
            "weights": {n: np.asarray(w, np.float64)
                        for n, w in self.weights.items()},
        }
        extra = {
            "it": int(self.it),
            "losses": [float(v) for v in self.losses],
            "stop": bool(self.stop),
            "cursor": int(self.cursor),
            "batch_rng": self.batch_rng,
            "protocol_rng": self.protocol_rng,
            "select_rng": self.select_rng,
            "dealer_drawn": int(self.dealer["drawn"]),
            "noise_pool_fill": int(self.noise_pool_fill),
            "meter_sends": send_rows(self.meter_sends),
            "rounds": int(self.rounds),
            "runtime_s": float(self.runtime_s),
            "measured_sends": None if self.measured_sends is None
            else send_rows(self.measured_sends),
            "overhead_bytes": int(self.overhead_bytes),
            "frames_sent": int(self.frames_sent),
            "party_names": sorted(self.weights),
        }
        return tree, extra

    @staticmethod
    def tree_template(party_names) -> dict:
        """Structure-only template for `CheckpointManager.restore` (leaf
        values are irrelevant; the treedef must match `to_checkpoint`)."""
        return {"dealer_key": 0, "jkey": 0, "order": 0,
                "weights": {n: 0 for n in party_names}}

    @staticmethod
    def from_checkpoint(tree: dict, extra: dict) -> "TrainState":
        return TrainState(
            it=int(extra["it"]),
            weights={n: np.asarray(w, np.float64)
                     for n, w in tree["weights"].items()},
            losses=[float(v) for v in extra["losses"]],
            stop=bool(extra["stop"]),
            order=np.asarray(tree["order"], np.int64),
            cursor=int(extra["cursor"]),
            batch_rng=extra["batch_rng"],
            jkey=np.asarray(tree["jkey"], np.uint32),
            protocol_rng=extra["protocol_rng"],
            select_rng=extra["select_rng"],
            dealer={"key": np.asarray(tree["dealer_key"], np.uint32),
                    "drawn": int(extra["dealer_drawn"])},
            noise_pool_fill=int(extra["noise_pool_fill"]),
            meter_sends=[list(s) for s in extra["meter_sends"]],
            rounds=int(extra["rounds"]),
            runtime_s=float(extra["runtime_s"]),
            measured_sends=None if extra.get("measured_sends") is None
            else [list(s) for s in extra["measured_sends"]],
            overhead_bytes=int(extra.get("overhead_bytes", 0)),
            frames_sent=int(extra.get("frames_sent", 0)),
        )

    # -- comparison (numpy fields break dataclass ==) -----------------------
    def equals(self, other: "TrainState") -> bool:
        if not isinstance(other, TrainState):
            return False
        scalar = ("it", "losses", "stop", "cursor", "batch_rng",
                  "protocol_rng", "select_rng", "noise_pool_fill",
                  "rounds", "overhead_bytes", "frames_sent")
        for f in scalar:
            a, b = getattr(self, f), getattr(other, f)
            if _normalize(a) != _normalize(b):
                return False
        for f in ("meter_sends", "measured_sends"):
            a, b = getattr(self, f), getattr(other, f)
            if (a is None) != (b is None):
                return False
            if a is not None and send_rows(a) != send_rows(b):
                return False
        if set(self.weights) != set(other.weights):
            return False
        for n in self.weights:
            if not np.array_equal(self.weights[n], other.weights[n]):
                return False
        # runtime_s is wall clock — informational, not part of equality
        return (np.array_equal(self.order, other.order)
                and np.array_equal(self.jkey, other.jkey)
                and np.array_equal(np.asarray(self.dealer["key"]),
                                   np.asarray(other.dealer["key"]))
                and int(self.dealer["drawn"]) == int(other.dealer["drawn"]))


def _normalize(v: Any) -> Any:
    """Canonical form for comparing JSON-round-tripped values (tuples vs
    lists in meter send rows; numpy scalars vs Python ints)."""
    if isinstance(v, (list, tuple)):
        return [_normalize(x) for x in v]
    if isinstance(v, dict):
        return {k: _normalize(x) for k, x in v.items()}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


class LedgerView:
    """O(1) snapshot of an append-only send ledger: the shared list
    plus the length at capture time.  The transport only ever appends
    `Send` rows (and `restore` swaps in a *new* meter rather than
    truncating), so a view stays a faithful prefix forever — the
    per-step capture cost is two attribute writes, not an O(n) copy.
    Serialization (`send_rows`) materializes real rows; nothing mutable
    escapes into a checkpoint."""

    __slots__ = ("_sends", "_n")

    def __init__(self, sends: list, n: int | None = None):
        self._sends = sends
        self._n = len(sends) if n is None else int(n)

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        import itertools
        return itertools.islice(iter(self._sends), self._n)


def send_rows(sends) -> list[list]:
    """Canonical `[src, dst, tag, nbytes]` rows from a ledger that may
    hold `core.comm.Send` objects (cheap in-memory snapshots taken by
    the capture hot path) or already-row-shaped sequences (deserialized
    checkpoints)."""
    out = []
    for s in sends:
        if hasattr(s, "tag"):
            out.append([s.src, s.dst, s.tag, int(s.nbytes)])
        else:
            src, dst, tag, nbytes = s
            out.append([src, dst, tag, int(nbytes)])
    return out


def rebuild_meter(sends):
    """CommMeter from a (checkpointed or snapshot) send ledger."""
    from repro.core.comm import CommMeter
    m = CommMeter()
    for src, dst, tag, nbytes in send_rows(sends):
        m.add(src, dst, tag, int(nbytes))
    return m
