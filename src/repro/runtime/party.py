"""Party actors for the EFMVFL runtime.

Each actor owns only its local state — features, head weights, encoded
fixed-point features, its view of the HE backend (its own key pair in a
real deployment), and a handle on the protocol entropy source — and
steps via `handle(msg) -> [Message]`.  Cross-party values only move as
typed `runtime.messages` envelopes through a Transport; a party never
reads another party's attributes.

Roles: `LabelParty` is C (holds Y, computes the public loss, decides the
stop flag); `DataParty` is a feature provider B_k.  Computing-party (CP)
status rotates per iteration (Alg. 1 §4.3 — fixed or uniformly random
selection), so the CP behaviour lives in `CPRole`, a mixin every party
carries and activates only for iterations in which it is selected.

Simulation note: the two CPs' *joint* share computations (Protocol 2 and
the Beaver-multiplication legs of Protocol 4) are evaluated in-process
by the scheduler over the pair's states, exactly like `mpc.beaver`; the
openings they would exchange are accounted as `beaver_open` messages by
the transport's dealer.

Threading note: actors are not internally synchronized.  Concurrent
transports (`PipelinedTransport.pump_async`) serialize each actor's
`handle` calls with a per-party delivery lock, so an actor only ever
needs to be safe against *other* actors running concurrently — which it
is by construction, since actors share no mutable state (the shared
protocol RNG is lock-wrapped by the transport, and the HE backend's
noise pool is internally locked).

Value conventions used below (see docs/protocols.md for the full map):
ring shares are `crypto.ring.R64` tensors (exact Z_2^64, fixed-point
with `cfg.f` fractional bits unless noted); ciphertexts are
Montgomery-domain mod-n² uint32 limb arrays of shape (batch, L2) under a
named party's Paillier key (the mock backend carries R64 instead);
weights and features are host float64.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.crypto import fixed_point, ring
from repro.crypto.ring import R64
from repro.mpc import sharing
from repro.runtime import messages as msg


@dataclasses.dataclass
class CPState:
    """Per-iteration state a party holds only while it is a CP."""
    index: int                          # 0 or 1: which share stream it owns
    peer: str                           # the other CP
    d_self: Optional[R64] = None        # ⟨d⟩ from Protocol 2
    ct_self: object = None              # [[⟨d⟩]] under own key
    z_acc: Optional[R64] = None         # Σ_p ⟨z_p⟩  (Protocol 1)
    y_share: Optional[R64] = None
    ez_by_src: dict = dataclasses.field(default_factory=dict)
    l_self: Optional[R64] = None        # ⟨loss⟩ from Protocol 4
    n_p1: int = 0                       # Protocol-1 envelopes absorbed

    def ez_ordered(self, names: list[str]) -> list[R64]:
        """e^{z_p} shares in roster order — the chaining order must not
        depend on message arrival order (socket delivery is racy; the
        chained Beaver products don't commute bit-for-bit under
        probabilistic truncation)."""
        return [self.ez_by_src[n] for n in names if n in self.ez_by_src]


class CPRole:
    """Computing-party behaviour, active only when `self.cp` is set."""

    cp: Optional[CPState] = None

    def accumulate_share(self, m: msg.RingMessage) -> None:
        st = self.cp
        st.n_p1 += 1
        if isinstance(m, msg.ZShare):
            st.z_acc = m.payload if st.z_acc is None \
                else ring.add(st.z_acc, m.payload)
        elif isinstance(m, msg.YShare):
            st.y_share = m.payload
        elif isinstance(m, msg.EzShare):
            st.ez_by_src[m.src] = m.payload

    def announce_enc_d(self) -> msg.EncD:
        """Protocol 3 line 1: encrypt ⟨d⟩ under own key, send to the peer
        (the broadcast to non-CPs reuses the same ciphertext)."""
        st = self.cp
        st.ct_self = self.backend.encrypt_share(self.name, st.d_self)
        # line 2 (local): own term X_p^T ⟨d⟩_p joins the gradient sum.
        # Accumulate (don't assign): under WAN latency the peer CP can
        # race ahead — for GLMs whose gradient needs no Beaver openings
        # (logistic) its EncD round-trip can complete while this party is
        # still collecting Protocol-1 shares, in which case the peer's
        # unmasked term is already sitting in `_grad_acc`.
        local = protocols.local_grad_share(self._feats_b, st.d_self)
        self._grad_acc = local if self._grad_acc is None \
            else ring.add(self._grad_acc, local)
        self._grad_ready = True
        if not self._pending_unmask:
            self._apply_update()
        return msg.EncD(self.name, st.peer, st.ct_self,
                        n_cts=self._nb, key_bits=self.backend.key_bits(self.name),
                        key_owner=self.name)

    def broadcast_enc_d(self, others: list[str]) -> list[msg.EncDBroadcast]:
        st = self.cp
        kb = self.backend.key_bits(self.name)
        return [msg.EncDBroadcast(self.name, p, st.ct_self, n_cts=self._nb,
                                  key_bits=kb, key_owner=self.name)
                for p in others]

    def _decrypt_unmask(self, m: msg.MaskedGrad) -> list[msg.Message]:
        """Protocol 3 line 7 (key owner): decrypt + offset-correct with the
        own d-share, return the ring share to the requester."""
        w = protocols.decrypt_offset_corrected(
            self.backend, self.name, m.payload, self.cp.d_self,
            self._feats_b.width)
        return [msg.UnmaskedShare(self.name, m.src, w)]


class Party(CPRole):
    """One EFMVFL participant (B_k); subclassed by LabelParty for C.

    Args:
      name: wire identity ("C", "B1", …) — message routing key.
      X: (n, m_p) float64 local features; encoded once into protocol
        form (`EncodedFeatures`: offset-lifted fixed-point exponents +
        precomputed window digits).
      cfg: `VFLConfig` (fixed-point widths fx/f, exp_width, lr, …).
      backend: HE backend view (`PaillierBackend`/`MockHEBackend`) — in
        a real deployment, the party's own keypair plus peers' public
        keys.
      rng: shared protocol entropy source (Protocol-3 masks); lock-
        wrapped by concurrent transports.

    Public state: `W` (m_p,) float64 head weights (never leave the
    party); `stop` — C's latest flag.
    """

    def __init__(self, name: str, X: np.ndarray, cfg, backend, rng):
        self.name = name
        self.X = np.asarray(X, np.float64)
        self.W = np.zeros(self.X.shape[1])
        self.cfg = cfg
        self.backend = backend
        self.rng = rng
        self.feats = protocols.EncodedFeatures.make(self.X, cfg.fx,
                                                    cfg.exp_width)
        self.stop = False
        # serving: pinned model versions (see repro/serve/cache.py)
        self.model_version: Optional[int] = None
        self.serving_cache = None
        # per-iteration scratch
        self.cp = None
        self._idx = None
        self._cps = ()
        self._nb = 0
        self._mask_bound = 0
        self._feats_b = None
        self._wx = None
        self._grad_acc: Optional[R64] = None
        self._grad_ready = False
        self._masks: dict[str, R64] = {}
        self._pending_unmask: set[str] = set()

    # -- iteration lifecycle ------------------------------------------------
    def begin_iteration(self, idx, cps: tuple[str, str], nb: int,
                        mask_bound: int) -> None:
        """Reset per-iteration scratch for batch `idx` (host int array of
        row indices, len nb): slice features, compute the local linear
        predictor X[idx] @ W (float64), activate `CPRole` iff this party
        is in `cps`, and record `mask_bound` (bits — see
        `scheduler.mask_bound_bits`) for the Protocol-3 masks."""
        self._idx = idx
        self._cps = cps
        self._nb = nb
        self._mask_bound = mask_bound
        self._feats_b = self.feats.slice(idx)
        self._wx = self.X[idx] @ self.W
        self._masks = {}
        self._grad_acc = None
        if self.name in cps:
            i = cps.index(self.name)
            self.cp = CPState(index=i, peer=cps[1 - i])
            self._pending_unmask = {self.cp.peer}
            # a CP's own X_p^T ⟨d⟩_p term lands in `announce_enc_d`; the
            # update must wait for it even if the peer's unmasked share
            # comes back first (see the race note there)
            self._grad_ready = False
        else:
            self.cp = None
            self._grad_acc = ring.zeros((self.X.shape[1],))
            self._pending_unmask = set(cps)
            self._grad_ready = True

    # -- Protocol 1 ---------------------------------------------------------
    def share_z(self, key) -> list[msg.Message]:
        """Protocol 1 / Alg. 1 line 7: 2-out-of-2 share the local linear
        predictor z_p = X_p W_p.

        Args:
          key: jax PRNG key for the share split (scheduler's key ladder,
            so the randomness stream is transport-independent).
        Returns:
          Two `P1.z_share` messages (R64, f fractional bits), one per CP.
        """
        val = fixed_point.encode(self._wx, self.cfg.f)
        s0, s1 = sharing.share(val, key)
        return [msg.ZShare(self.name, self._cps[0], s0),
                msg.ZShare(self.name, self._cps[1], s1)]

    def share_ez(self, key, exp_sign: int) -> list[msg.Message]:
        """Protocol 1, Poisson/Gamma leg: share e^{exp_sign · z_p}
        (exp_sign = GLM.exp_sign: +1 Poisson, −1 Gamma; input clipped to
        [−30, 8] before exp).  Returns two `P1.ez_share` messages (R64,
        f fractional bits)."""
        ezp = np.exp(np.clip(exp_sign * self._wx, -30, 8))
        s0, s1 = sharing.share(fixed_point.encode(ezp, self.cfg.f), key)
        return [msg.EzShare(self.name, self._cps[0], s0),
                msg.EzShare(self.name, self._cps[1], s1)]

    # -- message dispatch ---------------------------------------------------
    def handle(self, m: msg.Message) -> list[msg.Message]:
        """Single actor step: absorb one envelope, return the envelopes
        it triggers (possibly none).  The transport owns delivery order,
        metering, and (for concurrent transports) per-party locking —
        `handle` itself assumes it is never re-entered."""
        if isinstance(m, (msg.ZShare, msg.YShare, msg.EzShare)):
            self.accumulate_share(m)
            return []
        if isinstance(m, (msg.EncD, msg.EncDBroadcast)):
            return self._produce_masked_grad(m)
        if isinstance(m, msg.MaskedGrad):
            return self._decrypt_unmask(m)
        if isinstance(m, msg.UnmaskedShare):
            self._absorb_unmasked(m)
            return []
        if isinstance(m, msg.LossShare):
            return self._absorb_loss(m)
        if isinstance(m, msg.Flag):
            self.stop = m.stop
            return []
        if isinstance(m, msg.WxShare):
            return self._absorb_wx(m)
        return []

    # -- Protocol 3 ---------------------------------------------------------
    def _produce_masked_grad(self, m: msg.Message) -> list[msg.Message]:
        """Feature owner's leg: matvec under the d-owner's key + mask."""
        owner = m.key_owner
        enc_masked, Rr = protocols.masked_matvec(
            self.backend, owner, m.payload, self._feats_b,
            self._mask_bound, self.rng)
        self._masks[owner] = Rr
        return [msg.MaskedGrad(self.name, owner, enc_masked,
                               n_cts=self.X.shape[1],
                               key_bits=self.backend.key_bits(owner),
                               key_owner=owner)]

    def _absorb_unmasked(self, m: msg.UnmaskedShare) -> None:
        Rr = self._masks.pop(m.src)
        term = ring.sub(m.payload, Rr)
        self._grad_acc = term if self._grad_acc is None \
            else ring.add(self._grad_acc, term)
        self._pending_unmask.discard(m.src)
        if not self._pending_unmask and self._grad_ready:
            self._apply_update()

    def _apply_update(self) -> None:
        """Eq. 6 — local: decode the (fx+f)-fractional-bit gradient, scale
        by 1/m, step.  Weights never leave the party."""
        g = fixed_point.decode(self._grad_acc, self.cfg.fx + self.cfg.f) \
            / self._nb
        self.W = self.W - self.cfg.lr * g

    # -- Protocol 4 ---------------------------------------------------------
    def _absorb_loss(self, m: msg.LossShare) -> list[msg.Message]:
        """CP0's leg: reconstruct the loss sum; route it to C."""
        total = sharing.reconstruct(self.cp.l_self, m.payload)
        return [msg.LossShare(self.name, "C", total)]

    # -- inference ----------------------------------------------------------
    def publish_version(self, version: int) -> None:
        """Pin the CURRENT weights as served model `version`: snapshot W
        and (re)build the serving cache — windowed-digit precompute of
        the weight row plus the encrypted constant [[w]] — keyed by
        (version, key fingerprint).  Versioned scoring is only possible
        after a publish; `predict_share(version=)` refuses otherwise
        (`StaleCacheError` — see repro/serve/cache.py)."""
        from repro.serve.cache import PartyServingCache
        self.model_version = int(version)
        self.serving_cache = PartyServingCache.build(self, int(version))

    def set_weights(self, W: np.ndarray, version: int) -> None:
        """Install swapped-in weights (hot model swap from a checkpoint
        slice) and publish them as `version` in one step — a serving-
        phase operation; never call it mid-training."""
        self.W = np.asarray(W, np.float64)
        self.publish_version(version)

    def predict_share(self, X_new: np.ndarray | None = None,
                      version: int | None = None) -> np.ndarray:
        """Local score share X_p W_p — the runtime-backed serving path.

        With `version=None` this is the unversioned path over the live
        weights (training-time diagnostics, legacy `cluster.score`).
        With a version, the share is computed against the PINNED
        snapshot of that published version, and a version/key mismatch
        refuses (`StaleCacheError`) instead of silently scoring the
        wrong model."""
        # matvec_rowwise, not @: batch-size-invariant float64 bits, so a
        # micro-batched share equals the one-shot scorer's bit-for-bit
        X = self.X if X_new is None else np.asarray(X_new, np.float64)
        if version is None:
            return glm_lib.matvec_rowwise(X, self.W)
        from repro.serve.cache import StaleCacheError, key_fingerprint_of
        if self.serving_cache is None:
            raise StaleCacheError(
                f"{self.name}: no published model version (call "
                f"publish_version) — refusing versioned score request "
                f"for version {int(version)}")
        cache = self.serving_cache.ensure(
            int(version), key_fingerprint_of(self.backend, self.name),
            party=self.name)
        return glm_lib.matvec_rowwise(X, cache.W)

    def wx_share_msg(self, X_new: np.ndarray, dst: str = "C",
                     version: int | None = None) -> msg.WxShare:
        """Score share as a wire message (8-byte float64 per row)."""
        wx = self.predict_share(X_new, version=version)
        return msg.WxShare(self.name, dst, wx, n_elems=len(wx))

    def _absorb_wx(self, m: msg.WxShare) -> list[msg.Message]:
        return []


class DataParty(Party):
    """B_k — a feature provider; pure Party behaviour."""


class LabelParty(Party):
    """C — holds the label, finalizes the public loss, owns the stop flag."""

    def __init__(self, name: str, X: np.ndarray, y: np.ndarray, cfg,
                 backend, rng, model: glm_lib.GLM):
        super().__init__(name, X, cfg, backend, rng)
        self.y = np.asarray(y, np.float64)
        self.model = model
        self.losses: list[float] = []
        self._wx_senders: list[str] = []
        self._wx_by_src: dict[str, np.ndarray] = {}

    def share_y(self, key) -> list[msg.Message]:
        val = fixed_point.encode(self.y[self._idx], self.cfg.f)
        s0, s1 = sharing.share(val, key)
        return [msg.YShare(self.name, self._cps[0], s0),
                msg.YShare(self.name, self._cps[1], s1)]

    def _absorb_loss(self, m: msg.LossShare) -> list[msg.Message]:
        if self.cp is not None and self.cp.index == 0:
            # C is CP0: reconstruct and finalize in one step
            total = sharing.reconstruct(self.cp.l_self, m.payload)
        else:
            total = m.payload               # forwarded (reconstructed) by CP0
        revealed = float(fixed_point.decode(total, self.cfg.f))
        self.losses.append(self.model.finalize_loss(
            revealed, self.y[self._idx], self._nb))
        return []

    def emit_flags(self, others: list[str]) -> list[msg.Message]:
        """Alg. 1 line 27: |Δloss| < tol ⇒ stop, broadcast every iter."""
        flag = (len(self.losses) > 1
                and abs(self.losses[-1] - self.losses[-2]) < self.cfg.tol)
        self.stop = flag
        return [msg.Flag(self.name, p, stop=flag) for p in others]

    # -- inference (serving path) ------------------------------------------
    def begin_inference(self, n_rows: int, senders: list[str]) -> None:
        """Open an inference batch of `n_rows` rows.  `senders` is the
        ROSTER-ORDERED list of data-party names expected to ship
        `infer.wx_share` frames.  Shares are held per-source and summed
        in roster order at `finish_inference`: socket arrival order is
        racy and float64 addition does not commute bit-for-bit, and the
        serving gauntlet asserts served predictions are bit-identical
        across transports."""
        self._wx_senders = [str(s) for s in senders]
        self._wx_by_src = {}
        self._wx_rows = int(n_rows)

    def _absorb_wx(self, m: msg.WxShare) -> list[msg.Message]:
        if m.src not in self._wx_senders:
            raise RuntimeError(f"{self.name}: score share from {m.src}, "
                               f"expected one of {self._wx_senders}")
        if m.src in self._wx_by_src:
            raise RuntimeError(f"{self.name}: duplicate score share "
                               f"from {m.src}")
        self._wx_by_src[m.src] = np.asarray(m.payload, np.float64)
        return []

    @property
    def inference_ready(self) -> bool:
        return all(s in self._wx_by_src for s in self._wx_senders)

    def finish_inference(self, X_own: np.ndarray,
                         version: int | None = None) -> np.ndarray:
        missing = [s for s in self._wx_senders if s not in self._wx_by_src]
        assert not missing, f"missing party score shares: {missing}"
        # own term first, then roster order — the same association as
        # TrainResult.predict_wx, so one-shot and served agree bitwise
        wx = self.predict_share(X_own, version=version)
        for nm in self._wx_senders:
            wx = wx + self._wx_by_src[nm]
        return self.model.predict(wx)
