"""Versioned binary wire codec for the party-runtime messages.

Every typed envelope in `runtime.messages` has one frame encoding:

    offset  size  field
    0       3     magic  b"EFM"
    3       1     codec version (currently 1)
    4       4     u32 LE header length H
    8       8     u64 LE payload length P
    16      4     u32 LE CRC-32 over header + payload
    20      H     header (type tag + routing + payload metadata)
    20+H    P     payload

The *payload* is the canonical serialization `Message.wire_bytes()`
accounts (the paper's comm columns count payloads; header/prelude bytes
are deployment overhead, reported separately by `SocketTransport`):

* ring tensors (`R64`) — 8-byte little-endian elements;
* float64 tensors (serving scores) — 8-byte little-endian elements;
* Paillier ciphertexts — canonical Z_{n²} residues, each packed into
  ⌈2·key_bits/8⌉ little-endian bytes.  In memory ciphertexts live in
  the Montgomery domain; the codec converts with `from_mont`/`to_mont`,
  which is bit-exact because Montgomery representatives out of
  `mont_mul` are fully reduced (< n²) and hence unique;
* mock-backend "ciphertexts" (ring values standing in for ciphertexts)
  — each 64-bit value zero-padded to the same canonical ciphertext
  width, so the mock backend's measured wire bytes equal the real
  backend's, exactly like its analytic accounting always did;
* stop flags — one byte;
* control frames (`messages.Control`) — UTF-8 JSON.

`encode` refuses to produce a frame whose payload length disagrees with
the message's own `wire_bytes()` — the analytic accounting and the wire
are kept equal by construction, not by convention.  `decode` rejects
truncated frames, bad magic, unknown versions/types, and CRC mismatches
with `CodecError`.

Decoding (and encoding) real-Paillier ciphertext payloads needs the
key owner's modulus, so a `Codec` is constructed with the local party's
HE backend view (`key_provider`); ring/float/flag/control frames need
no context and work with `Codec()`.
"""
from __future__ import annotations

import json
import struct
import zlib

import numpy as np

from repro.crypto import bigint, ring
from repro.crypto.ring import R64
from repro.runtime import messages as msg

MAGIC = b"EFM"
VERSION = 1
PRELUDE = struct.Struct("<3sBIQI")      # magic, version, H, P, crc
assert PRELUDE.size == 20

# payload kinds ------------------------------------------------------------
PK_NONE = 0          # synthetic traffic (byte accounting only)
PK_R64 = 1           # ring tensor, 8-byte LE elements
PK_F64 = 2           # float64 tensor, 8-byte LE elements
PK_CT = 3            # canonical Z_{n²} ciphertexts (Montgomery in memory)
PK_CT_MOCK = 4       # mock ciphertext: u64 zero-padded to canonical width
PK_FLAG = 5          # one stop byte
PK_JSON = 6          # control frame

#: stable type-id registry — appending is fine, renumbering is a version
#: bump.
MESSAGE_TYPES: list[type[msg.Message]] = [
    msg.ZShare, msg.YShare, msg.EzShare, msg.BeaverOpen,
    msg.UnmaskedShare, msg.LossShare, msg.WxShare,
    msg.EncD, msg.EncDBroadcast, msg.MaskedGrad,
    msg.Flag, msg.Control,
]
TYPE_ID = {cls: i + 1 for i, cls in enumerate(MESSAGE_TYPES)}
TYPE_BY_ID = {i: cls for cls, i in TYPE_ID.items()}


class CodecError(ValueError):
    """Malformed, truncated, or inconsistent frame."""


# ---------------------------------------------------------------------------
# header reader/writer
# ---------------------------------------------------------------------------

class _Writer:
    def __init__(self):
        self.parts: list[bytes] = []

    def u8(self, v: int):
        self.parts.append(struct.pack("<B", v))

    def u32(self, v: int):
        self.parts.append(struct.pack("<I", v))

    def u64(self, v: int):
        self.parts.append(struct.pack("<Q", v))

    def string(self, s: str):
        b = s.encode()
        if len(b) > 255:
            raise CodecError(f"string field too long ({len(b)} bytes)")
        self.u8(len(b))
        self.parts.append(b)

    def done(self) -> bytes:
        return b"".join(self.parts)


class _Reader:
    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def _take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise CodecError("truncated header")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return self._take(1)[0]

    def u32(self) -> int:
        return struct.unpack("<I", self._take(4))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self._take(8))[0]

    def string(self) -> str:
        return self._take(self.u8()).decode()


# ---------------------------------------------------------------------------
# payload codecs
# ---------------------------------------------------------------------------

def _r64_to_bytes(v: R64) -> bytes:
    return np.ascontiguousarray(
        ring.to_numpy_u64(v).astype("<u8")).tobytes()

def _r64_from_bytes(raw: bytes, shape: tuple[int, ...]) -> R64:
    n = int(np.prod(shape)) if shape else 1
    if len(raw) != 8 * n:
        raise CodecError("ring payload length mismatch")
    flat = np.frombuffer(raw, dtype="<u8").astype(np.uint64)
    return ring.from_numpy_u64(flat.reshape(shape))


def _ct_width_bytes(key_bits: int) -> int:
    from repro.core.comm import ciphertext_wire_bytes
    return ciphertext_wire_bytes(key_bits)


#: jitted Montgomery-domain boundary ops per modulus (keyed by n² value;
#: un-jitted mont_mul dispatches op-by-op and dominates encode time)
_MONT_FNS: dict = {}


def _mont_fns(mod):
    fns = _MONT_FNS.get(mod.value)
    if fns is None:
        import jax
        fns = (jax.jit(lambda a: bigint.from_mont(a, mod)),
               jax.jit(lambda a: bigint.to_mont(a, mod)))
        _MONT_FNS[mod.value] = fns
    return fns


def _ct_payload(cts, mod, width: int) -> bytes:
    """Montgomery-domain (n_cts, L2) limbs -> canonical LE residues."""
    from repro.crypto import paillier
    from_mont, _ = _mont_fns(mod)
    canon = from_mont(np.asarray(cts, np.uint32))
    vals = paillier.decode_ints(np.asarray(canon))
    return b"".join(int(v).to_bytes(width, "little") for v in vals)


def _ct_from_payload(raw: bytes, mod, width: int, n_cts: int):
    if len(raw) != width * n_cts:
        raise CodecError("ciphertext payload length mismatch")
    vals = [int.from_bytes(raw[i * width:(i + 1) * width], "little")
            for i in range(n_cts)]
    for v in vals:
        if v >= mod.value:
            raise CodecError("ciphertext residue out of range (>= n²)")
    limbs = bigint.ints_to_limbs(vals, mod.L)
    _, to_mont = _mont_fns(mod)
    return to_mont(limbs)


def _mock_ct_payload(v: R64, width: int) -> bytes:
    u = ring.to_numpy_u64(v).reshape(-1)
    out = np.zeros((u.shape[0], width), np.uint8)
    out[:, :8] = np.frombuffer(
        u.astype("<u8").tobytes(), np.uint8).reshape(-1, 8)
    return out.tobytes()


def _mock_ct_from_payload(raw: bytes, width: int, n_cts: int) -> R64:
    if len(raw) != width * n_cts:
        raise CodecError("mock ciphertext payload length mismatch")
    arr = np.frombuffer(raw, np.uint8).reshape(n_cts, width)
    if arr[:, 8:].any():
        raise CodecError("mock ciphertext has non-zero padding")
    u = np.frombuffer(arr[:, :8].tobytes(), "<u8").astype(np.uint64)
    return ring.from_numpy_u64(u)


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

class Codec:
    """Frame encoder/decoder.

    Args:
      key_provider: optional callable `name -> mod_n2 | None` resolving a
        key owner's Z_{n²} modulus (None = mock backend) — e.g.
        `netparty.PartyServer._resolve_mod`, which late-binds the
        party's HE backend view.  Only real-Paillier ciphertext frames
        need it.
    """

    def __init__(self, key_provider=None):
        self._key_provider = key_provider

    def _mod_for(self, owner: str):
        if self._key_provider is None:
            raise CodecError(
                f"no key provider: cannot code ciphertexts under {owner!r}")
        mod = self._key_provider(owner)
        if mod is None:
            raise CodecError(f"no modulus known for key owner {owner!r}")
        return mod

    # -- encode -------------------------------------------------------------
    def encode(self, m: msg.Message) -> bytes:
        cls = type(m)
        if cls not in TYPE_ID:
            raise CodecError(f"unregistered message type {cls.__name__}")
        w = _Writer()
        w.u8(TYPE_ID[cls])
        w.string(m.src)
        w.string(m.dst)
        kind, payload = self._encode_payload(m, w)
        header = w.done()
        crc = zlib.crc32(header + payload) & 0xFFFFFFFF
        prelude = PRELUDE.pack(MAGIC, VERSION, len(header), len(payload),
                               crc)
        if kind not in (PK_NONE, PK_JSON):
            expect = int(m.wire_bytes())
            if len(payload) != expect:
                raise CodecError(
                    f"{m.tag}: encoded payload is {len(payload)} B but "
                    f"wire_bytes() accounts {expect} B — analytic comm "
                    "accounting drifted from the wire format")
        return prelude + header + payload

    def _encode_payload(self, m: msg.Message, w: _Writer
                        ) -> tuple[int, bytes]:
        """Write the type-specific header fields (in exactly the order
        `_decode_body` reads them back) and return (kind, payload)."""
        if isinstance(m, msg.Flag):
            w.u8(PK_FLAG)
            return PK_FLAG, bytes([1 if m.stop else 0])
        if isinstance(m, msg.Control):
            w.string(m.kind)
            w.u8(PK_JSON)
            return PK_JSON, json.dumps(m.payload or {}).encode()
        if isinstance(m, msg.CipherMessage):
            w.u32(m.n_cts)
            w.u32(m.key_bits)
            w.string(m.key_owner)
            width = _ct_width_bytes(m.key_bits)
            if m.payload is None:
                w.u8(PK_NONE)
                return PK_NONE, b""
            if isinstance(m.payload, R64):
                v = m.payload
                n = int(np.prod(v.lo.shape)) if v.lo.shape else 1
                if n != m.n_cts:
                    raise CodecError(
                        f"{m.tag}: n_cts={m.n_cts} but payload has {n}")
                w.u8(PK_CT_MOCK)
                return PK_CT_MOCK, _mock_ct_payload(v, width)
            cts = np.asarray(m.payload, np.uint32)
            if cts.ndim != 2 or cts.shape[0] != m.n_cts:
                raise CodecError(
                    f"{m.tag}: ciphertext batch shape {cts.shape} does "
                    f"not match n_cts={m.n_cts}")
            w.u8(PK_CT)
            return PK_CT, _ct_payload(cts, self._mod_for(m.key_owner),
                                      width)
        if isinstance(m, msg.RingMessage):
            w.u8(0 if m.n_elems is None else 1)
            w.u64(0 if m.n_elems is None else int(m.n_elems))
            if m.payload is None:
                if m.n_elems is None:
                    raise CodecError(f"{m.tag}: neither payload nor n_elems")
                w.u8(PK_NONE)
                return PK_NONE, b""
            if isinstance(m.payload, R64):
                shape = tuple(int(d) for d in m.payload.lo.shape)
                n_payload = int(np.prod(shape)) if shape else 1
                if m.n_elems is not None and int(m.n_elems) != n_payload:
                    raise CodecError(
                        f"{m.tag}: n_elems={m.n_elems} disagrees with "
                        f"payload shape {shape}")
                w.u8(PK_R64)
                self._write_shape(w, shape)
                return PK_R64, _r64_to_bytes(m.payload)
            arr = np.asarray(m.payload, np.float64)
            shape = tuple(int(d) for d in arr.shape)
            w.u8(PK_F64)
            self._write_shape(w, shape)
            return PK_F64, np.ascontiguousarray(
                arr.astype("<f8")).tobytes()
        raise CodecError(f"cannot encode {type(m).__name__}")

    @staticmethod
    def _write_shape(w: _Writer, shape: tuple[int, ...]):
        if len(shape) > 255:
            raise CodecError("payload rank > 255")
        w.u8(len(shape))
        for d in shape:
            w.u32(d)

    @staticmethod
    def _read_shape(r: _Reader) -> tuple[int, ...]:
        return tuple(r.u32() for _ in range(r.u8()))

    # -- decode -------------------------------------------------------------
    def decode(self, buf: bytes) -> msg.Message:
        """Decode exactly one frame (must span the whole buffer)."""
        m, used = self.decode_prefix(buf)
        if used != len(buf):
            raise CodecError(f"{len(buf) - used} trailing bytes after frame")
        return m

    def decode_prefix(self, buf: bytes) -> tuple[msg.Message, int]:
        """Decode one frame from the start of `buf`; returns (msg, size)."""
        if len(buf) < PRELUDE.size:
            raise CodecError("truncated frame (prelude)")
        magic, version, hlen, plen, crc = PRELUDE.unpack_from(buf)
        if magic != MAGIC:
            raise CodecError(f"bad magic {magic!r}")
        if version != VERSION:
            raise CodecError(f"unsupported codec version {version}")
        total = PRELUDE.size + hlen + plen
        if len(buf) < total:
            raise CodecError("truncated frame (body)")
        body = buf[PRELUDE.size:total]
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise CodecError("CRC mismatch (corrupt frame)")
        header, payload = body[:hlen], body[hlen:]
        return self._decode_body(header, payload), total

    def _decode_body(self, header: bytes, payload: bytes) -> msg.Message:
        r = _Reader(header)
        type_id = r.u8()
        cls = TYPE_BY_ID.get(type_id)
        if cls is None:
            raise CodecError(f"unknown message type id {type_id}")
        src, dst = r.string(), r.string()
        if cls is msg.Flag:
            kind = r.u8()
            if kind != PK_FLAG or len(payload) != 1 \
                    or payload[0] not in (0, 1):
                raise CodecError("malformed flag frame")
            return msg.Flag(src, dst, stop=bool(payload[0]))
        if cls is msg.Control:
            ckind = r.string()
            if r.u8() != PK_JSON:
                raise CodecError("malformed control frame")
            try:
                data = json.loads(payload.decode())
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise CodecError(f"bad control JSON: {e}") from None
            return msg.Control(src, dst, payload=data, kind=ckind)
        if issubclass(cls, msg.CipherMessage):
            n_cts, key_bits = r.u32(), r.u32()
            owner = r.string()
            kind = r.u8()
            width = _ct_width_bytes(key_bits)
            if kind == PK_NONE:
                body = None
            elif kind == PK_CT_MOCK:
                body = _mock_ct_from_payload(payload, width, n_cts)
            elif kind == PK_CT:
                body = _ct_from_payload(payload, self._mod_for(owner),
                                        width, n_cts)
            else:
                raise CodecError(f"bad ciphertext payload kind {kind}")
            return cls(src, dst, body, n_cts=n_cts, key_bits=key_bits,
                       key_owner=owner)
        if issubclass(cls, msg.RingMessage):
            has_n = r.u8()
            n_raw = r.u64()
            n_elems = n_raw if has_n else None
            kind = r.u8()
            if kind == PK_NONE:
                return cls(src, dst, None, n_elems=n_elems)
            shape = self._read_shape(r)
            if kind == PK_R64:
                return cls(src, dst, _r64_from_bytes(payload, shape),
                           n_elems=n_elems)
            if kind == PK_F64:
                n = int(np.prod(shape)) if shape else 1
                if len(payload) != 8 * n:
                    raise CodecError("float payload length mismatch")
                arr = np.frombuffer(payload, "<f8").astype(
                    np.float64).reshape(shape)
                return cls(src, dst, arr, n_elems=n_elems)
            raise CodecError(f"bad ring payload kind {kind}")
        raise CodecError(f"cannot decode {cls.__name__}")


def frame_overhead_bytes(frame: bytes) -> int:
    """Header + prelude bytes of an encoded frame (total − payload)."""
    _, _, hlen, _, _ = PRELUDE.unpack_from(frame)
    return PRELUDE.size + hlen
