"""Unified retry / timeout / backoff policy for the socket runtime.

Before this module, liveness constants were scattered: the event-loop
deadline lived in `runtime/netparty.py` (`REPRO_WIRE_TIMEOUT_S`), the
heartbeat cadence and the bye/join/terminate/poll timeouts were inline
literals in `launch/cluster.py`, and the chaos ARQ layer would have
grown a third set.  `RetryPolicy` is the one block that owns all of
them, plus the exponential-backoff schedule the reliable-link layer
(`runtime/chaos.py`) uses for retransmissions.

Design rules:

* **One deadline vocabulary.**  Every blocking wait in the cluster is
  one of: a protocol wait (`io_timeout_s` — satisfied only by protocol
  progress, never by heartbeats), a bootstrap wait (`connect_timeout_s`
  for dials/accepts), or a teardown wait (`bye_timeout_s`,
  `join_timeout_s`, `term_timeout_s`).  Per-frame-kind overrides
  (`frame_deadlines`) exist for control kinds whose expected latency
  differs from the default (e.g. `bye` during shutdown).
* **Deterministic, seeded backoff jitter.**  Retransmission delays are
  exponential with multiplicative jitter drawn from a *pure hash* of
  (link, seq, attempt) — replayable, so a chaos run's retry trace is a
  function of its fault schedule, never of `random` global state.
* **Budgeted retries.**  A reliable frame is retransmitted at most
  `retry_budget` times before the link is declared dead; the budget ×
  the capped backoff bounds how long a partition may last before the
  supervisor takes over (quarantine / restart — `launch/cluster.py`).

The policy is a frozen dataclass with `to_dict`/`from_dict` so the
cluster launcher can ship ONE policy to every spawned party process
(the parties must agree on deadlines *before* the handshake travels,
so it rides the spawn args, not the handshake).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import struct
from typing import Optional


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


#: historical default of `REPRO_WIRE_TIMEOUT_S` (kept as the policy
#: default so existing deployments see no behavior change)
DEFAULT_IO_TIMEOUT_S = 300.0


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Every timeout/heartbeat/backoff knob of the socket runtime.

    Fields (all seconds unless noted):
      io_timeout_s        protocol-progress deadline: the longest a
                          party/conductor waits for the next *protocol*
                          frame (heartbeats never extend it).
      connect_timeout_s   bootstrap: dial/accept/port-report deadline.
      bye_timeout_s       graceful-shutdown bye collection.
      join_timeout_s      process join after shutdown.
      term_timeout_s      process join after terminate escalation.
      poll_interval_s     liveness poll cadence while blocked in a
                          collection loop (child exit-code checks).
      heartbeat_interval_s  keep-alive cadence; None derives the
                          historical `min(io_timeout/3, 30)`.
      rto_initial_s       first retransmission timeout of a reliable
                          frame (chaos ARQ layer).
      rto_max_s           retransmission timeout cap.
      rto_multiplier      exponential backoff factor per attempt.
      retry_budget        max retransmissions per frame before the link
                          is declared dead (int).
      frame_deadlines     per-control-kind deadline overrides, e.g.
                          {"bye": 10.0}.
    """

    io_timeout_s: float = DEFAULT_IO_TIMEOUT_S
    connect_timeout_s: Optional[float] = None      # None -> io_timeout_s
    bye_timeout_s: float = 10.0
    join_timeout_s: float = 10.0
    term_timeout_s: float = 5.0
    poll_interval_s: float = 1.0
    heartbeat_interval_s: Optional[float] = None   # None -> derived
    rto_initial_s: float = 0.25
    rto_max_s: float = 5.0
    rto_multiplier: float = 2.0
    retry_budget: int = 24
    frame_deadlines: tuple = ()                    # ((kind, seconds), ...)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_env(cls, **overrides) -> "RetryPolicy":
        """The deployment default: `REPRO_WIRE_TIMEOUT_S` keeps working
        exactly as before; everything else takes the dataclass
        defaults unless overridden."""
        io = overrides.pop("io_timeout_s",
                           _env_float("REPRO_WIRE_TIMEOUT_S",
                                      DEFAULT_IO_TIMEOUT_S))
        return cls(io_timeout_s=io, **overrides)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["frame_deadlines"] = [list(kv) for kv in self.frame_deadlines]
        return d

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "RetryPolicy":
        if d is None:
            return cls.from_env()
        d = dict(d)
        d["frame_deadlines"] = tuple(
            (str(k), float(v)) for k, v in d.get("frame_deadlines", ()))
        return cls(**d)

    # -- derived values -----------------------------------------------------
    def connect_timeout(self) -> float:
        return (self.io_timeout_s if self.connect_timeout_s is None
                else self.connect_timeout_s)

    def heartbeat_interval(self) -> float:
        if self.heartbeat_interval_s is not None:
            return self.heartbeat_interval_s
        return min(self.io_timeout_s / 3.0, 30.0)

    def deadline_for(self, kind: Optional[str]) -> float:
        """Protocol-wait deadline for a control kind (`io_timeout_s`
        unless the kind carries an explicit override)."""
        for k, v in self.frame_deadlines:
            if k == kind:
                return v
        return self.io_timeout_s

    # -- backoff schedule ---------------------------------------------------
    def rto(self, attempt: int) -> float:
        """Base retransmission timeout before jitter for `attempt`
        (1-indexed: attempt 1 is the first RE-transmission)."""
        raw = self.rto_initial_s * (self.rto_multiplier ** (attempt - 1))
        return min(raw, self.rto_max_s)

    def backoff(self, link_seed: int, seq: int, attempt: int) -> float:
        """Deterministic jittered backoff delay for retransmission
        `attempt` of frame `seq`: rto(attempt) × U[0.5, 1.5), where U
        is a pure hash of (link_seed, seq, attempt).  Replayable — the
        retry trace of a seeded chaos run is itself seeded."""
        u = _unit_hash(link_seed, seq, attempt)
        return self.rto(attempt) * (0.5 + u)

    def max_outage_s(self) -> float:
        """Upper bound on how long a link outage can last before the
        retry budget is exhausted (sum of max jittered backoffs) — the
        figure to compare a partition duration against."""
        return sum(1.5 * self.rto(a) for a in range(1,
                                                    self.retry_budget + 1))


def _unit_hash(*vals: int) -> float:
    """Pure [0,1) hash of integers — the shared deterministic entropy
    source for backoff jitter and the chaos fault schedule."""
    h = hashlib.blake2b(struct.pack(f"<{len(vals)}q", *vals),
                        digest_size=8).digest()
    return struct.unpack("<Q", h)[0] / 2.0 ** 64
