"""Reusable selective-receive dispatch core for frame-driven servers.

Extracted from `runtime.netparty.PartyServer` so the TRAINING loop and
the SERVING loop run on ONE event loop implementation: the same
single-deadline wait with heartbeat filtering, the same stash
discipline, the same control-frame semantics (`PeerLost` attribution,
shutdown refusal).  Serving traffic (`infer.wx_share`) therefore flows
through exactly the codec/transport/meter stack training uses — which
is what lets the serving gauntlet assert measured bytes == analytic
per tag with no serving-specific accounting.

The core owns three concerns and nothing else:

  * `next_message` — block for one PROTOCOL frame with one deadline for
    the whole wait; heartbeats keep the link warm but never extend it
    (a wedged-but-beating peer must still trip the timeout);
  * `route` — deliver a frame to the handler, unless a registered
    `Stash` claims it (messages that must not reach the actor yet:
    Beaver openings pop per-peer by the leg openers, Protocol-1 shares
    wait for `begin_iteration`, score shares wait for an open inference
    batch — the predicates close over the server's phase flags);
  * `pump_one` / `next_ctrl` — the two wait shapes every request
    handler is built from: service protocol traffic while blocked, and
    turn mid-protocol control frames into the right exception.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Optional

from repro.runtime import messages as msg


class PeerLost(RuntimeError):
    """A transport link died mid-protocol.  `peer` names the far end so
    the conductor can attribute the failure to the party that actually
    vanished rather than to the collateral reporter — the supervisor's
    flap-quarantine accounting keys on that attribution."""

    def __init__(self, message: str, peer: str):
        super().__init__(message)
        self.peer = peer


class Stash:
    """Messages withheld from the handler, bucketed by an optional key
    (e.g. per-peer Beaver openings).  Truthiness/len reflect the total
    across buckets; `[key]` exposes one bucket's deque."""

    def __init__(self, match: Callable[[msg.Message], bool],
                 key: Optional[Callable[[msg.Message], Any]] = None):
        self.match = match
        self._key = key or (lambda m: None)
        self.buckets: dict[Any, collections.deque] = \
            collections.defaultdict(collections.deque)

    def put(self, m: msg.Message) -> None:
        self.buckets[self._key(m)].append(m)

    def popleft(self, key: Any = None) -> msg.Message:
        return self.buckets[key].popleft()

    def __getitem__(self, key: Any) -> collections.deque:
        return self.buckets[key]

    def __len__(self) -> int:
        return sum(len(q) for q in self.buckets.values())


class DispatchCore:
    """The request-dispatch engine of a `PartyServer`-shaped process.

    Args:
      name: this endpoint's wire identity (error attribution).
      transport: a Transport with an `inbound` queue of decoded frames.
      io_timeout: protocol-progress deadline per wait (seconds).
      deliver: final delivery callback for unstashed protocol frames
        (the actor dispatch: counts, `actor.handle`, `post_all`).
    """

    def __init__(self, name: str, transport, io_timeout: float,
                 deliver: Callable[[msg.Message], None]):
        self.name = name
        self.tp = transport
        self.io_timeout = float(io_timeout)
        self._deliver = deliver
        self._stashes: list[Stash] = []

    def add_stash(self, match: Callable[[msg.Message], bool],
                  key: Optional[Callable[[msg.Message], Any]] = None
                  ) -> Stash:
        """Register a withholding rule; earlier stashes win.  The match
        predicate may close over caller phase flags (it is re-evaluated
        per frame, so flipping a flag re-opens the path to `deliver`)."""
        st = Stash(match, key)
        self._stashes.append(st)
        return st

    # -- waiting -----------------------------------------------------------
    def next_message(self) -> msg.Message:
        import queue
        import time
        # ONE deadline for the whole wait: heartbeats are discarded
        # WITHOUT extending it — they keep the link warm and give the
        # conductor early dead-link detection, but only *protocol*
        # progress may satisfy this waiter (a wedged-but-beating
        # conductor must still trip the timeout, as it did before
        # heartbeats existed)
        deadline = time.monotonic() + self.io_timeout
        while True:
            try:
                m = self.tp.inbound.get(
                    timeout=max(deadline - time.monotonic(), 0.0))
            except queue.Empty:
                raise TimeoutError(
                    f"{self.name}: no protocol frame for "
                    f"{self.io_timeout}s (lost conductor or peer?)") \
                    from None
            if isinstance(m, msg.Control) and m.kind == "hb":
                continue        # keep-alive only — never routed
            return m

    # -- routing -----------------------------------------------------------
    def route(self, m: msg.Message) -> None:
        """Deliver one protocol message, stashing the classes that must
        not reach the handler yet."""
        for st in self._stashes:
            if st.match(m):
                st.put(m)
                return
        self._deliver(m)

    def pump_one(self) -> None:
        """Receive one frame and route it; control frames mid-protocol
        mean shutdown/peer-loss and raise."""
        m = self.next_message()
        if isinstance(m, msg.Control):
            if m.kind == "__closed__":
                raise PeerLost(
                    f"{self.name}: connection to {m.src} failed: "
                    f"{m.payload.get('error')}", peer=m.src)
            if m.kind == "shutdown":
                raise RuntimeError(
                    f"{self.name}: shutdown while mid-protocol")
            raise RuntimeError(f"{self.name}: unexpected control frame "
                               f"{m.kind!r} mid-request")
        self.route(m)

    def next_ctrl(self, expect: Optional[str] = None) -> msg.Control:
        """Block for the next control frame, servicing protocol traffic
        in the meantime (a fast peer's next-phase frames can beat the
        conductor's control frame and must be stashed)."""
        while True:
            m = self.next_message()
            if isinstance(m, msg.Control):
                if m.kind == "__closed__":
                    raise PeerLost(
                        f"{self.name}: connection to {m.src} failed: "
                        f"{m.payload.get('error')}", peer=m.src)
                if expect is not None and m.kind != expect \
                        and m.kind != "shutdown":
                    raise RuntimeError(
                        f"{self.name}: expected {expect!r}, got {m.kind!r}")
                return m
            self.route(m)
