"""Seed-stream registry: every derived randomness stream in one place.

The single-process scheduler and the process-isolated socket runtime
must derive bit-identical streams from one run seed, and the frozen
seed-trainer oracle in tests must keep matching both — so the offsets
and derivations live here, not as magic numbers scattered per module.

Streams:

  protocol_rng(seed)     masks, Paillier noise, random CP selection on
                         the bit-exact local replay, and — via its
                         FIRST k draws — the per-party key seeds
                         (`trainer.make_backend` consumes this stream
                         directly; `key_seeds` replicates those draws
                         for the distributed runtime).
  cp_select_rng(seed)    dedicated CP-selection stream for transports
                         whose mask draws are not globally ordered
                         (PipelinedTransport threads, socket cluster).
  party_rng(seed, i)     per-party mask/noise stream in the socket
                         runtime (mask values cancel exactly, so this
                         may differ from the local replay's shared
                         stream without changing the trained model).
  dealer_seed(seed)      Beaver-triple dealer; each party replicates it
                         (`DealerTripleSource(dealer_seed(s))`) and
                         keeps it aligned via `skip()`.
  (batch schedule and the Protocol-1 jax key ladder use the run seed
  itself: `np.random.default_rng(seed)` / `jax.random.key(seed)`.)
"""
from __future__ import annotations

import numpy as np

#: offset of the shared protocol stream (masks/noise/keygen draws)
PROTOCOL_OFFSET = 90001
#: offset of the dedicated CP-selection stream
CP_SELECT_OFFSET = 90002
#: tag separating per-party streams in the socket runtime
PARTY_STREAM_TAG = 90101


def protocol_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed + PROTOCOL_OFFSET)


def cp_select_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed + CP_SELECT_OFFSET)


def party_rng(seed: int, party_index: int) -> np.random.Generator:
    return np.random.default_rng([seed, PARTY_STREAM_TAG, party_index])


def dealer_seed(seed: int) -> int:
    return seed + 1


def key_seeds(seed: int, names: list[str]) -> dict[str, int]:
    """The per-party Paillier key seeds, exactly as `trainer.make_backend`
    draws them: the first k scalar draws of the protocol stream, in
    roster order."""
    rng = protocol_rng(seed)
    return {n: int(rng.integers(2 ** 31)) for n in names}
