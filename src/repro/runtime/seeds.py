"""Seed-stream registry: every derived randomness stream in one place.

The single-process scheduler and the process-isolated socket runtime
must derive bit-identical streams from one run seed, and the frozen
seed-trainer oracle in tests must keep matching both — so the offsets
and derivations live here, not as magic numbers scattered per module.

Streams:

  protocol_rng(seed)     masks, Paillier noise, random CP selection on
                         the bit-exact local replay, and — via its
                         FIRST k draws — the per-party key seeds
                         (`trainer.make_backend` consumes this stream
                         directly; `key_seeds` replicates those draws
                         for the distributed runtime).
  cp_select_rng(seed)    dedicated CP-selection stream for transports
                         whose mask draws are not globally ordered
                         (PipelinedTransport threads, socket cluster).
  party_rng(seed, i)     per-party mask/noise stream in the socket
                         runtime (mask values cancel exactly, so this
                         may differ from the local replay's shared
                         stream without changing the trained model).
  dealer_seed(seed)      Beaver-triple dealer; each party replicates it
                         (`DealerTripleSource(dealer_seed(s))`) and
                         keeps it aligned via `skip()`.
  (batch schedule and the Protocol-1 jax key ladder use the run seed
  itself: `np.random.default_rng(seed)` / `jax.random.key(seed)`.)

Drawn-count accounting.  Every generator the registry hands out is a
`CountedGenerator`: a transparent proxy that counts method-level draws
(`drawn()`), so stream positions are *auditable* — resumable sessions
(`runtime.session.TrainState`) persist the exact bit-generator state,
and the resume handshake asserts the counters that must agree across
parties (dealer draws, batch cursor) actually do.  The counter is a
draw-call count, not an entropy-word count: it identifies *where in the
program's draw sequence* a stream sits, which is the invariant the
replicated-stream discipline needs.
"""
from __future__ import annotations

from typing import Any

import numpy as np

#: offset of the shared protocol stream (masks/noise/keygen draws)
PROTOCOL_OFFSET = 90001
#: offset of the dedicated CP-selection stream
CP_SELECT_OFFSET = 90002
#: tag separating per-party streams in the socket runtime
PARTY_STREAM_TAG = 90101


class CountedGenerator:
    """Transparent counting proxy over `np.random.Generator`.

    Every callable attribute access returns a wrapper that increments
    `drawn()` before delegating, so the number of draw *calls* a stream
    has served is always known.  Non-callable attributes
    (`bit_generator`, …) pass through untouched.  `state()` /
    `set_state()` capture and restore the exact generator position plus
    the counter — the serialized form `runtime.session.TrainState`
    checkpoints.

    Thread-safety note: the proxy itself is not locked; concurrent
    transports wrap it in `transport.LockedRNG`, whose per-call lock
    also serializes the counter increment.
    """

    def __init__(self, rng: np.random.Generator, drawn: int = 0):
        self._rng = rng
        self._drawn = int(drawn)

    def drawn(self) -> int:
        """Number of draw calls served since construction/`set_state`."""
        return self._drawn

    def state(self) -> dict[str, Any]:
        """JSON-able snapshot: exact bit-generator position + counter."""
        return {"bit_generator": self._rng.bit_generator.state,
                "drawn": self._drawn}

    def set_state(self, st: dict[str, Any]) -> None:
        """Restore in place (aliases holding this generator — backends,
        actors — see the restored position immediately)."""
        self._rng.bit_generator.state = st["bit_generator"]
        self._drawn = int(st["drawn"])

    def __getattr__(self, name):
        attr = getattr(self._rng, name)
        if not callable(attr):
            return attr

        def counted(*args, **kwargs):
            self._drawn += 1
            return attr(*args, **kwargs)

        return counted


def generator_state(rng: np.random.Generator) -> dict[str, Any]:
    """Snapshot a *plain* generator (the batch-schedule stream, which
    predates the counted registry and is position-audited by the batch
    cursor instead)."""
    return rng.bit_generator.state


def restore_generator(rng: np.random.Generator, st: dict[str, Any]) -> None:
    rng.bit_generator.state = st


def protocol_rng(seed: int) -> CountedGenerator:
    return CountedGenerator(np.random.default_rng(seed + PROTOCOL_OFFSET))


def cp_select_rng(seed: int) -> CountedGenerator:
    return CountedGenerator(np.random.default_rng(seed + CP_SELECT_OFFSET))


def party_rng(seed: int, party_index: int) -> CountedGenerator:
    return CountedGenerator(
        np.random.default_rng([seed, PARTY_STREAM_TAG, party_index]))


def dealer_seed(seed: int) -> int:
    return seed + 1


def key_seeds(seed: int, names: list[str]) -> dict[str, int]:
    """The per-party Paillier key seeds, exactly as `trainer.make_backend`
    draws them: the first k scalar draws of the protocol stream, in
    roster order."""
    rng = protocol_rng(seed)
    return {n: int(rng.integers(2 ** 31)) for n in names}
