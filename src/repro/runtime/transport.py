"""Pluggable transports for the party runtime.

A Transport is the only place communication happens: parties hand it
typed `Message` envelopes (`post`), it meters their `wire_bytes()` and
queues them, and `pump()` delivers them to the recipients' `handle()`
until the network is quiet.  One pump *sweep* delivers every message
that was in flight when the sweep started — i.e. one network latency
step — so `rounds` counts the protocol's communication rounds (the
paper's comm-rounds columns) for free.

* `LocalTransport` — bit-identical replay of the original single-process
  simulation: messages are delivered sequentially in a deterministic
  order, and shared-randomness consumption matches the seed trainer
  draw-for-draw.
* `PipelinedTransport` — overlaps the data-independent legs of
  Protocol 3: the CP↔CP encrypted-gradient exchange and the CP→non-CP
  broadcasts enter the same sweep (they only depend on the Protocol-2
  output d), and each sweep's per-party handler work runs on a thread
  pool, so the two CPs' HE matvecs overlap the non-CP matvecs on real
  hardware.  Masks are drawn behind a lock and cancel exactly, so the
  trained model is bit-identical to LocalTransport under fixed CP
  selection; CP *selection* uses a dedicated stream so the trajectory
  stays deterministic regardless of thread interleaving.
"""
from __future__ import annotations

import collections
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.comm import CommMeter
from repro.runtime.messages import Message


class LockedRNG:
    """Thread-safe proxy over a np.random.Generator: every method call is
    serialized, so concurrent handlers can share one entropy source."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng
        self._lock = threading.Lock()

    def __getattr__(self, name):
        attr = getattr(self._rng, name)
        if not callable(attr):
            return attr
        lock = self._lock

        def locked(*args, **kwargs):
            with lock:
                return attr(*args, **kwargs)

        return locked


class Transport:
    """Base: metering + FIFO inboxes + sweep-based delivery."""

    #: whether the Protocol-3 CP exchange and non-CP broadcasts may share
    #: a sweep (they are data-independent; the local replay keeps them
    #: serial to match the seed trainer's draw order).
    overlaps_p3 = False

    #: background executor for data-independent precompute (the Paillier
    #: noise pool).  None = fully synchronous transport.
    executor = None

    def __init__(self, meter: CommMeter | None = None):
        self.meter = meter if meter is not None else CommMeter()
        self.rounds = 0
        self._inbox: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)
        self._parties: dict[str, object] = {}

    # -- wiring -------------------------------------------------------------
    def bind(self, parties) -> None:
        self._parties = {p.name: p for p in parties}

    def wrap_rng(self, rng: np.random.Generator):
        """Hook: make the shared protocol generator safe for this
        transport's execution model."""
        return rng

    def cp_select_rng(self, shared_rng, seed: int):
        """Generator used for per-iteration CP selection.  The local
        replay shares the protocol stream (seed-trainer parity); the
        pipelined transport gets a dedicated stream so concurrent mask
        draws can't shift the selection trajectory."""
        return shared_rng

    # -- sending ------------------------------------------------------------
    def account(self, msg: Message) -> None:
        """Meter a message that is applied in-place by joint simulation
        (e.g. Beaver openings evaluated inside mpc.beaver.mul)."""
        self.meter.add(msg.src, msg.dst, msg.tag, msg.wire_bytes())

    def post(self, msg: Message) -> None:
        """Meter + enqueue.  A message to oneself is a local handoff:
        delivered, never metered."""
        if msg.src != msg.dst:
            self.account(msg)
        self._inbox[msg.dst].append(msg)

    def post_all(self, msgs) -> None:
        for m in msgs or ():
            self.post(m)

    def exchange_round(self) -> None:
        """Count one latency step that carries no queued message (joint
        Beaver openings)."""
        self.rounds += 1

    # -- delivery -----------------------------------------------------------
    def pump(self, order: list[str] | None = None) -> None:
        """Deliver until quiet.  Each sweep delivers only the messages
        present at sweep start; handler outputs join the next sweep."""
        priority = list(order or [])
        priority += [n for n in self._parties if n not in priority]
        while any(self._inbox[n] for n in self._parties):
            self.rounds += 1
            snapshot = [(n, len(self._inbox[n])) for n in priority
                        if self._inbox[n]]
            self._sweep(snapshot)

    def _deliver_one(self, name: str, count: int) -> list[Message]:
        party = self._parties[name]
        out: list[Message] = []
        for _ in range(count):
            out.extend(party.handle(self._inbox[name].popleft()) or ())
        return out

    def _sweep(self, snapshot) -> None:
        for name, count in snapshot:
            self.post_all(self._deliver_one(name, count))


class LocalTransport(Transport):
    """Sequential in-process delivery; replays the seed simulation
    bit-for-bit (losses, weights, and per-tag meter bytes)."""


class PipelinedTransport(Transport):
    """Thread-pooled sweeps + merged Protocol-3 send phase."""

    overlaps_p3 = True

    def __init__(self, meter: CommMeter | None = None,
                 max_workers: int | None = None):
        super().__init__(meter)
        self._pool = ThreadPoolExecutor(max_workers=max_workers or 8)

    @property
    def executor(self):
        """The sweep pool doubles as the noise-prefetch executor: r^n
        modexps scheduled on it overlap the Protocol-3 handler legs."""
        return self._pool

    def wrap_rng(self, rng: np.random.Generator):
        return LockedRNG(rng)

    def cp_select_rng(self, shared_rng, seed: int):
        return np.random.default_rng(seed + 90002)

    def _sweep(self, snapshot) -> None:
        if len(snapshot) <= 1:
            for name, count in snapshot:
                self.post_all(self._deliver_one(name, count))
            return
        futs = [self._pool.submit(self._deliver_one, name, count)
                for name, count in snapshot]
        for f in futs:
            self.post_all(f.result())
