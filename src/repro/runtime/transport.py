"""Pluggable transports for the party runtime.

A Transport is the only place communication happens: parties hand it
typed `Message` envelopes (`post`), it meters their `wire_bytes()` and
queues them, and `pump()` delivers them to the recipients' `handle()`
until the network is quiet.  One pump *sweep* delivers every message
that was in flight when the sweep started — i.e. one network latency
step — so `rounds` counts the protocol's communication rounds (the
paper's comm-rounds columns) for free.

* `LocalTransport` — bit-identical replay of the original single-process
  simulation: messages are delivered sequentially in a deterministic
  order, and shared-randomness consumption matches the seed trainer
  draw-for-draw.
* `SocketTransport` — the real wire: every posted envelope is encoded
  by the versioned binary codec (`runtime.codec`) and written to a TCP
  connection; inbound frames are decoded by per-connection reader
  threads into one event queue the hosting `netparty.PartyServer` (or
  the conductor) drains.  Analytic metering is identical to the local
  transports; additionally the *measured* payload bytes of every frame
  actually sent are recorded per tag, and frame/header overhead is
  tracked separately, so analytic accounting can be asserted against
  the wire.
* `PipelinedTransport` — overlaps the data-independent legs of
  Protocol 3: the CP↔CP encrypted-gradient exchange and the CP→non-CP
  broadcasts enter the same sweep (they only depend on the Protocol-2
  output d), and each sweep's per-party handler work runs on a thread
  pool, so the two CPs' HE matvecs overlap the non-CP matvecs on real
  hardware.  With `concurrent_legs` (default), the scheduler upgrades
  the sweep to `pump_async`: every message becomes its own pool future
  the moment it is visible — no per-sweep barrier — so all k−2 non-CP
  masked-matvec legs and both CP decrypt legs run as independent
  futures, joined only once the network is quiet (the barrier before
  Protocol 4).  Masks are drawn behind a lock and cancel exactly, so
  the trained model is bit-identical to LocalTransport under fixed CP
  selection; CP *selection* uses a dedicated stream so the trajectory
  stays deterministic regardless of thread interleaving.
"""
from __future__ import annotations

import collections
import queue as _queue
import socket as _socket
import threading
import time as _time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import numpy as np

from repro.core.comm import CommMeter
from repro.runtime.codec import frame_overhead_bytes
from repro.runtime.messages import Message


class LockedRNG:
    """Thread-safe proxy over a np.random.Generator: every method call is
    serialized, so concurrent handlers can share one entropy source."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng
        self._lock = threading.Lock()

    def __getattr__(self, name):
        attr = getattr(self._rng, name)
        if not callable(attr):
            return attr
        lock = self._lock

        def locked(*args, **kwargs):
            with lock:
                return attr(*args, **kwargs)

        return locked


class Transport:
    """Base: metering + FIFO inboxes + sweep-based delivery.

    Subclasses choose the execution model only — message metering
    (`wire_bytes()` at `post`) and round counting are shared, so every
    transport reports identical per-tag byte totals for the same
    protocol run.
    """

    #: whether the Protocol-3 CP exchange and non-CP broadcasts may share
    #: a sweep (they are data-independent; the local replay keeps them
    #: serial to match the seed trainer's draw order).
    overlaps_p3 = False

    #: whether the scheduler may dispatch protocol legs as independent
    #: pool futures (per-message delivery via `pump_async`, no per-sweep
    #: barrier).  Requires `executor`.
    concurrent_legs = False

    #: background executor for data-independent precompute (the Paillier
    #: noise pool).  None = fully synchronous transport.
    executor = None

    def __init__(self, meter: CommMeter | None = None):
        self.meter = meter if meter is not None else CommMeter()
        self.rounds = 0
        self._inbox: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)
        self._parties: dict[str, object] = {}
        self._locks: dict[str, threading.Lock] = {}

    # -- wiring -------------------------------------------------------------
    def bind(self, parties) -> None:
        """Register the actors; messages route by `Party.name`.  Also
        pre-creates one delivery lock per party (concurrent transports
        serialize each actor's `handle` calls with it)."""
        self._parties = {p.name: p for p in parties}
        self._locks = {p.name: threading.Lock() for p in parties}

    def wrap_rng(self, rng: np.random.Generator):
        """Hook: make the shared protocol generator safe for this
        transport's execution model."""
        return rng

    def cp_select_rng(self, shared_rng, seed: int):
        """Generator used for per-iteration CP selection.  The local
        replay shares the protocol stream (seed-trainer parity); the
        pipelined transport gets a dedicated stream so concurrent mask
        draws can't shift the selection trajectory."""
        return shared_rng

    # -- sending ------------------------------------------------------------
    def account(self, msg: Message) -> None:
        """Meter a message that is applied in-place by joint simulation
        (e.g. Beaver openings evaluated inside mpc.beaver.mul)."""
        self.meter.add(msg.src, msg.dst, msg.tag, msg.wire_bytes())

    def post(self, msg: Message) -> None:
        """Meter + enqueue.  A message to oneself is a local handoff:
        delivered, never metered."""
        if msg.src != msg.dst:
            self.account(msg)
        self._inbox[msg.dst].append(msg)

    def post_all(self, msgs) -> None:
        for m in msgs or ():
            self.post(m)

    def exchange_round(self) -> None:
        """Count one latency step that carries no queued message (joint
        Beaver openings)."""
        self.rounds += 1

    # -- delivery -----------------------------------------------------------
    def pump(self, order: list[str] | None = None) -> None:
        """Deliver until quiet.  Each sweep delivers only the messages
        present at sweep start; handler outputs join the next sweep."""
        priority = list(order or [])
        priority += [n for n in self._parties if n not in priority]
        while any(self._inbox[n] for n in self._parties):
            self.rounds += 1
            snapshot = [(n, len(self._inbox[n])) for n in priority
                        if self._inbox[n]]
            self._sweep(snapshot)

    def _deliver_one(self, name: str, count: int) -> list[Message]:
        party = self._parties[name]
        out: list[Message] = []
        for _ in range(count):
            out.extend(party.handle(self._inbox[name].popleft()) or ())
        return out

    def _sweep(self, snapshot) -> None:
        for name, count in snapshot:
            self.post_all(self._deliver_one(name, count))


class LocalTransport(Transport):
    """Sequential in-process delivery; replays the seed simulation
    bit-for-bit (losses, weights, and per-tag meter bytes).  No
    executor, so the scheduler runs every protocol leg inline — this is
    the 'sequential' baseline the concurrent schedules are verified
    against."""


class PipelinedTransport(Transport):
    """Thread-pooled sweeps + merged Protocol-3 send phase + per-message
    concurrent delivery (`pump_async`).

    Args:
      meter: byte accounting sink (fresh `CommMeter` if None).
      max_workers: thread-pool size (default 8; bound it to the host's
        useful parallelism — each worker runs whole HE matvec/decrypt
        legs).
      concurrent_legs: allow the scheduler to use `pump_async` for the
        Protocol-3 legs (False falls back to barrier sweeps — kept as a
        comparison/debug knob; model output is bit-identical either
        way).
    """

    overlaps_p3 = True

    def __init__(self, meter: CommMeter | None = None,
                 max_workers: int | None = None,
                 concurrent_legs: bool = True):
        super().__init__(meter)
        self._pool = ThreadPoolExecutor(max_workers=max_workers or 8)
        self.concurrent_legs = concurrent_legs

    @property
    def executor(self):
        """The sweep pool doubles as the noise-prefetch executor: r^n
        modexps scheduled on it overlap the Protocol-3 handler legs."""
        return self._pool

    def wrap_rng(self, rng: np.random.Generator):
        return LockedRNG(rng)

    def cp_select_rng(self, shared_rng, seed: int):
        from repro.runtime import seeds
        return seeds.cp_select_rng(seed)

    def _sweep(self, snapshot) -> None:
        if len(snapshot) <= 1:
            for name, count in snapshot:
                self.post_all(self._deliver_one(name, count))
            return
        futs = [self._pool.submit(self._deliver_one, name, count)
                for name, count in snapshot]
        for f in futs:
            self.post_all(f.result())

    # -- per-message concurrent delivery ------------------------------------
    def _handle_locked(self, m: Message) -> list[Message]:
        """Deliver one message under the recipient's lock (each actor
        stays effectively single-threaded; different actors' legs run
        concurrently)."""
        with self._locks[m.dst]:
            return self._parties[m.dst].handle(m) or []

    def pump_async(self, order: list[str] | None = None) -> None:
        """Event-driven drain: every queued message is submitted to the
        pool as its own future the moment it is visible, and a handler's
        outputs are submitted immediately — no per-sweep barrier, so a
        fast party's next leg never waits for a slow party's current
        one.  Returns only when the network is quiet: this return IS the
        join barrier the scheduler needs before Protocol 4.

        `rounds` grows by the longest message dependency chain (the
        number of latency steps a real network would pay), matching what
        `pump` counts for the same traffic.  `order` is accepted for
        signature parity with `pump`; delivery order is nondeterministic
        by design, so callers must only drain order-insensitive phases
        (Protocol 3's ring-share accumulations commute exactly).
        """
        seed: list[Message] = []
        names = list(order or [])
        names += [n for n in self._parties if n not in names]
        for n in names:
            q = self._inbox[n]
            while q:
                seed.append(q.popleft())
        futs = {self._pool.submit(self._handle_locked, m): 1 for m in seed}
        max_gen = 1 if futs else 0
        while futs:
            done, _ = wait(set(futs), return_when=FIRST_COMPLETED)
            for f in done:
                gen = futs.pop(f)
                for m in f.result():
                    if m.src != m.dst:
                        self.account(m)
                    futs[self._pool.submit(self._handle_locked, m)] = gen + 1
                    max_gen = max(max_gen, gen + 1)
        self.rounds += max_gen


# ---------------------------------------------------------------------------
# Socket transport — encoded frames over TCP between party processes
# ---------------------------------------------------------------------------

class PeerClosed(ConnectionError):
    """A peer's connection closed or failed mid-protocol."""


class SocketTransport(Transport):
    """One node's endpoint of the distributed runtime.

    Unlike the in-process transports, delivery is event-driven rather
    than sweep-driven: `post` serializes the envelope with the binary
    codec and writes it to the destination's TCP connection (a message
    to oneself is a local handoff straight into the event queue, never
    metered — same rule as the in-process transports), and every
    connection has a reader thread that decodes inbound frames into
    `inbound`, which the hosting event loop (`netparty.PartyServer` /
    `launch.cluster.SocketCluster`) drains.  `pump` therefore does not
    apply here and raises.

    Byte accounting:
      * `meter`     — analytic `wire_bytes()` per tag (identical to the
        local transports for the same protocol run);
      * `measured`  — actual encoded payload bytes per tag, as framed on
        the wire (asserted equal to `meter` in the parity tests);
      * `overhead_bytes` / `frames_sent` — codec prelude + header cost,
        reported separately (the paper's comm columns count payloads).

    Control frames (`messages.Control`) ride the same connections via
    `send_control` but touch neither meter: they are conductor
    orchestration, not protocol traffic.
    """

    def __init__(self, name: str, codec, meter: CommMeter | None = None):
        super().__init__(meter)
        self.name = name
        self.codec = codec
        self.measured = CommMeter()
        self.overhead_bytes = 0
        self.frames_sent = 0
        self.inbound: "queue.Queue" = _queue.Queue()
        self._conns: dict[str, "socket.socket"] = {}
        self._send_locks: dict[str, threading.Lock] = {}
        self._readers: dict[str, threading.Thread] = {}
        self._hb_threads: list[threading.Thread] = []
        self._stop_evt = threading.Event()
        self._closing = False

    # -- wiring -------------------------------------------------------------
    def attach(self, peer: str, sock) -> None:
        """Register an established connection to `peer` and start its
        reader thread.  Re-attaching an existing peer REPLACES the link
        (reconnect after a drop): the stale socket is closed, its reader
        dies on the closed fd, and subsequent sends use the new
        connection.  The reader blocks without a timeout — a mesh
        link between two parties that exchange nothing for a long run
        (e.g. two non-CPs) must not fake a peer loss; liveness bounds
        live on the *waiters* (event-queue timeouts), not the wire."""
        stale = self._conns.pop(peer, None)
        if stale is not None:
            _close_sock(stale)
        old_reader = self._readers.pop(peer, None)
        sock.settimeout(None)
        self._conns[peer] = sock
        self._send_locks[peer] = threading.Lock()
        t = threading.Thread(target=self._reader, args=(peer, sock),
                             name=f"wire-{self.name}-from-{peer}",
                             daemon=True)
        self._readers[peer] = t
        t.start()
        if old_reader is not None:       # exits on the closed stale fd
            old_reader.join(timeout=2.0)

    def detach(self, peer: str) -> None:
        """Drop the link to `peer` and JOIN its reader thread (it exits
        on the closed fd) without surfacing a `__closed__` event — the
        caller already knows; used before a deliberate reconnect."""
        sock = self._conns.pop(peer, None)
        if sock is not None:
            _close_sock(sock)
        t = self._readers.pop(peer, None)
        if t is not None and t is not threading.current_thread():
            t.join(timeout=2.0)

    def peers(self):
        return list(self._conns)

    # -- liveness -----------------------------------------------------------
    def start_heartbeat(self, dst: str, interval_s: float) -> None:
        """Ship `hb` control frames to `dst` every `interval_s` while the
        transport is open.  Heartbeats keep idle links warm (middlebox/
        NAT state, half-open detection) and give the SENDER early
        dead-peer detection: a kill surfaces as a send error on the next
        beat instead of lying dormant until the next protocol frame.
        Receivers discard `hb` frames without extending their protocol
        timeouts (`netparty._next_message` keeps one deadline across
        them — a wedged-but-beating peer must still trip the failure
        detector); they are liveness traffic, never metered."""
        from repro.runtime import messages as msg_lib

        def beat() -> None:
            while not self._closing and dst in self._conns:
                # Event.wait, not sleep: close() wakes the beat loop
                # immediately so teardown can JOIN it instead of leaking
                # a sleeping thread per peer
                if self._stop_evt.wait(interval_s):
                    return
                if self._closing or dst not in self._conns:
                    return
                try:
                    self.send_control(msg_lib.Control(
                        self.name, dst, kind="hb"))
                except Exception:            # noqa: BLE001 — link gone;
                    return                   # waiters surface the loss

        t = threading.Thread(target=beat, daemon=True,
                             name=f"hb-{self.name}-to-{dst}")
        self._hb_threads.append(t)
        t.start()

    # -- sending ------------------------------------------------------------
    def _send_frame(self, dst: str, frame: bytes) -> None:
        sock = self._conns.get(dst)
        if sock is None:
            raise PeerClosed(f"{self.name}: no connection to {dst!r}")
        with self._send_locks[dst]:
            sock.sendall(frame)

    def _ship(self, dst: str, frame: bytes, reliable: bool = True) -> None:
        """THE egress seam: every encoded frame leaves through here,
        AFTER metering.  The base transport writes straight to the
        socket; `runtime.chaos.FaultyTransport` overrides this with an
        enveloped, shaped, fault-injected reliable link — which is why
        retransmits and duplicates can never touch the meters.
        `reliable=False` marks traffic (heartbeats) that may be lost
        without recovery."""
        self._send_frame(dst, frame)

    def post(self, m: Message) -> None:
        if m.dst == self.name:              # local handoff, never metered
            self.inbound.put(m)
            return
        frame = self.codec.encode(m)
        if m.src != m.dst:
            self.account(m)
            overhead = frame_overhead_bytes(frame)
            self.measured.add(m.src, m.dst, m.tag, len(frame) - overhead)
            self.overhead_bytes += overhead
            self.frames_sent += 1
        self._ship(m.dst, frame, reliable=True)

    def send_control(self, m: Message) -> None:
        """Ship a control frame without touching the protocol meters.
        Heartbeats are marked unreliable: a chaos link may drop them
        freely without burning retransmission budget on keep-alives."""
        if m.dst == self.name:
            self.inbound.put(m)
            return
        frame = self.codec.encode(m)
        self.overhead_bytes += len(frame)
        self._ship(m.dst, frame,
                   reliable=getattr(m, "kind", None) != "hb")

    # -- receiving ----------------------------------------------------------
    def _reader(self, peer: str, sock) -> None:
        from repro.runtime import messages as msg_lib
        try:
            while True:
                m = recv_frame(sock, self.codec)
                self.inbound.put(m)
        except Exception as e:               # noqa: BLE001 — surfaced below
            # a deliberately detached/replaced link (reconnect) is not a
            # peer loss: only the currently registered socket may report
            if not self._closing and self._conns.get(peer) is sock:
                self.inbound.put(msg_lib.Control(
                    peer, self.name, kind="__closed__",
                    payload={"error": f"{type(e).__name__}: {e}"}))

    # -- bootstrap ----------------------------------------------------------
    def recv_bootstrap(self, conn):
        """Read one message from a connection that is not yet attached
        (the handshake/hello reads in `netparty` happen before the peer
        is known).  The chaos transport overrides this to peel its link
        envelope; the two MUST agree, so parties read bootstrap frames
        through their transport, never via raw `recv_frame`."""
        return recv_frame(conn, self.codec)

    # -- lifecycle ----------------------------------------------------------
    def pump(self, order=None) -> None:
        raise NotImplementedError(
            "SocketTransport is event-driven; the hosting PartyServer/"
            "conductor drains .inbound instead of pump sweeps")

    def flush(self, timeout: float = 5.0) -> bool:
        """Wait until every shipped frame has actually left this host.
        Synchronous sends have nothing to wait for; the chaos transport
        overrides this to drain its shaped egress pipe.  Call before
        `close` when the last frames (bye, error) must arrive."""
        return True

    def close(self) -> None:
        self._closing = True
        self._stop_evt.set()
        for sock in self._conns.values():
            _close_sock(sock)
        self._conns.clear()
        # no leaked threads: reader threads exit on their closed fds,
        # beat loops on the stop event — join them all (skipping the
        # calling thread, should close ever run on one of them)
        me = threading.current_thread()
        for t in list(self._readers.values()) + self._hb_threads:
            if t is not me and t.is_alive():
                t.join(timeout=2.0)
        self._readers.clear()
        self._hb_threads.clear()


def _close_sock(sock) -> None:
    try:
        sock.shutdown(_socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


def _recv_exact(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise PeerClosed("connection closed mid-frame")
        buf += chunk
    return bytes(buf)


#: refuse frames whose declared sizes are absurd — corrupt/hostile
#: preludes must not drive allocations.
MAX_FRAME_BYTES = 1 << 30


def recv_frame(sock, codec):
    """Read exactly one codec frame from a blocking socket."""
    from repro.runtime.codec import PRELUDE, CodecError
    prelude = _recv_exact(sock, PRELUDE.size)
    _, _, hlen, plen, _ = PRELUDE.unpack(prelude)
    if hlen + plen > MAX_FRAME_BYTES:
        raise CodecError(f"frame too large ({hlen + plen} bytes)")
    body = _recv_exact(sock, hlen + plen)
    return codec.decode(prelude + body)
