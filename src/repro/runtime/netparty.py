"""Process-isolated EFMVFL parties: one TCP server per party.

`PartyServer` hosts exactly one `Party`/`LabelParty` actor in its own OS
process and speaks nothing but codec frames (`runtime.codec`) over TCP:

  bind → handshake → mesh → key exchange → [resume] → iterate → serve
  → shutdown

Durability.  With a checkpoint directory configured, the party persists
its OWN `runtime.session.TrainState` slice (weights, stream cursors,
meter ledgers — never a share, never key material) through
`checkpoint.CheckpointManager` every `cfg.checkpoint_every` iterations,
*before* acking `iter_done`; on a resume handshake it offers its valid
steps, rolls back to the cluster-agreed common step, and reports its
audited stream counters.  See docs/fault_tolerance.md.

Topology.  Every party listens on a loopback/LAN port.  The conductor
(`launch.cluster.SocketCluster`) connects to every party and drives the
run with `Control` frames; the parties form a full mesh among
themselves (party i initiates to every lower-index peer, accepts every
higher-index one) and exchange *all* protocol traffic directly — the
conductor never sees a share or a ciphertext, preserving the paper's
no-third-party trust model.

Determinism.  The handshake carries the run seed; every party re-derives
the streams the single-process scheduler owns so the trained model is
bit-identical to `LocalTransport` (losses, weights, per-tag bytes).
The derivations live in ONE registry, `runtime/seeds.py`:

  * batch schedule      — `default_rng(seed)` (identical replicas)
  * Protocol-1 shares   — `jax.random.key(seed)` ladder (identical)
  * Beaver triples      — `DealerTripleSource(seed+1)` replicas; non-CP
    parties `skip()` the pair's per-iteration draw count to stay aligned
  * Paillier key seeds  — first k draws of `default_rng(seed+90001)`,
    matching `trainer.make_backend`; each party generates only its OWN
    keypair and learns the peers' public `n` through the conductor
    (a real deployment would replace the seed derivation with local
    entropy — the message flow would not change)
  * masks & noise       — per-party stream `default_rng([seed, 90101,
    index])`.  Mask values differ from the single-process run, which is
    invisible in the result: Protocol-3 masks cancel exactly and
    encryption noise never reaches a decrypted value.
  * CP selection        — conductor-owned `default_rng(seed+90002)`
    (the `PipelinedTransport` convention), broadcast per iteration.

Joint CP arithmetic runs as `mpc.pairwise` legs: the Beaver openings
that the simulation only *accounted* are real `beaver_open` frames here
(identical per-tag bytes — 2 ring elements per product element per
direction).

Event loop.  The actor is single-threaded; reader threads only enqueue
decoded frames.  While waiting for anything, the server keeps
dispatching other protocol messages to the actor (selective receive),
so a computing party serves decrypt requests even while blocked in an
opening exchange.  Messages that must not hit the actor early are
stashed: `beaver_open` frames queue per-peer for the leg openers,
Protocol-1 shares queue until `begin_iteration` has run (they can
arrive before the conductor's `iter` frame), and serving-path score
shares queue until C opens an inference batch.  The conductor's
iteration barrier (every party acks `iter_done`, and no party acks
before consuming everything it needed) guarantees the network is quiet
between iterations.
"""
from __future__ import annotations

import os
import socket
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, party_checkpoint_dir
from repro.core import glm as glm_lib
from repro.core import protocols
from repro.crypto import paillier, ring
from repro.crypto import engine as engine_mod
from repro.crypto.ring import R64
from repro.mpc import beaver, pairwise
from repro.runtime import codec as codec_lib
from repro.runtime import messages as msg
from repro.runtime import seeds as seeds_lib
from repro.runtime import session as session_lib
from repro.runtime.dispatch import DispatchCore, PeerLost
from repro.runtime.party import DataParty, LabelParty
from repro.runtime.policy import RetryPolicy
from repro.runtime.scheduler import mask_bound_bits, validate_key_bits
from repro.runtime.transport import SocketTransport

CONDUCTOR = "conductor"

#: re-export for importers: the event-loop core (and its peer-loss
#: exception) moved to runtime/dispatch.py so serving shares it
PeerLost = PeerLost
#: historical module constant, now derived from the central policy
#: block (runtime/policy.py) — kept for importers
IO_TIMEOUT_S = RetryPolicy.from_env().io_timeout_s

_P1_TYPES = (msg.ZShare, msg.YShare, msg.EzShare)


#: re-export: the per-party key seeds, exactly as `trainer.make_backend`
#: draws them (see runtime/seeds.py — the stream registry).
derive_key_seeds = seeds_lib.key_seeds


class PartyServer:
    """One EFMVFL party as a network server.  See the module docstring
    for the protocol; `run()` is the process entry point."""

    def __init__(self, name: str, X: np.ndarray,
                 y: Optional[np.ndarray] = None, host: str = "127.0.0.1",
                 io_timeout: float | None = None,
                 checkpoint_dir: Optional[str] = None,
                 wire: Optional[dict] = None):
        self.name = name
        self.X = np.asarray(X, np.float64)
        self.y = None if y is None else np.asarray(y, np.float64)
        if name == "C" and self.y is None:
            raise ValueError("party C must hold the label vector")
        self.host = host
        # `wire` is the launcher-shipped link configuration: {"policy":
        # RetryPolicy dict, "chaos": ChaosProfile dict | None,
        # "compression": scheme}.  It rides the SPAWN ARGS, not the
        # handshake — the party needs its deadlines before the first
        # handshake frame can travel.
        self.wire = dict(wire or {})
        self.policy = RetryPolicy.from_dict(self.wire.get("policy"))
        if io_timeout is not None:       # explicit override wins
            self.policy = RetryPolicy.from_dict(
                dict(self.policy.to_dict(), io_timeout_s=float(io_timeout)))
        self.io_timeout = self.policy.io_timeout_s
        # party-LOCAL durable state: each party checkpoints only its own
        # TrainState slice under <dir>/party_<name>; shares and private
        # key material never leave the process (keys are seed-derived and
        # re-derived on resume — see docs/fault_tolerance.md)
        self.checkpoint_dir = None if checkpoint_dir is None else \
            party_checkpoint_dir(checkpoint_dir, name)
        self.ckpt: Optional[CheckpointManager] = None
        self.resume = False
        self.backend = None
        self.actor = None
        self._p1_open = False
        self._scoring = False
        self._flags_seen = 0
        self._unmask_served = 0
        self._dealer_draws = 0
        # selective-receive core + stashes are built in _run once the
        # transport exists (runtime/dispatch.py); the match predicates
        # close over the phase flags above
        self.core: Optional[DispatchCore] = None
        self._pending_p1 = None
        self._pending_wx = None
        self._opens = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def run(self, ready_queue=None) -> None:
        """Serve one training run; returns after `shutdown`.  On error,
        a best-effort `error` control frame carries the traceback to the
        conductor before the exception propagates (→ nonzero exit)."""
        try:
            self._run(ready_queue)
        except Exception as e:
            tb = traceback.format_exc()
            try:
                # etype lets the conductor separate deterministic
                # refusals (never retried) from transient failures
                self.tp.send_control(msg.Control(
                    self.name, CONDUCTOR, kind="error",
                    payload={"party": self.name, "traceback": tb,
                             "etype": type(e).__name__,
                             "peer": getattr(e, "peer", None)}))
            except Exception:                    # noqa: BLE001
                pass
            raise
        finally:
            tp = getattr(self, "tp", None)
            if tp is not None:
                # drain the (possibly shaped) egress pipe first: the
                # last frames out — bye, or the error report above —
                # must actually leave before the sockets die
                try:
                    tp.flush(timeout=self.policy.bye_timeout_s)
                except Exception:                # noqa: BLE001
                    pass
                tp.close()

    def _make_transport(self) -> SocketTransport:
        """Plain socket transport, or the chaos link layer when the
        launcher configured fault injection / wire compression — EVERY
        endpoint of a run must pick the same framing."""
        chaos = self.wire.get("chaos")
        compression = self.wire.get("compression", "none")
        if chaos is None and compression == "none":
            return SocketTransport(self.name, self.codec)
        from repro.runtime.chaos import ChaosProfile, FaultyTransport
        return FaultyTransport(
            self.name, self.codec,
            profile=ChaosProfile.from_dict(chaos),
            policy=self.policy, compression=compression)

    def _run(self, ready_queue) -> None:
        self._listen = socket.create_server((self.host, 0), backlog=32)
        self._listen.settimeout(self.policy.connect_timeout())
        self.port = self._listen.getsockname()[1]
        self.codec = codec_lib.Codec(self._resolve_mod)
        self.tp = self._make_transport()
        # shared request-dispatch core: training and serving both run on
        # it, so infer.wx_share frames cross the same codec/meter stack
        # as training traffic (see runtime/dispatch.py)
        self.core = DispatchCore(self.name, self.tp, self.io_timeout,
                                 deliver=self._dispatch)
        self._opens = self.core.add_stash(
            lambda m: isinstance(m, msg.BeaverOpen), key=lambda m: m.src)
        self._pending_p1 = self.core.add_stash(
            lambda m: isinstance(m, _P1_TYPES) and not self._p1_open)
        self._pending_wx = self.core.add_stash(
            lambda m: isinstance(m, msg.WxShare) and not self._scoring)
        if ready_queue is not None:
            ready_queue.put((self.name, self.port))

        # conductor connects first (parties only learn the roster from
        # its handshake, so no peer can connect before it).
        conn = self._accept()
        hello = self.tp.recv_bootstrap(conn)
        if not (isinstance(hello, msg.Control) and hello.kind == "handshake"):
            raise RuntimeError(f"{self.name}: expected handshake, got "
                               f"{getattr(hello, 'kind', type(hello))}")
        self._apply_handshake(hello.payload)
        self.tp.attach(CONDUCTOR, conn)

        # full party mesh: initiate to lower-index peers (their listeners
        # are up before the conductor handshakes anyone), accept the rest.
        i_self = self.names.index(self.name)
        for peer in self.names[:i_self]:
            s = socket.create_connection(self.roster[peer],
                                         timeout=self.policy
                                         .connect_timeout())
            s.settimeout(self.io_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self.tp.attach(peer, s)
            self.tp.send_control(msg.Control(self.name, peer, kind="hello"))
        for _ in self.names[i_self + 1:]:
            conn = self._accept()
            first = self.tp.recv_bootstrap(conn)
            if not (isinstance(first, msg.Control) and first.kind == "hello"):
                raise RuntimeError(f"{self.name}: expected hello, got "
                                   f"{getattr(first, 'kind', type(first))}")
            self.tp.attach(first.src, conn)

        self._setup_crypto()
        if self.checkpoint_dir is not None:
            self.ckpt = CheckpointManager(
                self.checkpoint_dir,
                config_hash=session_lib.config_hash(self.cfg),
                codec_version=session_lib.CODEC_VERSION)
        # offer this party's valid, config-compatible checkpoint steps to
        # the conductor's resume handshake (CheckpointMismatch propagates
        # as an `error` control frame — a mismatched resume is REFUSED)
        steps = self.ckpt.steps() if (self.resume and self.ckpt) else []
        self.tp.send_control(msg.Control(self.name, CONDUCTOR, kind="ready",
                                         payload={"ckpt_steps": steps}))
        self._main_loop()

    def _accept(self):
        conn, _ = self._listen.accept()
        conn.settimeout(self.io_timeout)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return conn

    def _apply_handshake(self, payload: dict) -> None:
        from repro.core.trainer import VFLConfig
        self.names = [r[0] for r in payload["roster"]]
        self.roster = {r[0]: (r[1], int(r[2])) for r in payload["roster"]}
        self.cfg = VFLConfig(**payload["cfg"])
        self.resume = bool(payload.get("resume", False))
        cfg = self.cfg
        self.model = glm_lib.GLMS[cfg.glm]
        self.index = self.names.index(self.name)
        self.n_total = self.X.shape[0]
        self.mask_bound = mask_bound_bits(cfg)
        validate_key_bits(cfg, self.mask_bound)
        # seed-derived stream replicas (registry: runtime/seeds.py)
        self.batch_rng = np.random.default_rng(cfg.seed)
        self.order = self.batch_rng.permutation(self.n_total)
        self.cursor = 0
        self.jkey = jax.random.key(cfg.seed)
        self.dealer = beaver.DealerTripleSource(
            seed=seeds_lib.dealer_seed(cfg.seed))
        self.rng = seeds_lib.party_rng(cfg.seed, self.index)

    def _setup_crypto(self) -> None:
        cfg = self.cfg
        if cfg.he_backend == "mock":
            self.backend = protocols.MockHEBackend(cfg.key_bits)
        else:
            seeds = derive_key_seeds(cfg.seed, self.names)
            own = paillier.keygen(cfg.key_bits, seed=seeds[self.name])
            self.tp.send_control(msg.Control(
                self.name, CONDUCTOR, kind="pubkey",
                payload={"name": self.name, "n": hex(own.pub.n)}))
            roster = self._next_ctrl(expect="pubkeys").payload["keys"]
            keys: dict = {}
            for nm, n_hex in roster.items():
                if nm == self.name:
                    keys[nm] = own
                else:
                    keys[nm] = paillier.PeerKey(paillier.public_key_from_n(
                        int(n_hex, 16), cfg.key_bits))
            self.backend = protocols.PaillierBackend(
                keys, self.rng, engine=engine_mod.make(cfg.crypto_engine))
        if self.name == "C":
            self.actor = LabelParty(self.name, self.X, self.y, cfg,
                                    self.backend, self.rng, self.model)
        else:
            self.actor = DataParty(self.name, self.X, cfg, self.backend,
                                   self.rng)

    def _resolve_mod(self, owner: str):
        """Codec key provider: the key owner's Z_{n²} modulus (None for
        the mock backend → mock ciphertext packing)."""
        if self.backend is None or not hasattr(self.backend, "keys"):
            return None
        return self.backend.keys[owner].pub.mod_n2

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------

    def _dispatch(self, m: msg.Message) -> None:
        if isinstance(m, msg.Flag):
            self._flags_seen += 1
        elif isinstance(m, msg.MaskedGrad):
            self._unmask_served += 1
        self.tp.post_all(self.actor.handle(m) or [])

    def _pump_one(self) -> None:
        self.core.pump_one()

    def _next_ctrl(self, expect: str | None = None) -> msg.Control:
        return self.core.next_ctrl(expect)

    def _main_loop(self) -> None:
        while True:
            c = self._next_ctrl()
            if c.kind == "iter":
                self._run_iteration(int(c.payload["it"]),
                                    tuple(c.payload["cps"]))
            elif c.kind == "resume":
                self._run_resume(int(c.payload["step"]))
            elif c.kind == "score":
                self._run_score(c.payload)
            elif c.kind == "publish":
                self._run_publish(c.payload)
            elif c.kind == "swap":
                self._run_swap(c.payload)
            elif c.kind == "fetch":
                self._run_fetch()
            elif c.kind == "shutdown":
                self.tp.send_control(msg.Control(self.name, CONDUCTOR,
                                                 kind="bye"))
                return
            else:
                raise RuntimeError(f"{self.name}: unknown control "
                                   f"{c.kind!r}")

    # ------------------------------------------------------------------
    # resumable sessions: party-local TrainState slice
    # ------------------------------------------------------------------

    def _capture_state(self, it: int) -> session_lib.TrainState:
        """This party's slice of the step-state machine (see
        runtime/session.py): own weights + own stream positions + own
        meter views.  Never includes another party's weights, any share,
        or any private key material."""
        tp = self.tp
        return session_lib.TrainState(
            it=int(it),
            weights={self.name: np.array(self.actor.W, np.float64)},
            losses=[float(v) for v in getattr(self.actor, "losses", [])],
            stop=bool(self.actor.stop),
            order=np.asarray(self.order, np.int64),
            cursor=int(self.cursor),
            batch_rng=seeds_lib.generator_state(self.batch_rng),
            jkey=np.asarray(jax.random.key_data(self.jkey)),
            protocol_rng=self.rng.state(),
            select_rng=None,               # CP selection is conductor-owned
            dealer=self.dealer.state(),
            noise_pool_fill=0,             # no prefetch pool on this path
            meter_sends=session_lib.LedgerView(tp.meter.sends),
            rounds=int(tp.rounds),
            runtime_s=0.0,
            measured_sends=session_lib.LedgerView(tp.measured.sends),
            overhead_bytes=int(tp.overhead_bytes),
            frames_sent=int(tp.frames_sent))

    def _restore_state(self, st: session_lib.TrainState) -> None:
        """In-place restore: the HE backend's rng handle aliases
        `self.rng`, so the mask/noise stream position propagates."""
        self.actor.W = np.array(st.weights[self.name], np.float64)
        self.actor.stop = bool(st.stop)
        if self.name == "C":
            self.actor.losses = [float(v) for v in st.losses]
        seeds_lib.restore_generator(self.batch_rng, st.batch_rng)
        self.order = np.asarray(st.order, np.int64)
        self.cursor = int(st.cursor)
        self.jkey = jax.random.wrap_key_data(
            jnp.asarray(np.asarray(st.jkey, np.uint32)))
        self.rng.set_state(st.protocol_rng)
        self.dealer.set_state(st.dealer)
        tp = self.tp
        tp.meter = session_lib.rebuild_meter(st.meter_sends)
        tp.measured = session_lib.rebuild_meter(st.measured_sends or [])
        tp.overhead_bytes = int(st.overhead_bytes)
        tp.frames_sent = int(st.frames_sent)
        tp.rounds = int(st.rounds)

    def _save_checkpoint(self, step: int) -> None:
        tree, extra = self._capture_state(step).to_checkpoint()
        self.ckpt.save(step, tree, extra)

    def _run_resume(self, step: int) -> None:
        """Roll back to the cluster-agreed common step (0 = fresh start)
        and report the audited stream positions; the conductor asserts
        the replicated counters (dealer draws, batch cursor, iteration)
        agree across all k parties before training continues."""
        if step > 0:
            if self.ckpt is None:
                raise RuntimeError(f"{self.name}: resume to step {step} "
                                   "without a checkpoint directory")
            got = self.ckpt.restore(
                session_lib.TrainState.tree_template([self.name]),
                step=step)
            if got is None:
                raise RuntimeError(
                    f"{self.name}: agreed resume step {step} is missing "
                    "or invalid in this party's checkpoint directory")
            _, tree, extra = got
            self._restore_state(
                session_lib.TrainState.from_checkpoint(tree, extra))
        audit = {"party": self.name, "step": int(step),
                 "dealer_drawn": int(self.dealer.drawn),
                 "cursor": int(self.cursor),
                 "rng_drawn": int(self.rng.drawn())}
        if self.name == "C":
            audit.update(losses=[float(v) for v in self.actor.losses],
                         stop=bool(self.actor.stop))
        self.tp.send_control(msg.Control(self.name, CONDUCTOR,
                                         kind="resume_ok", payload=audit))

    # ------------------------------------------------------------------
    # one Algorithm-1 iteration
    # ------------------------------------------------------------------

    def _leg_opener(self, peer: str):
        """Network opener for `mpc.pairwise.PairLeg`: ship (d,e) halves
        as one stacked `beaver_open` frame, then pump until the peer's
        matching frame arrives (TCP keeps per-connection order, and the
        legs are in program lockstep, so the next open from `peer` is
        THE matching one)."""
        def opener(d_self: R64, e_self: R64):
            import jax.numpy as jnp
            both = R64(jnp.stack([d_self.hi, e_self.hi]),
                       jnp.stack([d_self.lo, e_self.lo]))
            n = int(np.prod(d_self.lo.shape)) if d_self.lo.shape else 1
            self.tp.post(msg.BeaverOpen(self.name, peer, both,
                                        n_elems=2 * n))
            while not self._opens[peer]:
                self._pump_one()
            m = self._opens[peer].popleft()
            d_peer = R64(m.payload.hi[0], m.payload.lo[0])
            e_peer = R64(m.payload.hi[1], m.payload.lo[1])
            return (ring.add(d_self, d_peer), ring.add(e_self, e_peer))
        return opener

    def _leg_triples(self, cp_index: int):
        def triples(shape):
            self._dealer_draws += 1
            return self.dealer.elementwise(shape)[cp_index]
        return triples

    def _run_iteration(self, it: int, cps: tuple[str, str]) -> None:
        cfg, tp, party, names = self.cfg, self.tp, self.actor, self.names
        k = len(names)
        model = self.model
        # batch schedule — replicated from VFLScheduler.run
        if self.cursor + cfg.batch_size > self.n_total:
            self.order = self.batch_rng.permutation(self.n_total)
            self.cursor = 0
        idx = self.order[self.cursor:self.cursor + cfg.batch_size]
        self.cursor += cfg.batch_size
        nb = len(idx)
        self.jkey, *subkeys = jax.random.split(self.jkey, k * 2 + 3)
        party.begin_iteration(idx, cps, nb, self.mask_bound)
        self._flags_seen = 0
        self._unmask_served = 0
        self._dealer_draws = 0
        is_cp = self.name in cps
        self._p1_open = is_cp
        if is_cp:
            while self._pending_p1:        # shares that beat the iter frame
                self._dispatch(self._pending_p1.popleft())
        elif self._pending_p1:
            raise RuntimeError(f"{self.name}: Protocol-1 share addressed "
                               "to a non-CP")

        # -- Protocol 1: post this party's shares ------------------------
        tp.post_all(party.share_z(subkeys[self.index]))
        if self.name == "C":
            tp.post_all(party.share_y(subkeys[k]))
        if model.needs_exp:
            tp.post_all(party.share_ez(subkeys[k + 1 + self.index],
                                       model.exp_sign))

        noncps = [n for n in names if n not in cps]
        expected_muls = glm_lib.joint_muls_per_iteration(cfg.glm, k)
        if is_cp:
            cpi = cps.index(self.name)
            peer = cps[1 - cpi]
            expect_p1 = k + 1 + (k if model.needs_exp else 0)
            while party.cp.n_p1 < expect_p1:
                self._pump_one()
            self._p1_open = False

            leg = pairwise.PairLeg(cpi, self._leg_triples(cpi),
                                   self._leg_opener(peer))
            ez = None
            if model.needs_exp:
                ez = glm_lib.ez_chain_leg(leg, party.cp.ez_ordered(names),
                                          cfg.f)
            ctx = glm_lib.LegCtx(z=party.cp.z_acc, y=party.cp.y_share,
                                 ez=ez, f=cfg.f)
            # -- Protocol 2 + 3 ------------------------------------------
            party.cp.d_self = model.gradient_leg(leg, ctx)
            tp.post(party.announce_enc_d())
            tp.post_all(party.broadcast_enc_d(noncps))
            # -- Protocol 4 ----------------------------------------------
            # (dealer order matches the local scheduler: chain + gradient
            # muls, then loss muls; Protocol 3 draws nothing)
            party.cp.l_self = model.loss_leg(leg, ctx)
            if cpi == 1:
                tp.post(msg.LossShare(self.name, cps[0], party.cp.l_self,
                                      n_elems=1))
            if self._dealer_draws != expected_muls:
                raise RuntimeError(
                    f"{self.name}: drew {self._dealer_draws} Beaver "
                    f"triples, stream model says {expected_muls} — dealer "
                    "replicas would desynchronize")
        else:
            self.dealer.skip(expected_muls)

        # -- completion: weights updated; C reveals loss + flags.  A CP
        # additionally drains all k-1 decrypt obligations (one MaskedGrad
        # per other party) BEFORE acking: the durable checkpoint below
        # snapshots the send ledger, and an UnmaskedShare reply serviced
        # after the snapshot would vanish from the meters if this step
        # ever becomes a resume point.
        owed = (k - 1) if is_cp else 0
        if self.name == "C":
            while party._pending_unmask or len(party.losses) < it + 1 \
                    or self._unmask_served < owed:
                self._pump_one()
            tp.post_all(party.emit_flags([n for n in names if n != "C"]))
        else:
            while party._pending_unmask or not self._flags_seen \
                    or self._unmask_served < owed:
                self._pump_one()
        # durable checkpoint BEFORE the ack: once the conductor's barrier
        # sees every party's iter_done for a cadence step, every party
        # has the step on disk (a crash mid-save leaves a torn file the
        # loader skips, so the previous cadence step wins the handshake)
        step = it + 1
        if self.ckpt is not None and self.cfg.checkpoint_every \
                and step % self.cfg.checkpoint_every == 0:
            self._save_checkpoint(step)
        done = {"it": it}
        if self.name == "C":
            done.update(loss=party.losses[-1], stop=bool(party.stop))
        tp.send_control(msg.Control(self.name, CONDUCTOR, kind="iter_done",
                                    payload=done))

    # ------------------------------------------------------------------
    # serving + result collection
    # ------------------------------------------------------------------

    def _run_score(self, payload: dict) -> None:
        """Serving path over the same wire: each party ships its local
        score share X_p W_p to C as an `infer.wx_share` frame; C sums
        in roster order and applies the inverse link.

        With a `version` in the payload the share is computed against
        that PUBLISHED version's pinned weights; a party whose serving
        cache disagrees (version or key fingerprint) refuses with
        `StaleCacheError` — a deterministic refusal the conductor never
        retries — instead of silently scoring the wrong model."""
        rows = np.asarray(payload["rows"], np.float64)
        version = payload.get("version")
        if self.name != "C":
            self.tp.post(self.actor.wx_share_msg(rows, dst="C",
                                                 version=version))
            return
        self._scoring = True
        self.actor.begin_inference(rows.shape[0],
                                   [n for n in self.names if n != "C"])
        while self._pending_wx:            # shares that beat the score frame
            self._dispatch(self._pending_wx.popleft())
        while not self.actor.inference_ready:
            self._pump_one()
        preds = self.actor.finish_inference(rows, version=version)
        self._scoring = False
        self.tp.send_control(msg.Control(
            self.name, CONDUCTOR, kind="score_result",
            payload={"rid": payload.get("rid"), "preds": preds.tolist(),
                     "version": version}))

    def _run_publish(self, payload: dict) -> None:
        """Pin the actor's CURRENT weights as served model `version` and
        build the per-version serving cache (windowed digits + encrypted
        constant — repro/serve/cache.py)."""
        v = int(payload["version"])
        self.actor.publish_version(v)
        self.tp.send_control(msg.Control(
            self.name, CONDUCTOR, kind="publish_ok",
            payload={"party": self.name, "version": v,
                     "key_fp": self.actor.serving_cache.key_fp}))

    def _run_swap(self, payload: dict) -> None:
        """Hot-model-swap barrier leg: load this party's OWN TrainState
        slice from the agreed checkpoint step and republish it as the
        new version.  The conductor's engine only issues `swap` with no
        batch in flight, and every subsequent `score` frame carries the
        new version, so no batch is ever scored by mixed versions (a
        straggler party would refuse via the version check above)."""
        step, v = int(payload["step"]), int(payload["version"])
        if self.ckpt is None:
            raise RuntimeError(f"{self.name}: hot swap to step {step} "
                               "without a checkpoint directory")
        got = self.ckpt.restore(
            session_lib.TrainState.tree_template([self.name]), step=step)
        if got is None:
            raise RuntimeError(
                f"{self.name}: swap step {step} is missing or invalid "
                "in this party's checkpoint directory")
        _, tree, extra = got
        st = session_lib.TrainState.from_checkpoint(tree, extra)
        self.actor.set_weights(st.weights[self.name], version=v)
        self.tp.send_control(msg.Control(
            self.name, CONDUCTOR, kind="swap_ok",
            payload={"party": self.name, "version": v, "step": step,
                     "key_fp": self.actor.serving_cache.key_fp}))

    def _run_fetch(self) -> None:
        dump = {
            "party": self.name,
            "weights": np.asarray(self.actor.W, np.float64).tolist(),
            "sends": [[s.src, s.dst, s.tag, s.nbytes]
                      for s in self.tp.meter.sends],
            "measured": [[s.src, s.dst, s.tag, s.nbytes]
                         for s in self.tp.measured.sends],
            "overhead_bytes": self.tp.overhead_bytes,
            "frames_sent": self.tp.frames_sent,
        }
        stats = getattr(self.tp, "chaos_stats", None)
        if stats is not None:
            dump["chaos"] = stats.to_dict()
        if self.name == "C":
            dump["losses"] = [float(v) for v in self.actor.losses]
        self.tp.send_control(msg.Control(self.name, CONDUCTOR,
                                         kind="result", payload=dump))


def run_party_server(name: str, X, y, ready_queue,
                     host: str = "127.0.0.1",
                     checkpoint_dir: str | None = None,
                     wire: dict | None = None) -> None:
    """Spawn entry point (multiprocessing 'spawn' target)."""
    PartyServer(name, X, y=y, host=host,
                checkpoint_dir=checkpoint_dir, wire=wire).run(ready_queue)
