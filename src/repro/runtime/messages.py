"""Typed message envelopes for the EFMVFL party runtime.

Every cross-party value in Algorithm 1 travels as one of these envelopes.
A `Message` knows its own wire size (`wire_bytes()`), so communication
accounting is a property of the *transport* that carries it — the
protocol math never touches a CommMeter.  Sizes use the wire format a
real deployment serializes: 8-byte ring elements, canonical
2·key_bits-bit Paillier ciphertexts, 1-byte flags.

Message type ↔ paper mapping (also surfaced in README.md):

  P1.z_share        Protocol 1 / Alg. 1 line 7   share of z_p = X_p W_p
  P1.y_share        Protocol 1 / Alg. 1 line 8   share of the label Y
  P1.ez_share       Protocol 1 (Poisson/Gamma)   share of e^{±z_p}
  beaver_open       Beaver mult (Protocol 2/4)   masked openings d, e
  P3.enc_d          Protocol 3 line 1            [[⟨d⟩]] CP ↔ CP exchange
  P3.enc_d_bcast    Alg. 1 line 17               CP → non-CP broadcast
  P3.masked_grad    Protocol 3 lines 5–6         masked encrypted gradient
  P3.unmasked_share Protocol 3 line 7            decrypted+offset-corrected
  P4.loss_share     Protocol 4                   loss share → CP0 → C
  infer.wx_share    serving path                 local score share X_p W_p
  flag              Alg. 1 line 27               C's stop-flag broadcast
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar

import numpy as np

from repro.core.comm import FLAG_BYTES, RING_BYTES, ciphertext_wire_bytes

TAG_PROTOCOL: dict[str, str] = {
    "P1.z_share": "Protocol 1 / Alg.1 line 7 — share of z_p = X_p W_p",
    "P1.y_share": "Protocol 1 / Alg.1 line 8 — share of the label Y",
    "P1.ez_share": "Protocol 1 (Poisson/Gamma) — share of e^{±z_p}",
    "beaver_open": "Beaver multiplication — masked openings d = x−a, e = y−b",
    "P3.enc_d": "Protocol 3 line 1 — [[⟨d⟩]] exchanged between the CPs",
    "P3.enc_d_bcast": "Alg.1 line 17 — CP broadcast of [[⟨d⟩]] to non-CPs",
    "P3.masked_grad": "Protocol 3 lines 5–6 — masked encrypted gradient",
    "P3.unmasked_share": "Protocol 3 line 7 — decrypted, offset-corrected share",
    "P4.loss_share": "Protocol 4 — loss share to CP0, forwarded to C",
    "infer.wx_share": "Serving — local score share X_p W_p sent to C",
    "flag": "Alg.1 line 27 — C's stop-flag broadcast",
}


def ciphertext_bytes(n_cts: int, key_bits: int) -> int:
    """Canonical Paillier ciphertext batch: elements of Z_{n²}, each
    serialized as ⌈2·key_bits / 8⌉ bytes.  (The ceiling matters: for key
    sizes not divisible by 4 the old floor division under-counted what
    the codec actually has to put on the wire — runtime/codec.py asserts
    the two agree for every encoded message.)  Delegates to
    `core.comm.ciphertext_wire_bytes`, the shared single formula."""
    return n_cts * ciphertext_wire_bytes(key_bits)


@dataclasses.dataclass
class Message:
    """Base envelope.

    Fields:
      src/dst: party names ("C", "B1", …) — the transport's routing keys.
      payload: the value carried (subclass-specific; None for synthetic
        traffic that only needs byte accounting).
      tag: class-level wire tag, the key of all per-tag byte accounting
        (`CommMeter.by_tag`) and of `TAG_PROTOCOL`.

    `wire_bytes()` returns the serialized size in bytes a real
    deployment would put on the wire for this envelope's payload
    (headers excluded — the paper's comm columns count payloads).
    """
    src: str
    dst: str
    payload: Any = None
    tag: ClassVar[str] = "?"

    def wire_bytes(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class RingMessage(Message):
    """Payload is an R64 ring tensor (8 bytes per element on the wire),
    or None with `n_elems` given — traffic synthesis for dry-runs that
    never materialize values."""
    n_elems: int | None = None

    def wire_bytes(self) -> int:
        n = self.n_elems
        if n is None:
            n = int(np.prod(self.payload.lo.shape))
        return n * RING_BYTES


@dataclasses.dataclass
class CipherMessage(Message):
    """Payload is a batch of `n_cts` Paillier ciphertexts under
    `key_owner`'s public key — on the wire, canonical Z_{n²} elements of
    2·`key_bits` bits each (in memory, Montgomery-domain uint32 limb
    arrays; the mock backend carries ring values but meters identical
    bytes)."""
    n_cts: int = 0
    key_bits: int = 0
    key_owner: str = ""

    def wire_bytes(self) -> int:
        return ciphertext_bytes(self.n_cts, self.key_bits)


class ZShare(RingMessage):
    """Protocol 1 / Alg. 1 line 7 — share of z_p = X_p W_p (R64, f
    fractional bits), party → one CP."""
    tag = "P1.z_share"


class YShare(RingMessage):
    """Protocol 1 / Alg. 1 line 8 — share of the label Y (R64, f
    fractional bits), C → one CP."""
    tag = "P1.y_share"


class EzShare(RingMessage):
    """Protocol 1, Poisson/Gamma — share of e^{±z_p} (R64, f fractional
    bits), party → one CP."""
    tag = "P1.ez_share"


class BeaverOpen(RingMessage):
    """Beaver multiplication (Protocols 2/4) — the masked openings
    d = x−a, e = y−b one CP sends the other (2 R64 elements per product
    element; accounted by `scheduler.TransportDealer`)."""
    tag = "beaver_open"


class UnmaskedShare(RingMessage):
    """Protocol 3 line 7 — the decrypted, offset-corrected gradient term
    (R64, fx+f fractional bits), key owner → feature owner."""
    tag = "P3.unmasked_share"


class LossShare(RingMessage):
    """Protocol 4 — scalar loss share (R64, f fractional bits),
    CP₁ → CP₀, then the reconstructed sum CP₀ → C."""
    tag = "P4.loss_share"


class WxShare(RingMessage):
    """Serving path — local score share X_p W_p (float64, 8 B/row),
    party → C."""
    tag = "infer.wx_share"


class EncD(CipherMessage):
    """Protocol 3 line 1 — [[⟨d⟩]] (nb ciphertexts under the sender's
    own key), CP ↔ CP exchange."""
    tag = "P3.enc_d"

    @staticmethod
    def mesh_payload_spec(n_parties: int, n_cts: int, limbs: int):
        """ShapeDtypeStruct of the pod-major [[⟨d⟩]] payload used when the
        protocol step is lowered onto the production mesh (pod = party):
        one Z_{n²} ciphertext per batch sample, `limbs` 12-bit limbs."""
        import jax
        import jax.numpy as jnp
        return jax.ShapeDtypeStruct((n_parties, n_cts, limbs), jnp.uint32)


class EncDBroadcast(CipherMessage):
    """Alg. 1 line 17 — the same [[⟨d⟩]] ciphertext batch, CP → each
    non-CP (payload shared with the `EncD` exchange; metered per
    recipient, as a real broadcast would be)."""
    tag = "P3.enc_d_bcast"


class MaskedGrad(CipherMessage):
    """Protocol 3 lines 5–6 — the masked encrypted gradient (m_p
    ciphertexts under `key_owner`'s key), feature owner → key owner."""
    tag = "P3.masked_grad"

    @staticmethod
    def mesh_payload_spec(n_parties: int, n_features: int, limbs: int):
        import jax
        import jax.numpy as jnp
        return jax.ShapeDtypeStruct((n_parties, n_features, limbs),
                                    jnp.uint32)


@dataclasses.dataclass
class Flag(Message):
    """C's stop decision, broadcast every iteration (Alg. 1 line 27)."""
    stop: bool = False
    tag: ClassVar[str] = "flag"

    def wire_bytes(self) -> int:
        return FLAG_BYTES


@dataclasses.dataclass
class Control(Message):
    """Conductor-plane envelope for the distributed runtime (handshake,
    iteration barriers, result collection, scoring RPCs, shutdown).

    `kind` selects the action; `payload` is a JSON-able dict.  Control
    frames ride the same socket framing as protocol messages but are
    NOT protocol traffic: they are never routed through the metered
    `Transport.post` path, so per-tag byte accounting stays comparable
    with the single-process transports (the paper's comm columns count
    protocol payloads only).  See docs/transports.md for the kinds.
    """
    kind: str = ""
    tag: ClassVar[str] = "ctrl"

    def wire_bytes(self) -> int:
        import json
        return len(json.dumps(self.payload or {}).encode())


def iteration_traffic(n_parties: int, nb: int, m_per_party: int,
                      key_bits: int, glm: str = "logistic"
                      ) -> tuple[dict[str, int], int]:
    """One training iteration of Algorithm 1 as a synthetic message list
    (fixed CP selection: C and B1).  Returns (bytes by tag, round count).
    Used by launch/secure_dryrun.py for the comm columns of its report —
    the same typed envelopes the live runtime routes."""
    names = ["C"] + [f"B{i}" for i in range(1, n_parties)]
    cps, noncps = (names[0], names[1]), names[2:]
    msgs: list[Message] = []
    rounds = 0

    def share_to_cps(owner, cls):
        for cp in cps:
            if cp != owner:
                msgs.append(cls(owner, cp, n_elems=nb))

    for p in names:                              # Protocol 1
        share_to_cps(p, ZShare)
    share_to_cps("C", YShare)
    rounds += 1
    if glm in ("poisson", "gamma"):
        for p in names:
            share_to_cps(p, EzShare)
        for _ in range(n_parties - 1):           # chained Beaver products
            msgs.append(BeaverOpen(cps[0], cps[1], n_elems=2 * nb))
            msgs.append(BeaverOpen(cps[1], cps[0], n_elems=2 * nb))
            rounds += 1
    # Protocol 3
    msgs.append(EncD(cps[0], cps[1], n_cts=nb, key_bits=key_bits))
    msgs.append(EncD(cps[1], cps[0], n_cts=nb, key_bits=key_bits))
    for p in noncps:
        for cp in cps:
            msgs.append(EncDBroadcast(cp, p, n_cts=nb, key_bits=key_bits))
    for a, b in (cps, cps[::-1]):
        msgs.append(MaskedGrad(a, b, n_cts=m_per_party, key_bits=key_bits))
        msgs.append(UnmaskedShare(b, a, n_elems=m_per_party))
    for p in noncps:
        for cp in cps:
            msgs.append(MaskedGrad(p, cp, n_cts=m_per_party,
                                   key_bits=key_bits))
            msgs.append(UnmaskedShare(cp, p, n_elems=m_per_party))
    rounds += 3                                  # enc_d / masked / unmasked
    # Protocol 4 joint Beaver products (logistic: t and t² in the loss;
    # gamma: one in the gradient operator + one in the loss)
    n_loss_muls = {"logistic": 2, "linear": 1, "poisson": 1, "gamma": 2}[glm]
    for _ in range(n_loss_muls):
        msgs.append(BeaverOpen(cps[0], cps[1], n_elems=2 * nb))
        msgs.append(BeaverOpen(cps[1], cps[0], n_elems=2 * nb))
        rounds += 1
    msgs.append(LossShare(cps[1], cps[0], n_elems=1))
    rounds += 1
    for p in names[1:]:
        msgs.append(Flag("C", p))
    rounds += 1

    by_tag: dict[str, int] = {}
    for m in msgs:
        by_tag[m.tag] = by_tag.get(m.tag, 0) + m.wire_bytes()
    return by_tag, rounds
