"""Chaos link layer: netem-style shaping + seeded fault injection + ARQ.

`FaultyTransport` is a `SocketTransport` whose frames travel through an
adversarial link emulator instead of straight `sendall`.  Every codec
frame is wrapped in a small link envelope and handed to a shaped egress
pipe that can delay, drop, duplicate, reorder, reset, and partition it
according to a *seeded, replayable* fault schedule; a per-link ARQ
layer (sequence numbers, cumulative in-order delivery, acks, budgeted
retransmission with deterministic jittered backoff from
`runtime.policy.RetryPolicy`) restores exactly-once in-order delivery
on top — so Algorithm 1 trains **bit-identically** to the fault-free
run while the wire underneath misbehaves.

Layering (why the meters cannot move):

        actor / PartyServer                 protocol semantics
        ─ post() ───────────────────────    ← analytic + measured meters
        codec frame (runtime.codec)         ← `overhead_bytes` boundary
        ─ _ship() ──────────────────────    ← THE seam this module plugs
        link envelope  CHL1|flags|seq|crc   ← ARQ + compression live here
        fault schedule + shaping heap
        TCP (`_send_frame`)

`post` meters a message exactly once, *before* `_ship` — retransmits,
duplicates, acks, and envelope headers are link-layer artifacts and are
accounted separately in `ChaosStats`, never in the protocol meters.
That is what makes "losses, weights, and per-tag analytic AND measured
bytes bit-identical to the fault-free run" achievable: the protocol
sees an ideal reliable channel; only wall-clock and `ChaosStats`
change.

Link envelope (little-endian, 21 bytes):

    4s  magic     b"CHL1"
    B   flags     RELIABLE | DEFLATED | ACK
    Q   seq       per-link stream counter (RELIABLE: contiguous;
                  ACK: the acked seq; unreliable: hash diversity only)
    I   crc32     of the body as shipped (post-compression)
    I   body_len

Reliability semantics:

* RELIABLE frames (all protocol + control traffic except heartbeats)
  carry a contiguous per-directed-link seq.  The receiver delivers them
  to `inbound` strictly in seq order (a reorder buffer holds early
  arrivals), discards duplicates, and acks **every** arrival — a lost
  ack must not wedge the sender.  The sender keeps the wire bytes until
  acked and retransmits on a deterministic backoff schedule
  (`RetryPolicy.backoff`, floored by the shaped RTT so latency profiles
  don't cause spurious-retransmit storms); `retry_budget` exhausted ⇒
  the link is declared dead and a `__closed__` event surfaces, exactly
  like a real peer loss (the PR-5 supervisor takes over).
* Heartbeats and acks are UNRELIABLE: never retransmitted, never acked.
  A partition therefore cannot exhaust retry budgets on keep-alives,
  and ack loss is recovered by the sender's retransmit → re-ack cycle.
* `reset` emulates a connection RST at the emulated layer: the egress
  pipe for that link is flushed (everything in flight dies), and ARQ
  recovers the reliable stream.  Genuine socket teardown (SIGKILL,
  `detach`) is covered by the existing transport paths.
* `partition` blackholes one *directed* link for `partition_s` seconds
  — everything (data, retransmits, acks, heartbeats) is dropped at
  fire time.  It triggers deterministically at that link's
  `partition_at`-th reliable first-send, on links selected by a seeded
  hash draw (`partition_p`).

Every fault decision is a pure blake2b hash of (profile.seed, directed
link, seq, attempt, channel, salt) — see `FaultSchedule` — so a run's
fault trace is a function of its seed and traffic, never of wall-clock
or `random` global state: schedules replay exactly.

Compression (`wire_compression="zlib"`): the whole codec frame may be
deflated below the metering boundary when a deterministic 4 KiB probe
says it will shrink (`distributed.compression.worth_deflating`) — dense
Paillier/ring payloads skip it, zero-padded mock ciphertexts and JSON
controls take it.  Lossless only; lossy schemes are refused at config
time (`distributed.compression.validate_wire_scheme`).  Savings are
reported in `ChaosStats`, not subtracted from the meters — the meters
state what the *protocol* moved, the stats state what the wire carried.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import struct
import threading
import time
import zlib
from typing import Optional

from repro.distributed import compression as comp_lib
from repro.runtime.policy import RetryPolicy, _unit_hash
from repro.runtime.transport import (MAX_FRAME_BYTES, PeerClosed,
                                     SocketTransport, _recv_exact)

#: link envelope: magic, flags, seq, body crc32, body length
ENVELOPE = struct.Struct("<4sBQII")
MAGIC = b"CHL1"
F_RELIABLE = 1
F_DEFLATED = 2
F_ACK = 4

#: fault-decision salts — one per decision kind, so a single (link, seq,
#: attempt) position yields independent draws for each fault
_S_DROP, _S_DUP, _S_REORDER, _S_RESET, _S_PART, _S_JITTER = 1, 2, 3, 4, 5, 6

#: fault channels — reliable data, unreliable (hb), acks — decorrelate
#: decisions for frames that share a seq number across streams
CH_DATA, CH_UNREL, CH_ACK = 0, 1, 2


class LinkError(ConnectionError):
    """The chaos link layer rejected a frame (bad magic, crc mismatch,
    oversized body) or declared a link dead (retry budget exhausted)."""


# ---------------------------------------------------------------------------
# profile + schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosProfile:
    """Declarative link behavior: WAN shaping + fault probabilities.

    All fault decisions derive from `seed` (see `FaultSchedule`);
    probabilities are per frame-send attempt on a directed link.
    `bandwidth_bps` of 0 means unconstrained.
    """

    seed: int = 0
    latency_s: float = 0.0          # one-way propagation delay
    jitter_s: float = 0.0           # max extra delay (uniform hash draw)
    bandwidth_bps: float = 0.0      # serialization rate; 0 = infinite
    drop_p: float = 0.0
    dup_p: float = 0.0
    reorder_p: float = 0.0
    reorder_extra_s: float = 0.005  # how far a reordered frame is held back
    reset_p: float = 0.0            # emulated RST: flushes the egress pipe
    partition_p: float = 0.0        # per-link chance of one partition
    partition_at: int = 4           # triggers at nth reliable first-send
    partition_s: float = 0.0        # outage duration (must stay well
                                    # under RetryPolicy.max_outage_s())

    def shaped(self) -> bool:
        return (self.latency_s > 0 or self.jitter_s > 0
                or self.bandwidth_bps > 0)

    def faulty(self) -> bool:
        return any(p > 0 for p in (self.drop_p, self.dup_p, self.reorder_p,
                                   self.reset_p, self.partition_p))

    def active(self) -> bool:
        return self.shaped() or self.faulty()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "ChaosProfile":
        return cls() if d is None else cls(**d)

    def replace(self, **kw) -> "ChaosProfile":
        return dataclasses.replace(self, **kw)


#: named profiles — `wan20`/`wan100` are pure shaping (the WAN bench),
#: `lossy`/`chaos` add faults (tests scale the timings down further)
PROFILES: dict[str, ChaosProfile] = {
    "off": ChaosProfile(),
    "lan": ChaosProfile(latency_s=0.0002, jitter_s=0.0001),
    "wan20": ChaosProfile(latency_s=0.020, jitter_s=0.002),
    "wan100": ChaosProfile(latency_s=0.100, jitter_s=0.010),
    "lossy": ChaosProfile(latency_s=0.002, jitter_s=0.001,
                          drop_p=0.03, dup_p=0.02, reorder_p=0.05),
    "chaos": ChaosProfile(latency_s=0.002, jitter_s=0.001,
                          drop_p=0.05, dup_p=0.03, reorder_p=0.05,
                          reset_p=0.01, partition_p=0.25,
                          partition_at=4, partition_s=0.3),
}


def resolve_profile(spec) -> Optional[ChaosProfile]:
    """None | name | dict | ChaosProfile → ChaosProfile (None stays
    None: 'no chaos layer at all')."""
    if spec is None or isinstance(spec, ChaosProfile):
        return spec
    if isinstance(spec, str):
        try:
            return PROFILES[spec]
        except KeyError:
            raise ValueError(f"unknown chaos profile {spec!r} "
                             f"(have {sorted(PROFILES)})") from None
    if isinstance(spec, dict):
        return ChaosProfile.from_dict(spec)
    raise TypeError(f"cannot resolve chaos profile from {type(spec)}")


def link_seed(seed: int, src: str, dst: str) -> int:
    """Stable per-directed-link seed: both what the fault schedule keys
    its draws on and what `RetryPolicy.backoff` jitters with."""
    h = hashlib.blake2b(f"{src}>{dst}".encode(), digest_size=8,
                        key=struct.pack("<q", seed)).digest()
    return struct.unpack("<Q", h)[0] & (2 ** 63 - 1)


class FaultSchedule:
    """Replayable fault decisions: every method is a pure function of
    (profile.seed-derived link seed, seq, attempt, channel) — no clock,
    no global RNG.  Replaying a run with the same profile and traffic
    replays byte-for-byte the same fault trace."""

    def __init__(self, profile: ChaosProfile):
        self.profile = profile

    def _hit(self, p: float, salt: int, ls: int, seq: int, attempt: int,
             chan: int) -> bool:
        return p > 0 and _unit_hash(ls, seq, attempt,
                                    chan * 8 + salt) < p

    def drop(self, ls: int, seq: int, attempt: int, chan: int) -> bool:
        return self._hit(self.profile.drop_p, _S_DROP, ls, seq, attempt,
                         chan)

    def dup(self, ls: int, seq: int) -> bool:
        """Duplicates apply only to a reliable frame's first send."""
        return self._hit(self.profile.dup_p, _S_DUP, ls, seq, 0, CH_DATA)

    def reorder(self, ls: int, seq: int, attempt: int, chan: int) -> bool:
        return self._hit(self.profile.reorder_p, _S_REORDER, ls, seq,
                         attempt, chan)

    def reset(self, ls: int, seq: int, attempt: int) -> bool:
        return self._hit(self.profile.reset_p, _S_RESET, ls, seq, attempt,
                         CH_DATA)

    def jitter(self, ls: int, seq: int, attempt: int, chan: int) -> float:
        if self.profile.jitter_s <= 0:
            return 0.0
        return self.profile.jitter_s * _unit_hash(ls, seq, attempt,
                                                  chan * 8 + _S_JITTER)

    def partition_point(self, ls: int) -> Optional[int]:
        """The reliable first-send index at which this link partitions,
        or None — at most one partition per link incarnation."""
        p = self.profile
        if p.partition_p <= 0 or p.partition_s <= 0:
            return None
        if _unit_hash(ls, 0, 0, _S_PART) < p.partition_p:
            return max(1, int(p.partition_at))
        return None


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

class ChaosStats:
    """Link-layer accounting, kept strictly apart from the protocol
    meters: injected faults, ARQ recovery work, and compression savings.
    `to_dict` feeds the fetch/report paths; `merge` aggregates the
    per-party dicts at the conductor."""

    INT_FIELDS = ("drops", "dups", "reorders", "resets", "partitions",
                  "partition_drops", "retransmits", "retransmit_bytes",
                  "acks_sent", "rx_dups", "rx_buffered", "deflated_frames",
                  "deflate_saved_bytes", "envelope_bytes", "budget_deaths")

    def __init__(self):
        self._lock = threading.Lock()
        for f in self.INT_FIELDS:
            setattr(self, f, 0)
        self.backoff_total_s = 0.0

    def bump(self, field: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def add_backoff(self, seconds: float) -> None:
        with self._lock:
            self.backoff_total_s += seconds

    def injected(self) -> int:
        return (self.drops + self.dups + self.reorders + self.resets
                + self.partitions)

    def to_dict(self) -> dict:
        with self._lock:
            d = {f: int(getattr(self, f)) for f in self.INT_FIELDS}
            d["backoff_total_s"] = float(self.backoff_total_s)
        return d

    @staticmethod
    def merge(dicts) -> dict:
        out: dict = {}
        for d in dicts:
            for k, v in (d or {}).items():
                out[k] = out.get(k, 0) + v
        return out


# ---------------------------------------------------------------------------
# per-link state
# ---------------------------------------------------------------------------

class _Link:
    """Sender-side state of one directed link (this node → peer)."""

    def __init__(self, seed: int, schedule: FaultSchedule):
        self.seed = seed
        self.seq_r = 0                      # next reliable seq (contiguous)
        self.seq_u = 0                      # unreliable seq (hash diversity)
        self.pending: dict[int, bytes] = {}  # unacked reliable wire bytes
        self.first_sends = 0
        self.partition_trigger = schedule.partition_point(seed)
        self.partition_until = 0.0
        self.tx_epoch = 0                   # bumped by emulated RSTs
        self.busy_until = 0.0               # bandwidth serialization clock
        self.dead = False


class _Rx:
    """Receiver-side state of one directed link (peer → this node)."""

    def __init__(self):
        self.next = 0                       # next reliable seq to deliver
        self.buf: dict[int, object] = {}    # early arrivals (decoded)


def read_envelope(sock) -> tuple[int, int, bytes]:
    """Read one link envelope off a blocking socket → (flags, seq, body).
    Truncated, oversized, or corrupt envelopes raise `LinkError` —
    integrity failures are link faults, never silently delivered."""
    hdr = _recv_exact(sock, ENVELOPE.size)
    magic, flags, seq, crc, ln = ENVELOPE.unpack(hdr)
    if magic != MAGIC:
        raise LinkError(f"bad link magic {magic!r}")
    if ln > MAX_FRAME_BYTES:
        raise LinkError(f"link body too large ({ln} bytes)")
    body = _recv_exact(sock, ln)
    if zlib.crc32(body) != crc:
        raise LinkError(f"link crc mismatch on seq {seq}")
    return flags, seq, body


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------

class FaultyTransport(SocketTransport):
    """`SocketTransport` + chaos link layer.  Construct it on EVERY
    endpoint of a run (conductor and all parties) — the envelope framing
    is not interoperable with a plain `SocketTransport` peer.

    Args:
      profile: `ChaosProfile` (or None → null profile: pure reliable
        link layer, useful for compression without faults).
      policy: `RetryPolicy` (retransmit schedule + budgets).
      compression: "none" | "zlib" (validated; lossy schemes refused).
    """

    def __init__(self, name: str, codec, profile: ChaosProfile | None = None,
                 policy: RetryPolicy | None = None,
                 compression: str = "none", meter=None):
        super().__init__(name, codec, meter)
        self.profile = profile or ChaosProfile()
        self.policy = policy or RetryPolicy.from_env()
        comp_lib.validate_wire_scheme(compression)
        self.compression = compression
        self.schedule = FaultSchedule(self.profile)
        self.chaos_stats = ChaosStats()
        # the first retransmit must wait out at least one shaped RTT or
        # every frame on a wan profile retransmits spuriously
        self._rtt_pad = 2.0 * (self.profile.latency_s
                               + self.profile.jitter_s
                               + self.profile.reorder_extra_s)
        self._links: dict[str, _Link] = {}
        self._rx: dict[str, _Rx] = {}
        self._lk = threading.Lock()
        self._heap: list = []
        self._hn = 0
        self._cv = threading.Condition()
        self._pump_thread = threading.Thread(
            target=self._pump_loop, daemon=True, name=f"chaos-pump-{name}")
        self._pump_thread.start()

    # -- link state ---------------------------------------------------------
    def _link(self, peer: str) -> _Link:
        with self._lk:
            link = self._links.get(peer)
            if link is None:
                link = _Link(link_seed(self.profile.seed, self.name, peer),
                             self.schedule)
                self._links[peer] = link
            return link

    def _rx_state(self, peer: str) -> _Rx:
        with self._lk:
            rx = self._rx.get(peer)
            if rx is None:
                rx = self._rx[peer] = _Rx()
            return rx

    def attach(self, peer: str, sock) -> None:
        if peer in self._conns:
            # replacement connection ⇒ fresh link incarnation: seq
            # numbering and ordering state restart with the new stream
            with self._lk:
                self._links.pop(peer, None)
                self._rx.pop(peer, None)
        super().attach(peer, sock)

    def detach(self, peer: str) -> None:
        with self._lk:
            self._links.pop(peer, None)
            self._rx.pop(peer, None)
        super().detach(peer)

    # -- egress: envelope → faults → shaping → wire -------------------------
    def _ship(self, dst: str, frame: bytes, reliable: bool = True) -> None:
        if dst not in self._conns:
            raise PeerClosed(f"{self.name}: no connection to {dst!r}")
        st = self.chaos_stats
        body, flags = frame, (F_RELIABLE if reliable else 0)
        if self.compression == "zlib" and comp_lib.worth_deflating(frame):
            deflated = comp_lib.deflate_frame(frame)
            if len(deflated) < len(frame):
                body, flags = deflated, flags | F_DEFLATED
                st.bump("deflated_frames")
                st.bump("deflate_saved_bytes", len(frame) - len(deflated))
        link = self._link(dst)
        now = time.monotonic()
        with self._lk:
            if reliable:
                seq, chan = link.seq_r, CH_DATA
                link.seq_r += 1
            else:
                seq, chan = link.seq_u, CH_UNREL
                link.seq_u += 1
            wire = ENVELOPE.pack(MAGIC, flags, seq, zlib.crc32(body),
                                 len(body)) + body
            if reliable:
                link.pending[seq] = wire
                link.first_sends += 1
                if link.first_sends == link.partition_trigger:
                    link.partition_until = now + self.profile.partition_s
                    st.bump("partitions")
        st.bump("envelope_bytes", ENVELOPE.size)
        if reliable:
            delay = self._rtt_pad + self.policy.backoff(link.seed, seq, 1)
            self._schedule(now + delay, "rto", dst, (seq, 1))
        self._egress(link, dst, wire, seq, 0, chan, now)

    def _egress(self, link: _Link, dst: str, wire: bytes, seq: int,
                attempt: int, chan: int, now: float) -> None:
        """Apply the fault schedule to one send attempt and enqueue the
        surviving copies into the shaped egress heap."""
        sch, st, p = self.schedule, self.chaos_stats, self.profile
        if sch.drop(link.seed, seq, attempt, chan):
            st.bump("drops")            # reliable frames recover via RTO
            return
        delay = p.latency_s + sch.jitter(link.seed, seq, attempt, chan)
        with self._lk:
            if p.bandwidth_bps > 0:
                tx = len(wire) * 8.0 / p.bandwidth_bps
                start = max(now + delay, link.busy_until)
                link.busy_until = start + tx
                delay = (start + tx) - now
            epoch = link.tx_epoch
        if chan == CH_DATA and attempt == 0 and sch.dup(link.seed, seq):
            st.bump("dups")
            self._schedule(now + delay, "tx", dst, (wire, seq, attempt,
                                                    epoch))
        if sch.reorder(link.seed, seq, attempt, chan):
            st.bump("reorders")
            delay += p.reorder_extra_s
        self._schedule(now + delay, "tx", dst, (wire, seq, attempt, epoch))

    def _schedule(self, due: float, kind: str, dst: str, payload) -> None:
        with self._cv:
            heapq.heappush(self._heap, (due, self._hn, kind, dst, payload))
            self._hn += 1
            self._cv.notify()

    def _pump_loop(self) -> None:
        while True:
            with self._cv:
                if self._closing:
                    return
                if not self._heap:
                    self._cv.wait()
                    continue
                due = self._heap[0][0]
                now = time.monotonic()
                if due > now:
                    self._cv.wait(due - now)
                    continue
                item = heapq.heappop(self._heap)
            try:
                self._fire(item)
            except Exception:            # noqa: BLE001 — the pump must
                pass                     # survive individual link errors

    def _fire(self, item) -> None:
        _, _, kind, dst, payload = item
        link = self._links.get(dst)
        if link is None or link.dead:
            return
        st = self.chaos_stats
        now = time.monotonic()
        if kind == "tx":
            wire, seq, attempt, epoch = payload
            with self._lk:
                if epoch < link.tx_epoch:
                    return               # flushed by an emulated RST
                if now < link.partition_until:
                    st.bump("partition_drops")
                    return
            if self.schedule.reset(link.seed, seq, attempt):
                with self._lk:
                    link.tx_epoch += 1   # RST: everything in the pipe dies
                st.bump("resets")
                return
            try:
                self._send_frame(dst, wire)
            except Exception as e:       # noqa: BLE001
                self._link_down(dst, link, e)
        elif kind == "rto":
            seq, attempt = payload
            with self._lk:
                wire = link.pending.get(seq)
            if wire is None:
                return                   # acked — timer is moot
            if attempt > self.policy.retry_budget:
                st.bump("budget_deaths")
                self._link_down(dst, link, LinkError(
                    f"retry budget exhausted on seq {seq} after "
                    f"{attempt - 1} retransmissions"))
                return
            st.bump("retransmits")
            st.bump("retransmit_bytes", len(wire))
            self._egress(link, dst, wire, seq, attempt, CH_DATA, now)
            delay = self._rtt_pad + self.policy.backoff(link.seed, seq,
                                                        attempt + 1)
            st.add_backoff(delay)
            self._schedule(now + delay, "rto", dst, (seq, attempt + 1))

    def _link_down(self, dst: str, link: _Link, err: Exception) -> None:
        from repro.runtime import messages as msg_lib
        if self._closing or link.dead:
            return
        link.dead = True
        if dst in self._conns:
            self.inbound.put(msg_lib.Control(
                dst, self.name, kind="__closed__",
                payload={"error": f"{type(err).__name__}: {err}"}))

    # -- ingress: envelope → ack + dedup + reorder → codec ------------------
    def _reader(self, peer: str, sock) -> None:
        from repro.runtime import messages as msg_lib
        try:
            while True:
                for m in self._read_link(peer, sock):
                    self.inbound.put(m)
        except Exception as e:           # noqa: BLE001 — surfaced below
            if not self._closing and self._conns.get(peer) is sock:
                self.inbound.put(msg_lib.Control(
                    peer, self.name, kind="__closed__",
                    payload={"error": f"{type(e).__name__}: {e}"}))

    def _read_link(self, peer: str, sock) -> list:
        flags, seq, body = read_envelope(sock)
        if flags & F_ACK:
            link = self._links.get(peer)
            if link is not None:
                with self._lk:
                    link.pending.pop(seq, None)
            return []
        frame = comp_lib.inflate_frame(body) if flags & F_DEFLATED else body
        m = self.codec.decode(frame)
        if not flags & F_RELIABLE:
            return [m]                   # hb — unordered, best-effort
        self._send_ack(peer, seq)
        return self._rx_ingest(peer, seq, m)

    def _send_ack(self, peer: str, seq: int) -> None:
        """Ack one reliable arrival (duplicates re-acked).  Acks travel
        the shaped, faulted egress like everything else, but are
        unreliable: a lost ack is recovered by the peer's retransmit."""
        self.chaos_stats.bump("acks_sent")
        self.chaos_stats.bump("envelope_bytes", ENVELOPE.size)
        ack = ENVELOPE.pack(MAGIC, F_ACK, seq, 0, 0)
        self._egress(self._link(peer), peer, ack, seq, 0, CH_ACK,
                     time.monotonic())

    def _rx_ingest(self, peer: str, seq: int, m) -> list:
        """Exactly-once, in-order delivery per link: duplicates are
        discarded, early arrivals buffered until the gap fills."""
        rx = self._rx_state(peer)
        st = self.chaos_stats
        with self._lk:
            if seq < rx.next or seq in rx.buf:
                st.bump("rx_dups")
                return []
            rx.buf[seq] = m
            if seq != rx.next:
                st.bump("rx_buffered")
            out = []
            while rx.next in rx.buf:
                out.append(rx.buf.pop(rx.next))
                rx.next += 1
        return out

    def recv_bootstrap(self, conn):
        """Read one message from a not-yet-attached connection (the
        handshake/hello bootstrap reads in `netparty`).  The rx state it
        creates is keyed by the sender's name, so the reader thread
        continues the same ordering stream after `attach`.  Acks are
        written straight to the socket (the shaped egress has no
        registered peer yet); the sender's schedule may still drop or
        delay its side, which the ARQ recovers."""
        while True:
            flags, seq, body = read_envelope(conn)
            if flags & F_ACK:
                continue   # stale ack of a previous link incarnation
            frame = (comp_lib.inflate_frame(body) if flags & F_DEFLATED
                     else body)
            m = self.codec.decode(frame)
            if not flags & F_RELIABLE:
                continue   # a heartbeat cannot bootstrap a link
            conn.sendall(ENVELOPE.pack(MAGIC, F_ACK, seq, 0, 0))
            self.chaos_stats.bump("acks_sent")
            self.chaos_stats.bump("envelope_bytes", ENVELOPE.size)
            msgs = self._rx_ingest(m.src, seq, m)
            if not msgs:
                continue   # out-of-order arrival — keep reading
            for extra in msgs[1:]:
                self.inbound.put(extra)
            return msgs[0]

    # -- lifecycle ----------------------------------------------------------
    def flush(self, timeout: float = 5.0) -> bool:
        """Best-effort drain: wait until the egress heap holds no tx
        items and every reliable frame is acked.  Call before `close` so
        teardown frames (`bye`, `error`) actually leave the host."""
        deadline = time.monotonic() + timeout
        while True:
            with self._cv:
                tx_busy = any(it[2] == "tx" for it in self._heap)
            with self._lk:
                unacked = any(l.pending for l in self._links.values()
                              if not l.dead)
            if not tx_busy and not unacked:
                return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.01)

    def close(self) -> None:
        super().close()
        with self._cv:
            self._cv.notify_all()
        if self._pump_thread is not threading.current_thread():
            self._pump_thread.join(timeout=5.0)
