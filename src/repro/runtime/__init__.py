"""Party runtime: actors + typed messages + pluggable transports.

The deployment seam for EFMVFL — protocol code talks to a Transport
instead of shared local variables, so the same actors run under the
bit-exact local replay, the concurrent-leg pipelined schedule
(`PipelinedTransport`: per-message pool futures via `pump_async`,
join barrier before Protocol 4), or real OS processes over TCP
(`SocketTransport` + `netparty.PartyServer`, launched by
`launch/cluster.py`).  See docs/architecture.md for the layer diagram,
docs/protocols.md for the paper ↔ code map, and docs/transports.md for
the wire format and distributed deployment.
"""
from repro.runtime import messages
from repro.runtime.chaos import (ChaosProfile, ChaosStats, FaultSchedule,
                                 FaultyTransport)
from repro.runtime.codec import Codec, CodecError
from repro.runtime.party import CPState, DataParty, LabelParty, Party
from repro.runtime.policy import RetryPolicy
from repro.runtime.scheduler import (TransportDealer, VFLScheduler,
                                     mask_bound_bits, validate_key_bits)
from repro.runtime.session import TrainState, config_hash
from repro.runtime.transport import (LocalTransport, LockedRNG,
                                     PipelinedTransport, SocketTransport,
                                     Transport)

__all__ = [
    "messages", "Party", "DataParty", "LabelParty", "CPState",
    "VFLScheduler", "TransportDealer", "mask_bound_bits",
    "validate_key_bits", "Transport", "LocalTransport",
    "PipelinedTransport", "SocketTransport", "LockedRNG",
    "Codec", "CodecError", "TrainState", "config_hash",
    "RetryPolicy", "ChaosProfile", "ChaosStats", "FaultSchedule",
    "FaultyTransport",
]
