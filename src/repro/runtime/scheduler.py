"""Algorithm 1 as a thin scheduler over party actors.

The scheduler owns only what the algorithm's *conductor* owns: the batch
schedule, the per-iteration CP selection, the jax key ladder for
Protocol-1 share randomness, and the phase ordering.  All cross-party
values move as typed messages through the Transport, which meters every
`wire_bytes()` and counts communication rounds; all party state lives in
the actors.

The two CPs' joint share arithmetic (Protocol 2, the Beaver legs of
Protocols 1 and 4) is evaluated in-process over the CP pair's states —
the same simulation convention as `mpc.beaver` — with the openings the
parties would exchange accounted through the transport's dealer.

Training is an explicit step-state machine: `step(state) -> state`
advances one iteration over a `runtime.session.TrainState` (everything
an iteration consumes — weights, every stream position, meters), and
`run()` is a thin fold over `step`, so runs can be checkpointed and
resumed bit-exactly, even into a fresh scheduler instance
(tests/test_resumable.py; docs/fault_tolerance.md).

With `LocalTransport` this replays the pre-refactor `train_vfl`
simulation bit-for-bit (losses, weights, per-tag meter bytes — see
tests/test_runtime_parity.py); `PipelinedTransport` overlaps the
data-independent Protocol-3 legs, and with its `concurrent_legs`
default the scheduler dispatches each party's Protocol-1 share
computation and every Protocol-3 masked-matvec/decrypt leg as an
independent pool future (join barrier before Protocol 4), keeping the
latency-step count flat in the party count k and per-iteration
wall-clock below k× the k=2 cost (gauged in BENCH_scaling.json via
benchmarks/fig2_scaling.py; on a single shared CPU host the legs
contend for cores, so absolute speedup needs per-party hardware).
"""
from __future__ import annotations

import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.mpc import beaver
from repro.runtime import messages as msg
from repro.runtime import seeds
from repro.runtime.party import DataParty, LabelParty, Party
from repro.runtime import session as session_lib
from repro.runtime.session import TrainState, rebuild_meter
from repro.runtime.transport import LocalTransport, Transport


class TransportDealer:
    """Beaver triples whose online openings (2 values × 2 directions per
    elementwise product) are accounted as `beaver_open` messages."""

    def __init__(self, dealer, transport: Transport, a: str, b: str):
        self._dealer = dealer
        self._transport = transport
        self._a, self._b = a, b

    def elementwise(self, shape):
        n = int(np.prod(shape))
        self._transport.account(msg.BeaverOpen(self._a, self._b,
                                               n_elems=2 * n))
        self._transport.account(msg.BeaverOpen(self._b, self._a,
                                               n_elems=2 * n))
        self._transport.exchange_round()
        return self._dealer.elementwise(shape)


def mask_bound_bits(cfg) -> int:
    """Bit bound on the Protocol-3 pre-mask value (paper §4.3).

    The value a feature owner masks is  v = Σ_i exps[i,j]·⟨d⟩_i  over
    the batch: each offset-lifted exponent is < 2^exp_width, each ring
    share < 2^64, and the sum has ⌈log2 batch_size⌉ carry bits plus one
    slack bit.  Masks are then drawn uniformly from
    [0, 2^(bound + STAT_SEC)), giving 2^-STAT_SEC statistical hiding.

    Args:
      cfg: `VFLConfig` (uses `exp_width`, `batch_size`).
    Returns:
      The bound in bits (an upper bound on ⌈log2 v⌉).
    """
    return 64 + cfg.exp_width + int(np.ceil(np.log2(cfg.batch_size))) + 1


def min_key_bits(cfg) -> int:
    """Smallest key that can carry a live run's masked values:
    mask bound + STAT_SEC statistical-hiding bits + 2 slack bits (the
    masked value + mask sum must stay < n so mod-2^64 share recovery is
    exact)."""
    return mask_bound_bits(cfg) + protocols.STAT_SEC + 2


def validate_key_bits(cfg, bound: int) -> None:
    """Check the Paillier plaintext-capacity bound
    key_bits ≥ bound + STAT_SEC + 2 (see `min_key_bits`).  Enforced for
    BOTH backends: a mock run whose key couldn't carry its own masked
    values would report wire bytes a real deployment can't achieve.

    Args:
      cfg: `VFLConfig` (uses `key_bits`).
      bound: the `mask_bound_bits(cfg)` result.
    Raises:
      ValueError: when the key is too small.
    """
    need = bound + protocols.STAT_SEC + 2
    if cfg.key_bits < need:
        raise ValueError(f"key_bits={cfg.key_bits} too small; need >= {need}")


class VFLScheduler:
    """Drives Algorithm 1 over Party actors.

    Args:
      party_data: sequence of `PartyData`-shaped objects (`.name`,
        `.X` (n, m_p) float features); `party_data[0]` must be C, the
        label holder.
      y: (n,) float labels, held only by C's actor.
      cfg: `core.trainer.VFLConfig` (GLM family, fixed-point widths,
        HE backend, CP-selection mode, seeds).
      backend: optional HE backend (`protocols.PaillierBackend` /
        `MockHEBackend`); built from `cfg` when None.
      transport: optional `Transport`; `LocalTransport` (bit-exact seed
        replay) when None.  A transport exposing an `executor` and
        `concurrent_legs` gets the fan-out schedule: Protocol-1 share
        computations and Protocol-3 legs as independent pool futures.

    `run()` returns a `core.trainer.TrainResult` (weights per party,
    public loss trace, byte meter, round count).
    """

    def __init__(self, party_data: Sequence, y: np.ndarray, cfg,
                 backend=None, transport: Transport | None = None):
        from repro.core import trainer as trainer_lib  # config/backends
        assert party_data[0].name == "C"
        self.cfg = cfg
        self.model = glm_lib.GLMS[cfg.glm]
        self.transport = transport if transport is not None \
            else LocalTransport()
        self.names = [p.name for p in party_data]
        rng = seeds.protocol_rng(cfg.seed)              # protocol randomness
        self.rng = self.transport.wrap_rng(rng)
        self.select_rng = self.transport.cp_select_rng(self.rng, cfg.seed)
        self.batch_rng = np.random.default_rng(cfg.seed)  # batch schedule
        self.jkey = jax.random.key(cfg.seed)              # (matches oracle)
        if backend is None:
            # consumes the protocol stream's first k draws as key seeds
            # (replicated by runtime.seeds.key_seeds for the socket path)
            backend = trainer_lib.make_backend(cfg, self.names, self.rng)
        self.backend = backend
        self.dealer = beaver.DealerTripleSource(
            seed=seeds.dealer_seed(cfg.seed))
        self.mask_bound = mask_bound_bits(cfg)
        validate_key_bits(cfg, self.mask_bound)
        self.parties: list[Party] = [
            LabelParty(party_data[0].name, party_data[0].X, y, cfg,
                       backend, self.rng, self.model)]
        self.parties += [DataParty(p.name, p.X, cfg, backend, self.rng)
                         for p in party_data[1:]]
        self.by_name = {p.name: p for p in self.parties}
        self.transport.bind(self.parties)
        self.n_total = self.parties[0].X.shape[0]
        # noise-pool prefetch: hand the backend the transport's executor
        # so the data-independent r^n modexps overlap Protocol 3
        ex = getattr(self.transport, "executor", None)
        if ex is not None and hasattr(self.backend, "attach_noise_executor"):
            self.backend.attach_noise_executor(ex)
        #: the TrainState the live objects currently embody (identity
        #: check lets the fold skip the per-step restore)
        self._live_state: TrainState | None = None

    @property
    def label_party(self) -> LabelParty:
        return self.parties[0]

    def _fanout(self, thunks):
        """Evaluate independent protocol legs: as pool futures when the
        transport supports concurrent legs, inline otherwise.  Results
        come back in thunk order either way, so everything downstream
        (post order, hence delivery order and the CPs' order-sensitive
        ez chaining) is schedule-independent — the single place that
        keeps the concurrent and sequential schedules bit-identical."""
        ex = self.transport.executor
        if ex is not None and getattr(self.transport, "concurrent_legs",
                                      False):
            futs = [ex.submit(t) for t in thunks]
            return [f.result() for f in futs]
        return [t() for t in thunks]

    def _prefetch_noise(self, cps: tuple[str, str], nb: int) -> None:
        """Schedule this iteration's encryption noise (r^n modexps —
        data-independent) on the transport's pool before Protocol 1 runs,
        so the hot Protocol-3 path pays ~one mont_mul per encryption.
        The raw r draws stay on the conductor thread, so the entropy
        stream is consumed deterministically; the values themselves never
        reach a decrypted quantity, so the trained model is unchanged."""
        be = self.backend
        if not hasattr(be, "prefetch_noise"):
            return
        for cp in cps:
            be.prefetch_noise(cp, nb)          # [[⟨d⟩]] under own key
        for p in self.parties:                 # mask encryptions per leg
            m = p.X.shape[1]
            if p.name in cps:
                be.prefetch_noise(cps[1] if p.name == cps[0] else cps[0], m)
            else:
                for cp in cps:
                    be.prefetch_noise(cp, m)

    # -- one iteration ------------------------------------------------------
    def _select_cps(self) -> tuple[str, str]:
        if self.cfg.cp_selection == "random":
            i = self.select_rng.choice(len(self.names), size=2, replace=False)
            return (self.names[i[0]], self.names[i[1]])
        return (self.names[0], self.names[1])

    def _iteration(self, idx) -> None:
        cfg, tp = self.cfg, self.transport
        nb = len(idx)
        cps = self._select_cps()
        noncps = [p.name for p in self.parties if p.name not in cps]
        self.jkey, *subkeys = jax.random.split(
            self.jkey, len(self.names) * 2 + 3)
        for p in self.parties:
            p.begin_iteration(idx, cps, nb, self.mask_bound)
        cp0, cp1 = self.by_name[cps[0]], self.by_name[cps[1]]
        ex = tp.executor
        concurrent = ex is not None and getattr(tp, "concurrent_legs", False)
        if tp.overlaps_p3:
            self._prefetch_noise(cps, nb)

        # -- Protocol 1: share intermediate results -------------------------
        # Each party's share computation (local matvec + encode + split) is
        # independent, so _fanout runs them on the pool when the transport
        # allows; results are POSTED in party order either way, keeping
        # delivery — and hence the CPs' order-sensitive ez chaining —
        # deterministic.
        for out in self._fanout(
                [functools.partial(p.share_z, subkeys[i])
                 for i, p in enumerate(self.parties)]
                + [functools.partial(self.label_party.share_y,
                                     subkeys[len(self.names)])]):
            tp.post_all(out)
        tp.pump(order=list(cps))
        mdealer = TransportDealer(self.dealer, tp, cps[0], cps[1])
        ez = None
        if self.model.needs_exp:
            for out in self._fanout(
                    [functools.partial(p.share_ez,
                                       subkeys[len(self.names) + 1 + i],
                                       self.model.exp_sign)
                     for i, p in enumerate(self.parties)]):
                tp.post_all(out)
            tp.pump(order=list(cps))
            # e^{Σz_p} = Π e^{z_p}: chained Beaver products over the pair
            # (roster order — arrival order is racy under pump_async)
            e0 = cp0.cp.ez_ordered(self.names)
            e1 = cp1.cp.ez_ordered(self.names)
            ez = glm_lib.ez_chain_pair(list(zip(e0, e1)), cfg.f, mdealer)

        ctx = glm_lib.ShareCtx(z=(cp0.cp.z_acc, cp1.cp.z_acc),
                               y=(cp0.cp.y_share, cp1.cp.y_share),
                               ez=ez, f=cfg.f, dealer=mdealer)

        # -- Protocol 2: gradient-operator on shares ------------------------
        d0, d1 = self.model.gradient_operator(ctx)
        cp0.cp.d_self, cp1.cp.d_self = d0, d1

        # -- Protocol 3: secure gradients -----------------------------------
        # The two CPs' encrypt legs fan out on the pool when possible.
        enc0, enc1 = self._fanout([cp0.announce_enc_d, cp1.announce_enc_d])
        tp.post(enc0)
        tp.post(enc1)
        if concurrent:
            # Concurrent legs: every masked-matvec / decrypt / unmask
            # leg of all k parties becomes an independent pool future
            # (pump_async) — the k−2 non-CP legs overlap instead of
            # queueing.  pump_async's return is the join barrier before
            # Protocol 4; the ring accumulations it races commute
            # exactly, so the trained model is bit-identical to the
            # sequential schedule (tests/test_runtime_parity.py, k=8).
            for cp in (cp0, cp1):
                tp.post_all(cp.broadcast_enc_d(noncps))
            tp.pump_async(order=[*cps, *noncps])
        elif tp.overlaps_p3:
            # broadcasts are data-independent of the CP exchange:
            # same sweep
            for cp in (cp0, cp1):
                tp.post_all(cp.broadcast_enc_d(noncps))
            tp.pump(order=[*cps, *noncps])
        else:
            tp.pump(order=list(cps))
            for p in noncps:
                for cp in (cp0, cp1):
                    tp.post_all(cp.broadcast_enc_d([p]))
            tp.pump(order=[*noncps, *cps])

        # -- Protocol 4: secure loss ----------------------------------------
        l0, l1 = self.model.loss_shares(ctx)
        cp0.cp.l_self = l0
        tp.post(msg.LossShare(cps[1], cps[0], l1))
        tp.pump(order=list(cps))

        # -- stop flag ------------------------------------------------------
        tp.post_all(self.label_party.emit_flags(self.names[1:]))
        tp.pump()
        if hasattr(self.backend, "discard_pooled_noise"):
            self.backend.discard_pooled_noise()   # bound pool to one iter

    # -- step-state machine -------------------------------------------------
    # `run()` is a thin fold over `step()`: every iteration consumes and
    # produces an explicit `session.TrainState`, so a run can be paused,
    # checkpointed, and resumed (even in a FRESH scheduler instance)
    # with a bit-identical trajectory — losses, weights, per-tag bytes.

    def init_state(self) -> TrainState:
        """State before iteration 0.  Draws the first epoch permutation
        — the same first `batch_rng` draw the pre-refactor loop made."""
        order = self.batch_rng.permutation(self.n_total)
        return self._capture(it=0, order=order, cursor=0, runtime_s=0.0)

    def _capture(self, it: int, order, cursor: int,
                 runtime_s: float) -> TrainState:
        be = self.backend
        pool = 0
        if hasattr(be, "_noise"):
            pool = sum(len(q) for q in be._noise.values())
        shared_select = self.select_rng is self.rng
        state = TrainState(
            it=int(it),
            weights={p.name: np.array(p.W, np.float64)
                     for p in self.parties},
            losses=list(self.label_party.losses),
            stop=bool(self.label_party.stop),
            order=np.asarray(order, np.int64),
            cursor=int(cursor),
            batch_rng=seeds.generator_state(self.batch_rng),
            jkey=np.asarray(jax.random.key_data(self.jkey)),
            protocol_rng=self.rng.state(),
            select_rng=None if shared_select else self.select_rng.state(),
            dealer=self.dealer.state(),
            noise_pool_fill=pool,
            # O(1) prefix view of the append-only ledger — rows are
            # materialized only at serialization time (session.send_rows)
            meter_sends=session_lib.LedgerView(self.transport.meter.sends),
            rounds=int(self.transport.rounds),
            runtime_s=float(runtime_s))
        self._live_state = state
        return state

    def restore(self, state: TrainState) -> None:
        """Load a TrainState into the live objects.  Idempotent — `step`
        restores every iteration, so a freshly deserialized state and
        the fold's own successor states take the identical path.  All
        stream restores are in-place, so aliases (the HE backend's rng
        handle, a LockedRNG wrapper) see the restored position too."""
        for p in self.parties:
            p.W = np.array(state.weights[p.name], np.float64)
            p.stop = bool(state.stop)
        self.label_party.losses = list(state.losses)
        seeds.restore_generator(self.batch_rng, state.batch_rng)
        self.jkey = jax.random.wrap_key_data(
            jnp.asarray(np.asarray(state.jkey, np.uint32)))
        self.rng.set_state(state.protocol_rng)
        if state.select_rng is not None and self.select_rng is not self.rng:
            self.select_rng.set_state(state.select_rng)
        self.dealer.set_state(state.dealer)
        if hasattr(self.backend, "discard_pooled_noise"):
            # the pool is data-independent scratch; a resumed iteration
            # re-prefetches its own batches (state.noise_pool_fill is 0
            # at every boundary capture)
            self.backend.discard_pooled_noise()
        self.transport.meter = rebuild_meter(state.meter_sends)
        self.transport.rounds = int(state.rounds)
        self._live_state = state

    def step(self, state: TrainState) -> TrainState:
        """One Algorithm-1 iteration as a state transition.  When
        `state` is the object the last capture produced (the fold's
        common case), the live objects already embody it and the
        restore is skipped — a deserialized or older state gets the
        full in-place restore."""
        cfg = self.cfg
        if state is not self._live_state:
            self.restore(state)
        t0 = time.perf_counter()
        order, cursor = state.order, int(state.cursor)
        if cursor + cfg.batch_size > self.n_total:
            order = self.batch_rng.permutation(self.n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        self._iteration(idx)
        return self._capture(
            it=state.it + 1, order=order, cursor=cursor,
            runtime_s=state.runtime_s + (time.perf_counter() - t0))

    # -- training loop ------------------------------------------------------
    def run(self, state: TrainState | None = None):
        """Fold `step` from `state` (or a fresh `init_state`) until
        max_iter/stop; bit-exact vs the pre-refactor monolithic loop."""
        from repro.core.trainer import TrainResult
        cfg = self.cfg
        if state is None:
            state = self.init_state()
        while state.it < cfg.max_iter and not state.stop:
            state = self.step(state)
        if state is not self._live_state:
            self.restore(state)    # live objects reflect the final state
        return TrainResult(
            weights={n: np.array(w) for n, w in state.weights.items()},
            losses=list(state.losses),
            meter=self.transport.meter,
            runtime_s=state.runtime_s,
            n_iter=state.it,
            rounds=self.transport.rounds)
