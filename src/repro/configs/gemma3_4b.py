"""gemma3-4b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; head_dim 256,
sliding window 1024 on local layers, 1M rope theta on global layers,
logit softcapping.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        rope_theta=1e4, sliding_window=1024, local_global_ratio=5,
        attn_logit_softcap=50.0, logits_softcap=30.0,
        tie_embeddings=True, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, sliding_window=32,
        remat=False)
