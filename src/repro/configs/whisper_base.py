"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified].
6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048 vocab=51865; frontend is
a stub providing 1500 frame embeddings (30 s of audio at 50 Hz).
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=6, encoder_layers=6, encoder_seq=1500,
        d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=51865, head_dim=64,
        rope_theta=1e4,   # unused: whisper uses absolute positions
        act="gelu", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, encoder_layers=2, encoder_seq=64,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=512, remat=False)
