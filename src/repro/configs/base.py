"""Model / mesh / training configuration dataclasses + shape cells.

Every assigned architecture is a `ModelConfig` instance in its own module
(`repro/configs/<id>.py`), selectable via ``--arch <id>`` (registry.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads
    # --- attention flavor ---
    rope_theta: float = 1e4
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # window for "local" layers
    local_global_ratio: int = 0            # gemma3: 5 → 5 local : 1 global
    attn_logit_softcap: Optional[float] = None
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None
    moe_impl: str = "sorted"               # sorted | dense
    capacity_factor: float = 1.25
    # --- SSM / RWKV ---
    ssm_state: int = 0
    conv_width: int = 4
    shared_attn_every: int = 0             # zamba2: shared block cadence
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                   # stub frontend frames
    # --- VLM stub ---
    vision_patches: int = 0
    # --- misc ---
    act: str = "silu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    optimizer: str = "adamw"               # adamw | sgd (memory-bound archs)
    kv_cache_dtype: str = "bfloat16"       # bfloat16 | int8 (§Perf knob)
    # replicate KV heads r× so (K·r) divides the TP axis → cache shards on
    # heads instead of sequence, eliminating the decode gather (§Perf knob;
    # exact: each duplicated head serves 1/r of its original query group)
    kv_head_replication: int = 1
    # numerics
    logits_softcap: Optional[float] = None
    # debug: fully unroll layer scans (exact XLA cost_analysis; tests only)
    debug_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.family in ("ssm",):
            mix = 6 * d * d        # rwkv6 r/k/v/g/o/w (approx, lora extra)
            blk = mix + 3 * d * f // 2 * 2
            return v * d * (1 if self.tie_embeddings else 2) \
                + self.n_layers * blk
        if self.family == "hybrid":
            mamba = 2 * d * (2 * d + 2 * self.ssm_state) + 2 * d * d
            shared = attn + 3 * d * f
            n_shared_apps = (self.n_layers // max(1, self.shared_attn_every))
            return v * d * 2 + self.n_layers * mamba + shared
        ff = 3 * d * f if self.act == "silu" else 2 * d * f
        if self.n_experts:
            ff = self.n_experts * 3 * d * (self.moe_d_ff or f) \
                + d * self.n_experts
        blk = attn + ff
        layers = self.n_layers + self.encoder_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        return emb + layers * blk

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        ff_all = self.n_experts * 3 * d * (self.moe_d_ff or self.d_ff)
        ff_act = self.experts_per_token * 3 * d * (self.moe_d_ff or self.d_ff)
        return self.param_count() - self.n_layers * (ff_all - ff_act)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic attention (DESIGN.md §4): only these
# run it; pure full-attention archs record a documented SKIP.
LONG_CONTEXT_ARCHS = {"rwkv6-1.6b", "zamba2-7b", "gemma3-4b"}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: Optional[int] = None   # gradient accumulation chunk
    seed: int = 0
