"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].
16L d_model=2048 16H (GQA kv=16) vocab=50304, MoE 64e top-8, expert
d_ff=1024.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304, head_dim=128,
        n_experts=64, experts_per_token=8, moe_d_ff=1024,
        rope_theta=1e4, qk_norm=True, act="silu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=64, moe_d_ff=64, vocab_size=512,
        n_experts=8, experts_per_token=2, remat=False)
