"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173; hf].
40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-15b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152, head_dim=128,
        rope_theta=1e5, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, remat=False)
