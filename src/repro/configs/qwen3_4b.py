"""qwen3-4b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936; head_dim 128.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab_size=151936, head_dim=128,
        rope_theta=1e6, qk_norm=True, act="silu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, remat=False)
