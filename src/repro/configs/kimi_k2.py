"""kimi-k2-1t-a32b — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384e top-8, expert
d_ff=2048.  SGD optimizer + full remat: the 1T-parameter memory plan
(EXPERIMENTS.md §Dry-run) needs stateless updates at 256 chips.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab_size=163840, head_dim=112,
        n_experts=384, experts_per_token=8, moe_d_ff=2048,
        rope_theta=5e4, act="silu",
        optimizer="sgd",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=64, moe_d_ff=64, vocab_size=512,
        n_experts=8, experts_per_token=2, remat=False, optimizer="adamw")
