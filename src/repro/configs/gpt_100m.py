"""gpt-100m — the end-to-end training-driver example model (~110M params;
not part of the assigned pool).  Small enough to train a few hundred
steps on CPU, big enough to exercise every framework layer."""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "gpt-100m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=3072, vocab_size=32768, head_dim=64,
        rope_theta=1e4, act="silu", remat=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=1024)
