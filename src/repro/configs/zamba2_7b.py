"""zamba2-7b — Mamba2 + shared attention blocks [arXiv:2411.15242;
unverified].
81L d_model=3584, shared attn 32H (kv=32 — full MHA), shared MLP
d_ff=14336, ssm_state=64; shared block applied every 6 Mamba2 layers,
two blocks alternating.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "zamba2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112,
        ssm_state=64, conv_width=4, shared_attn_every=6,
        rope_theta=1e4, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ssm_state=16,
        shared_attn_every=3, remat=False)
