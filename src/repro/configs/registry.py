"""--arch <id> lookup for all assigned architectures + the paper's own
GLM configurations."""
from __future__ import annotations

from repro.configs import (gemma3_4b, gpt_100m, kimi_k2, minitron_4b,
                           olmoe_1b_7b, qwen2_vl_72b, qwen3_4b, rwkv6_1b6,
                           starcoder2_15b, whisper_base, zamba2_7b)
from repro.configs.base import ModelConfig

# the 10 assigned architectures (dry-run / roofline matrix)
_MODULES = [rwkv6_1b6, minitron_4b, starcoder2_15b, gemma3_4b, qwen3_4b,
            olmoe_1b_7b, kimi_k2, qwen2_vl_72b, zamba2_7b, whisper_base]
# extras (examples / drivers), selectable but outside the assigned matrix
_EXTRAS = [gpt_100m]

ARCHS: dict[str, object] = {m.ARCH_ID: m for m in _MODULES}
_ALL: dict[str, object] = {**ARCHS, **{m.ARCH_ID: m for m in _EXTRAS}}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ALL:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(_ALL)}")
    return _ALL[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _ALL[arch_id].smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)
