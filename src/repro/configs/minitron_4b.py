"""minitron-4b — pruned nemotron [arXiv:2407.14679; hf].
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "minitron-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab_size=256000, head_dim=128,
        rope_theta=1e4, act="silu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, remat=False)
