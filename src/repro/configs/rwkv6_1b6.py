"""rwkv6-1.6b — Finch, data-dependent decay [arXiv:2404.05892; unverified].
24L d_model=2048 (attn-free) d_ff=7168 vocab=65536; head size 64 → 32 heads.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536, head_dim=64,
        rope_theta=1e4,        # unused (attention-free)
        optimizer="adamw",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512, remat=False)
