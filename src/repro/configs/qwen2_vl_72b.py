"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

Backbone-only per the assignment: the vision tower is a STUB —
`input_specs()` provides precomputed patch embeddings (B, 256, d_model)
prepended to the token stream; M-RoPE degrades to standard RoPE on the
text backbone (DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        vision_patches=256,
        rope_theta=1e6, act="silu",
        optimizer="sgd",      # 72B × AdamW exceeds 16 GB/chip at 256 chips
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, vision_patches=8,
        remat=False, optimizer="adamw")
