"""Config system: one module per assigned architecture + the registry."""
from repro.configs.base import (LONG_CONTEXT_ARCHS, SHAPE_CELLS, ModelConfig,
                                ShapeCell, TrainConfig)

__all__ = ["ModelConfig", "ShapeCell", "TrainConfig", "SHAPE_CELLS",
           "LONG_CONTEXT_ARCHS"]
