"""Beaver-triple multiplication over Z_2^64 shares.

Two triple sources:

* `DealerTripleSource` — the classic preprocessing model (a semi-honest
  dealer, or an offline phase run before training).  Cheap; used by
  benchmarks to match the paper's accounting, which treats triples as
  preprocessing.
* `paillier_triple` — 2-party online generation using the same Paillier
  keys the framework already has, closing the "no third party anywhere"
  loop: c = (a0+a1)(b0+b1) with cross terms computed under P1→P0
  encryption.  (Gilboa-style; one ciphertext round-trip per triple
  batch.)

`mul` consumes one triple per elementwise product:
  z = c + d·b + e·a + d·e   with d = x−a, e = y−b revealed.
The opened d, e are uniformly masked, so nothing leaks (Theorem 3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import paillier, prng, ring
from repro.crypto import bigint, fixed_point
from repro.crypto.ring import R64
from repro.mpc import sharing


@dataclasses.dataclass
class TripleShares:
    """One party's share of (a, b, c) with c = a*b (elementwise)."""
    a: R64
    b: R64
    c: R64


class DealerTripleSource:
    """Preprocessing-phase triples from a seeded dealer.

    `drawn` counts stream advances (one per `elementwise` draw or
    `skip` unit) so replicated dealers can be audited for alignment;
    `state()`/`set_state()` capture the exact stream position for
    resumable sessions (`runtime.session.TrainState`)."""

    def __init__(self, seed: int = 0):
        self._key = jax.random.key(seed)
        self.drawn = 0

    def _next_key(self):
        self.drawn += 1
        self._key, sub = jax.random.split(self._key)
        return sub

    def state(self) -> dict:
        return {"key": np.asarray(jax.random.key_data(self._key)),
                "drawn": int(self.drawn)}

    def set_state(self, st: dict) -> None:
        self._key = jax.random.wrap_key_data(
            jnp.asarray(np.asarray(st["key"], np.uint32)))
        self.drawn = int(st["drawn"])

    def skip(self, n: int) -> None:
        """Advance the triple stream by `n` draws without materializing
        them.  The distributed runtime replicates one dealer per party
        from the shared seed; parties not selected as computing parties
        this iteration skip the draws the CP pair consumed so every
        replica stays stream-aligned (one key split per draw, shapes
        irrelevant)."""
        for _ in range(n):
            self._next_key()

    def elementwise(self, shape) -> tuple[TripleShares, TripleShares]:
        ka, kb, ks1, ks2, ks3 = jax.random.split(self._next_key(), 5)
        a = R64(*prng.u32_pair(ka, shape))
        b = R64(*prng.u32_pair(kb, shape))
        c = ring.mul(a, b)
        a0, a1 = sharing.share(a, ks1)
        b0, b1 = sharing.share(b, ks2)
        c0, c1 = sharing.share(c, ks3)
        return TripleShares(a0, b0, c0), TripleShares(a1, b1, c1)


def open_masked(x0: R64, x1: R64) -> R64:
    """Both parties exchange and add their shares of a *masked* value.
    (Communication: 8 bytes per element per direction — metered by the
    caller's transport.)"""
    return ring.add(x0, x1)


def mul(x: tuple[R64, R64], y: tuple[R64, R64],
        t0: TripleShares, t1: TripleShares) -> tuple[R64, R64]:
    """Elementwise share multiplication (simulation evaluates both
    parties).  Returns shares of x*y."""
    d = open_masked(ring.sub(x[0], t0.a), ring.sub(x[1], t1.a))
    e = open_masked(ring.sub(y[0], t0.b), ring.sub(y[1], t1.b))
    de = ring.mul(d, e)

    def party(i, t, xs, ys):
        z = ring.add(t.c, ring.mul(d, t.b))
        z = ring.add(z, ring.mul(e, t.a))
        if i == 0:
            z = ring.add(z, de)
        return z

    return (party(0, t0, x[0], y[0]), party(1, t1, x[1], y[1]))


def square(x: tuple[R64, R64], t0: TripleShares, t1: TripleShares):
    return mul(x, x, t0, t1)


def dot(x: tuple[R64, R64], y: tuple[R64, R64],
        t0: TripleShares, t1: TripleShares) -> tuple[R64, R64]:
    """Shares of sum_i x_i * y_i (triple shapes match x)."""
    z0, z1 = mul(x, y, t0, t1)
    return ring.sum_axis(z0, 0), ring.sum_axis(z1, 0)


# ---------------------------------------------------------------------------
# Paillier-based triple generation (fully third-party-free preprocessing)
# ---------------------------------------------------------------------------

def paillier_triple(shape, key0: paillier.PrivateKey,
                    rng: np.random.Generator, jkey: jax.Array
                    ) -> tuple[TripleShares, TripleShares]:
    """P0 owns key0.  P0 samples (a0, b0), P1 samples (a1, b1, r).
    P1 computes [[a0]]⊗b1 ⊕ [[b0]]⊗a1 ⊕ [[r]] and returns it; then
      c0 = a0 b0 + Dec(·) mod 2^64,   c1 = a1 b1 − r mod 2^64.
    Residue-lift semantics make the mod-2^64 reduction exact (DESIGN §7);
    requires key_bits ≥ 64 + 64 + log2(#terms) + 40 — use ≥ 256-bit keys.
    """
    pub = key0.pub
    if pub.key_bits < 192:
        raise ValueError("paillier_triple needs >=192-bit keys for exactness")
    n_elems = int(np.prod(shape))
    k0, k1, k2, k3 = jax.random.split(jkey, 4)
    a0 = R64(*prng.u32_pair(k0, shape))
    b0 = R64(*prng.u32_pair(k1, shape))
    a1 = R64(*prng.u32_pair(k2, shape))
    b1 = R64(*prng.u32_pair(k3, shape))
    # P0 -> P1: [[a0]], [[b0]]
    ca0 = paillier.encrypt(pub, fixed_point.r64_to_limbs(a0, pub.Ln).reshape(-1, pub.Ln), rng=rng)
    cb0 = paillier.encrypt(pub, fixed_point.r64_to_limbs(b0, pub.Ln).reshape(-1, pub.Ln), rng=rng)
    # P1: cross terms + statistical mask r (uniform 64+40 bits)
    r_ints = prng.host_uniform_below(1 << 104, n_elems, rng=rng)
    r_limbs = bigint.ints_to_limbs(r_ints, pub.Ln)
    cr = paillier.encrypt(pub, r_limbs, rng=rng)
    b1_bits = fixed_point.u64_bits_msb(b1).reshape(n_elems, 64)
    a1_bits = fixed_point.u64_bits_msb(a1).reshape(n_elems, 64)
    cross = paillier.add_ct(pub, paillier.smul_bits(pub, ca0, b1_bits),
                            paillier.smul_bits(pub, cb0, a1_bits))
    cross = paillier.add_ct(pub, cross, cr)
    # P0 decrypts, reduces mod 2^64
    dec = paillier.decrypt(key0, cross)
    cross64 = fixed_point.limbs_to_r64(dec)
    cross64 = R64(cross64.hi.reshape(shape), cross64.lo.reshape(shape))
    c0 = ring.add(ring.mul(a0, b0), cross64)
    r64v = fixed_point.limbs_to_r64(jnp.asarray(r_limbs))
    r64v = R64(r64v.hi.reshape(shape), r64v.lo.reshape(shape))
    c1 = ring.sub(ring.mul(a1, b1), r64v)
    return TripleShares(a0, b0, c0), TripleShares(a1, b1, c1)
