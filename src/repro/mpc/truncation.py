"""Fixed-point truncation on Z_2^64 shares (SecureML §4.1, local).

After a fixed-point product the value carries 2f fractional bits; each
party truncates its own share:
  P0: ⟨x⟩_0' = ⌊⟨x⟩_0 / 2^f⌋
  P1: ⟨x⟩_1' = 2^64 − ⌊(2^64 − ⟨x⟩_1) / 2^f⌋
With |x| < 2^ℓ the result equals ⌊x/2^f⌋ ± 1 except with probability
2^{ℓ+1−64} (error event: the shares straddle the wrap point).  ℓ ≤ 45 in
our protocols → failure ≤ 2^−18 per element per step; the end-to-end GLM
tests bound the induced noise empirically.
"""
from __future__ import annotations

from repro.crypto import ring
from repro.crypto.ring import R64


def trunc_share(x: R64, f: int, party: int) -> R64:
    if f == 0:
        return x
    if party == 0:
        return ring.shift_right_logical(x, f)
    neg = ring.neg(x)
    return ring.neg(ring.shift_right_logical(neg, f))


def trunc_pair(x0: R64, x1: R64, f: int) -> tuple[R64, R64]:
    return trunc_share(x0, f, 0), trunc_share(x1, f, 1)
