"""Secret-sharing MPC substrate over Z_2^64 (Protocol 1 + share algebra)."""
from repro.mpc import beaver, sharing, truncation

__all__ = ["sharing", "beaver", "truncation"]
