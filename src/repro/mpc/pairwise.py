"""Per-party legs of the two-CP joint share arithmetic.

The EFMVFL computing parties evaluate Protocol 2 (gradient operator),
Protocol 4 (loss) and the Poisson/Gamma e^z chaining *jointly*: linear
share ops are local, and every share-by-share product is one Beaver
multiplication whose masked openings d = x−a, e = y−b are exchanged
between the two CPs.  Historically the simulation evaluated both
parties' steps in one call (`mpc.beaver.mul` over share pairs); the
socket runtime needs each CP to run *its own* leg in its own process
with the openings travelling over the wire.

This module provides that leg form once, so both execution modes share
one implementation of the math:

* `PairLeg` — one CP's view: its share index (0/1), a triple source
  returning *its half* of each Beaver triple, and an `opener` callback
  that exchanges the masked openings with the peer (over a socket in
  the distributed runtime; an in-process rendezvous in simulation).
* `joint(fn, dealer)` — the simulation driver: runs `fn(leg)` for both
  legs in lockstep (leg 1 on a worker thread), drawing each triple
  exactly once from `dealer` and rendezvousing at every opening, so a
  pair evaluation consumes the dealer stream and produces bit-for-bit
  the values `mpc.beaver.mul` produced.

Bit-exactness argument: the only cross-leg data flow is the opened
(d, e) pair; both legs compute d = ⟨d⟩₀ + ⟨d⟩₁ themselves, and ring
addition over Z_2^64 is exact and commutative, so operand order cannot
matter.  Everything else is per-share-local (`truncation.trunc_share`,
ring linear ops), identical to the pair-at-once evaluation.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

from repro.crypto import ring
from repro.crypto.ring import R64
from repro.mpc import truncation
from repro.mpc.beaver import TripleShares

#: rendezvous / network-opening wait bound — a leg blocked longer than
#: this has lost its peer (crashed process, dropped connection).
OPEN_TIMEOUT_S = 120.0


class PairLeg:
    """One computing party's execution context for the joint arithmetic.

    Args:
      index: this CP's share index (0 or 1) — decides which triple half
        it consumes, which leg adds public constants, and which
        truncation branch it takes.
      triples: callable `(shape) -> TripleShares`, this party's half of
        the next Beaver triple.  Both legs must observe the same draw
        sequence (simulation: one shared dealer draw split in two;
        distributed: seed-synchronized local dealers).
      opener: callable `(d_self, e_self) -> (d, e)` that exchanges the
        masked openings with the peer and returns the opened values.
    """

    def __init__(self, index: int, triples: Callable[[tuple], TripleShares],
                 opener: Callable[[R64, R64], tuple[R64, R64]]):
        assert index in (0, 1)
        self.index = index
        self._triples = triples
        self._opener = opener

    # -- interactive ---------------------------------------------------------
    def mul(self, x: R64, y: R64) -> R64:
        """One Beaver multiplication: this leg's share of x*y.

        Mirrors `mpc.beaver.mul` exactly: z_i = c_i + d·b_i + e·a_i,
        with leg 0 adding the public d·e term.  Communication: one
        `beaver_open` exchange (2 ring elements per product element in
        each direction), performed by `opener`.
        """
        t = self._triples(x.lo.shape)
        d, e = self._opener(ring.sub(x, t.a), ring.sub(y, t.b))
        z = ring.add(t.c, ring.mul(d, t.b))
        z = ring.add(z, ring.mul(e, t.a))
        if self.index == 0:
            z = ring.add(z, ring.mul(d, e))
        return z

    # -- local ---------------------------------------------------------------
    def trunc(self, x: R64, s: int) -> R64:
        """Probabilistic fixed-point truncation of this leg's share."""
        return truncation.trunc_share(x, s, self.index)

    def add_pub(self, x: R64, pub: R64) -> R64:
        """x + c for public c: only leg 0 adds the constant."""
        return ring.add(x, pub) if self.index == 0 else x


# ---------------------------------------------------------------------------
# Simulation driver — both legs in one process, lockstep
# ---------------------------------------------------------------------------

class _SharedTriples:
    """Serve both legs the same dealer draw per program point.

    Legs advance through multiplications in program order (the opening
    rendezvous is a barrier), so draw j is requested by both legs
    between barriers j−1 and j; whichever arrives first performs the
    single `dealer.elementwise` call.
    """

    def __init__(self, dealer):
        self._dealer = dealer
        self._drawn: list[tuple[TripleShares, TripleShares]] = []
        self._lock = threading.Lock()
        self._counts = [0, 0]

    def for_leg(self, index: int):
        def triples(shape):
            with self._lock:
                j = self._counts[index]
                self._counts[index] += 1
                if len(self._drawn) <= j:
                    self._drawn.append(self._dealer.elementwise(shape))
                return self._drawn[j][index]
        return triples


def _rendezvous_openers(timeout: float = OPEN_TIMEOUT_S):
    """Two openers that exchange (d_i, e_i) through a queue pair."""
    qs = (queue.Queue(), queue.Queue())

    def make(i):
        def opener(d_self, e_self):
            qs[1 - i].put((d_self, e_self))
            try:
                d_peer, e_peer = qs[i].get(timeout=timeout)
            except queue.Empty:
                raise RuntimeError(
                    f"pairwise leg {i}: peer never opened (deadlocked or "
                    "crashed leg)") from None
            return ring.add(d_self, d_peer), ring.add(e_self, e_peer)
        return opener

    return make(0), make(1)


def joint(fn: Callable[[PairLeg], R64], dealer):
    """Evaluate both CPs' legs of `fn` in lockstep; returns (out0, out1).

    `dealer` is consumed exactly once per Beaver multiplication (shapes
    and order identical to the pair-at-once evaluation), so transports
    that meter `beaver_open` traffic at the dealer keep counting the
    same bytes and rounds.
    """
    triples = _SharedTriples(dealer)
    open0, open1 = _rendezvous_openers()
    leg0 = PairLeg(0, triples.for_leg(0), open0)
    leg1 = PairLeg(1, triples.for_leg(1), open1)

    result1: list = [None]
    error1: list = [None]

    def run1():
        try:
            result1[0] = fn(leg1)
        except BaseException as e:              # noqa: BLE001 — re-raised
            error1[0] = e

    worker = threading.Thread(target=run1, name="pairwise-leg1",
                              daemon=True)
    worker.start()
    try:
        out0 = fn(leg0)
    finally:
        worker.join(timeout=OPEN_TIMEOUT_S)
    if error1[0] is not None:
        raise error1[0]
    if worker.is_alive():
        raise RuntimeError("pairwise leg 1 did not finish (deadlock)")
    return out0, result1[0]
