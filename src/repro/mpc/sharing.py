"""Protocol 1 — additive secret sharing over Z_2^64.

`share(x, key)` splits a ring tensor into uniformly-random additive
shares; `reconstruct` adds them back.  The multi-party variant splits into
exactly two shares destined for the two computing parties (CPs), matching
EFMVFL §4.3 — non-CP parties never hold shares of anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto import prng, ring
from repro.crypto.ring import R64


def share(x: R64, key: jax.Array) -> tuple[R64, R64]:
    """x -> (⟨x⟩_0, ⟨x⟩_1), ⟨x⟩_0 uniform (Theorem 2's PRNG assumption)."""
    hi, lo = prng.u32_pair(key, x.lo.shape)
    s0 = R64(hi, lo)
    s1 = ring.sub(x, s0)
    return s0, s1


def share_zero(shape, key: jax.Array) -> tuple[R64, R64]:
    """Shares of zero (used for re-randomization)."""
    hi, lo = prng.u32_pair(key, shape)
    s0 = R64(hi, lo)
    return s0, ring.neg(s0)


def reconstruct(*shares: R64) -> R64:
    acc = shares[0]
    for s in shares[1:]:
        acc = ring.add(acc, s)
    return acc


# Share-level linear algebra (each party runs these locally on its share;
# addition/subtraction/public-scalar ops commute with reconstruction).

add = ring.add
sub = ring.sub
neg = ring.neg


def add_public(share_val: R64, pub: R64, party: int) -> R64:
    """x + c where c is public: only party 0 adds the constant."""
    return ring.add(share_val, pub) if party == 0 else share_val


def mul_public_int(share_val: R64, k: int) -> R64:
    return ring.mul_pub_int(share_val, k)


def mul_public_elem(share_val: R64, pub: R64) -> R64:
    """Elementwise multiply by a public ring tensor."""
    return ring.mul(share_val, pub)


def matmul_public(x_pub_int: jnp.ndarray, share_val: R64) -> R64:
    return ring.matmul(x_pub_int, share_val)
