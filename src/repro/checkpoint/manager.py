"""Atomic, async, integrity-checked checkpointing (restart/preemption
safety at cluster scale).

Layout per step:
  <dir>/step_<n>.npz       — flattened pytree leaves (numpy archive)
  <dir>/step_<n>.json      — manifest: step, keys, treedef repr, sha256

Write protocol: tmp file + fsync + atomic rename, manifest LAST — a crash
mid-write can never leave a manifest pointing at a torn archive.  Restore
takes the newest manifest whose hash verifies (corrupt/partial tails are
skipped).  `save_async` offloads serialization to a worker thread so the
step loop never blocks on I/O (orbax-style).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any

import jax
import numpy as np


try:
    import ml_dtypes
    _EXT_DTYPES = {
        "bfloat16": (ml_dtypes.bfloat16, np.uint16),
        "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
        "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
    }
except ImportError:      # pragma: no cover
    _EXT_DTYPES = {}


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], list[str], str]:
    """npz can't store ml_dtypes extension types — store a uint view plus
    the dtype name; `_unflatten_leaf` restores the view."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat, dtypes = {}, []
    for i, x in enumerate(leaves):
        arr = np.asarray(x)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _EXT_DTYPES:
            arr = arr.view(_EXT_DTYPES[str(arr.dtype)][1])
        flat[f"leaf_{i}"] = arr
    return flat, dtypes, str(treedef)


def _restore_leaf(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name][0])
    return arr


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, dtypes, treedef = _flatten(tree)
    base = os.path.join(directory, f"step_{step}")
    tmp = f"{base}.npz.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, base + ".npz")
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "dtypes": dtypes,
        "treedef": treedef,
        "sha256": _sha256(base + ".npz"),
        "extra": extra or {},
    }
    mtmp = f"{base}.json.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(mtmp, base + ".json")
    return base


def load_checkpoint(directory: str, template: Any,
                    step: int | None = None) -> tuple[int, Any, dict] | None:
    """Restore the newest (or given) valid checkpoint into the structure
    of `template`.  Returns (step, tree, extra) or None."""
    if not os.path.isdir(directory):
        return None
    manifests = sorted(
        (f for f in os.listdir(directory) if f.endswith(".json")),
        key=lambda f: int(f.split("_")[1].split(".")[0]), reverse=True)
    for mf in manifests:
        s = int(mf.split("_")[1].split(".")[0])
        if step is not None and s != step:
            continue
        base = os.path.join(directory, mf[:-5])
        try:
            with open(base + ".json") as f:
                manifest = json.load(f)
            if _sha256(base + ".npz") != manifest["sha256"]:
                continue                       # torn write — skip
            data = np.load(base + ".npz")
            dtypes = manifest.get("dtypes") or [None] * manifest["n_leaves"]
            leaves = [_restore_leaf(data[f"leaf_{i}"], dtypes[i])
                      for i in range(manifest["n_leaves"])]
            treedef = jax.tree_util.tree_structure(template)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
            return manifest["step"], tree, manifest.get("extra", {})
        except (OSError, KeyError, ValueError):
            continue
    return None


class CheckpointManager:
    """keep-N rotation + async save."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()                             # never race a pending async
        tree = jax.tree.map(np.asarray, tree)   # device→host snapshot
        save_checkpoint(self.directory, step, tree, extra)
        self._gc()

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()
        tree = jax.tree.map(np.asarray, tree)   # snapshot BEFORE returning
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, tree, extra), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            self._gc()

    def restore(self, template: Any):
        self.wait()
        return load_checkpoint(self.directory, template)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted({int(f.split("_")[1].split(".")[0])
                        for f in os.listdir(self.directory)
                        if f.endswith(".json")}, reverse=True)
        for s in steps[self.keep:]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory, f"step_{s}{ext}"))
                except OSError:
                    pass
