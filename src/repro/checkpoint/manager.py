"""Atomic, async, integrity-checked checkpointing (restart/preemption
safety at cluster scale).

Layout per step:
  <dir>/step_<n>.npz       — flattened pytree leaves (numpy archive)
  <dir>/step_<n>.json      — manifest: step, keys, treedef repr, sha256,
                             optional config/codec compatibility hashes

Write protocol: tmp file + fsync + atomic rename + directory fsync,
manifest LAST — a crash mid-write can never leave a manifest pointing at
a torn archive, and the rename itself is durable before the manifest
appears.  Restore takes the newest manifest whose JSON parses (torn
manifests are detected and skipped), whose schema is complete, and whose
archive hash verifies.  `save_async` offloads serialization to a worker
thread so the step loop never blocks on I/O (orbax-style).

Compatibility refusal: a manifest may carry `config_hash` (semantic
run-config fingerprint) and `codec_version` (wire-format version).  A
load that passes the matching `expect_*` values REFUSES — raises
`CheckpointMismatch` with both values spelled out — rather than silently
resuming a run whose recovered streams would diverge.  Torn/corrupt
checkpoints are *skipped* (fall back to an older valid step); mismatched
ones are *refused* (the operator pointed a different run at this
directory — falling back would hide the operator error).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any

import jax
import numpy as np


class CheckpointMismatch(RuntimeError):
    """A valid checkpoint exists but belongs to an incompatible run
    (config or codec-version hash differs) — resume refused."""


try:
    import ml_dtypes
    _EXT_DTYPES = {
        "bfloat16": (ml_dtypes.bfloat16, np.uint16),
        "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
        "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
    }
except ImportError:      # pragma: no cover
    _EXT_DTYPES = {}

#: manifest keys a readable checkpoint must carry — a parsed-but-partial
#: manifest (e.g. truncated then padded by a broken filesystem) is torn.
_REQUIRED_MANIFEST_KEYS = ("step", "n_leaves", "dtypes", "treedef",
                           "sha256")


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], list[str], str]:
    """npz can't store ml_dtypes extension types — store a uint view plus
    the dtype name; `_unflatten_leaf` restores the view."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat, dtypes = {}, []
    for i, x in enumerate(leaves):
        arr = np.asarray(x)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _EXT_DTYPES:
            arr = arr.view(_EXT_DTYPES[str(arr.dtype)][1])
        flat[f"leaf_{i}"] = arr
    return flat, dtypes, str(treedef)


def _restore_leaf(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name][0])
    return arr


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_dir(directory: str) -> None:
    """Make a completed rename durable (POSIX: the rename lives in the
    directory entry, which has its own write-back)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:          # platform without directory fds
        return
    try:
        os.fsync(fd)
    except OSError:          # pragma: no cover — fsync unsupported here
        pass
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: dict | None = None,
                    config_hash: str | None = None,
                    codec_version: int | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, dtypes, treedef = _flatten(tree)
    base = os.path.join(directory, f"step_{step}")
    tmp = f"{base}.npz.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, base + ".npz")
    _fsync_dir(directory)
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "dtypes": dtypes,
        "treedef": treedef,
        "sha256": _sha256(base + ".npz"),
        "extra": extra or {},
    }
    if config_hash is not None:
        manifest["config_hash"] = config_hash
    if codec_version is not None:
        manifest["codec_version"] = int(codec_version)
    mtmp = f"{base}.json.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(mtmp, base + ".json")
    _fsync_dir(directory)
    return base


def _read_manifest(path: str) -> dict | None:
    """Parse one manifest; None for torn/partial manifests (truncated
    JSON, missing required keys) — callers skip those."""
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(manifest, dict) or \
            any(k not in manifest for k in _REQUIRED_MANIFEST_KEYS):
        return None
    return manifest


def check_compat(manifest: dict, expect_config_hash: str | None,
                 expect_codec_version: int | None) -> None:
    """Refuse (raise `CheckpointMismatch`) when the caller expects a
    config/codec fingerprint and the manifest's differs or is absent."""
    if expect_config_hash is not None:
        got = manifest.get("config_hash")
        if got != expect_config_hash:
            raise CheckpointMismatch(
                f"checkpoint step {manifest.get('step')}: config hash "
                f"{got!r} != this run's {expect_config_hash!r} — the "
                "checkpoint belongs to a different run configuration; "
                "resume refused (use a fresh --checkpoint-dir or the "
                "original config)")
    if expect_codec_version is not None:
        got = manifest.get("codec_version")
        if got != int(expect_codec_version):
            raise CheckpointMismatch(
                f"checkpoint step {manifest.get('step')}: codec version "
                f"{got!r} != this build's {expect_codec_version!r} — "
                "serialized stream state is not portable across codec "
                "versions; resume refused")


def _manifest_files(directory: str) -> list[str]:
    return sorted(
        (f for f in os.listdir(directory)
         if f.startswith("step_") and f.endswith(".json")),
        key=lambda f: int(f.split("_")[1].split(".")[0]), reverse=True)


def valid_steps(directory: str,
                expect_config_hash: str | None = None,
                expect_codec_version: int | None = None) -> list[int]:
    """Steps whose manifest parses, matches the expected fingerprints,
    and whose archive hash verifies — the set a resume handshake may
    offer.  Ascending.  Torn entries are skipped; fingerprint mismatches
    raise `CheckpointMismatch` (refusal, not fallback)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for mf in _manifest_files(directory):
        base = os.path.join(directory, mf[:-5])
        manifest = _read_manifest(base + ".json")
        if manifest is None:
            continue
        check_compat(manifest, expect_config_hash, expect_codec_version)
        try:
            if _sha256(base + ".npz") != manifest["sha256"]:
                continue
        except OSError:
            continue
        steps.append(int(manifest["step"]))
    return sorted(steps)


def load_checkpoint(directory: str, template: Any,
                    step: int | None = None,
                    expect_config_hash: str | None = None,
                    expect_codec_version: int | None = None
                    ) -> tuple[int, Any, dict] | None:
    """Restore the newest (or given) valid checkpoint into the structure
    of `template`.  Returns (step, tree, extra) or None.  Torn archives
    and torn manifests are skipped (older steps tried next);
    config/codec fingerprint mismatches raise `CheckpointMismatch`."""
    if not os.path.isdir(directory):
        return None
    for mf in _manifest_files(directory):
        s = int(mf.split("_")[1].split(".")[0])
        if step is not None and s != step:
            continue
        base = os.path.join(directory, mf[:-5])
        manifest = _read_manifest(base + ".json")
        if manifest is None:
            continue                       # torn manifest — skip
        check_compat(manifest, expect_config_hash, expect_codec_version)
        try:
            if _sha256(base + ".npz") != manifest["sha256"]:
                continue                   # torn archive — skip
            data = np.load(base + ".npz")
            dtypes = manifest.get("dtypes") or [None] * manifest["n_leaves"]
            leaves = [_restore_leaf(data[f"leaf_{i}"], dtypes[i])
                      for i in range(manifest["n_leaves"])]
            treedef = jax.tree_util.tree_structure(template)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
            return manifest["step"], tree, manifest.get("extra", {})
        except (OSError, KeyError, ValueError):
            continue
    return None


class CheckpointManager:
    """keep-N rotation + async save + compatibility fingerprints.

    Args:
      directory: checkpoint root (one party/run per directory).
      keep: number of newest steps retained by rotation.
      config_hash / codec_version: when given, stamped into every saved
        manifest and *required* to match on `restore`/`steps` — a
        mismatched directory refuses with `CheckpointMismatch` instead
        of silently resuming an incompatible run.
    """

    def __init__(self, directory: str, keep: int = 3,
                 config_hash: str | None = None,
                 codec_version: int | None = None):
        self.directory = directory
        self.keep = keep
        self.config_hash = config_hash
        self.codec_version = codec_version
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()                             # never race a pending async
        tree = jax.tree.map(np.asarray, tree)   # device→host snapshot
        save_checkpoint(self.directory, step, tree, extra,
                        config_hash=self.config_hash,
                        codec_version=self.codec_version)
        self._gc()

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()
        tree = jax.tree.map(np.asarray, tree)   # snapshot BEFORE returning
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, tree, extra),
            kwargs={"config_hash": self.config_hash,
                    "codec_version": self.codec_version},
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            self._gc()

    def restore(self, template: Any, step: int | None = None):
        self.wait()
        return load_checkpoint(self.directory, template, step=step,
                               expect_config_hash=self.config_hash,
                               expect_codec_version=self.codec_version)

    def steps(self) -> list[int]:
        """Valid, compatible steps currently on disk (ascending)."""
        self.wait()
        return valid_steps(self.directory,
                           expect_config_hash=self.config_hash,
                           expect_codec_version=self.codec_version)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted({int(f.split("_")[1].split(".")[0])
                        for f in os.listdir(self.directory)
                        if f.startswith("step_") and f.endswith(".json")},
                       reverse=True)
        for s in steps[self.keep:]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory, f"step_{s}{ext}"))
                except OSError:
                    pass
