"""Fault-tolerant checkpointing."""
from repro.checkpoint.manager import (CheckpointManager, CheckpointMismatch,
                                      load_checkpoint, save_checkpoint,
                                      valid_steps)

__all__ = ["CheckpointManager", "CheckpointMismatch", "load_checkpoint",
           "save_checkpoint", "valid_steps"]
