"""Fault-tolerant checkpointing."""
import os

from repro.checkpoint.manager import (CheckpointManager, CheckpointMismatch,
                                      load_checkpoint, save_checkpoint,
                                      valid_steps)


def party_checkpoint_dir(root: str, name: str) -> str:
    """Canonical location of one party's TrainState-slice checkpoints
    under a run's checkpoint root.  One definition, three consumers:
    the party server writes here, the supervisor's handoff plan reads
    here, and the serving engine's hot model swap loads here."""
    return os.path.join(root, f"party_{name}")


__all__ = ["CheckpointManager", "CheckpointMismatch", "load_checkpoint",
           "save_checkpoint", "valid_steps", "party_checkpoint_dir"]
