"""Algorithm 1 — the EFMVFL training loop, as a thin wrapper over the
party runtime (`repro.runtime`).

The protocol itself lives in actor form: `runtime.party` actors own all
party-local state (features, weights, key pairs), `runtime.messages`
types carry every cross-party value, a `runtime.transport.Transport`
meters each message's `wire_bytes()` and counts communication rounds,
and `runtime.scheduler.VFLScheduler` conducts the phases.  The default
`LocalTransport` replays the original single-process simulation
bit-for-bit (losses, weights, per-tag comm bytes — asserted by
tests/test_runtime_parity.py); pass `PipelinedTransport` to overlap the
data-independent Protocol-3 legs.

Roles: party "C" holds the label; "B1".."Bk" are data providers.  Two
computing parties (CPs) hold all shares (paper §4.3); CP selection is
fixed (C, B1) by default, or uniformly random per iteration
(`cp_selection="random"`) as the paper suggests against CP collusion.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.core.comm import CommMeter
from repro.crypto import paillier


@dataclasses.dataclass
class VFLConfig:
    glm: str = "logistic"
    lr: float = 0.15
    max_iter: int = 30
    tol: float = 1e-4                 # |Δloss| stopping threshold
    batch_size: int = 2048
    f: int = 18                       # ring fractional bits (shared values)
    fx: int = 14                      # feature fixed-point bits
    exp_width: int = protocols.DEFAULT_EXP_BITS
    key_bits: int = 1024
    he_backend: str = "paillier"      # "paillier" | "mock"
    cp_selection: str = "fixed"       # "fixed" | "random"
    crypto_engine: str = "auto"       # "auto" | "jnp" | "pallas-interpret"
                                      # | "pallas" (see crypto.engine)
    seed: int = 0
    record_every: int = 1
    checkpoint_every: int = 0         # party-local checkpoint cadence in
                                      # iterations (0 = off); operational,
                                      # excluded from session.config_hash
    wire_compression: str = "none"    # socket-wire frame deflation:
                                      # "none" | "zlib" — LOSSLESS only,
                                      # validated by distributed.
                                      # compression.validate_wire_scheme;
                                      # below the metering boundary, so
                                      # also excluded from config_hash


@dataclasses.dataclass
class PartyData:
    name: str
    X: np.ndarray                     # (n, m_p) float


@dataclasses.dataclass
class TrainResult:
    weights: dict[str, np.ndarray]
    losses: list[float]
    meter: CommMeter
    runtime_s: float
    n_iter: int
    rounds: int = 0                   # communication rounds (transport count)

    def predict_wx(self, parties: Sequence[PartyData]) -> np.ndarray:
        # matvec_rowwise (not @): the one-shot scorer must agree
        # bit-for-bit with the micro-batched serving path
        return sum(glm_lib.matvec_rowwise(p.X, self.weights[p.name])
                   for p in parties)


def make_backend(cfg: VFLConfig, party_names: Sequence[str],
                 rng: np.random.Generator):
    if cfg.he_backend == "mock":
        return protocols.MockHEBackend(cfg.key_bits)
    from repro.crypto import engine as engine_mod
    keys = {p: paillier.keygen(cfg.key_bits, seed=int(rng.integers(2**31)))
            for p in party_names}
    return protocols.PaillierBackend(
        keys, rng, engine=engine_mod.make(cfg.crypto_engine))


def train_vfl(parties: list[PartyData], y: np.ndarray, cfg: VFLConfig,
              backend=None, transport=None) -> TrainResult:
    """parties[0] must be C (the label holder)."""
    from repro.runtime.scheduler import VFLScheduler
    sched = VFLScheduler(parties, y, cfg, backend=backend,
                         transport=transport)
    return sched.run()


# ---------------------------------------------------------------------------
# Centralized float oracle (same MacLaurin gradients — the quality target)
# ---------------------------------------------------------------------------

def train_centralized(X: np.ndarray, y: np.ndarray, cfg: VFLConfig
                      ) -> tuple[np.ndarray, list[float]]:
    """Same batch schedule and same pre-update loss semantics as Algorithm 1
    so the loss curves are directly comparable (paper Fig. 1)."""
    model = glm_lib.GLMS[cfg.glm]
    rng = np.random.default_rng(cfg.seed)
    w = np.zeros(X.shape[1])
    losses = []
    order = rng.permutation(len(X))
    cursor = 0
    for _ in range(cfg.max_iter):
        if cursor + cfg.batch_size > len(X):
            order = rng.permutation(len(X))
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        wx = X[idx] @ w
        d = model.d_float(wx, y[idx])
        losses.append(model.loss_float(wx, y[idx]))   # loss at current w
        w = w - cfg.lr * (X[idx].T @ d) / len(idx)
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            break
    return w, losses
