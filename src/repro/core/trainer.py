"""Algorithm 1 — the EFMVFL training loop (multi-party, simulation mode).

One process plays all parties; every cross-party value passes through the
CommMeter with real wire sizes, so communication results are exact.  The
same protocol code is re-targeted onto the production mesh by
launch/secure_dryrun.py (pod axis = party).

Roles: party "C" holds the label; "B1".."Bk" are data providers.  Two
computing parties (CPs) hold all shares (paper §4.3); CP selection is
fixed (C, B1) by default, or uniformly random per iteration
(`cp_selection="random"`) as the paper suggests against CP collusion.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from repro.core import glm as glm_lib
from repro.core import protocols
from repro.core.comm import CommMeter
from repro.crypto import fixed_point, paillier, ring
from repro.crypto.ring import R64
from repro.mpc import beaver, sharing


@dataclasses.dataclass
class VFLConfig:
    glm: str = "logistic"
    lr: float = 0.15
    max_iter: int = 30
    tol: float = 1e-4                 # |Δloss| stopping threshold
    batch_size: int = 2048
    f: int = 18                       # ring fractional bits (shared values)
    fx: int = 14                      # feature fixed-point bits
    exp_width: int = protocols.DEFAULT_EXP_BITS
    key_bits: int = 1024
    he_backend: str = "paillier"      # "paillier" | "mock"
    cp_selection: str = "fixed"       # "fixed" | "random"
    seed: int = 0
    record_every: int = 1


@dataclasses.dataclass
class PartyData:
    name: str
    X: np.ndarray                     # (n, m_p) float


@dataclasses.dataclass
class TrainResult:
    weights: dict[str, np.ndarray]
    losses: list[float]
    meter: CommMeter
    runtime_s: float
    n_iter: int

    def predict_wx(self, parties: Sequence[PartyData]) -> np.ndarray:
        return sum(p.X @ self.weights[p.name] for p in parties)


class _MeteredDealer:
    """Counts the online Beaver openings (2 values × 2 directions × 8B)."""

    def __init__(self, dealer, meter: CommMeter, a: str, b: str):
        self._dealer = dealer
        self._meter = meter
        self._a, self._b = a, b

    def elementwise(self, shape):
        n = int(np.prod(shape))
        self._meter.ring(self._a, self._b, "beaver_open", 2 * n)
        self._meter.ring(self._b, self._a, "beaver_open", 2 * n)
        return self._dealer.elementwise(shape)


def _share_to_cps(val: R64, owner: str, cps: tuple[str, str],
                  meter: CommMeter, key: jax.Array,
                  tag: str) -> tuple[R64, R64]:
    """Protocol 1 with CP routing (Algorithm 1 lines 7/15-16)."""
    s0, s1 = sharing.share(val, key)
    n = int(np.prod(val.lo.shape))
    if owner == cps[0]:
        meter.ring(owner, cps[1], tag, n)
    elif owner == cps[1]:
        meter.ring(owner, cps[0], tag, n)
    else:
        meter.ring(owner, cps[0], tag, n)
        meter.ring(owner, cps[1], tag, n)
    return s0, s1


def make_backend(cfg: VFLConfig, party_names: Sequence[str],
                 rng: np.random.Generator):
    if cfg.he_backend == "mock":
        return protocols.MockHEBackend(cfg.key_bits)
    keys = {p: paillier.keygen(cfg.key_bits, seed=int(rng.integers(2**31)))
            for p in party_names}
    return protocols.PaillierBackend(keys, rng)


def train_vfl(parties: list[PartyData], y: np.ndarray, cfg: VFLConfig,
              backend=None) -> TrainResult:
    """parties[0] must be C (the label holder)."""
    assert parties[0].name == "C"
    model = glm_lib.GLMS[cfg.glm]
    names = [p.name for p in parties]
    rng = np.random.default_rng(cfg.seed + 90001)   # protocol randomness
    batch_rng = np.random.default_rng(cfg.seed)     # batch schedule (matches
    jkey = jax.random.key(cfg.seed)                 # train_centralized)
    meter = CommMeter()
    if backend is None:
        backend = make_backend(cfg, names, rng)
    dealer = beaver.DealerTripleSource(seed=cfg.seed + 1)

    n_total = parties[0].X.shape[0]
    W = {p.name: np.zeros(p.X.shape[1]) for p in parties}
    feats = {p.name: protocols.EncodedFeatures.make(p.X, cfg.fx, cfg.exp_width)
             for p in parties}
    # v ≤ n·2^width·2^64 → mask bound for statistical hiding
    mask_bound = 64 + cfg.exp_width + int(np.ceil(np.log2(cfg.batch_size))) + 1
    if cfg.he_backend == "paillier":
        need = mask_bound + protocols.STAT_SEC + 2
        if cfg.key_bits < need:
            raise ValueError(f"key_bits={cfg.key_bits} too small; need >= {need}")

    losses: list[float] = []
    flag = False
    t0 = time.perf_counter()
    order = batch_rng.permutation(n_total)
    cursor = 0
    it = 0
    while it < cfg.max_iter and not flag:
        # -- iteration setup -------------------------------------------------
        if cursor + cfg.batch_size > n_total:
            order = batch_rng.permutation(n_total)
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        nb = len(idx)
        if cfg.cp_selection == "random":
            cp_idx = rng.choice(len(names), size=2, replace=False)
            cps = (names[cp_idx[0]], names[cp_idx[1]])
        else:
            cps = (names[0], names[1])
        jkey, *subkeys = jax.random.split(jkey, len(names) * 2 + 3)

        # -- Protocol 1: share intermediate results -------------------------
        z_shares = [None, None]
        ez_shares = None
        for i, p in enumerate(parties):
            zp = p.X[idx] @ W[p.name]
            s0, s1 = _share_to_cps(fixed_point.encode(zp, cfg.f), p.name,
                                   cps, meter, subkeys[i], "P1.z_share")
            z_shares[0] = s0 if z_shares[0] is None else ring.add(z_shares[0], s0)
            z_shares[1] = s1 if z_shares[1] is None else ring.add(z_shares[1], s1)
        y_shares = _share_to_cps(fixed_point.encode(y[idx], cfg.f), "C",
                                 cps, meter, subkeys[len(names)], "P1.y_share")
        mdealer = _MeteredDealer(dealer, meter, cps[0], cps[1])
        if model.needs_exp:
            for i, p in enumerate(parties):
                ezp = np.exp(np.clip(model.exp_sign * (p.X[idx] @ W[p.name]),
                                     -30, 8))
                es = _share_to_cps(fixed_point.encode(ezp, cfg.f), p.name,
                                   cps, meter,
                                   subkeys[len(names) + 1 + i], "P1.ez_share")
                if ez_shares is None:
                    ez_shares = es
                else:   # e^{Σz_p} = Π e^{z_p}: Beaver product + truncation
                    prod = beaver.mul(ez_shares, es, *mdealer.elementwise((nb,)))
                    from repro.mpc import truncation
                    ez_shares = truncation.trunc_pair(prod[0], prod[1], cfg.f)

        ctx = glm_lib.ShareCtx(z=tuple(z_shares), y=y_shares, ez=ez_shares,
                               f=cfg.f, dealer=mdealer)

        # -- Protocol 2: gradient-operator on shares ------------------------
        d0, d1 = model.gradient_operator(ctx)

        # -- Protocol 3: secure gradients ------------------------------------
        # CPs encrypt their d-share under their own key, exchange/broadcast.
        ct0 = backend.encrypt_share(cps[0], d0)
        ct1 = backend.encrypt_share(cps[1], d1)
        meter.cipher(cps[1], cps[0], "P3.enc_d", nb, backend.key_bits(cps[1]))
        meter.cipher(cps[0], cps[1], "P3.enc_d", nb, backend.key_bits(cps[0]))
        grads: dict[str, R64] = {}
        grads[cps[0]] = protocols.secure_gradient_cp(
            backend, meter, p0=cps[0], p1=cps[1],
            feats=_slice_feats(feats[cps[0]], idx),
            d_self=d0, d_other_ct=ct1, d_other_share=d1,
            mask_bound_bits=mask_bound, rng=rng)
        grads[cps[1]] = protocols.secure_gradient_cp(
            backend, meter, p0=cps[1], p1=cps[0],
            feats=_slice_feats(feats[cps[1]], idx),
            d_self=d1, d_other_ct=ct0, d_other_share=d0,
            mask_bound_bits=mask_bound, rng=rng)
        for p in parties:
            if p.name in cps:
                continue
            meter.cipher(cps[0], p.name, "P3.enc_d_bcast", nb,
                         backend.key_bits(cps[0]))
            meter.cipher(cps[1], p.name, "P3.enc_d_bcast", nb,
                         backend.key_bits(cps[1]))
            grads[p.name] = protocols.secure_gradient_noncp(
                backend, meter, party=p.name, cps=cps,
                feats=_slice_feats(feats[p.name], idx),
                d_cts={cps[0]: ct0, cps[1]: ct1},
                d_shares={cps[0]: d0, cps[1]: d1},
                mask_bound_bits=mask_bound, rng=rng)

        # -- local weight update (eq. 6; 1/m applied at reveal) --------------
        for p in parties:
            g = fixed_point.decode(grads[p.name], cfg.fx + cfg.f) / nb
            W[p.name] = W[p.name] - cfg.lr * g

        # -- Protocol 4: secure loss -----------------------------------------
        l0, l1 = model.loss_shares(ctx)
        meter.ring(cps[1], cps[0], "P4.loss_share", 1)
        if cps[0] != "C":           # loss must reach C (Protocol 4 line 3)
            meter.ring(cps[0], "C", "P4.loss_share", 1)
        revealed = float(fixed_point.decode(sharing.reconstruct(l0, l1), cfg.f))
        loss = model.finalize_loss(revealed, y[idx], nb)
        losses.append(loss)

        # -- stop flag --------------------------------------------------------
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            flag = True
        for p in names[1:]:
            meter.add("C", p, "flag", 1)
        it += 1

    return TrainResult(weights=W, losses=losses, meter=meter,
                       runtime_s=time.perf_counter() - t0, n_iter=it)


def _slice_feats(f: protocols.EncodedFeatures, idx) -> protocols.EncodedFeatures:
    return protocols.EncodedFeatures(
        x_int=f.x_int[idx], exps=f.exps[idx], fx=f.fx, width=f.width)


# ---------------------------------------------------------------------------
# Centralized float oracle (same MacLaurin gradients — the quality target)
# ---------------------------------------------------------------------------

def train_centralized(X: np.ndarray, y: np.ndarray, cfg: VFLConfig
                      ) -> tuple[np.ndarray, list[float]]:
    """Same batch schedule and same pre-update loss semantics as Algorithm 1
    so the loss curves are directly comparable (paper Fig. 1)."""
    model = glm_lib.GLMS[cfg.glm]
    rng = np.random.default_rng(cfg.seed)
    w = np.zeros(X.shape[1])
    losses = []
    order = rng.permutation(len(X))
    cursor = 0
    for _ in range(cfg.max_iter):
        if cursor + cfg.batch_size > len(X):
            order = rng.permutation(len(X))
            cursor = 0
        idx = order[cursor:cursor + cfg.batch_size]
        cursor += cfg.batch_size
        wx = X[idx] @ w
        d = model.d_float(wx, y[idx])
        losses.append(model.loss_float(wx, y[idx]))   # loss at current w
        w = w - cfg.lr * (X[idx].T @ d) / len(idx)
        if len(losses) > 1 and abs(losses[-1] - losses[-2]) < cfg.tol:
            break
    return w, losses
