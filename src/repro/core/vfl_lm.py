"""Federated GLM head over LM backbones — EFMVFL as a first-class feature
of the LM framework (DESIGN.md §4).

Each party runs its own backbone over its private inputs (text tokens,
audio frames, image patches, or plain tabular features via the identity
backbone), pools the final hidden states, and the pooled representations
X_p feed the paper's protocols: the per-party head weights W_p train
against C's labels with secret-shared intermediates + HE gradients — no
third party, and no raw representations ever leave a party.

The paper's tabular setting is exactly `identity_backbone`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, trainer
from repro.core.trainer import PartyData, TrainResult, VFLConfig


@dataclasses.dataclass
class BackboneParty:
    name: str
    extract: Callable[[np.ndarray], np.ndarray]   # raw inputs -> (n, d_p)
    inputs: np.ndarray


def identity_backbone(x: np.ndarray) -> np.ndarray:
    return x


def make_lm_backbone(api, params, batch_size: int = 16) -> Callable:
    """Pooled final-hidden-state extractor for a (dense-family) registry
    ModelAPI.  Representations are computed locally by the owning party."""
    pool = jax.jit(lambda toks: _embed_pool(api, params, toks))

    def extract(tokens: np.ndarray) -> np.ndarray:
        outs = []
        for i in range(0, len(tokens), batch_size):
            h = pool(jnp.asarray(tokens[i:i + batch_size]))
            outs.append(np.asarray(h, np.float64))
        return np.concatenate(outs, 0)

    return extract


def _embed_pool(api, params, tokens):
    """Mean-pooled final hidden states (family-dispatched)."""
    from repro.models import transformer
    cfg = api.cfg
    meta = transformer.layer_meta(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, xs):
        x, aux = carry
        p, window, theta = xs
        x, _, aux_i = transformer._block(cfg, p, x, positions, window,
                                         theta, None, None)
        return (x, aux + aux_i), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             (params["layers"], jnp.asarray(meta["window"]),
                              jnp.asarray(meta["theta"])))
    from repro.models import layers as L
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x.mean(axis=1).astype(jnp.float32)


def standardize(reps: np.ndarray) -> np.ndarray:
    mu = reps.mean(0, keepdims=True)
    sd = reps.std(0, keepdims=True) + 1e-6
    return np.clip((reps - mu) / sd, -8, 8)


def train_federated_head(parties: list[BackboneParty], y: np.ndarray,
                         cfg: VFLConfig) -> tuple[TrainResult, dict]:
    """Extract per-party representations locally, then run Algorithm 1."""
    reps = {p.name: standardize(p.extract(p.inputs)) for p in parties}
    vfl_parties = [PartyData(p.name, reps[p.name]) for p in parties]
    res = trainer.train_vfl(vfl_parties, y, cfg)
    wx = res.predict_wx(vfl_parties)
    quality = {"train_auc": metrics.auc(y, wx)} \
        if cfg.glm == "logistic" else {}
    return res, quality
