"""Communication metering — reproduces the paper's `comm` columns.

Every protocol send is routed through a CommMeter; sizes use the wire
format a real deployment serializes (8-byte ring elements, canonical
2*key_bits-bit Paillier ciphertexts, 1-byte flags).
"""
from __future__ import annotations

import collections
import dataclasses

RING_BYTES = 8
FLAG_BYTES = 1


def ciphertext_wire_bytes(key_bits: int) -> int:
    """Serialized size of ONE canonical Z_{n²} ciphertext: ⌈2·key_bits/8⌉.
    The single source of truth — `runtime.messages`, the meter helper
    below, and `crypto.paillier` all delegate here so the analytic
    accounting can never disagree with what `runtime.codec` frames."""
    return (2 * key_bits + 7) // 8


@dataclasses.dataclass
class Send:
    src: str
    dst: str
    tag: str
    nbytes: int


class CommMeter:
    def __init__(self) -> None:
        self.sends: list[Send] = []
        self.by_tag: dict[str, int] = collections.defaultdict(int)

    def add(self, src: str, dst: str, tag: str, nbytes: int) -> None:
        self.sends.append(Send(src, dst, tag, int(nbytes)))
        self.by_tag[tag] += int(nbytes)

    def ring(self, src: str, dst: str, tag: str, n_elems: int) -> None:
        self.add(src, dst, tag, n_elems * RING_BYTES)

    def cipher(self, src: str, dst: str, tag: str, n_cts: int,
               key_bits: int) -> None:
        self.add(src, dst, tag, n_cts * ciphertext_wire_bytes(key_bits))

    @property
    def total_bytes(self) -> int:
        return sum(s.nbytes for s in self.sends)

    @property
    def total_mb(self) -> float:
        return self.total_bytes / 1e6

    def summary(self) -> dict[str, float]:
        out = {k: v / 1e6 for k, v in sorted(self.by_tag.items())}
        out["TOTAL_MB"] = self.total_mb
        return out
