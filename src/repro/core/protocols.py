"""EFMVFL Protocols 2–4 (Protocol 1 lives in mpc.sharing).

The crown jewel is Protocol 3 (secure gradient computing): the non-local
share of g_p = X_p^T d is evaluated under the *other* party's Paillier key
as a plaintext-matrix × ciphertext-vector product, masked, decrypted by
the key owner and unmasked locally — "only one product between plaintext
matrix and ciphertext vector for each party in each iteration" (paper §5.3).

Engineering notes (DESIGN.md §7):

* Exponent offset trick: X's signed fixed-point entries are lifted by
  OFF = 2^{w−1} so every HE exponent is a short non-negative integer
  (w ≈ 22 bits instead of 64): the key owner removes the OFF·Σ⟨d⟩ term
  *locally* after decryption since it knows its own d-share.  This is a
  beyond-paper micro-optimization (≈3× fewer Montgomery ops) that changes
  no message flow.
* Exact mod-2^64 semantics: all Z_n values stay non-negative integers
  < n, so reducing decrypted integers mod 2^64 recovers ring shares
  exactly (Paillier plaintext wrap never triggers).
* `MockHEBackend` carries the identical mod-2^64 values without
  encryption and meters identical wire bytes — used for large-scale
  benchmarks; `tests/test_protocols.py` asserts mock ≡ Paillier bit-for-bit.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint, fixed_point, paillier, prng, ring
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import mont_mul, mont_one
from repro.crypto.ring import R64

_U32 = jnp.uint32

DEFAULT_EXP_BITS = 22   # fixed-point feature width + sign headroom
STAT_SEC = 40           # statistical masking security (bits)


# ---------------------------------------------------------------------------
# HE matvec:  out_j = ⊕_i  (cts_i ⊗ exps[i, j])      (Protocol 3 line 4)
# ---------------------------------------------------------------------------

def _tree_hom_prod(c: jnp.ndarray, mod) -> jnp.ndarray:
    """⊕-reduce axis 0 of Montgomery-domain ciphertexts (log-depth)."""
    while c.shape[0] > 1:
        half = c.shape[0] // 2
        merged = mont_mul(c[:half], c[half:2 * half], mod)
        if c.shape[0] % 2:
            merged = jnp.concatenate([merged, c[2 * half:]], axis=0)
        c = merged
    return c[0]


DEFAULT_WINDOW = 4      # fixed-window exponentiation (§Perf: 3.7× fewer
                        # Montgomery products than bit-serial at w=22)


def window_digits(exps, width: int, window: int):
    """MSB-first fixed-window digit decomposition: (…, levels) values in
    [0, 2^window).  Works on numpy (EncodedFeatures precompute) and jnp
    (traced fallback) arrays alike."""
    levels = -(-width // window)
    mask = (1 << window) - 1
    cols = [(exps >> ((levels - 1 - lvl) * window)) & mask
            for lvl in range(levels)]
    stack = np.stack if isinstance(exps, np.ndarray) else jnp.stack
    return stack(cols, axis=-1)


@functools.partial(jax.jit, static_argnums=(0, 3))
def _he_matvec_bitserial(pub_static, cts, exps, width):
    pub = pub_static.pub
    mod = pub.mod_n2
    bits = fixed_point.int_bits_msb(exps, width)          # (n, m, w)
    one = jnp.broadcast_to(mont_one(mod), cts.shape)       # (n, L2)
    m = exps.shape[1]
    acc0 = jnp.broadcast_to(mont_one(mod), (m, mod.L))

    def step(acc, bits_t):                                # bits_t: (n, m)
        acc = mont_mul(acc, acc, mod)
        sel = jnp.where(bits_t[..., None] == 1,
                        cts[:, None, :], one[:, None, :])  # (n, m, L2)
        prod = _tree_hom_prod(sel, mod)                    # (m, L2)
        return mont_mul(acc, prod, mod), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    return acc


@functools.partial(jax.jit, static_argnums=(0, 3))
def _he_matvec_windowed(pub_static, cts, digits, window):
    """Fixed-window ladder: precompute c_i^j for j<2^window once per row,
    then one gather + tree-⊕ per digit level.  Montgomery-product count:
      n·(2^w − 2)  precompute  +  levels·(n·m tree + w·m squarings)
    vs bit-serial  width·(n·m + 2m) — ≈ window× fewer in the n·m term.
    `digits`: (n, m, levels) MSB-first window digits (precomputed once
    per batch by EncodedFeatures.make)."""
    pub = pub_static.pub
    mod = pub.mod_n2
    n, L2 = cts.shape
    m = digits.shape[1]
    # power table: (2^w, n, L2)
    table = [jnp.broadcast_to(mont_one(mod), cts.shape), cts]
    for _ in range(2, 1 << window):
        table.append(mont_mul(table[-1], cts, mod))
    table = jnp.stack(table, axis=0)

    acc0 = jnp.broadcast_to(mont_one(mod), (m, mod.L))

    def step(acc, digits_lvl):                            # (n, m)
        for _ in range(window):
            acc = mont_mul(acc, acc, mod)
        # gather c_i^{digit}: (n, m, L2)
        sel = jnp.take_along_axis(
            table[:, :, None, :], digits_lvl[None, :, :, None], axis=0)[0]
        prod = _tree_hom_prod(sel, mod)
        return mont_mul(acc, prod, mod), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(digits, -1, 0))
    return acc


class _HashablePub:
    """Hashable wrapper so the public key can be a static jit arg."""

    def __init__(self, pub: paillier.PublicKey):
        self.pub = pub

    def __hash__(self):
        return hash(self.pub.n)

    def __eq__(self, other):
        return isinstance(other, _HashablePub) and other.pub.n == self.pub.n


def he_matvec(pub: paillier.PublicKey, cts: jnp.ndarray,
              exps: jnp.ndarray, width: int,
              window: int = DEFAULT_WINDOW, *,
              digits=None, engine=None) -> jnp.ndarray:
    """cts: (n, L2) Montgomery ciphertexts; exps: (n, m) uint32 < 2^width.
    Returns (m, L2) ciphertexts of Σ_i exps[i,j]·m_i (integer, no wrap).
    window=1 → bit-serial ladder; window=4 (default) → fixed-window.
    `digits` may carry the precomputed MSB-first window decomposition
    (EncodedFeatures.digits, derived at DEFAULT_WINDOW); it is used only
    for window=DEFAULT_WINDOW with a matching level count, else
    re-derived.  `engine` (default: the
    process engine) routes the ladder to the fused Pallas kernel or the
    jnp library — bit-identical either way."""
    eng = engine if engine is not None else engine_mod.get_engine()
    # Route through the engine when it has somewhere better to go than
    # the jitted library ladders here: a mesh, the RNS pipeline (either
    # form), or the CIOS kernel where engine._route actually selects it
    # (compiled backend, or an explicitly pinned pipeline).  Interpret-
    # mode small-modulus ops stay on the library path — never slower
    # than the library (kernel_bench guard rows).
    route = None if eng.sharded else eng._route(pub.mod_n2)
    engine_routed = (eng.sharded or route in ("rns", "rns-jnp")
                     or (eng.uses_kernels and route == "cios"))
    if window <= 1:
        if engine_routed:
            bits = fixed_point.int_bits_msb(exps.astype(_U32), width)
            return eng.he_matvec_windowed(cts, bits, pub.mod_n2, 1)
        return _he_matvec_bitserial(_HashablePub(pub), cts,
                                    exps.astype(_U32), width)
    # precomputed digits are the DEFAULT_WINDOW decomposition — a level-
    # count match alone can coincide across windows, so key on the window
    if digits is None or window != DEFAULT_WINDOW \
            or digits.shape[-1] != -(-width // window):
        digits = window_digits(exps.astype(_U32), width, window)
    if engine_routed:
        return eng.he_matvec_windowed(cts, digits, pub.mod_n2, window)
    return _he_matvec_windowed(_HashablePub(pub), cts,
                               jnp.asarray(digits, _U32), window)


# ---------------------------------------------------------------------------
# HE backends
# ---------------------------------------------------------------------------

class PaillierBackend:
    """Real Paillier (128…2048-bit keys).  Each party owns a keypair.

    All hot loops dispatch through `engine` (None → the process default
    CryptoEngine, i.e. fused Pallas kernels on TPU, jnp library on CPU).

    Noise pool: the encryption-noise modexps r^n mod n² are data-
    independent, so once `attach_noise_executor` hands the backend a
    thread pool (the runtime scheduler wires the PipelinedTransport's
    pool), `prefetch_noise` draws r synchronously (keeping the entropy
    stream deterministic) and runs the expensive modexp off-thread,
    overlapped with the Protocol-3 legs.  Consumers match pooled batches
    by (party, count); a miss falls back to the synchronous path, so the
    pool is purely a scheduling optimization — masks cancel exactly and
    noise never reaches a decrypted value, hence the trained model is
    bit-identical with or without it (tests/test_engine.py).

    Fixed-base tables: `attach_table` (or a `PrivateKey.noise_table`
    from `keygen(table_path=…)`, picked up automatically) switches a
    party's noise to the DJN short-exponent form h^ρ evaluated from the
    persistent table (`crypto.fixed_base`) — ~24× cheaper per batch at
    1024-bit keys.  Both the prefetch path and the synchronous fallback
    use the table; masks still cancel exactly, so trained models remain
    bit-identical across noise forms."""

    name = "paillier"

    def __init__(self, keys: dict[str, paillier.PrivateKey],
                 rng: np.random.Generator, engine=None):
        self.keys = keys
        self.rng = rng
        self.engine = engine
        self._noise: dict[str, collections.deque] = collections.defaultdict(
            collections.deque)
        self._noise_lock = threading.Lock()
        self._noise_exec = None
        # fixed-base noise tables per party — seed from any keys that
        # were generated with keygen(table_path=…)
        self.tables: dict[str, object] = {}
        for party, key in keys.items():
            table = getattr(key, "noise_table", None)
            if table is not None:
                self.tables[party] = table

    def key_bits(self, party: str) -> int:
        return self.keys[party].pub.key_bits

    # -- noise pool ---------------------------------------------------------
    def attach_noise_executor(self, executor) -> None:
        self._noise_exec = executor

    def attach_table(self, party: str, table) -> None:
        """Route `party`'s encryption noise through a persistent fixed-
        base table (fingerprint-checked against the party's key)."""
        from repro.crypto import fixed_base
        pub = self.keys[party].pub
        if table.fingerprint != fixed_base.key_fingerprint(pub.n):
            raise fixed_base.TableMismatchError(
                f"table fingerprint does not match {party!r}'s public key")
        self.tables[party] = table

    def attach_tables(self, tables: dict) -> None:
        for party, table in tables.items():
            self.attach_table(party, table)

    def _noise_job(self, party: str, count: int):
        """Draw the randomness for `count` noises synchronously (the
        entropy stream stays deterministic) and return the deferred
        compute closure: table-backed h^ρ when a table is attached, the
        r^n ladder otherwise."""
        pub = self.keys[party].pub
        table = self.tables.get(party)
        if table is not None:
            from repro.crypto import fixed_base
            digits = fixed_base.draw_exponent_digits(table, count, self.rng)
            return (paillier.noise_from_table, pub, table, digits,
                    self.engine)
        raw = paillier.raw_noise(pub, count, self.rng)
        return (paillier.noise_to_mont, pub, raw, self.engine)

    def prefetch_noise(self, party: str, count: int) -> None:
        """Schedule `count` fresh encryption noises under `party`'s key."""
        if self._noise_exec is None or count <= 0:
            return
        fn, *args = self._noise_job(party, count)
        fut = self._noise_exec.submit(fn, *args)
        with self._noise_lock:
            self._noise[party].append((count, fut))

    def _pooled_noise(self, party: str, count: int):
        """Pop a prefetched r^n batch of exactly `count` rows, or None."""
        with self._noise_lock:
            q = self._noise[party]
            for i, (c, fut) in enumerate(q):
                if c == count:
                    del q[i]
                    break
            else:
                return None
        return fut.result()

    def discard_pooled_noise(self) -> None:
        """Drop any unconsumed prefetched batches (the scheduler calls
        this at iteration end so a prefetch/consumption count drift can
        never grow the pool unboundedly — it just wastes one batch and
        the next consumer falls back to the synchronous path)."""
        with self._noise_lock:
            self._noise.clear()

    def _encrypt(self, pub, m_limbs, party: str, count: int) -> jnp.ndarray:
        rn = self._pooled_noise(party, count)
        if rn is None:                          # pool miss: compute inline
            fn, *args = self._noise_job(party, count)
            rn = fn(*args)
        m = jnp.asarray(m_limbs, _U32)
        rn = jnp.asarray(rn, _U32).reshape(m.shape[:-1] + (pub.Ln2,))
        return paillier.encrypt_with_noise(pub, m, rn, self.engine)

    # -- protocol ops -------------------------------------------------------
    def encrypt_share(self, party: str, d: R64) -> jnp.ndarray:
        pub = self.keys[party].pub
        m = fixed_point.r64_to_limbs(d, pub.Ln)
        count = int(np.prod(m.shape[:-1])) if m.ndim > 1 else 1
        return self._encrypt(pub, m, party, count)

    def matvec(self, party: str, cts, exps, width, digits=None
               ) -> jnp.ndarray:
        return he_matvec(self.keys[party].pub, cts, exps, width,
                         digits=digits, engine=self.engine)

    def add_mask(self, party: str, cts, mask_ints: list[int]) -> jnp.ndarray:
        """cts ⊕ Enc(R) with fresh noise — masks AND re-randomizes."""
        pub = self.keys[party].pub
        m = bigint.ints_to_limbs(mask_ints, pub.Ln)
        cr = self._encrypt(pub, m, party, len(mask_ints))
        return paillier.add_ct(pub, cts, cr, self.engine)

    def decrypt_to_r64(self, party: str, cts) -> R64:
        key = self.keys[party]
        if not hasattr(key, "lam"):     # paillier.PeerKey: public half only
            raise PermissionError(
                f"cannot decrypt under {party!r}: this backend view holds "
                "only the peer's public key (distributed runtime)")
        dec = paillier.decrypt_crt(key, cts, engine=self.engine)
        return fixed_point.limbs_to_r64(dec)


class MockHEBackend:
    """Carries the identical mod-2^64 integers without encryption (for
    large benchmarks).  Message flow, masking and byte accounting are
    identical to PaillierBackend; tests assert value-equality."""

    name = "mock"

    def __init__(self, key_bits: int = 1024):
        self._key_bits = key_bits

    def key_bits(self, party: str) -> int:
        return self._key_bits

    def encrypt_share(self, party: str, d: R64) -> R64:
        return d

    def matvec(self, party: str, cts: R64, exps, width, digits=None) -> R64:
        xs = exps.astype(_U32)
        xa = R64(jnp.zeros_like(xs), xs)                 # lift u32 exponents
        # (n, m) exps × (n,) cts -> (m,)
        prod = ring.mul(xa, R64(cts.hi[:, None], cts.lo[:, None]))
        return ring.sum_axis(prod, 0)

    def add_mask(self, party: str, cts: R64, mask_ints: list[int]) -> R64:
        m = ring.from_numpy_u64(
            np.array([v % (1 << 64) for v in mask_ints], np.uint64))
        return ring.add(cts, m)

    def decrypt_to_r64(self, party: str, cts: R64) -> R64:
        return cts


# ---------------------------------------------------------------------------
# Protocol 3 — secure gradient computing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncodedFeatures:
    """A party's local features in protocol form."""
    x_int: np.ndarray        # (n, m_p) int32 signed fixed-point
    exps: np.ndarray         # (n, m_p) uint32 = x_int + OFF
    fx: int
    width: int
    digits: np.ndarray | None = None  # (n, m_p, levels) MSB-first window
                                      # digits at DEFAULT_WINDOW — derived
                                      # once here, sliced per batch, so
                                      # he_matvec never re-decomposes

    @staticmethod
    def make(x: np.ndarray, fx: int, width: int = DEFAULT_EXP_BITS):
        xi = np.rint(np.asarray(x, np.float64) * (1 << fx)).astype(np.int64)
        off = 1 << (width - 1)
        if np.any(np.abs(xi) >= off):
            raise ValueError("feature fixed-point exceeds exponent width; "
                             "raise width or normalize features")
        exps = (xi + off).astype(np.uint32)
        return EncodedFeatures(
            x_int=xi.astype(np.int32),
            exps=exps,
            fx=fx, width=width,
            digits=window_digits(exps, width, DEFAULT_WINDOW)
            .astype(np.uint32))

    def slice(self, idx) -> "EncodedFeatures":
        return EncodedFeatures(
            x_int=self.x_int[idx], exps=self.exps[idx],
            fx=self.fx, width=self.width,
            digits=None if self.digits is None else self.digits[idx])


def mask_ints(bound_bits: int, m: int, rng: np.random.Generator) -> list[int]:
    """Statistical masks R_j uniform in [0, 2^(bound_bits + STAT_SEC))."""
    return prng.host_uniform_below(1 << (bound_bits + STAT_SEC), m, rng=rng)


def offset_correction(d_share: R64, width: int) -> R64:
    """OFF · Σ_i ⟨d⟩_i  mod 2^64 — the key owner's local correction."""
    s = ring.sum_axis(d_share, 0)
    return ring.mul_pub_int(s, 1 << (width - 1))


def mask_to_r64(R: list[int]) -> R64:
    """The mask owner's local mod-2^64 image of R (for unmasking)."""
    return ring.from_numpy_u64(np.array([r % (1 << 64) for r in R],
                                        np.uint64))


# --- per-party steps (pure: no metering — byte accounting happens at the
# transport boundary via runtime.messages.Message.wire_bytes()) ------------

def local_grad_share(feats: EncodedFeatures, d_self: R64) -> R64:
    """Protocol 3 line 2 — a CP's local term X_p^T ⟨d⟩_p."""
    return _from_col(ring.matmul(jnp.asarray(feats.x_int.T),
                                 _as_col(d_self)))


def masked_matvec(backend, key_owner: str, d_ct, feats: EncodedFeatures,
                  mask_bound_bits: int, rng: np.random.Generator):
    """Protocol 3 lines 4–6 at the feature owner: plaintext-matrix ×
    ciphertext-vector under `key_owner`'s key, statistically masked and
    re-randomized.  Returns (enc_masked, R_mod264) — the caller ships
    enc_masked as a `P3.masked_grad` message and keeps R for unmasking."""
    m = feats.exps.shape[1]
    enc_g = backend.matvec(key_owner, d_ct, jnp.asarray(feats.exps),
                           feats.width, digits=feats.digits)
    R = mask_ints(mask_bound_bits, m, rng)
    return backend.add_mask(key_owner, enc_g, R), mask_to_r64(R)


def decrypt_offset_corrected(backend, key_owner: str, enc_masked,
                             d_own: R64, width: int) -> R64:
    """Protocol 3 line 7 at the key owner: decrypt, reduce mod 2^64,
    remove the OFF·Σ⟨d⟩ exponent-lift term (local: it knows its d-share).
    The result goes back as a `P3.unmasked_share` message."""
    w = backend.decrypt_to_r64(key_owner, enc_masked)
    return ring.sub(w, offset_correction(d_own, width))


# --- whole-protocol compositions (simulation evaluates both parties'
# local steps in one call; tests and oracles use these) --------------------

def secure_gradient_cp(
    backend, *,
    p0: str, p1: str,
    feats: EncodedFeatures,
    d_self: R64,                  # ⟨d⟩_{p0}, held by p0
    d_other_ct,                   # [[⟨d⟩_{p1}]]_{p1}, received from p1
    d_other_share: R64,           # ⟨d⟩_{p1} (used only for p1's local step)
    mask_bound_bits: int,
    rng: np.random.Generator,
) -> R64:
    """Protocol 3 with P0 = a computing party.  Returns g_{p0} as ring
    fixed-point with (fx + f) fractional bits."""
    g_self = local_grad_share(feats, d_self)
    enc_masked, Rr = masked_matvec(backend, p1, d_other_ct, feats,
                                   mask_bound_bits, rng)
    w = decrypt_offset_corrected(backend, p1, enc_masked, d_other_share,
                                 feats.width)
    return ring.sub(ring.add(g_self, w), Rr)


def secure_gradient_noncp(
    backend, *,
    party: str, cps: tuple[str, str],
    feats: EncodedFeatures,
    d_cts: dict,                  # {cp: [[⟨d⟩_cp]]_cp} received broadcasts
    d_shares: dict,               # {cp: ⟨d⟩_cp} (for each CP's local step)
    mask_bound_bits: int,
    rng: np.random.Generator,
) -> R64:
    """Algorithm 1 lines 17–21: a non-computing party computes its gradient
    under BOTH CPs' keys.  g_p = Σ_cp (dec_cp − R_cp-correction)."""
    m = feats.exps.shape[1]
    total = ring.zeros((m,))
    for cp in cps:
        enc_masked, Rr = masked_matvec(backend, cp, d_cts[cp], feats,
                                       mask_bound_bits, rng)
        w = decrypt_offset_corrected(backend, cp, enc_masked, d_shares[cp],
                                     feats.width)
        total = ring.add(total, ring.sub(w, Rr))
    return total


def _as_col(d: R64) -> R64:
    return R64(d.hi[:, None], d.lo[:, None])


def _from_col(g: R64) -> R64:
    return R64(g.hi[:, 0], g.lo[:, 0])
