"""EFMVFL core: the paper's protocols, GLM family and training loop."""
from repro.core import comm, glm, metrics, protocols, trainer

__all__ = ["comm", "glm", "metrics", "protocols", "trainer"]
