"""Evaluation metrics matching the paper's tables (AUC, KS, MAE, RMSE)."""
from __future__ import annotations

import numpy as np


def auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (Mann–Whitney).  y_true in {−1,+1} or {0,1}."""
    y = (np.asarray(y_true) > 0).astype(np.int64)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ranks for ties
    s_sorted = np.asarray(scores)[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    n_pos = y.sum()
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def ks(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Kolmogorov–Smirnov statistic: max |TPR − FPR| over thresholds."""
    y = (np.asarray(y_true) > 0).astype(np.int64)
    order = np.argsort(-scores)
    y_sorted = y[order]
    tpr = np.cumsum(y_sorted) / max(1, y_sorted.sum())
    fpr = np.cumsum(1 - y_sorted) / max(1, (1 - y_sorted).sum())
    return float(np.max(np.abs(tpr - fpr)))


def mae(y_true: np.ndarray, pred: np.ndarray) -> float:
    return float(np.mean(np.abs(np.asarray(y_true) - np.asarray(pred))))


def rmse(y_true: np.ndarray, pred: np.ndarray) -> float:
    return float(np.sqrt(np.mean((np.asarray(y_true) - np.asarray(pred)) ** 2)))
