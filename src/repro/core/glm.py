"""Generalized linear models — the paper's model family (§3.3, §4.2).

Each GLM supplies
  * the gradient-operator `d` on secret shares (paper eq. 7 / 8): the part
    of eq. (5) that must be computed jointly,
  * the loss on shares (paper eq. 1 / 3, MacLaurin where the paper does),
  * float-domain oracles (centralized training) for tests/benchmarks,
  * the inverse link for prediction.

Share-domain convention: all shared values carry `f` fractional bits; the
1/m factor and fixed-point scaling are applied after gradient/loss values
are *revealed to their owner* (exact, public constants).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.crypto import ring
from repro.crypto.ring import R64
from repro.mpc import beaver, sharing, truncation


@dataclasses.dataclass(frozen=True)
class ShareCtx:
    """Both CPs' shares of the Protocol-1 outputs, plus triple source.
    Simulation mode: index 0 == party C's share, 1 == party B1's."""
    z: tuple[R64, R64]                 # shares of WX = sum_p W_p X_p
    y: tuple[R64, R64] | None          # shares of the label (C shared it)
    ez: tuple[R64, R64] | None         # shares of e^{WX} (Poisson only)
    f: int                             # fractional bits
    dealer: beaver.DealerTripleSource


def _shift(shares: tuple[R64, R64], s: int) -> tuple[R64, R64]:
    """Multiply the shared value by 2^-s (probabilistic truncation)."""
    return truncation.trunc_pair(shares[0], shares[1], s)


# ---------------------------------------------------------------------------
# Logistic regression (paper eq. 1, 2, 7) — Y ∈ {−1, +1}
# ---------------------------------------------------------------------------

def lr_gradient_operator(ctx: ShareCtx) -> tuple[R64, R64]:
    """d = 0.25*WX − 0.5*Y (MacLaurin, eq. 7; 1/m deferred to reveal)."""
    qz = _shift(ctx.z, 2)
    hy = _shift(ctx.y, 1)
    return (ring.sub(qz[0], hy[0]), ring.sub(qz[1], hy[1]))


def lr_loss_shares(ctx: ShareCtx) -> tuple[R64, R64]:
    """Σ_i ln(1+e^{−t}) with t=Y·WX, 2nd-order MacLaurin:
    ln2 − t/2 + t²/8 (same approximation family the paper uses)."""
    n = ctx.z[0].lo.shape[0]
    t = beaver.mul(ctx.y, ctx.z, *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    t = _shift(t, ctx.f)
    t2 = beaver.mul(t, t, *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    t2 = _shift(t2, ctx.f)
    half_t = truncation.trunc_pair(t[0], t[1], 1)
    eighth_t2 = truncation.trunc_pair(t2[0], t2[1], 3)
    li = (ring.sub(eighth_t2[0], half_t[0]), ring.sub(eighth_t2[1], half_t[1]))
    s0 = ring.sum_axis(li[0], 0)
    s1 = ring.sum_axis(li[1], 0)
    ln2 = ring.from_signed_f64(np.float64(n * math.log(2.0)), ctx.f)
    s0 = ring.add(s0, ln2)   # public constant: party 0 adds
    return s0, s1


# ---------------------------------------------------------------------------
# Poisson regression (paper eq. 3, 4, 8)
# ---------------------------------------------------------------------------

def pr_gradient_operator(ctx: ShareCtx) -> tuple[R64, R64]:
    """d = e^{WX} − Y (eq. 8).  e^{WX} shares come from Protocol 1
    (parties share local e^{W_p X_p}; products via Beaver, see trainer)."""
    assert ctx.ez is not None, "Poisson needs shares of e^{WX}"
    return (ring.sub(ctx.ez[0], ctx.y[0]), ring.sub(ctx.ez[1], ctx.y[1]))


def pr_loss_shares(ctx: ShareCtx) -> tuple[R64, R64]:
    """Σ_i (Y·WX − e^{WX}); the −ln(Y!) term is public to C and added
    after reveal (C holds Y in plaintext)."""
    t = beaver.mul(ctx.y, ctx.z, *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    t = _shift(t, ctx.f)
    li = (ring.sub(t[0], ctx.ez[0]), ring.sub(t[1], ctx.ez[1]))
    return ring.sum_axis(li[0], 0), ring.sum_axis(li[1], 0)


# ---------------------------------------------------------------------------
# Float-domain oracles + prediction (centralized reference & metrics)
# ---------------------------------------------------------------------------

def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


@dataclasses.dataclass(frozen=True)
class GLM:
    name: str
    gradient_operator: Callable[[ShareCtx], tuple[R64, R64]]
    loss_shares: Callable[[ShareCtx], tuple[R64, R64]]
    needs_exp: bool
    # float oracles -----------------------------------------------------
    d_float: Callable[[np.ndarray, np.ndarray], np.ndarray]
    loss_float: Callable[[np.ndarray, np.ndarray], float]
    predict: Callable[[np.ndarray], np.ndarray]
    # C combines the revealed share-sum (float, already 2^-f scaled) with
    # its public label knowledge:  loss = finalize_loss(revealed, y, m)
    finalize_loss: Callable[[float, np.ndarray, int], float]
    # sign of the exponent when parties share e^{±z_p} (poisson +1, gamma −1)
    exp_sign: int = 1


LOGISTIC = GLM(
    name="logistic",
    gradient_operator=lr_gradient_operator,
    loss_shares=lr_loss_shares,
    needs_exp=False,
    d_float=lambda wx, y: 0.25 * wx - 0.5 * y,
    loss_float=lambda wx, y: float(np.mean(
        np.log(2.0) - 0.5 * (y * wx) + (y * wx) ** 2 / 8.0)),
    predict=lambda wx: sigmoid(wx),
    finalize_loss=lambda revealed, y, m: revealed / m,
)

POISSON = GLM(
    name="poisson",
    gradient_operator=pr_gradient_operator,
    loss_shares=pr_loss_shares,
    needs_exp=True,
    d_float=lambda wx, y: np.exp(wx) - y,
    loss_float=lambda wx, y: float(-np.mean(
        y * wx - np.exp(wx) - _log_factorial(y))),
    predict=lambda wx: np.exp(wx),
    finalize_loss=lambda revealed, y, m: (
        float(np.sum(_log_factorial(y))) - revealed) / m,
)

LINEAR = GLM(   # bonus GLM (paper: "also suitable for Linear, Gamma, …")
    name="linear",
    gradient_operator=lambda ctx: (ring.sub(ctx.z[0], ctx.y[0]),
                                   ring.sub(ctx.z[1], ctx.y[1])),
    loss_shares=lambda ctx: _mse_loss_shares(ctx),
    needs_exp=False,
    d_float=lambda wx, y: wx - y,
    loss_float=lambda wx, y: float(0.5 * np.mean((wx - y) ** 2)),
    predict=lambda wx: wx,
    finalize_loss=lambda revealed, y, m: revealed / m,
)


def _mse_loss_shares(ctx: ShareCtx) -> tuple[R64, R64]:
    r = (ring.sub(ctx.z[0], ctx.y[0]), ring.sub(ctx.z[1], ctx.y[1]))
    r2 = beaver.mul(r, r, *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    r2 = _shift(r2, ctx.f + 1)
    return ring.sum_axis(r2[0], 0), ring.sum_axis(r2[1], 0)


def _log_factorial(y: np.ndarray) -> np.ndarray:
    from scipy.special import gammaln
    return gammaln(np.asarray(y, np.float64) + 1.0)


# --- Gamma / Tweedie (paper §4.2: "also suitable for Linear, Gamma,
# Tweedie regression, etc.") — log link, so the gradient-operator has the
# same e^{WX} − y·(…) structure as Poisson and reuses its share plumbing.

def gamma_gradient_operator(ctx: ShareCtx) -> tuple[R64, R64]:
    """Gamma with log link: d = 1 − y·e^{−WX}.  Protocol form: parties
    share e^{-z_p} in the ez slot (trainer handles the sign), giving
    d = 1 − y∘ez via one Beaver product."""
    assert ctx.ez is not None
    prod = beaver.mul(ctx.y, ctx.ez,
                      *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    prod = _shift(prod, ctx.f)
    one = ring.from_signed_f64(np.ones(ctx.z[0].lo.shape), ctx.f)
    return (ring.sub(one, prod[0]), ring.neg(prod[1]))


def gamma_loss_shares(ctx: ShareCtx) -> tuple[R64, R64]:
    """Σ_i (WX + y·e^{−WX}) (unit-deviance core; constants at C)."""
    prod = beaver.mul(ctx.y, ctx.ez,
                      *ctx.dealer.elementwise(ctx.z[0].lo.shape))
    prod = _shift(prod, ctx.f)
    li = (ring.add(ctx.z[0], prod[0]), ring.add(ctx.z[1], prod[1]))
    return ring.sum_axis(li[0], 0), ring.sum_axis(li[1], 0)


GAMMA = GLM(
    name="gamma",
    gradient_operator=gamma_gradient_operator,
    loss_shares=gamma_loss_shares,
    needs_exp=True,          # trainer shares e^{-z_p} for gamma
    d_float=lambda wx, y: 1.0 - y * np.exp(-wx),
    loss_float=lambda wx, y: float(np.mean(wx + y * np.exp(-wx))),
    predict=lambda wx: np.exp(wx),
    finalize_loss=lambda revealed, y, m: revealed / m,
    exp_sign=-1,
)

GLMS = {g.name: g for g in (LOGISTIC, POISSON, LINEAR, GAMMA)}
