"""Generalized linear models — the paper's model family (§3.3, §4.2).

Each GLM supplies
  * the gradient-operator `d` on secret shares (paper eq. 7 / 8): the part
    of eq. (5) that must be computed jointly,
  * the loss on shares (paper eq. 1 / 3, MacLaurin where the paper does),
  * float-domain oracles (centralized training) for tests/benchmarks,
  * the inverse link for prediction.

Share-domain convention: all shared values carry `f` fractional bits; the
1/m factor and fixed-point scaling are applied after gradient/loss values
are *revealed to their owner* (exact, public constants).

Execution forms: the share math is written once as per-CP *legs*
(`*_leg(leg, ctx)` over a single share, `mpc.pairwise.PairLeg` carrying
the Beaver interaction) so the socket runtime can run each computing
party's half in its own process; the classic pair-at-once API
(`gradient_operator(ctx)` / `loss_shares(ctx)` over `ShareCtx`) is the
same legs driven in lockstep by `mpc.pairwise.joint` and stays
bit-identical to the historical `mpc.beaver.mul`-based evaluation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from repro.crypto import ring
from repro.crypto.ring import R64
from repro.mpc import beaver, pairwise
from repro.mpc.pairwise import PairLeg


@dataclasses.dataclass(frozen=True)
class ShareCtx:
    """Both CPs' shares of the Protocol-1 outputs, plus triple source.
    Simulation mode: index 0 == party C's share, 1 == party B1's."""
    z: tuple[R64, R64]                 # shares of WX = sum_p W_p X_p
    y: tuple[R64, R64] | None          # shares of the label (C shared it)
    ez: tuple[R64, R64] | None         # shares of e^{WX} (Poisson only)
    f: int                             # fractional bits
    dealer: beaver.DealerTripleSource


@dataclasses.dataclass(frozen=True)
class LegCtx:
    """ONE CP's view of the Protocol-1 outputs (share index = the
    `PairLeg.index` it runs under)."""
    z: R64
    y: Optional[R64]
    ez: Optional[R64]
    f: int


def _pair(leg_fn: Callable[[PairLeg, LegCtx], R64]
          ) -> Callable[[ShareCtx], tuple[R64, R64]]:
    """Lift a per-CP leg to the pair-at-once simulation API."""
    def pair_fn(ctx: ShareCtx) -> tuple[R64, R64]:
        def run(leg: PairLeg) -> R64:
            i = leg.index
            return leg_fn(leg, LegCtx(
                z=ctx.z[i],
                y=None if ctx.y is None else ctx.y[i],
                ez=None if ctx.ez is None else ctx.ez[i],
                f=ctx.f))
        return pairwise.joint(run, ctx.dealer)
    return pair_fn


def ez_chain_leg(leg: PairLeg, ez_list: list[R64], f: int) -> R64:
    """e^{Σz_p} = Π_p e^{z_p}: chain the parties' e^{z_p} shares with one
    Beaver product (+ truncation) per factor.  `ez_list` must be in
    roster order on both legs (the products do not commute bit-for-bit
    under probabilistic truncation)."""
    ez = ez_list[0]
    for e in ez_list[1:]:
        ez = leg.trunc(leg.mul(ez, e), f)
    return ez


def ez_chain_pair(ez_shares: list[tuple[R64, R64]], f: int, dealer
                  ) -> tuple[R64, R64]:
    """Pair-at-once form of `ez_chain_leg` (simulation scheduler)."""
    return pairwise.joint(
        lambda leg: ez_chain_leg(leg, [s[leg.index] for s in ez_shares], f),
        dealer)


# ---------------------------------------------------------------------------
# Logistic regression (paper eq. 1, 2, 7) — Y ∈ {−1, +1}
# ---------------------------------------------------------------------------

def lr_gradient_leg(leg: PairLeg, c: LegCtx) -> R64:
    """d = 0.25*WX − 0.5*Y (MacLaurin, eq. 7; 1/m deferred to reveal).
    Purely local: truncations and subtraction act share-wise."""
    return ring.sub(leg.trunc(c.z, 2), leg.trunc(c.y, 1))


def lr_loss_leg(leg: PairLeg, c: LegCtx) -> R64:
    """Σ_i ln(1+e^{−t}) with t=Y·WX, 2nd-order MacLaurin:
    ln2 − t/2 + t²/8 (same approximation family the paper uses)."""
    n = c.z.lo.shape[0]
    t = leg.trunc(leg.mul(c.y, c.z), c.f)
    t2 = leg.trunc(leg.mul(t, t), c.f)
    half_t = leg.trunc(t, 1)
    eighth_t2 = leg.trunc(t2, 3)
    s = ring.sum_axis(ring.sub(eighth_t2, half_t), 0)
    ln2 = ring.from_signed_f64(np.float64(n * math.log(2.0)), c.f)
    return leg.add_pub(s, ln2)


lr_gradient_operator = _pair(lr_gradient_leg)
lr_loss_shares = _pair(lr_loss_leg)


# ---------------------------------------------------------------------------
# Poisson regression (paper eq. 3, 4, 8)
# ---------------------------------------------------------------------------

def pr_gradient_leg(leg: PairLeg, c: LegCtx) -> R64:
    """d = e^{WX} − Y (eq. 8).  e^{WX} shares come from Protocol 1
    (parties share local e^{W_p X_p}; products chained via Beaver)."""
    assert c.ez is not None, "Poisson needs shares of e^{WX}"
    return ring.sub(c.ez, c.y)


def pr_loss_leg(leg: PairLeg, c: LegCtx) -> R64:
    """Σ_i (Y·WX − e^{WX}); the −ln(Y!) term is public to C and added
    after reveal (C holds Y in plaintext)."""
    t = leg.trunc(leg.mul(c.y, c.z), c.f)
    return ring.sum_axis(ring.sub(t, c.ez), 0)


pr_gradient_operator = _pair(pr_gradient_leg)
pr_loss_shares = _pair(pr_loss_leg)


# ---------------------------------------------------------------------------
# Float-domain oracles + prediction (centralized reference & metrics)
# ---------------------------------------------------------------------------

def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def matvec_rowwise(X: np.ndarray, W: np.ndarray) -> np.ndarray:
    """X @ W with a FIXED summation order: accumulate column-by-column
    with element-wise multiply-adds, never a BLAS reduction.

    BLAS gemv picks its reduction kernel (and therefore its float64
    association) from the matrix shape and buffer alignment, so the
    same row dotted inside a (7, m) micro-batch, a (96, m) one-shot
    matrix, or as a lone row view can differ in the last ulp.  Scoring
    must be batch-size-invariant — a served prediction is compared
    bit-for-bit against the one-shot scorer — so every wx path
    (serving `predict_share`, one-shot `TrainResult.predict_wx`) funnels
    through this kernel: out[i] depends only on row i with one fixed op
    order, which IEEE-754 makes reproducible.  m is the per-party
    feature count (small); the O(n·m) elementwise cost matches gemv's.
    """
    X = np.asarray(X, np.float64)
    W = np.asarray(W, np.float64)
    out = np.zeros(X.shape[0], np.float64)
    for j in range(X.shape[1]):
        out += X[:, j] * W[j]
    return out


@dataclasses.dataclass(frozen=True)
class GLM:
    name: str
    gradient_operator: Callable[[ShareCtx], tuple[R64, R64]]
    loss_shares: Callable[[ShareCtx], tuple[R64, R64]]
    needs_exp: bool
    # float oracles -----------------------------------------------------
    d_float: Callable[[np.ndarray, np.ndarray], np.ndarray]
    loss_float: Callable[[np.ndarray, np.ndarray], float]
    predict: Callable[[np.ndarray], np.ndarray]
    # C combines the revealed share-sum (float, already 2^-f scaled) with
    # its public label knowledge:  loss = finalize_loss(revealed, y, m)
    finalize_loss: Callable[[float, np.ndarray, int], float]
    # sign of the exponent when parties share e^{±z_p} (poisson +1, gamma −1)
    exp_sign: int = 1
    # per-CP leg forms of the joint share math (socket runtime) — the
    # pair-at-once callables above are these legs driven in lockstep
    gradient_leg: Callable[[PairLeg, LegCtx], R64] | None = None
    loss_leg: Callable[[PairLeg, LegCtx], R64] | None = None


def linear_gradient_leg(leg: PairLeg, c: LegCtx) -> R64:
    return ring.sub(c.z, c.y)


def linear_loss_leg(leg: PairLeg, c: LegCtx) -> R64:
    r = ring.sub(c.z, c.y)
    r2 = leg.trunc(leg.mul(r, r), c.f + 1)
    return ring.sum_axis(r2, 0)


LOGISTIC = GLM(
    name="logistic",
    gradient_operator=lr_gradient_operator,
    loss_shares=lr_loss_shares,
    needs_exp=False,
    d_float=lambda wx, y: 0.25 * wx - 0.5 * y,
    loss_float=lambda wx, y: float(np.mean(
        np.log(2.0) - 0.5 * (y * wx) + (y * wx) ** 2 / 8.0)),
    predict=lambda wx: sigmoid(wx),
    finalize_loss=lambda revealed, y, m: revealed / m,
    gradient_leg=lr_gradient_leg,
    loss_leg=lr_loss_leg,
)

POISSON = GLM(
    name="poisson",
    gradient_operator=pr_gradient_operator,
    loss_shares=pr_loss_shares,
    needs_exp=True,
    d_float=lambda wx, y: np.exp(wx) - y,
    loss_float=lambda wx, y: float(-np.mean(
        y * wx - np.exp(wx) - _log_factorial(y))),
    predict=lambda wx: np.exp(wx),
    finalize_loss=lambda revealed, y, m: (
        float(np.sum(_log_factorial(y))) - revealed) / m,
    gradient_leg=pr_gradient_leg,
    loss_leg=pr_loss_leg,
)

LINEAR = GLM(   # bonus GLM (paper: "also suitable for Linear, Gamma, …")
    name="linear",
    gradient_operator=_pair(linear_gradient_leg),
    loss_shares=_pair(linear_loss_leg),
    needs_exp=False,
    d_float=lambda wx, y: wx - y,
    loss_float=lambda wx, y: float(0.5 * np.mean((wx - y) ** 2)),
    predict=lambda wx: wx,
    finalize_loss=lambda revealed, y, m: revealed / m,
    gradient_leg=linear_gradient_leg,
    loss_leg=linear_loss_leg,
)


def _log_factorial(y: np.ndarray) -> np.ndarray:
    from scipy.special import gammaln
    return gammaln(np.asarray(y, np.float64) + 1.0)


# --- Gamma / Tweedie (paper §4.2: "also suitable for Linear, Gamma,
# Tweedie regression, etc.") — log link, so the gradient-operator has the
# same e^{WX} − y·(…) structure as Poisson and reuses its share plumbing.

def gamma_gradient_leg(leg: PairLeg, c: LegCtx) -> R64:
    """Gamma with log link: d = 1 − y·e^{−WX}.  Protocol form: parties
    share e^{-z_p} in the ez slot (trainer handles the sign), giving
    d = 1 − y∘ez via one Beaver product."""
    assert c.ez is not None
    prod = leg.trunc(leg.mul(c.y, c.ez), c.f)
    if leg.index == 0:
        one = ring.from_signed_f64(np.ones(c.z.lo.shape), c.f)
        return ring.sub(one, prod)
    return ring.neg(prod)


def gamma_loss_leg(leg: PairLeg, c: LegCtx) -> R64:
    """Σ_i (WX + y·e^{−WX}) (unit-deviance core; constants at C)."""
    prod = leg.trunc(leg.mul(c.y, c.ez), c.f)
    return ring.sum_axis(ring.add(c.z, prod), 0)


gamma_gradient_operator = _pair(gamma_gradient_leg)
gamma_loss_shares = _pair(gamma_loss_leg)

GAMMA = GLM(
    name="gamma",
    gradient_operator=gamma_gradient_operator,
    loss_shares=gamma_loss_shares,
    needs_exp=True,          # trainer shares e^{-z_p} for gamma
    d_float=lambda wx, y: 1.0 - y * np.exp(-wx),
    loss_float=lambda wx, y: float(np.mean(wx + y * np.exp(-wx))),
    predict=lambda wx: np.exp(wx),
    finalize_loss=lambda revealed, y, m: revealed / m,
    exp_sign=-1,
    gradient_leg=gamma_gradient_leg,
    loss_leg=gamma_loss_leg,
)

GLMS = {g.name: g for g in (LOGISTIC, POISSON, LINEAR, GAMMA)}

#: Beaver multiplications in the gradient-operator + loss legs (the
#: e^z chaining adds k−1 more for exp-family models) — see
#: `joint_muls_per_iteration`.
JOINT_LOSS_MULS = {"logistic": 2, "linear": 1, "poisson": 1, "gamma": 2}


def joint_muls_per_iteration(glm_name: str, n_parties: int) -> int:
    """Beaver-triple draws the CP pair consumes in one Algorithm-1
    iteration.  The distributed runtime uses this to keep every party's
    seed-replicated dealer stream aligned: non-CP parties `skip()` this
    many draws per iteration, CP parties assert they drew exactly it."""
    chain = n_parties - 1 if GLMS[glm_name].needs_exp else 0
    return chain + JOINT_LOSS_MULS[glm_name]
