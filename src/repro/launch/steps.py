"""Step builders: assemble (step_fn, arg ShapeDtypeStructs, in/out
shardings) for every (arch × shape-cell × mesh) — the dry-run contract
and the train/serve drivers both build on this."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeCell, TrainConfig
from repro.distributed import sharding
from repro.models import registry as models
from repro.models import mamba2, rwkv6, transformer, whisper
from repro.optim import clip_by_global_norm, make_optimizer


@dataclasses.dataclass
class StepBundle:
    fn: Any                   # the python step function
    args: tuple               # ShapeDtypeStructs (lower(*args))
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()


def make_train_step(api: models.ModelAPI, tc: TrainConfig):
    opt = make_optimizer(api.cfg.optimizer)

    def _loss_and_grads(params, batch):
        if tc.microbatch is None:
            return jax.value_and_grad(api.train_loss)(params, batch)
        # gradient accumulation: scan over microbatches (peak activation
        # memory ÷ n_micro; equal-size chunks → mean of means is exact)
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % tc.microbatch == 0, "batch % microbatch != 0"
        n_micro = B // tc.microbatch
        chunked = jax.tree.map(
            lambda x: x.reshape((n_micro, tc.microbatch) + x.shape[1:]),
            batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(api.train_loss)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 grad_acc, grads)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, chunked)
        scale = 1.0 / n_micro
        return loss_sum * scale, jax.tree.map(
            lambda g: g * scale, grad_sum)

    def train_step(params, opt_state, batch):
        loss, grads = _loss_and_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        new_params, new_opt = opt.update(grads, opt_state, params, tc.lr)
        return loss, gnorm, new_params, new_opt

    return opt, train_step


def make_prefill_step(api: models.ModelAPI, max_len: int):
    cfg = api.cfg

    def prefill_step(params, batch):
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.prefill(
                params, cfg, batch["tokens"], max_len,
                vision_embeds=batch.get("vision_embeds"))
        if cfg.family == "ssm":
            logits, state = rwkv6.forward(params, cfg, batch["tokens"])
            return logits[:, -1], state
        if cfg.family == "hybrid":
            logits, state = mamba2.forward(params, cfg, batch["tokens"],
                                           max_len=max_len)
            return logits[:, -1], state
        if cfg.family == "audio":
            enc_out = whisper.encode(params, cfg, batch["frames"])
            logits, cache = whisper.decode(params, cfg, batch["tokens"],
                                           enc_out, max_len=max_len)
            return logits[:, -1], cache
        raise ValueError(cfg.family)

    return prefill_step


def make_decode_step(api: models.ModelAPI):
    cfg = api.cfg

    def decode_step(params, state, batch):
        extras = {}
        if cfg.family == "audio":
            extras["enc_out"] = batch["enc_out"]
        return api.decode_step(params, state, batch["token"], **extras)

    return decode_step


def build_step_bundle(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                      tc: TrainConfig | None = None) -> StepBundle:
    api = models.build(cfg)
    tc = tc or TrainConfig()
    key = jax.random.key(0)
    params_shapes = jax.eval_shape(api.init_params, key)
    pspecs = sharding.param_specs(params_shapes, mesh)
    batch_shapes = models.input_specs(cfg, cell)
    bspecs = sharding.batch_specs(batch_shapes, mesh)
    rep = sharding.replicated(mesh)

    if cell.kind == "train":
        opt, step = make_train_step(api, tc)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        ospecs = sharding.param_specs(opt_shapes, mesh) \
            if jax.tree.leaves(opt_shapes) else jax.tree.map(
                lambda _: rep, opt_shapes)
        # scalars inside adamw state (t) → replicated
        ospecs = jax.tree.map(
            lambda sh, sp: rep if sh.ndim == 0 else sp, opt_shapes, ospecs)
        return StepBundle(
            fn=step,
            args=(params_shapes, opt_shapes, batch_shapes),
            in_shardings=(pspecs, ospecs, bspecs),
            out_shardings=(rep, rep, pspecs, ospecs),
            donate=(0, 1),
        )

    if cell.kind == "prefill":
        step = make_prefill_step(api, max_len=cell.seq_len)
        state_shapes = jax.eval_shape(step, params_shapes, batch_shapes)[1]
        sspecs = sharding.state_specs(state_shapes, mesh)
        logits_shape = jax.eval_shape(step, params_shapes, batch_shapes)[0]
        lspec = sharding.batch_specs(logits_shape, mesh)
        return StepBundle(
            fn=step,
            args=(params_shapes, batch_shapes),
            in_shardings=(pspecs, bspecs),
            out_shardings=(lspec, sspecs),
        )

    # decode
    step = make_decode_step(api)
    state_shapes = jax.eval_shape(
        lambda: api.init_decode_state(cell.global_batch, cell.seq_len))
    sspecs = sharding.state_specs(state_shapes, mesh)
    out_shapes = jax.eval_shape(step, params_shapes, state_shapes,
                                batch_shapes)
    lspec = sharding.batch_specs(out_shapes[0], mesh)
    return StepBundle(
        fn=step,
        args=(params_shapes, state_shapes, batch_shapes),
        in_shardings=(pspecs, sspecs, bspecs),
        out_shardings=(lspec, sspecs),
        donate=(1,),
    )
