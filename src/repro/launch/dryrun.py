"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination and extract memory / cost / collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

The first two lines force 512 host platform devices BEFORE any jax import
(jax locks the device count at first init).  Do NOT replicate this in
conftest/pyproject: smoke tests must see 1 device.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import registry                     # noqa: E402
from repro.configs.base import (LONG_CONTEXT_ARCHS,    # noqa: E402
                                SHAPE_CELLS)
from repro.launch import mesh as mesh_lib              # noqa: E402
from repro.launch import steps as steps_lib            # noqa: E402

# v5e hardware model (roofline constants; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_COLL_RE = re.compile(
    r"=\s*\(?(\w+)\[([\d,]*)\][^)]*?\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def peak_bytes(ma) -> int:
    """Peak device memory from a CompiledMemoryStats, tolerating jax
    versions that don't expose `peak_memory_in_bytes` (fall back to
    args + outputs + temps — the steady-state resident set)."""
    peak = getattr(ma, "peak_memory_in_bytes", None)
    if peak:
        return int(peak)
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the SPMD
    module, by op kind ('-done' halves of async pairs are skipped so
    nothing is double-counted)."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind, suffix = m.groups()
        if suffix == "-done" or dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dtype]
        out[kind] = out.get(kind, 0.0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    out["total_bytes"] = float(sum(out.values()))
    out["op_counts"] = count
    return out


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS,
        "memory_s": hbm_bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes_per_dev / ICI_BW,
    }


def cell_plan(arch: str) -> list[str]:
    """Which shape cells run for this arch (documented skips)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells


# ---------------------------------------------------------------------------
# Cost source: XLA's cost_analysis() counts each while-loop (lax.scan) body
# ONCE, so scanned stacks/recurrences undercount by orders of magnitude.
# Roofline terms therefore come from the analytic model in costmodel.py,
# which is validated against cost_analysis on fully-UNROLLED small configs
# (tests/test_costmodel.py) — the regime where XLA's numbers are exact.
# The raw full-compile numbers + the HLO collective op census are kept in
# each record for structural cross-checks.
# ---------------------------------------------------------------------------
from repro.launch import costmodel  # noqa: E402


def _apply_overrides(cfg, overrides: str | None):
    """--set k=v,k=v — §Perf variant knobs (dataclasses.replace)."""
    if not overrides:
        return cfg
    import dataclasses
    kw = {}
    for pair in overrides.split(","):
        k, v = pair.split("=")
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v.lower() in ("1", "true")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, cell_name: str, mesh, *, smoke: bool = False,
             overrides: str | None = None) -> dict:
    cfg = (registry.get_smoke_config(arch) if smoke
           else registry.get_config(arch))
    cfg = _apply_overrides(cfg, overrides)
    cell = SHAPE_CELLS[cell_name]
    if smoke:   # shrink the cell so CI meshes can lower it quickly
        import dataclasses
        cell = dataclasses.replace(cell, seq_len=256,
                                   global_batch=mesh.devices.size * 2 //
                                   (2 if "pod" in mesh.axis_names else 1))
    t0 = time.time()
    bundle = steps_lib.build_step_bundle(cfg, cell, mesh)
    lowered = jax.jit(bundle.fn,
                      in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=bundle.donate).lower(*bundle.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = costmodel.xla_cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text())
    n_dev = mesh.devices.size
    costs = costmodel.cell_costs(cfg, cell, mesh)
    flops = costs["flops_per_dev"]
    hbm_bytes = costs["hbm_bytes_per_dev"]
    coll_bytes = costs["coll_bytes_per_dev"]
    res = {
        "arch": arch, "cell": cell_name, "overrides": overrides,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "peak_bytes_per_dev": peak_bytes(ma),
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "output_bytes_per_dev": int(ma.output_size_in_bytes),
        "flops_per_dev": flops,
        "hbm_bytes_per_dev": hbm_bytes,
        "coll_bytes_per_dev": coll_bytes,
        "costmodel": costs,
        "raw_fullcompile_hlo": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": colls["total_bytes"]},
        "collectives": colls,
        **roofline_terms(flops, hbm_bytes, coll_bytes),
    }
    # model-FLOPs utilisation denominators (6·N·D; MoE: active params)
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        model_flops = 6 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        model_flops = 2 * n_active * tokens
    else:
        tokens = cell.global_batch
        model_flops = 2 * n_active * tokens
    res["model_flops_total"] = float(model_flops)
    res["useful_flops_ratio"] = (
        float(model_flops) / (flops * n_dev) if flops else 0.0)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2-pod (2,16,16) mesh")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs on a small mesh (CI)")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 64x4 (perf-iteration "
                         "sharding variants; axes stay (data, model))")
    ap.add_argument("--set", dest="overrides", default=None,
                    help="config overrides, e.g. kv_cache_dtype=int8,"
                         "kv_head_replication=2")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split("x"))
        names = ("data", "model") if len(dims) == 2 else \
            ("pod", "data", "model")
        meshes = [mesh_lib._make_mesh(dims, names)]
    elif args.smoke:
        meshes = [mesh_lib.make_debug_mesh(),
                  mesh_lib.make_debug_mesh(multi_pod=True)]
    else:
        meshes = []
        if not args.multi_pod_only:
            meshes.append(mesh_lib.make_production_mesh())
        if args.multi_pod or args.multi_pod_only:
            meshes.append(mesh_lib.make_production_mesh(multi_pod=True))

    archs = [args.arch] if args.arch else registry.list_archs()
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh in meshes:
        mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            cells = [args.cell] if args.cell else cell_plan(arch)
            for cell_name in cells:
                tag = f"{arch}__{cell_name}__{mesh_tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, cell_name, mesh, smoke=args.smoke,
                                   overrides=args.overrides)
                    print(f"  ok: peak={res['peak_bytes_per_dev']/2**30:.2f}"
                          f"GiB compute={res['compute_s']*1e3:.2f}ms "
                          f"mem={res['memory_s']*1e3:.2f}ms "
                          f"coll={res['collective_s']*1e3:.2f}ms "
                          f"(compile {res['compile_s']:.0f}s)", flush=True)
                except Exception as e:   # noqa: BLE001 — record, keep going
                    failures += 1
                    res = {"arch": arch, "cell": cell_name, "mesh": mesh_tag,
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  FAIL: {res['error'][:200]}", flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
