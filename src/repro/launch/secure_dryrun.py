"""EFMVFL on the production mesh: lower + compile the 2-party secure
gradient iteration with  pod = party,  data = sample shards,  model =
feature shards.

This is the paper's protocol as ONE XLA program (DESIGN.md §5): each pod
is an organizational party; within a pod the Protocol-3 hot path
(plaintext-matrix × ciphertext-vector) shards samples over `data` and
feature columns over `model`; the homomorphic ⊕-reduction across sample
shards is the modmul ppermute ladder (psum can't express it).

  PYTHONPATH=src python -m repro.launch.secure_dryrun \
      [--samples 30720] [--features 32] [--key-bits 1024] \
      [--mesh 2x16x16] [--transport local|pipelined|socket]

`--mesh PxDxM` picks the pod×data×model mesh shape (product ≤ the 512
forced host devices), so the same lowering compiles at laptop scale
(`--mesh 2x2x4`) or pod scale; the analytic roofline terms follow the
chosen shape.

`--transport` additionally runs a small *measured* 2-party training
iteration on the chosen runtime transport and reports its per-tag
bytes next to the analytic `protocol_comm` table — with `socket` the
bytes are counted off real encoded TCP frames between party processes
(`runtime.codec` / `launch.cluster`), asserting the analytic table is
what actually crosses the wire.  `--checkpoint-dir` (socket) enables
party-local checkpoints in the measured run and reports the cadence;
adding `--resume` runs the kill-and-resume drill and reports the
`resume_verdict` (docs/fault_tolerance.md).  `--chaos PROFILE`
(socket) routes the measured run through the fault-injection link
layer (`runtime.chaos`) and reports injected faults, ARQ recovery
work, and whether the meters survived bit-exact.

`--tables PATH` builds (or loads) the persistent fixed-base noise table
for a real keypair at `--key-bits` and reports its build time, on-disk
size, and the per-iteration modexp savings of the h^ρ table walk over
the r^n ladder next to the analytic `protocol_comm` table — the
deployment-economics view of docs/engine.md §fixed-base tables.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
from repro.distributed.shardmap_compat import shard_map    # noqa: E402

from repro.crypto.bigint import Modulus, mont_one            # noqa: E402
from repro.crypto import engine as engine_mod                # noqa: E402
from repro.crypto import fixed_point                         # noqa: E402
from repro.crypto.ring import R64                            # noqa: E402
from repro.crypto import ring                                # noqa: E402
from repro.distributed.secure_ops import modmul_reduce       # noqa: E402
from repro.launch import mesh as mesh_lib                    # noqa: E402
from repro.launch.costmodel import xla_cost_analysis         # noqa: E402
from repro.launch.dryrun import (parse_collectives,          # noqa: E402
                                 peak_bytes, roofline_terms)
from repro.runtime import messages as msg_lib                # noqa: E402


def montmul_count(n_loc: int, m_loc: int, width: int, window: int,
                  data_size: int) -> float:
    """Analytic Montgomery-product count per device for the secure step
    (XLA counts scan bodies once — see costmodel.py rationale)."""
    if window <= 1:
        return width * (n_loc * m_loc + 2 * m_loc) \
            + m_loc * max(data_size.bit_length() - 1, 0)
    levels = -(-width // window)
    pre = n_loc * ((1 << window) - 2)
    return pre + levels * (n_loc * m_loc + (window + 1) * m_loc) \
        + m_loc * max(data_size.bit_length() - 1, 0)


def flops_per_montmul(L: int) -> float:
    """CIOS: L rounds × (2 MAC rows + lazy carries) ≈ 8·L² int32 ops."""
    return 8.0 * L * L


def make_secure_grad_step(mesh, mod: Modulus, width: int, window: int = 1,
                          shard_mode: str = "feature", engine=None):
    """Builds the jitted 2-party Protocol-3 step.

    Global shapes (pod-major):
      exps   (2, n, m)  uint32 — per-party offset-lifted fixed-point X
      cts    (2, n, L2) uint32 — [[⟨d⟩_other]] under the other party's key
      d_hi/lo(2, n)     uint32 — own share ⟨d⟩_self (ring 2^64)
    Returns per-party (2, m, L2) encrypted masked gradients + (2, m)
    ring shares of the local term X^T⟨d⟩_self.
    window=1: bit-serial (paper-faithful baseline); window=4: fixed-window
    ladder (§Perf optimized variant, ~3.6× fewer Montgomery products).
    `engine` routes the Montgomery products through the crypto compute
    engine — the same dispatch the trainer/runtime hits — so `--engine
    pallas` lowers the step with the fused kernels inside the shard_map.
    Default None = the jnp library (keeps the XLA cost model exact).
    """
    eng = engine if engine is not None \
        else engine_mod.CryptoEngine(backend="jnp")

    def mont_mul(a, b, m):
        return eng.mont_mul(a, b, m)

    data_size = mesh.shape["data"]
    model_size = mesh.shape["model"]
    L2 = mod.L
    # feature mode: samples/data, features/model (m_loc = m/16 — small
    # window-table amortization).  sample2d mode: samples over BOTH axes
    # (n_loc = n/256), features replicated — the table amortizes fully and
    # the ⊕-ladder runs over both axes (4+4 hops).
    sample_axes = ("data",) if shard_mode == "feature" else ("data", "model")

    def _tree(c):
        while c.shape[0] > 1:
            half = c.shape[0] // 2
            merged = mont_mul(c[:half], c[half:2 * half], mod)
            if c.shape[0] % 2:
                merged = jnp.concatenate([merged, c[2 * half:]], 0)
            c = merged
        return c[0]

    samp = sample_axes if len(sample_axes) > 1 else sample_axes[0]
    feat = "model" if shard_mode == "feature" else None
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod", samp, feat), P("pod", samp, None),
                  P("pod", samp), P("pod", samp)),
        out_specs=(P("pod", feat, None), P("pod", feat)),
        check_vma=False)
    def step(exps, cts, d_hi, d_lo):
        exps = exps[0]                       # (n_loc, m_loc) this party
        cts = cts[0]                         # (n_loc, L2)
        acc0 = jnp.broadcast_to(mont_one(mod), (exps.shape[1], L2))
        if window <= 1:
            # --- bit-serial ladder (baseline) ----------------------------
            bits = fixed_point.int_bits_msb(exps, width)     # (n, m, w)
            one = jnp.broadcast_to(mont_one(mod), cts.shape)

            def bit_step(acc, bits_t):
                acc = mont_mul(acc, acc, mod)
                sel = jnp.where(bits_t[..., None] == 1, cts[:, None, :],
                                one[:, None, :])
                return mont_mul(acc, _tree(sel), mod), None

            acc, _ = jax.lax.scan(bit_step, acc0,
                                  jnp.moveaxis(bits, -1, 0))
        else:
            # --- fixed-window ladder (§Perf variant) ---------------------
            levels = -(-width // window)
            digs = jnp.stack(
                [(exps >> ((levels - 1 - lv) * window))
                 & ((1 << window) - 1) for lv in range(levels)], axis=-1)
            table = [jnp.broadcast_to(mont_one(mod), cts.shape), cts]
            for _ in range(2, 1 << window):
                table.append(mont_mul(table[-1], cts, mod))
            table = jnp.stack(table, 0)

            def win_step(acc, d_lvl):
                for _ in range(window):
                    acc = mont_mul(acc, acc, mod)
                sel = jnp.take_along_axis(
                    table[:, :, None, :], d_lvl[None, :, :, None],
                    axis=0)[0]
                return mont_mul(acc, _tree(sel), mod), None

            acc, _ = jax.lax.scan(win_step, acc0,
                                  jnp.moveaxis(digs, -1, 0))
        # cross-shard ⊕-reduce over the sample axis/axes (modmul ladder)
        enc_g = modmul_reduce(acc, mod, "data", data_size)
        if shard_mode == "sample2d":
            enc_g = modmul_reduce(enc_g, mod, "model", model_size)

        # --- local ring term X^T ⟨d⟩_self (additive across sample shards:
        # a native psum — contrast with the ⊕ ladder above).  Z_2^64 sums
        # cross shards via 16-bit-split psums so carries survive in u32.
        d_self = R64(d_hi[0], d_lo[0])
        x_signed = (exps.astype(jnp.int32) - (1 << (width - 1)))
        g_loc = ring.matmul(x_signed.T,
                            R64(d_self.hi[:, None], d_self.lo[:, None]))
        lo, hi = g_loc.lo[:, 0], g_loc.hi[:, 0]
        p0 = jax.lax.psum(lo & jnp.uint32(0xFFFF), sample_axes)
        p1 = jax.lax.psum(lo >> 16, sample_axes)
        q0 = jax.lax.psum(hi & jnp.uint32(0xFFFF), sample_axes)
        q1 = jax.lax.psum(hi >> 16, sample_axes)
        mid = (p0 >> 16) + p1
        g_lo = (p0 & jnp.uint32(0xFFFF)) | (mid << 16)
        carry = mid >> 16
        g_hi = q0 + (q1 << 16) + carry
        return enc_g[None], jnp.stack([g_hi, g_lo], -1)[None]

    return step


def measured_comm(transport: str, features: int, key_bits: int,
                  samples: int = 256, checkpoint_dir: str | None = None,
                  resume_drill: bool = False,
                  chaos: str | None = None) -> dict:
    """One *measured* 2-party training iteration on a runtime transport.

    Mirrors the analytic `protocol_comm` shape (2 parties, `features`
    features EACH, fixed CP selection, mock HE at `key_bits`) at a
    reduced batch so the dry-run stays fast, and compares the per-tag
    bytes the transport actually metered against the analytic
    `iteration_traffic` synthesis for the same shape.  With `socket`
    the run spans real OS processes and the bytes are measured off the
    encoded TCP frames (plus the frame-overhead total the analytic
    table deliberately excludes).

    With `checkpoint_dir` (socket only) the run trains 2 iterations at
    `checkpoint_every=1` and records the party-local checkpoint cadence;
    `resume_drill` additionally SIGKILLs B1 mid-run, lets the supervisor
    resume from the checkpoints, and reports whether the recovered run
    is bit-identical to an uninterrupted single-process reference — the
    `resume_verdict` column of the dry-run table.

    `chaos` (socket only) names a `runtime.chaos.PROFILES` entry and
    runs the measured iteration through the fault-injection link layer
    (`FaultyTransport`): the report gains a `chaos` block with injected
    fault counts, ARQ recovery work (retransmits, backoff), and a
    `chaos_verdict` — `recovered_bit_exact` iff the per-tag meters
    still equal the analytic table despite the injected faults
    (docs/fault_tolerance.md §chaos).
    """
    import numpy as np
    from repro.core.trainer import PartyData, VFLConfig, train_vfl
    from repro.runtime.scheduler import min_key_bits
    from repro.runtime.transport import LocalTransport, PipelinedTransport

    nb = min(samples, 256)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(nb, 2 * features)) * 0.3
    y = (rng.random(nb) < 0.5).astype(np.float64) * 2 - 1
    parties = [PartyData("C", X[:, :features]),
               PartyData("B1", X[:, features:])]
    checkpointing = checkpoint_dir is not None and transport == "socket"
    n_iter = 2 if checkpointing else 1
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=n_iter, batch_size=nb,
                    he_backend="mock", key_bits=key_bits, tol=0.0, seed=0,
                    checkpoint_every=1 if checkpointing else 0)
    # a LIVE iteration needs a key that can carry its masked values; the
    # analytic lowering has no such floor (e.g. the documented 128-bit
    # compile check), so bump the measured run to the minimum viable key
    # and record it — the analytic comparison below uses the same size.
    key_bits = max(key_bits, min_key_bits(cfg))
    cfg.key_bits = key_bits
    out = {"transport": transport, "iterations": n_iter, "batch": nb,
           "features_per_party": features, "key_bits": key_bits}
    if transport == "socket":
        # party processes must not inherit the 512 forced host devices
        from repro.launch.cluster import (train_vfl_socket,
                                          train_vfl_socket_resilient)
        saved = os.environ.get("XLA_FLAGS")
        os.environ["XLA_FLAGS"] = (saved or "").replace(
            "--xla_force_host_platform_device_count=512", "").strip()
        try:
            if checkpointing and resume_drill:
                res = train_vfl_socket_resilient(
                    parties, y, cfg, checkpoint_dir=checkpoint_dir,
                    kill_plan={1: "B1"}, chaos=chaos)
            else:
                res = train_vfl_socket(parties, y, cfg,
                                       checkpoint_dir=checkpoint_dir,
                                       chaos=chaos)
        finally:
            if saved is not None:
                os.environ["XLA_FLAGS"] = saved
        out["measured_mb_by_tag"] = {
            k: v / 1e6 for k, v in sorted(res.measured_meter.by_tag.items())}
        out["frame_overhead_mb"] = res.wire_overhead_bytes / 1e6
        measured = dict(res.measured_meter.by_tag)
        if checkpointing:
            from repro.checkpoint import party_checkpoint_dir, valid_steps
            from repro.runtime import session
            ck = {"dir": checkpoint_dir, "every": cfg.checkpoint_every,
                  "steps_on_disk": {
                      p.name: valid_steps(
                          party_checkpoint_dir(checkpoint_dir, p.name),
                          expect_config_hash=session.config_hash(cfg),
                          expect_codec_version=session.CODEC_VERSION)
                      for p in parties}}
            if resume_drill:
                ref = train_vfl(parties, y, cfg,
                                transport=LocalTransport())
                identical = (res.losses == ref.losses
                             and dict(res.meter.by_tag)
                             == dict(ref.meter.by_tag)
                             and all(np.array_equal(res.weights[n],
                                                    ref.weights[n])
                                     for n in ref.weights))
                ck.update(restarts=getattr(res, "restarts", 0),
                          resume_step=res.resume_report.get("step"),
                          resume_verdict=("bit_identical" if identical
                                          else "DIVERGED"))
            out["checkpoint"] = ck
    else:
        tp = {"local": LocalTransport,
              "pipelined": PipelinedTransport}[transport]()
        res = train_vfl(parties, y, cfg, transport=tp)
        out["measured_mb_by_tag"] = {
            k: v / 1e6 for k, v in sorted(res.meter.by_tag.items())}
        measured = dict(res.meter.by_tag)
    analytic, _ = msg_lib.iteration_traffic(
        n_parties=2, nb=nb, m_per_party=features, key_bits=key_bits)
    out["matches_analytic"] = measured == {
        k: v * res.n_iter for k, v in analytic.items()}
    report = getattr(res, "chaos_report", None)
    if report is not None:
        t = report["total"]
        out["chaos"] = {
            "profile": chaos,
            "injected": {k: t.get(k, 0) for k in
                         ("drops", "dups", "reorders", "resets",
                          "partitions")},
            "retransmits": t.get("retransmits", 0),
            "rx_dups": t.get("rx_dups", 0),
            "backoff_total_s": round(t.get("backoff_total_s", 0.0), 3),
            "chaos_verdict": ("recovered_bit_exact"
                              if out["matches_analytic"] else "DIVERGED"),
        }
    return out


def serving_report(k: int = 3, n_req: int = 48, batch: int = 8) -> dict:
    """One in-process serving micro-run for the dry-run report: train a
    tiny k-party GLM, serve `n_req` requests through the continuous-
    batching scoring engine (`serve.VFLScoringEngine`, docs/serving.md)
    and report p50/p99 latency, throughput, and the serving wire
    identity — metered `infer.wx_share` bytes must equal the analytic
    n_req·(k−1)·8 — plus a hot-swap drill verdict: served predictions
    at the published version must be bit-identical to the one-shot
    scorer."""
    import numpy as np
    from repro.core import glm as glm_lib
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical
    from repro.runtime import VFLScheduler
    from repro.serve import VFLScoringEngine

    X, y = synthetic.credit_default(n=160, d=8, seed=23)
    parts = vertical.split_columns(X, k)
    names = ["C"] + [f"B{i}" for i in range(1, k)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm="logistic", lr=0.1, max_iter=2, batch_size=128,
                    he_backend="mock", tol=0.0, seed=23)
    sched = VFLScheduler(parties, y, cfg)
    res = sched.run()
    eng = VFLScoringEngine(sched.parties, max_batch=batch)
    for i in range(n_req):
        eng.submit({nm: part[i % part.shape[0]]
                    for nm, part in zip(names, parts)})
    done = sorted(eng.run(), key=lambda r: r.rid)
    lat = eng.latencies()
    got = np.array([r.prediction for r in done])
    want = glm_lib.GLMS[cfg.glm].predict(res.predict_wx(parties))[:n_req]
    wx_bytes = eng.transport.meter.by_tag["infer.wx_share"]
    return {
        "parties": k, "n_req": n_req, "max_batch": batch,
        "model_version": eng.model_version,
        "p50_ms": round(float(np.percentile(lat, 50) * 1e3), 4),
        "p99_ms": round(float(np.percentile(lat, 99) * 1e3), 4),
        "wx_share_bytes": int(wx_bytes),
        "wx_share_bytes_analytic": n_req * (k - 1) * 8,
        "wire_ok": int(wx_bytes) == n_req * (k - 1) * 8,
        "serve_verdict": ("bit_identical"
                          if np.array_equal(got, want) else "DIVERGED"),
    }


def tables_report(path: str, key_bits: int, engine_name: str,
                  nb: int, m: int) -> dict:
    """Fixed-base noise-table economics for a REAL keypair at
    `key_bits`: one-time build cost + on-disk size of the persistent
    table (`crypto/fixed_base.py`), and the measured per-noise modexp
    cost of the h^ρ table walk vs the r^n library ladder, scaled to one
    iteration's noise demand (nb ciphertexts for the CP's [[⟨d⟩]] + m
    for the non-CP masked-matvec leg) — the column that sits next to
    the analytic `protocol_comm` table."""
    import numpy as np
    from repro.crypto import fixed_base, paillier

    t0 = time.time()
    key = paillier.keygen(key_bits, seed=0)
    keygen_s = time.time() - t0
    pub = key.pub
    t0 = time.time()
    table, built = fixed_base.ensure_table(pub.n, pub.mod_n2, path,
                                           rng=np.random.default_rng(1))
    build_s = time.time() - t0
    eng = engine_mod.make(engine_name)
    rng = np.random.default_rng(2)
    batch = 4
    ladder = jax.jit(lambda rr: paillier.noise_to_mont(pub, rr, eng))
    raw = jnp.asarray(paillier.raw_noise(pub, batch, rng))
    jax.block_until_ready(ladder(raw))            # compile
    t0 = time.time()
    jax.block_until_ready(ladder(raw))
    ladder_us = (time.time() - t0) * 1e6 / batch
    digits = jnp.asarray(fixed_base.draw_exponent_digits(table, batch, rng))
    jax.block_until_ready(
        paillier.noise_from_table(pub, table, digits, eng))
    t0 = time.time()
    jax.block_until_ready(
        paillier.noise_from_table(pub, table, digits, eng))
    table_us = (time.time() - t0) * 1e6 / batch
    noise_per_iter = nb + m                       # k=2: CP nb + one leg m
    return {
        "path": path, "built_now": built, "engine": engine_name,
        "key_bits": key_bits,
        "keygen_s": round(keygen_s, 2),
        "build_s": round(build_s, 2),
        "bytes_on_disk": os.path.getsize(path),
        "window": table.window, "levels": table.levels,
        "exp_bits": table.exp_bits,
        "ladder_us_per_noise": round(ladder_us, 1),
        "table_us_per_noise": round(table_us, 1),
        "speedup": round(ladder_us / table_us, 1),
        "noise_terms_per_iteration": noise_per_iter,
        "modexp_savings_per_iteration_s": round(
            noise_per_iter * (ladder_us - table_us) / 1e6, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=30720)
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--key-bits", type=int, default=1024)
    ap.add_argument("--width", type=int, default=22)
    ap.add_argument("--window", type=int, default=1,
                    help="1 = paper-faithful bit-serial; 4 = §Perf variant")
    ap.add_argument("--shard-mode", default="feature",
                    choices=("feature", "sample2d"))
    ap.add_argument("--engine", default="jnp",
                    choices=("jnp", "pallas-interpret", "pallas"),
                    help="crypto compute engine for the Montgomery "
                         "products (jnp keeps the cost model exact)")
    ap.add_argument("--tables", default=None, metavar="PATH",
                    help="build/load the persistent fixed-base noise "
                         "table for a real keypair at --key-bits and "
                         "report build time, on-disk size, and the "
                         "per-iteration modexp savings of h^ρ table "
                         "walks vs the r^n ladder (docs/engine.md "
                         "§fixed-base tables)")
    ap.add_argument("--mesh", default="2x16x16",
                    help="pod×data×model mesh shape, e.g. 2x16x16 "
                         "(pod = party; product ≤ 512)")
    ap.add_argument("--transport", default="none",
                    choices=("none", "local", "pipelined", "socket"),
                    help="also run one measured training iteration on "
                         "this runtime transport (socket = real "
                         "processes over TCP) and report measured "
                         "per-tag bytes next to the analytic table")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="(socket transport) enable party-local "
                         "checkpoints at every iteration in the "
                         "measured run and report the cadence")
    ap.add_argument("--resume", action="store_true",
                    help="(with --checkpoint-dir) kill a party mid-run, "
                         "resume via the supervisor, and report the "
                         "resume verdict (bit_identical | DIVERGED)")
    from repro.runtime.chaos import PROFILES
    ap.add_argument("--chaos", default=None, choices=sorted(PROFILES),
                    help="(socket transport) run the measured iteration "
                         "through the fault-injection link layer with "
                         "this runtime.chaos profile and report injected "
                         "faults, ARQ recovery work, and the chaos "
                         "verdict next to the measured comm table")
    ap.add_argument("--serve", action="store_true",
                    help="also run an in-process serving micro-report "
                         "(continuous-batching scoring engine): p50/p99 "
                         "latency, throughput, the infer.wx_share wire "
                         "identity, and the served-vs-one-shot verdict "
                         "(docs/serving.md)")
    ap.add_argument("--out", default="results/secure_dryrun.json")
    args = ap.parse_args()

    if args.checkpoint_dir and args.transport != "socket":
        raise SystemExit("--checkpoint-dir needs --transport socket "
                         "(party-local checkpoints live in the party "
                         "processes)")
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume needs --checkpoint-dir")
    if args.chaos and args.transport != "socket":
        raise SystemExit("--chaos needs --transport socket (the fault-"
                         "injection link layer wraps the TCP transport)")
    try:
        dims = tuple(int(v) for v in args.mesh.lower().split("x"))
        assert len(dims) == 3 and all(d >= 1 for d in dims)
    except (ValueError, AssertionError):
        raise SystemExit(f"--mesh must be PxDxM (got {args.mesh!r})")
    n_dev = len(jax.devices())
    if int(jnp.prod(jnp.asarray(dims))) > n_dev:
        raise SystemExit(f"--mesh {args.mesh} needs {dims[0]*dims[1]*dims[2]}"
                         f" devices; only {n_dev} forced host devices")
    # the shard_map in_specs and the analytic roofline both assume exact
    # divisibility — fail loudly instead of reporting a zeroed roofline
    d_sz_, m_sz_ = dims[1], dims[2]
    pow2_axes = (d_sz_,) if args.shard_mode == "feature" else (d_sz_, m_sz_)
    if any(s & (s - 1) for s in pow2_axes):
        raise SystemExit(f"--mesh {args.mesh}: the homomorphic ⊕-ladder "
                         "(modmul_reduce butterfly) needs power-of-two "
                         "sample-shard axes (data; also model in "
                         "sample2d mode)")
    samp_div = d_sz_ if args.shard_mode == "feature" else d_sz_ * m_sz_
    if args.samples % samp_div:
        raise SystemExit(f"--samples {args.samples} must be a multiple of "
                         f"the sample shard factor {samp_div} (mesh "
                         f"{args.mesh}, shard-mode {args.shard_mode})")
    if args.shard_mode == "feature" and args.features % m_sz_:
        raise SystemExit(f"--features {args.features} must be a multiple "
                         f"of the model axis size {m_sz_} in feature "
                         "shard-mode")
    mesh = mesh_lib._make_mesh(dims, ("pod", "data", "model"))
    # a real key size's modulus shape — value content irrelevant for
    # lowering, but Modulus wants a genuine odd modulus for its constants
    mod = Modulus.make((1 << (2 * args.key_bits)) - 159)
    step = make_secure_grad_step(mesh, mod, args.width, args.window,
                                 args.shard_mode,
                                 engine=engine_mod.make(args.engine))

    n, m, L2 = args.samples, args.features, mod.L
    u32 = jnp.uint32
    # the [[⟨d⟩]] operand is exactly the runtime's P3.enc_d envelope,
    # lowered pod-major (pod axis = party); locals (exps, own d-share)
    # never cross the transport and are plain arrays.
    enc_d_spec = msg_lib.EncD.mesh_payload_spec(2, n, L2)
    specs = (
        jax.ShapeDtypeStruct((2, n, m), u32),
        enc_d_spec,
        jax.ShapeDtypeStruct((2, n), u32),
        jax.ShapeDtypeStruct((2, n), u32),
    )
    in_shardings = (
        NamedSharding(mesh, P("pod", "data", "model")),
        NamedSharding(mesh, P("pod", "data", None)),
        NamedSharding(mesh, P("pod", "data")),
        NamedSharding(mesh, P("pod", "data")),
    )
    t0 = time.time()
    lowered = jax.jit(step, in_shardings=in_shardings).lower(*specs)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text())
    # analytic roofline terms (HLO counts scan bodies once) — per-device
    # local sizes follow the chosen mesh shape
    d_sz, m_sz = mesh.shape["data"], mesh.shape["model"]
    if args.shard_mode == "feature":
        n_loc, m_loc, ladder = n // d_sz, max(m // m_sz, 1), d_sz
    else:
        n_loc, m_loc, ladder = n // (d_sz * m_sz), m, d_sz * m_sz
    mm = montmul_count(n_loc, m_loc, args.width, args.window, ladder)
    flops = mm * flops_per_montmul(L2)
    # HBM: ciphertext block re-read per ladder level + exps + outputs
    levels = args.width if args.window <= 1 else -(-args.width
                                                   // args.window)
    hbm = (n_loc * L2 * 4) * levels + n_loc * m_loc * 4
    coll = m_loc * L2 * 4 * max(d_sz.bit_length() - 1, 0)  # ⊕-ladder hops
    # per-iteration cross-party traffic, synthesized from the same typed
    # Message envelopes the live runtime routes (comm columns + rounds)
    by_tag, rounds = msg_lib.iteration_traffic(
        n_parties=2, nb=n, m_per_party=m, key_bits=args.key_bits)
    res = {
        "kind": "secure_efmvfl_grad_step",
        "mesh": args.mesh, "key_bits": args.key_bits,
        "engine": args.engine,
        "samples": n, "features": m, "exp_width": args.width,
        "window": args.window, "shard_mode": args.shard_mode,
        "montmuls_per_dev": mm,
        "compile_s": round(time.time() - t0, 1),
        "peak_bytes_per_dev": peak_bytes(ma),
        "flops_per_dev": flops,
        "hbm_bytes_per_dev": float(hbm),
        "raw_hlo": {"flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0))},
        "collectives": colls,
        "protocol_comm": {
            "per_iteration_mb_by_tag": {k: v / 1e6
                                        for k, v in sorted(by_tag.items())},
            "per_iteration_rounds": rounds,
        },
        **roofline_terms(flops, float(hbm), float(coll)),
        "ok": True,
    }
    if args.tables:
        res["fixed_base_tables"] = tables_report(
            args.tables, args.key_bits, args.engine, nb=n, m=m)
    if args.transport != "none":
        res["measured_comm"] = measured_comm(
            args.transport, m, args.key_bits,
            checkpoint_dir=args.checkpoint_dir,
            resume_drill=args.resume, chaos=args.chaos)
    if args.serve:
        res["serving"] = serving_report()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives",)}, indent=1))
    print("collective ops:", res["collectives"]["op_counts"])


if __name__ == "__main__":
    main()
