"""Analytic per-device cost model: FLOPs, HBM bytes, collective bytes for
every (arch × shape-cell × mesh).

WHY ANALYTIC: XLA's `compiled.cost_analysis()` counts each while-loop
(lax.scan) body ONCE — for an 80-layer scanned stack or a 32k-step
recurrence it undercounts by orders of magnitude (verified in
tests/test_costmodel.py, which also validates this model against
cost_analysis on fully-unrolled small configs, where XLA's numbers ARE
exact).  The dry-run keeps the raw HLO numbers and the collective op
counts for structural cross-checks; the roofline terms come from here.

Conventions
  * flops counted as 2·M·N·K per matmul; backward = 2× forward; full
    remat adds one forward recompute (train multiplier 4, else 3).
  * attention: causal S_att = (S+1)/2 per query; sliding window w:
    S_att = min(w, (S+1)/2); decode S_att = context length.
  * HBM bytes: weight reads (per TP shard), activation traffic
    (ACT_TENSORS_PER_LAYER·d per token per layer), KV/state reads for
    decode, f32 logits.  Optimizer traffic included for train.
  * collective wire bytes per device: ring factor (g-1)/g ≈ 1 applied;
    all-reduce counted 2× payload, all-gather/reduce-scatter 1×.
Knobs (kv dtype, last-token-logits, …) are explicit so §Perf iterations
change the model the same way they change the lowered program.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeCell


def xla_cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a dict across jax versions (older
    releases return a one-element list of per-computation dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


BF16 = 2
F32 = 4
ACT_TENSORS_PER_LAYER = 8     # saved/streamed activation tensors per layer


@dataclasses.dataclass(frozen=True)
class MeshModel:
    n_dev: int
    tp: int          # model axis
    dp: int          # data axis (per pod)
    pods: int = 1


@dataclasses.dataclass(frozen=True)
class CostKnobs:
    """§Perf iteration knobs — must mirror what the lowered step does."""
    kv_cache_bytes: int = BF16          # int8 KV → 1
    prefill_last_logits_only: bool = False
    decode_kv_gather: bool = True       # seq-sharded KV all-gather per layer
    moe_capacity_factor: float = 1.25
    train_remat: bool = True


@dataclasses.dataclass
class Costs:
    flops: float = 0.0          # per device
    hbm_bytes: float = 0.0      # per device
    coll_bytes: float = 0.0     # per device (wire)

    def add(self, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll


def mesh_model(mesh) -> MeshModel:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return MeshModel(n_dev=mesh.devices.size, tp=shape.get("model", 1),
                     dp=shape.get("data", 1), pods=shape.get("pod", 1))


# ---------------------------------------------------------------------------
# per-token forward flops by family (total, not per-device)
# ---------------------------------------------------------------------------

def _attn_flops_token(cfg, s_att: float) -> float:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj = 2 * d * hd * (H + 2 * K) + 2 * H * hd * d
    scores = 4 * s_att * H * hd
    return proj + scores


def _mlp_flops_token(cfg, f=None) -> float:
    f = f or cfg.d_ff
    mults = 3 if cfg.act == "silu" else 2
    return 2 * cfg.d_model * f * mults


def _moe_flops_token(cfg, knobs: CostKnobs) -> float:
    f = cfg.moe_d_ff or cfg.d_ff
    router = 2 * cfg.d_model * cfg.n_experts
    experts = (2 * cfg.d_model * f * 3 * cfg.experts_per_token
               * knobs.moe_capacity_factor)
    return router + experts


def _s_att(cfg, S: int, layer_window) -> float:
    half = (S + 1) / 2
    return min(layer_window, half) if layer_window else half


def _dense_layer_flops_token(cfg, S, knobs, decode_ctx=None) -> float:
    """Average per-layer flops/token over the (possibly 5:1) layer mix."""
    L = cfg.n_layers
    if cfg.local_global_ratio and cfg.sliding_window:
        r = cfg.local_global_ratio
        n_local = sum(1 for i in range(L) if (i % (r + 1)) != r)
        w = cfg.sliding_window
    else:
        n_local, w = 0, None
    if decode_ctx is not None:
        s_local = min(w, decode_ctx) if w else decode_ctx
        s_global = decode_ctx
    else:
        s_local = _s_att(cfg, S, w)
        s_global = _s_att(cfg, S, None)
    att = (n_local * _attn_flops_token(cfg, s_local)
           + (L - n_local) * _attn_flops_token(cfg, s_global)) / L
    ff = _moe_flops_token(cfg, knobs) if cfg.n_experts \
        else _mlp_flops_token(cfg)
    return att + ff


def _rwkv_layer_flops_token(cfg) -> float:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.hd
    proj = 2 * d * d * 5 + 2 * d * 64 * 2          # r,k,v,g,o + decay LoRA
    wkv = 5 * d * hd                               # state update + readout
    cmix = 2 * (2 * d * f + d * d)
    return proj + wkv + cmix


def _mamba_layer_flops_token(cfg) -> float:
    d = cfg.d_model
    d_inner = 2 * d
    hm = d_inner // 64
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    in_p = 2 * d * (d_inner + conv_dim + hm)
    conv = 2 * cfg.conv_width * conv_dim
    ssd = 8 * hm * 64 * N
    out_p = 2 * d_inner * d
    return in_p + conv + ssd + out_p


def forward_flops_total(cfg: ModelConfig, cell: ShapeCell,
                        knobs: CostKnobs) -> float:
    B, S = cell.global_batch, cell.seq_len
    decode = cell.kind == "decode"
    T = B if decode else B * S
    head_T = T if not (cell.kind == "prefill"
                       and knobs.prefill_last_logits_only) else B
    head = 2 * cfg.d_model * cfg.vocab_size * head_T

    if cfg.family in ("dense", "moe", "vlm"):
        S_eff = S + (cfg.vision_patches if cfg.family == "vlm" else 0)
        T_eff = B if decode else B * S_eff
        per_layer = _dense_layer_flops_token(
            cfg, S_eff, knobs, decode_ctx=S if decode else None)
        return T_eff * cfg.n_layers * per_layer + head

    if cfg.family == "ssm":
        return T * cfg.n_layers * _rwkv_layer_flops_token(cfg) + head

    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        n_shared = cfg.n_layers // k if k else 0
        mamba = T * cfg.n_layers * _mamba_layer_flops_token(cfg)
        s_att = S if decode else _s_att(cfg, S, None)
        shared = T * n_shared * (_attn_flops_token(
            dataclasses.replace(cfg), s_att) + _mlp_flops_token(cfg))
        return mamba + shared + head

    if cfg.family == "audio":
        F = cfg.encoder_seq
        enc = B * F * cfg.encoder_layers * (
            _attn_flops_token(cfg, F) + _mlp_flops_token(cfg))
        if decode:
            enc = 0.0       # encoder states precomputed (enc_out input)
        self_att = T * cfg.n_layers * _attn_flops_token(
            cfg, S if decode else _s_att(cfg, S, None))
        cross = T * cfg.n_layers * (
            2 * cfg.d_model * cfg.hd * cfg.n_heads * 2 + 4 * F
            * cfg.n_heads * cfg.hd)
        mlp = T * cfg.n_layers * _mlp_flops_token(cfg)
        return enc + self_att + cross + mlp + head

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# params / kv-cache bytes
# ---------------------------------------------------------------------------

def params_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BF16


def kv_cache_bytes_total(cfg: ModelConfig, cell: ShapeCell,
                         knobs: CostKnobs) -> float:
    B, S = cell.global_batch, cell.seq_len
    bpe = knobs.kv_cache_bytes
    rep = getattr(cfg, "kv_head_replication", 1)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return (cfg.n_layers * B * S * cfg.n_kv_heads * rep
                * cfg.hd * 2 * bpe)
    if cfg.family == "ssm":
        hd = cfg.d_model // cfg.n_heads
        return cfg.n_layers * B * (cfg.n_heads * hd * hd * F32
                                   + 2 * cfg.d_model * BF16)
    if cfg.family == "hybrid":
        d_inner = 2 * cfg.d_model
        hm = d_inner // 64
        n_sh = cfg.n_layers // max(1, cfg.shared_attn_every)
        mamba = cfg.n_layers * B * (hm * 64 * cfg.ssm_state * F32
                                    + (cfg.conv_width - 1)
                                    * (d_inner + 2 * cfg.ssm_state) * BF16)
        attn = n_sh * B * S * cfg.n_kv_heads * cfg.hd * 2 * bpe
        return mamba + attn
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# the full model
# ---------------------------------------------------------------------------

def cell_costs(cfg: ModelConfig, cell: ShapeCell, mesh,
               knobs: CostKnobs | None = None) -> dict:
    knobs = knobs or CostKnobs(
        train_remat=cfg.remat,
        kv_cache_bytes=1 if cfg.kv_cache_dtype == "int8" else BF16)
    mm = mesh_model(mesh)
    c = Costs()
    train = cell.kind == "train"
    decode = cell.kind == "decode"
    B, S = cell.global_batch, cell.seq_len
    T = B if decode else B * S
    T_loc = max(T / (mm.dp * mm.pods), 1.0)
    pbytes = params_bytes(cfg)
    pbytes_tp = pbytes / mm.tp                 # a device's TP slice
    L_all = cfg.n_layers + cfg.encoder_layers

    # ---- FLOPs ----------------------------------------------------------
    fwd = forward_flops_total(cfg, cell, knobs)
    mult = (4.0 if knobs.train_remat else 3.0) if train else 1.0
    c.add(flops=fwd * mult / mm.n_dev)

    # ---- HBM bytes ------------------------------------------------------
    passes = 3.0 if train else 1.0            # fwd + bwd-act + bwd-wt reads
    if decode:
        # weights stay fully sharded and resident (HLO census: XLA moves
        # the tiny activations, not weights) — each device reads its own
        # 1/n_dev shard per step
        c.add(hbm=pbytes / mm.n_dev)
    else:
        c.add(hbm=pbytes_tp * passes)
    if train:
        opt_mult = {"adamw": 3.0, "momentum": 2.0, "sgd": 1.0}.get(
            cfg.optimizer, 2.0)
        c.add(hbm=(pbytes / mm.n_dev) * 2.0 * opt_mult)   # opt read+write
    act_bytes = (T_loc * cfg.d_model * BF16
                 * ACT_TENSORS_PER_LAYER * L_all)
    c.add(hbm=act_bytes * (2.0 if train else 1.0))
    head_T_loc = (B / (mm.dp * mm.pods)) if (
        decode or (cell.kind == "prefill"
                   and knobs.prefill_last_logits_only)) else T_loc
    c.add(hbm=head_T_loc * cfg.vocab_size * F32 / mm.tp)  # f32 logits
    kvb = kv_cache_bytes_total(cfg, cell, knobs)
    if decode:
        c.add(hbm=kvb / mm.n_dev * 2)          # read ~full cache + write row
    elif cell.kind == "prefill":
        c.add(hbm=kvb / mm.n_dev)              # write the cache once

    # ---- collective bytes ------------------------------------------------
    dt_act = BF16
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        n_tp_layers = L_all if cfg.family != "hybrid" else (
            cfg.n_layers // max(1, cfg.shared_attn_every))
        # Megatron TP: 2 activation all-reduces per layer fwd (×2 wire),
        # mirrored in bwd for train
        ar_events = 2 * n_tp_layers * (2 if train else 1)
        c.add(coll=ar_events * 2 * T_loc * cfg.d_model * dt_act)
    if cfg.family == "ssm":
        ar_events = 2 * cfg.n_layers * (2 if train else 1)
        c.add(coll=ar_events * 2 * T_loc * cfg.d_model * dt_act)
    # FSDP param all-gathers (fwd + bwd) + grad reduce-scatter.
    # Decode is the exception: the HLO census shows XLA keeps weights
    # resident and moves the (tiny) activations instead — charge
    # activation-side gathers only (verified against the kimi decode HLO:
    # ~300 MiB/layer of all-gathers, no multi-GB weight gathers).
    if train:
        c.add(coll=pbytes_tp * 2.0 + pbytes_tp * 1.0)
        if mm.pods > 1:                        # cross-pod DP all-reduce
            c.add(coll=2.0 * pbytes / mm.n_dev)
    elif cell.kind == "prefill":
        c.add(coll=pbytes_tp * 1.0)            # weights gathered once
    else:                                      # decode: activation gathers
        c.add(coll=L_all * 2 * B * cfg.d_model * dt_act)
    if cfg.n_experts:                          # EP all-to-alls
        a2a = 4 * T_loc * cfg.d_model * dt_act * (1 if not train else 2)
        c.add(coll=a2a * cfg.n_layers)
    if decode and knobs.decode_kv_gather and \
            cfg.family in ("dense", "moe", "vlm", "audio"):
        kv_shardable = cfg.n_kv_heads * getattr(
            cfg, "kv_head_replication", 1)
        if kv_shardable % mm.tp != 0:          # seq-sharded KV → gather
            c.add(coll=kvb / mm.n_dev * (mm.tp - 1))
    return {
        "flops_per_dev": c.flops,
        "hbm_bytes_per_dev": c.hbm_bytes,
        "coll_bytes_per_dev": c.coll_bytes,
        "params_bytes_total": pbytes,
        "kv_bytes_total": kvb,
        "knobs": dataclasses.asdict(knobs),
    }
