"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch gpt-100m --steps 300 \
      --batch 8 --seq 256 --ckpt-dir /tmp/run1 [--resume]

Production posture on one binary:
  * pjit with the sharding rules (single device == trivial mesh),
  * resumable data pipeline + atomic async checkpoints (auto-resume),
  * preemption-safe: SIGTERM/SIGINT triggers a final checkpoint,
  * optional int8+error-feedback gradient compression across the pod axis.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data.tokens import TokenStream
from repro.launch.steps import make_train_step
from repro.models import registry as models


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--die-at-step", type=int, default=None,
                    help="fault-injection hook for the recovery test")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    api = models.build(cfg)
    tc = TrainConfig(lr=args.lr, seed=args.seed)
    opt, step_fn = make_train_step(api, tc)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq,
                         seed=args.seed)
    params = api.init_params(jax.random.key(args.seed))
    opt_state = opt.init(params)
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    if mgr and args.resume:
        restored = mgr.restore({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            stream.load_state(extra["stream"])
            print(f"[resume] from step {start_step}")

    stop = {"flag": False}

    def _graceful(signum, frame):   # preemption: checkpoint then exit
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _graceful)

    losses = []
    t0 = time.time()
    step = start_step
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        loss, gnorm, params, opt_state = jstep(params, opt_state, batch)
        losses.append(float(loss))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                           extra={"stream": stream.save_state()})
        if args.die_at_step is not None and step + 1 == args.die_at_step:
            print("[fault-injection] simulating node failure", flush=True)
            sys.exit(42)
        if stop["flag"]:
            break
    if mgr:
        mgr.save(step + 1, {"params": params, "opt": opt_state},
                 extra={"stream": stream.save_state()})
        mgr.wait()
    result = {"final_loss": losses[-1] if losses else None,
              "first_loss": losses[0] if losses else None,
              "steps": len(losses),
              "params": params}
    print(f"[done] steps={len(losses)} first={result['first_loss']:.4f} "
          f"final={result['final_loss']:.4f}")
    return result


if __name__ == "__main__":
    main()
