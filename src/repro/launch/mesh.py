"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device; only
dryrun.py forces 512 host devices — before any jax import).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                   # newer jax: explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # pinned toolchain: Auto is implicit
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2 pods × 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4,
                    multi_pod: bool = False) -> Mesh:
    """Small mesh for in-CI dry-run smoke tests (8 host devices)."""
    if multi_pod:
        return _make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return _make_mesh((n_data, n_model), ("data", "model"))
