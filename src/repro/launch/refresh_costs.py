"""Recompute the analytic cost-model fields of existing dry-run JSONs in
place (compile artifacts — memory analysis, HLO census — are reused; only
the costmodel-derived roofline terms are refreshed).  Used when the cost
model is refined after an expensive compile sweep.

  PYTHONPATH=src python -m repro.launch.refresh_costs results/dryrun ...
"""
from __future__ import annotations

import glob
import json
import sys

from repro.configs import registry
from repro.configs.base import SHAPE_CELLS
from repro.launch import costmodel
from repro.launch.dryrun import _apply_overrides, roofline_terms


class _MeshShim:
    def __init__(self, mesh_tag: str):
        dims = tuple(int(x) for x in mesh_tag.split("x"))
        names = ("data", "model") if len(dims) == 2 else \
            ("pod", "data", "model")
        self.axis_names = names
        self.devices = type("D", (), {})()
        self.devices.shape = dims
        self.devices.size = 1
        for d in dims:
            self.devices.size *= d


def refresh(path: str) -> bool:
    with open(path) as f:
        d = json.load(f)
    if not d.get("ok") or "cell" not in d:
        return False
    cfg = _apply_overrides(registry.get_config(d["arch"]),
                           d.get("overrides"))
    cell = SHAPE_CELLS[d["cell"]]
    mesh = _MeshShim(d["mesh"])
    costs = costmodel.cell_costs(cfg, cell, mesh)
    d["costmodel"] = costs
    d["flops_per_dev"] = costs["flops_per_dev"]
    d["hbm_bytes_per_dev"] = costs["hbm_bytes_per_dev"]
    d["coll_bytes_per_dev"] = costs["coll_bytes_per_dev"]
    d.update(roofline_terms(costs["flops_per_dev"],
                            costs["hbm_bytes_per_dev"],
                            costs["coll_bytes_per_dev"]))
    d["useful_flops_ratio"] = (d["model_flops_total"]
                               / (costs["flops_per_dev"]
                                  * d["n_devices"])) \
        if costs["flops_per_dev"] else 0.0
    with open(path, "w") as f:
        json.dump(d, f, indent=1)
    return True


def main() -> None:
    n = 0
    for pattern in sys.argv[1:] or ["results/dryrun"]:
        for path in sorted(glob.glob(pattern + "/*.json")):
            if refresh(path):
                n += 1
    print(f"refreshed {n} records")


if __name__ == "__main__":
    main()
