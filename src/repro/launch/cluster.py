"""Localhost cluster launcher: k EFMVFL party processes + a conductor.

Spawns one real OS process per party (`runtime.netparty.PartyServer`
via the multiprocessing *spawn* context — fresh interpreters, no shared
memory), wires the control plane over TCP, and drives Algorithm 1 by
`iter`/`iter_done` barrier frames.  All protocol traffic (shares,
ciphertexts, Beaver openings, flags) flows party↔party over the mesh —
the conductor never carries a share or a ciphertext, so the paper's
no-third-party trust model survives deployment.

The trained model is bit-identical to the single-process
`LocalTransport` run (losses, weights, per-tag bytes) under fixed CP
selection — asserted by `tests/test_runtime_parity.py` — and the
per-tag *measured* payload bytes (actual encoded frames) equal the
analytic `wire_bytes()` accounting exactly.

Crash recovery: with `checkpoint_dir` + `cfg.checkpoint_every`, every
party durably checkpoints its own state slice, and
`train_vfl_socket_resilient` supervises the run — on any party loss it
force-restarts the cluster with `resume=True`, the resume handshake
agrees on the max common checkpointed step, and training continues
bit-identically (docs/fault_tolerance.md, tests/test_resumable.py).

CLI (trains a synthetic run across real processes and prints the
measured-vs-analytic wire table):

  PYTHONPATH=src python -m repro.launch.cluster \
      [--glm logistic] [--parties 3] [--samples 400] [--iters 4] \
      [--he mock|paillier] [--key-bits 256]
"""
from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import queue as queue_lib
import socket
import time
from typing import Sequence

import numpy as np

from repro.core.comm import CommMeter
from repro.distributed import compression as comp_lib
from repro.runtime import chaos as chaos_lib
from repro.runtime import messages as msg
from repro.runtime import netparty, seeds
from repro.runtime.codec import Codec
from repro.runtime.netparty import CONDUCTOR, IO_TIMEOUT_S
from repro.runtime.policy import RetryPolicy
from repro.runtime.scheduler import mask_bound_bits, validate_key_bits
from repro.runtime.transport import SocketTransport


class ClusterError(RuntimeError):
    """A party process failed (carries the remote traceback if it
    managed to ship one).  `party` attributes the failure to a party
    name when the conductor can tell which one — the supervisor's
    flap-quarantine accounting keys on it (None = unattributed)."""

    def __init__(self, message: str, party: str | None = None):
        super().__init__(message)
        self.party = party


class FatalClusterError(ClusterError):
    """A deterministic refusal (e.g. `CheckpointMismatch`): restarting
    cannot help, so the supervisor re-raises instead of relaunching."""


#: remote exception types a restart can never fix — the party reports
#: the type name in its `error` frame (`netparty.PartyServer.run`).
#: `StaleCacheError` is the serving-path analogue of a checkpoint
#: mismatch: a version/key-fingerprint refusal replays identically.
NON_RETRYABLE_ERRORS = frozenset({"CheckpointMismatch", "StaleCacheError"})


class SocketCluster:
    """Handle on a running party cluster.

    Args:
      parties: `PartyData`-shaped sequence (`.name`, `.X`); index 0 must
        be C, the label holder.
      y: labels, handed only to C's process.
      cfg: `core.trainer.VFLConfig` — carried to every party in the
        handshake (the run seed inside it is the root of every derived
        stream, see `runtime.netparty`).
      host: bind/connect address (default loopback).

    Use as a context manager (`with SocketCluster(...) as cl:`) or call
    `start()` / `shutdown()` explicitly.  `train()` may be called once;
    `score()` any number of times afterwards.
    """

    def __init__(self, parties: Sequence, y: np.ndarray, cfg,
                 host: str = "127.0.0.1",
                 io_timeout: float | None = None,
                 checkpoint_dir: str | None = None, resume: bool = False,
                 policy: RetryPolicy | None = None, chaos=None):
        assert parties[0].name == "C", "parties[0] must be C"
        validate_key_bits(cfg, mask_bound_bits(cfg))   # fail before spawning
        self.parties = list(parties)
        self.names = [p.name for p in parties]
        self.y = np.asarray(y, np.float64)
        self.cfg = cfg
        self.host = host
        # ONE policy block owns every timeout/heartbeat/backoff constant
        # of the cluster (runtime/policy.py); the legacy `io_timeout`
        # float is folded into it for back-compat
        if policy is None:
            policy = RetryPolicy.from_env() if io_timeout is None \
                else RetryPolicy.from_env(io_timeout_s=float(io_timeout))
        elif io_timeout is not None:
            policy = RetryPolicy.from_dict(
                dict(policy.to_dict(), io_timeout_s=float(io_timeout)))
        self.policy = policy
        self.io_timeout = policy.io_timeout_s
        self.chaos = chaos_lib.resolve_profile(chaos)
        self.compression = comp_lib.validate_wire_scheme(
            getattr(cfg, "wire_compression", "none"))
        self.checkpoint_dir = checkpoint_dir
        self.resume = resume
        #: filled by the resume handshake: agreed step + audited per-party
        #: stream counters (see docs/fault_tolerance.md)
        self.resume_report: dict = {}
        self.procs: dict[str, mp.process.BaseProcess] = {}
        self.tp: SocketTransport | None = None
        self.n_iter = 0
        self.start_it = 0
        self._resume_stop = False
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "SocketCluster":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def start(self) -> None:
        """Spawn + wire the cluster; tears everything down on failure
        (a half-started cluster must not leak party processes — __exit__
        never runs when __enter__ raises)."""
        try:
            self._start()
            self._started = True
        except BaseException:
            self.shutdown()
            raise

    def _wire_options(self) -> dict:
        """Link configuration shipped to every party via spawn args
        (deadlines must exist before the handshake frame can travel)."""
        return {"policy": self.policy.to_dict(),
                "chaos": None if self.chaos is None else
                self.chaos.to_dict(),
                "compression": self.compression}

    def _make_transport(self) -> SocketTransport:
        if self.chaos is None and self.compression == "none":
            return SocketTransport(CONDUCTOR, Codec())
        return chaos_lib.FaultyTransport(
            CONDUCTOR, Codec(), profile=self.chaos or None,
            policy=self.policy, compression=self.compression)

    def _start(self) -> None:
        ctx = mp.get_context("spawn")
        ready: mp.queues.Queue = ctx.Queue()
        wire = self._wire_options()
        for p in self.parties:
            y = self.y if p.name == "C" else None
            proc = ctx.Process(
                target=netparty.run_party_server,
                args=(p.name, np.asarray(p.X, np.float64), y, ready,
                      self.host, self.checkpoint_dir, wire),
                name=f"vfl-party-{p.name}", daemon=True)
            proc.start()
            self.procs[p.name] = proc
        ports: dict[str, int] = {}
        deadline = time.monotonic() + self.policy.connect_timeout()
        while len(ports) < len(self.names):
            try:
                name, port = ready.get(timeout=self.policy.poll_interval_s)
                ports[name] = port
            except queue_lib.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    missing = sorted(set(self.names) - set(ports))
                    raise ClusterError(
                        "timed out waiting for party ports",
                        party=missing[0] if len(missing) == 1 else None)
        self.tp = self._make_transport()
        for name in self.names:
            s = socket.create_connection(
                (self.host, ports[name]),
                timeout=self.policy.connect_timeout())
            s.settimeout(self.io_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self.tp.attach(name, s)
        roster = [[name, self.host, ports[name]] for name in self.names]
        cfg_dict = dataclasses.asdict(self.cfg)
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="handshake",
                payload={"roster": roster, "cfg": cfg_dict,
                         "resume": bool(self.resume)}))
        if self.cfg.he_backend != "mock":
            anns = self._collect("pubkey")
            keys = {a.payload["name"]: a.payload["n"] for a in anns.values()}
            for name in self.names:
                self.tp.send_control(msg.Control(
                    CONDUCTOR, name, kind="pubkeys",
                    payload={"keys": keys}))
        ready = self._collect("ready")
        if self.resume:
            self._resume_handshake(ready)
        # conductor→party keep-alives: an idle party's event-queue timeout
        # stays a genuine failure detector during long quiet phases
        hb = self.policy.heartbeat_interval()
        for name in self.names:
            self.tp.start_heartbeat(name, hb)

    def _resume_handshake(self, ready: dict[str, msg.Control]) -> None:
        """Agree on the max COMMON checkpointed step, roll every party
        back to it, and audit the recovered stream positions: the
        replicated counters (Beaver-dealer draws, batch cursor) must be
        identical across all k parties or the resume is refused."""
        sets = [set(int(s) for s in (m.payload or {}).get("ckpt_steps", []))
                for m in ready.values()]
        common = set.intersection(*sets) if sets else set()
        step = max(common) if common else 0
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="resume",
                payload={"step": int(step)}))
        acks = self._collect("resume_ok")
        replicated = {(int(a.payload["dealer_drawn"]),
                       int(a.payload["cursor"]))
                      for a in acks.values()}
        if len(replicated) != 1:
            detail = {n: {"dealer_drawn": a.payload["dealer_drawn"],
                          "cursor": a.payload["cursor"]}
                      for n, a in acks.items()}
            raise ClusterError(
                "resume refused: replicated stream positions disagree "
                f"across parties after rollback to step {step}: {detail}")
        self.start_it = int(step)
        self._resume_stop = bool(acks["C"].payload.get("stop", False))
        self.resume_report = {
            "step": int(step),
            "offered_steps": {n: sorted(int(s) for s in
                                        (m.payload or {})
                                        .get("ckpt_steps", []))
                              for n, m in ready.items()},
            "dealer_drawn": next(iter(replicated))[0],
            "cursor": next(iter(replicated))[1],
            "rng_drawn": {n: int(a.payload["rng_drawn"])
                          for n, a in acks.items()},
        }

    def shutdown(self, force: bool = False) -> None:
        """Tear the cluster down.  `force` skips the graceful
        shutdown/bye exchange — the supervisor uses it after a party
        loss, when surviving parties are wedged mid-protocol and the
        only safe recovery is kill + relaunch + resume."""
        if self.tp is not None:
            if not force:
                for name in self.names:
                    try:
                        self.tp.send_control(msg.Control(CONDUCTOR, name,
                                                         kind="shutdown"))
                    except Exception:        # noqa: BLE001 — best effort
                        pass
                try:
                    self._collect("bye", timeout=self.policy.bye_timeout_s)
                except Exception:            # noqa: BLE001
                    pass
                try:                         # drain shaped egress (acks)
                    self.tp.flush(timeout=self.policy.bye_timeout_s)
                except Exception:            # noqa: BLE001
                    pass
            self.tp.close()
            self.tp = None
        for proc in self.procs.values():
            if force and proc.is_alive():
                proc.kill()
            proc.join(timeout=self.policy.join_timeout_s)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=self.policy.term_timeout_s)
        self.procs.clear()
        self._started = False

    def kill_party(self, name: str) -> None:
        """SIGKILL one party process mid-run — failure injection for
        crash-recovery tests and drills (the supervisor path must bring
        the run back bit-identically from party-local checkpoints)."""
        proc = self.procs[name]
        proc.kill()
        proc.join(timeout=self.policy.term_timeout_s)

    # -- control-plane plumbing --------------------------------------------
    def _blame(self, payload: dict) -> str | None:
        """Pick the party a reported failure is attributed to.  A process
        that died from a signal (negative exitcode) is the root cause —
        collateral crashes exit 1 after filing their report, and the
        victim itself never files one.  Failing that, a peer whose link
        died outranks the reporter; last resort is the reporter itself."""
        victims = [n for n, p in self.procs.items()
                   if p.exitcode is not None and p.exitcode < 0]
        if len(victims) == 1:
            return victims[0]
        peer = payload.get("peer")
        if peer in self.names:
            return peer
        return payload.get("party")

    def _check_alive(self) -> None:
        dead = {n: p.exitcode for n, p in self.procs.items()
                if p.exitcode not in (None, 0)}
        if not dead:
            return
        # a signal death (SIGKILL/OOM) is the root cause; parties that
        # exited 1 afterwards are collateral of the lost links
        victims = [n for n, code in dead.items() if code < 0]
        name = victims[0] if len(victims) == 1 else next(iter(dead))
        raise ClusterError(
            f"party {name} exited with code {dead[name]} "
            f"(all non-zero exits: {dead})",
            party=name)

    def _collect(self, kind: str, timeout: float | None = None
                 ) -> dict[str, msg.Control]:
        """One control frame of `kind` from every party.  Failures are
        attributed to a party (`ClusterError.party`) whenever the
        conductor can tell which one caused them — the supervisor's
        quarantine accounting depends on it."""
        got: dict[str, msg.Control] = {}
        if timeout is None:
            timeout = self.policy.deadline_for(kind)
        deadline = time.monotonic() + timeout
        while len(got) < len(self.names):
            try:
                m = self.tp.inbound.get(
                    timeout=self.policy.poll_interval_s)
            except queue_lib.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    missing = sorted(set(self.names) - set(got))
                    raise ClusterError(
                        f"timed out waiting for {kind!r} from {missing}",
                        party=missing[0] if len(missing) == 1 else None)
                continue
            if not isinstance(m, msg.Control):
                raise ClusterError(
                    f"conductor received protocol frame {m.tag!r} — "
                    "parties must never route data through the conductor")
            if m.kind == "error":
                cls = FatalClusterError \
                    if m.payload.get("etype") in NON_RETRYABLE_ERRORS \
                    else ClusterError
                raise cls(
                    f"party {m.payload.get('party')} failed:\n"
                    f"{m.payload.get('traceback')}",
                    party=self._blame(m.payload))
            if m.kind == "__closed__":
                self._check_alive()
                raise ClusterError(f"lost connection to {m.src}",
                                   party=m.src)
            if m.kind != kind:
                raise ClusterError(f"expected {kind!r}, got {m.kind!r} "
                                   f"from {m.src}")
            got[m.src] = m
        return got

    # -- training -----------------------------------------------------------
    def _select_cps(self, rng) -> tuple[str, str]:
        if self.cfg.cp_selection == "random":
            i = rng.choice(len(self.names), size=2, replace=False)
            return (self.names[i[0]], self.names[i[1]])
        return (self.names[0], self.names[1])

    def train(self, kill_plan: dict[int, str] | None = None):
        """Run Algorithm 1 to completion; returns `TrainResult` with two
        extra attributes: `measured_meter` (per-tag bytes actually framed
        on the wire) and `wire_overhead_bytes` (codec prelude+header
        cost, excluded from the protocol meters).

        After a resume handshake, the loop continues from the agreed
        common step: the conductor re-derives its CP-selection stream
        position by replaying the draws of the already-completed
        iterations (the conductor has no durable state of its own — all
        durable state is party-local).

        `kill_plan` maps iteration → party name; the conductor SIGKILLs
        that party right after dispatching the iteration (one-shot:
        entries are consumed), producing a genuine mid-iteration crash
        for the supervisor to recover from."""
        from repro.core.trainer import TrainResult
        assert self._started, "call start() first"
        cfg = self.cfg
        # dedicated CP-selection stream (PipelinedTransport convention —
        # concurrent mask draws can't exist here, but the trajectory
        # stays comparable across the concurrent transports)
        select_rng = seeds.cp_select_rng(cfg.seed)
        for _ in range(self.start_it):          # replay completed draws
            self._select_cps(select_rng)
        t0 = time.perf_counter()
        stop = self._resume_stop
        it = self.start_it
        while it < cfg.max_iter and not stop:
            cps = self._select_cps(select_rng)
            for name in self.names:
                self.tp.send_control(msg.Control(
                    CONDUCTOR, name, kind="iter",
                    payload={"it": it, "cps": list(cps)}))
            if kill_plan and it in kill_plan:
                self.kill_party(kill_plan.pop(it))
            acks = self._collect("iter_done")
            stop = bool(acks["C"].payload["stop"])   # full loss trace comes
            it += 1                                  # with the fetch below
        self.n_iter = it
        # -- result collection (out of protocol; nothing metered) ---------
        for name in self.names:
            self.tp.send_control(msg.Control(CONDUCTOR, name, kind="fetch"))
        results = self._collect("result")
        weights = {}
        meter, measured = CommMeter(), CommMeter()
        overhead = 0
        chaos_by_party: dict[str, dict] = {}
        for name, r in results.items():
            weights[name] = np.asarray(r.payload["weights"], np.float64)
            for src, dst, tag, nbytes in r.payload["sends"]:
                meter.add(src, dst, tag, nbytes)
            for src, dst, tag, nbytes in r.payload["measured"]:
                measured.add(src, dst, tag, nbytes)
            overhead += int(r.payload["overhead_bytes"])
            if r.payload.get("chaos") is not None:
                chaos_by_party[name] = r.payload["chaos"]
        # analytic latency steps (the paper's rounds column); measured
        # wall-clock is runtime_s
        _, rounds_per_iter = msg.iteration_traffic(
            len(self.names), cfg.batch_size,
            max(p.X.shape[1] for p in self.parties), cfg.key_bits,
            glm=cfg.glm)
        res = TrainResult(
            weights=weights,
            losses=[float(v) for v in results["C"].payload["losses"]],
            meter=meter,
            runtime_s=time.perf_counter() - t0,
            n_iter=it,
            rounds=rounds_per_iter * it)
        res.measured_meter = measured
        res.wire_overhead_bytes = overhead
        stats = getattr(self.tp, "chaos_stats", None)
        if stats is not None:
            chaos_by_party[CONDUCTOR] = stats.to_dict()
        if chaos_by_party:
            # per-endpoint link-layer accounting + the fleet total —
            # kept strictly apart from the protocol meters above
            res.chaos_report = {
                "profile": None if self.chaos is None
                else self.chaos.to_dict(),
                "compression": self.compression,
                "by_endpoint": chaos_by_party,
                "total": chaos_lib.ChaosStats.merge(
                    chaos_by_party.values()),
            }
        return res

    # -- serving ------------------------------------------------------------
    def publish_model(self, version: int = 0) -> dict[str, str]:
        """Pin every party's CURRENT weights as served model `version`
        (each party builds its per-version serving cache — windowed
        digits + encrypted constant, repro/serve/cache.py).  Returns
        {party: key fingerprint} from the acks."""
        assert self._started, "call start() first"
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="publish",
                payload={"version": int(version)}))
        acks = self._collect("publish_ok")
        return {n: a.payload.get("key_fp") for n, a in acks.items()}

    def swap_model(self, step: int, version: int) -> dict[str, dict]:
        """Hot-model-swap barrier: every party loads its OWN TrainState
        slice from checkpoint `step` and republishes it as `version`;
        returns the per-party acks once ALL parties have swapped.  The
        caller must guarantee no scoring batch is in flight
        (`VFLScoringEngine` drains before issuing the swap)."""
        assert self._started, "call start() first"
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="swap",
                payload={"step": int(step), "version": int(version)}))
        acks = self._collect("swap_ok")
        return {n: dict(a.payload) for n, a in acks.items()}

    def fetch_meters(self) -> dict:
        """Out-of-protocol meter snapshot from every party (re-runs the
        `fetch` collection): cumulative analytic + measured per-tag
        `CommMeter`s summed across parties, plus frame overhead.  Lets
        the serving gauntlet assert measured == analytic for
        `infer.wx_share` after scoring traffic, the same invariant
        training asserts per tag."""
        assert self._started, "call start() first"
        for name in self.names:
            self.tp.send_control(msg.Control(CONDUCTOR, name, kind="fetch"))
        results = self._collect("result")
        meter, measured = CommMeter(), CommMeter()
        overhead = 0
        for r in results.values():
            for src, dst, tag, nbytes in r.payload["sends"]:
                meter.add(src, dst, tag, nbytes)
            for src, dst, tag, nbytes in r.payload["measured"]:
                measured.add(src, dst, tag, nbytes)
            overhead += int(r.payload["overhead_bytes"])
        return {"meter": meter, "measured": measured,
                "overhead_bytes": overhead}

    def score(self, features: dict[str, np.ndarray],
              version: int | None = None) -> np.ndarray:
        """Score a batch of vertically-split rows over the socket path.

        Args:
          features: party name -> (n_rows, m_p) feature block.
          version: published model version to score at (None = the live
            weights, unversioned legacy path).  A party whose serving
            cache disagrees refuses — `StaleCacheError`, surfaced as a
            non-retryable `FatalClusterError`.
        Returns:
          (n_rows,) predictions (inverse link applied at C).
        """
        assert self._started, "call start() first"
        rid = int(time.monotonic_ns() % (1 << 31))
        for name in self.names:
            rows = np.asarray(features[name], np.float64)
            if rows.ndim == 1:
                rows = rows[None, :]
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="score",
                payload={"rid": rid, "rows": rows.tolist(),
                         "version": None if version is None
                         else int(version)}))
        while True:
            try:
                m = self.tp.inbound.get(timeout=self.io_timeout)
            except queue_lib.Empty:
                self._check_alive()
                raise ClusterError("timed out waiting for score_result")
            if not isinstance(m, msg.Control):
                raise ClusterError(
                    f"conductor received protocol frame {m.tag!r} — "
                    "parties must never route data through the conductor")
            if m.kind == "score_result":
                if m.payload.get("rid") != rid:
                    continue          # stale result of an abandoned request
                return np.asarray(m.payload["preds"], np.float64)
            if m.kind == "error":
                cls = FatalClusterError \
                    if m.payload.get("etype") in NON_RETRYABLE_ERRORS \
                    else ClusterError
                raise cls(
                    f"party {m.payload.get('party')} failed:\n"
                    f"{m.payload.get('traceback')}",
                    party=self._blame(m.payload))
            if m.kind == "__closed__":
                self._check_alive()
                raise ClusterError(f"lost connection to {m.src}",
                                   party=m.src)
            raise ClusterError(
                f"expected 'score_result', got {m.kind!r} from {m.src}")


def train_vfl_socket(parties: Sequence, y: np.ndarray, cfg,
                     host: str = "127.0.0.1",
                     checkpoint_dir: str | None = None,
                     resume: bool = False, policy: RetryPolicy | None = None,
                     chaos=None):
    """One-call distributed training: spawn, train, tear down."""
    with SocketCluster(parties, y, cfg, host=host,
                       checkpoint_dir=checkpoint_dir, resume=resume,
                       policy=policy, chaos=chaos) as cl:
        res = cl.train()
        res.resume_report = dict(cl.resume_report)
        return res


def train_vfl_socket_resilient(parties: Sequence, y: np.ndarray, cfg,
                               checkpoint_dir: str,
                               host: str = "127.0.0.1",
                               max_restarts: int = 3,
                               kill_plan: dict[int, str] | None = None,
                               policy: RetryPolicy | None = None,
                               chaos=None,
                               standby: dict[str, object] | None = None,
                               flap_threshold: int = 2):
    """Supervised distributed training: survive party-process crashes,
    quarantine flapping parties, and admit standby replacements.

    Restart policy: on ANY cluster failure (party killed, wedged, or
    errored) the supervisor force-kills the remaining party processes,
    relaunches the full cluster with `resume=True`, and the resume
    handshake rolls every party back to the max common checkpointed
    step — mid-iteration state is deliberately NOT recovered (it is
    never durable), so recovery is always roll-back-and-replay, which
    keeps the trajectory bit-identical to an uninterrupted run
    (tests/test_resumable.py).  `cfg.checkpoint_every` must be > 0 for
    recovery to make progress; with it 0, every restart replays from
    scratch.

    Elastic epochs: failures attributed to a party
    (`ClusterError.party`) are counted; once a party has caused
    `flap_threshold` failures and `standby` holds a replacement for it
    (a `PartyData`-shaped replica with the SAME name and feature block
    — vertical FL fixes each party's columns, so a replacement is a
    standby replica of the role, not an arbitrary node), the flapping
    party is quarantined: the replacement object takes its roster slot
    at the restart boundary, and `distributed.elastic
    .party_handoff_plan` records exactly which checkpoint files the
    replacement resumes from.  The epoch boundary IS the restart/resume
    boundary, so admission never happens mid-iteration.

    Returns the final `TrainResult` with `res.restarts` (count),
    `res.resume_report` (last handshake audit), `res.failures`
    (per-party attributed counts), and — when quarantines happened —
    `res.quarantined` ({name: handoff plan}) attached.  Raises the
    final `ClusterError` after `max_restarts` consecutive failures.
    """
    import collections as _collections

    attempt = 0
    resume = False
    roster = list(parties)
    standby = dict(standby or {})
    failures: dict[str, int] = _collections.Counter()
    quarantined: dict[str, dict] = {}
    while True:
        cl = SocketCluster(roster, y, cfg, host=host,
                           checkpoint_dir=checkpoint_dir, resume=resume,
                           policy=policy, chaos=chaos)
        try:
            cl.start()
            res = cl.train(kill_plan=kill_plan)
            cl.shutdown()
            res.restarts = attempt
            res.resume_report = dict(cl.resume_report)
            res.failures = dict(failures)
            if quarantined:
                res.quarantined = dict(quarantined)
            return res
        except (ClusterError, OSError) as e:
            cl.shutdown(force=True)
            if isinstance(e, FatalClusterError):
                # deterministic refusal (config/codec mismatch) —
                # restarting replays the identical refusal; surface it
                raise
            # OSError covers the conductor's own send path dying on a
            # lost party (PeerClosed/ConnectionError/TimeoutError are
            # all OSError subclasses) — every transient loss restarts
            attempt += 1
            if attempt > max_restarts:
                raise
            culprit = getattr(e, "party", None)
            if culprit is not None:
                failures[culprit] += 1
                if (failures[culprit] >= flap_threshold
                        and culprit in standby
                        and culprit not in quarantined):
                    # graceful degradation: stop restarting the flapping
                    # process image; admit its standby replica with a
                    # recorded state-handoff plan
                    from repro.distributed import elastic
                    replacement = standby.pop(culprit)
                    assert getattr(replacement, "name", None) == culprit, \
                        "standby replacement must keep the party's name " \
                        "(vertical FL fixes each party's feature columns)"
                    roster = [replacement if p.name == culprit else p
                              for p in roster]
                    quarantined[culprit] = elastic.party_handoff_plan(
                        checkpoint_dir, culprit)
            resume = True
        except BaseException:
            # anything else (caller bug, KeyboardInterrupt) must not
            # leak k live party processes
            cl.shutdown(force=True)
            raise


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--glm", default="logistic",
                    choices=("logistic", "poisson", "linear", "gamma"))
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--features", type=int, default=12)
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--he", default="mock", choices=("mock", "paillier"))
    ap.add_argument("--key-bits", type=int, default=256)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--chaos", default=None,
                    choices=sorted(chaos_lib.PROFILES),
                    help="run under a named chaos/shaping profile")
    args = ap.parse_args()

    if args.glm in ("poisson", "gamma"):
        X, y = synthetic.dvisits(n=args.samples, seed=args.seed)
    else:
        X, y = synthetic.credit_default(n=args.samples, d=args.features,
                                        seed=args.seed)
    parts = vertical.split_columns(X, args.parties)
    names = ["C"] + [f"B{i}" for i in range(1, args.parties)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm=args.glm, lr=0.1, max_iter=args.iters,
                    batch_size=args.batch, he_backend=args.he,
                    key_bits=args.key_bits, tol=0.0, seed=args.seed)

    print(f"spawning {args.parties} party processes + conductor "
          f"({args.he} backend"
          + (f", chaos={args.chaos}" if args.chaos else "") + ")…")
    res = train_vfl_socket(parties, y, cfg, chaos=args.chaos)
    print(f"iterations : {res.n_iter}   losses: "
          f"{[round(v, 4) for v in res.losses]}")
    print(f"wall clock : {res.runtime_s:.2f}s")
    print("per-tag wire bytes (measured == analytic asserted per frame):")
    for tag in sorted(res.meter.by_tag):
        print(f"  {tag:18s} analytic {res.meter.by_tag[tag]:>10d} B   "
              f"measured {res.measured_meter.by_tag[tag]:>10d} B")
    print(f"frame overhead (preludes+headers, unmetered): "
          f"{res.wire_overhead_bytes} B")
    report = getattr(res, "chaos_report", None)
    if report is not None:
        t = report["total"]
        print("chaos link layer (injected / recovered, unmetered):")
        print(f"  injected : {t.get('drops', 0)} drops, "
              f"{t.get('dups', 0)} dups, {t.get('reorders', 0)} reorders, "
              f"{t.get('resets', 0)} resets, "
              f"{t.get('partitions', 0)} partitions")
        print(f"  recovery : {t.get('retransmits', 0)} retransmits "
              f"({t.get('retransmit_bytes', 0)} B), "
              f"{t.get('acks_sent', 0)} acks, "
              f"backoff {t.get('backoff_total_s', 0.0):.2f}s")


if __name__ == "__main__":
    main()
