"""Localhost cluster launcher: k EFMVFL party processes + a conductor.

Spawns one real OS process per party (`runtime.netparty.PartyServer`
via the multiprocessing *spawn* context — fresh interpreters, no shared
memory), wires the control plane over TCP, and drives Algorithm 1 by
`iter`/`iter_done` barrier frames.  All protocol traffic (shares,
ciphertexts, Beaver openings, flags) flows party↔party over the mesh —
the conductor never carries a share or a ciphertext, so the paper's
no-third-party trust model survives deployment.

The trained model is bit-identical to the single-process
`LocalTransport` run (losses, weights, per-tag bytes) under fixed CP
selection — asserted by `tests/test_runtime_parity.py` — and the
per-tag *measured* payload bytes (actual encoded frames) equal the
analytic `wire_bytes()` accounting exactly.

Crash recovery: with `checkpoint_dir` + `cfg.checkpoint_every`, every
party durably checkpoints its own state slice, and
`train_vfl_socket_resilient` supervises the run — on any party loss it
force-restarts the cluster with `resume=True`, the resume handshake
agrees on the max common checkpointed step, and training continues
bit-identically (docs/fault_tolerance.md, tests/test_resumable.py).

CLI (trains a synthetic run across real processes and prints the
measured-vs-analytic wire table):

  PYTHONPATH=src python -m repro.launch.cluster \
      [--glm logistic] [--parties 3] [--samples 400] [--iters 4] \
      [--he mock|paillier] [--key-bits 256]
"""
from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import queue as queue_lib
import socket
import time
from typing import Sequence

import numpy as np

from repro.core.comm import CommMeter
from repro.runtime import messages as msg
from repro.runtime import netparty, seeds
from repro.runtime.codec import Codec
from repro.runtime.netparty import CONDUCTOR, IO_TIMEOUT_S
from repro.runtime.scheduler import mask_bound_bits, validate_key_bits
from repro.runtime.transport import SocketTransport


class ClusterError(RuntimeError):
    """A party process failed (carries the remote traceback if it
    managed to ship one)."""


class FatalClusterError(ClusterError):
    """A deterministic refusal (e.g. `CheckpointMismatch`): restarting
    cannot help, so the supervisor re-raises instead of relaunching."""


#: remote exception types a restart can never fix — the party reports
#: the type name in its `error` frame (`netparty.PartyServer.run`)
NON_RETRYABLE_ERRORS = frozenset({"CheckpointMismatch"})


class SocketCluster:
    """Handle on a running party cluster.

    Args:
      parties: `PartyData`-shaped sequence (`.name`, `.X`); index 0 must
        be C, the label holder.
      y: labels, handed only to C's process.
      cfg: `core.trainer.VFLConfig` — carried to every party in the
        handshake (the run seed inside it is the root of every derived
        stream, see `runtime.netparty`).
      host: bind/connect address (default loopback).

    Use as a context manager (`with SocketCluster(...) as cl:`) or call
    `start()` / `shutdown()` explicitly.  `train()` may be called once;
    `score()` any number of times afterwards.
    """

    def __init__(self, parties: Sequence, y: np.ndarray, cfg,
                 host: str = "127.0.0.1", io_timeout: float = IO_TIMEOUT_S,
                 checkpoint_dir: str | None = None, resume: bool = False):
        assert parties[0].name == "C", "parties[0] must be C"
        validate_key_bits(cfg, mask_bound_bits(cfg))   # fail before spawning
        self.parties = list(parties)
        self.names = [p.name for p in parties]
        self.y = np.asarray(y, np.float64)
        self.cfg = cfg
        self.host = host
        self.io_timeout = io_timeout
        self.checkpoint_dir = checkpoint_dir
        self.resume = resume
        #: filled by the resume handshake: agreed step + audited per-party
        #: stream counters (see docs/fault_tolerance.md)
        self.resume_report: dict = {}
        self.procs: dict[str, mp.process.BaseProcess] = {}
        self.tp: SocketTransport | None = None
        self.n_iter = 0
        self.start_it = 0
        self._resume_stop = False
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "SocketCluster":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def start(self) -> None:
        """Spawn + wire the cluster; tears everything down on failure
        (a half-started cluster must not leak party processes — __exit__
        never runs when __enter__ raises)."""
        try:
            self._start()
            self._started = True
        except BaseException:
            self.shutdown()
            raise

    def _start(self) -> None:
        ctx = mp.get_context("spawn")
        ready: mp.queues.Queue = ctx.Queue()
        for p in self.parties:
            y = self.y if p.name == "C" else None
            proc = ctx.Process(
                target=netparty.run_party_server,
                args=(p.name, np.asarray(p.X, np.float64), y, ready,
                      self.host, self.checkpoint_dir),
                name=f"vfl-party-{p.name}", daemon=True)
            proc.start()
            self.procs[p.name] = proc
        ports: dict[str, int] = {}
        deadline = time.monotonic() + self.io_timeout
        while len(ports) < len(self.names):
            try:
                name, port = ready.get(timeout=1.0)
                ports[name] = port
            except queue_lib.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    raise ClusterError("timed out waiting for party ports")
        self.tp = SocketTransport(CONDUCTOR, Codec())
        for name in self.names:
            s = socket.create_connection((self.host, ports[name]),
                                         timeout=self.io_timeout)
            s.settimeout(self.io_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self.tp.attach(name, s)
        roster = [[name, self.host, ports[name]] for name in self.names]
        cfg_dict = dataclasses.asdict(self.cfg)
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="handshake",
                payload={"roster": roster, "cfg": cfg_dict,
                         "resume": bool(self.resume)}))
        if self.cfg.he_backend != "mock":
            anns = self._collect("pubkey")
            keys = {a.payload["name"]: a.payload["n"] for a in anns.values()}
            for name in self.names:
                self.tp.send_control(msg.Control(
                    CONDUCTOR, name, kind="pubkeys",
                    payload={"keys": keys}))
        ready = self._collect("ready")
        if self.resume:
            self._resume_handshake(ready)
        # conductor→party keep-alives: an idle party's event-queue timeout
        # stays a genuine failure detector during long quiet phases
        hb = min(self.io_timeout / 3.0, 30.0)
        for name in self.names:
            self.tp.start_heartbeat(name, hb)

    def _resume_handshake(self, ready: dict[str, msg.Control]) -> None:
        """Agree on the max COMMON checkpointed step, roll every party
        back to it, and audit the recovered stream positions: the
        replicated counters (Beaver-dealer draws, batch cursor) must be
        identical across all k parties or the resume is refused."""
        sets = [set(int(s) for s in (m.payload or {}).get("ckpt_steps", []))
                for m in ready.values()]
        common = set.intersection(*sets) if sets else set()
        step = max(common) if common else 0
        for name in self.names:
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="resume",
                payload={"step": int(step)}))
        acks = self._collect("resume_ok")
        replicated = {(int(a.payload["dealer_drawn"]),
                       int(a.payload["cursor"]))
                      for a in acks.values()}
        if len(replicated) != 1:
            detail = {n: {"dealer_drawn": a.payload["dealer_drawn"],
                          "cursor": a.payload["cursor"]}
                      for n, a in acks.items()}
            raise ClusterError(
                "resume refused: replicated stream positions disagree "
                f"across parties after rollback to step {step}: {detail}")
        self.start_it = int(step)
        self._resume_stop = bool(acks["C"].payload.get("stop", False))
        self.resume_report = {
            "step": int(step),
            "offered_steps": {n: sorted(int(s) for s in
                                        (m.payload or {})
                                        .get("ckpt_steps", []))
                              for n, m in ready.items()},
            "dealer_drawn": next(iter(replicated))[0],
            "cursor": next(iter(replicated))[1],
            "rng_drawn": {n: int(a.payload["rng_drawn"])
                          for n, a in acks.items()},
        }

    def shutdown(self, force: bool = False) -> None:
        """Tear the cluster down.  `force` skips the graceful
        shutdown/bye exchange — the supervisor uses it after a party
        loss, when surviving parties are wedged mid-protocol and the
        only safe recovery is kill + relaunch + resume."""
        if self.tp is not None:
            if not force:
                for name in self.names:
                    try:
                        self.tp.send_control(msg.Control(CONDUCTOR, name,
                                                         kind="shutdown"))
                    except Exception:        # noqa: BLE001 — best effort
                        pass
                try:
                    self._collect("bye", timeout=10.0)
                except Exception:            # noqa: BLE001
                    pass
            self.tp.close()
            self.tp = None
        for proc in self.procs.values():
            if force and proc.is_alive():
                proc.kill()
            proc.join(timeout=10.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
        self.procs.clear()
        self._started = False

    def kill_party(self, name: str) -> None:
        """SIGKILL one party process mid-run — failure injection for
        crash-recovery tests and drills (the supervisor path must bring
        the run back bit-identically from party-local checkpoints)."""
        proc = self.procs[name]
        proc.kill()
        proc.join(timeout=5.0)

    # -- control-plane plumbing --------------------------------------------
    def _check_alive(self) -> None:
        for name, proc in self.procs.items():
            if proc.exitcode not in (None, 0):
                raise ClusterError(
                    f"party {name} exited with code {proc.exitcode}")

    def _collect(self, kind: str, timeout: float | None = None
                 ) -> dict[str, msg.Control]:
        """One control frame of `kind` from every party."""
        got: dict[str, msg.Control] = {}
        deadline = time.monotonic() + (timeout or self.io_timeout)
        while len(got) < len(self.names):
            try:
                m = self.tp.inbound.get(timeout=1.0)
            except queue_lib.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    missing = sorted(set(self.names) - set(got))
                    raise ClusterError(
                        f"timed out waiting for {kind!r} from {missing}")
                continue
            if not isinstance(m, msg.Control):
                raise ClusterError(
                    f"conductor received protocol frame {m.tag!r} — "
                    "parties must never route data through the conductor")
            if m.kind == "error":
                cls = FatalClusterError \
                    if m.payload.get("etype") in NON_RETRYABLE_ERRORS \
                    else ClusterError
                raise cls(
                    f"party {m.payload.get('party')} failed:\n"
                    f"{m.payload.get('traceback')}")
            if m.kind == "__closed__":
                self._check_alive()
                raise ClusterError(f"lost connection to {m.src}")
            if m.kind != kind:
                raise ClusterError(f"expected {kind!r}, got {m.kind!r} "
                                   f"from {m.src}")
            got[m.src] = m
        return got

    # -- training -----------------------------------------------------------
    def _select_cps(self, rng) -> tuple[str, str]:
        if self.cfg.cp_selection == "random":
            i = rng.choice(len(self.names), size=2, replace=False)
            return (self.names[i[0]], self.names[i[1]])
        return (self.names[0], self.names[1])

    def train(self, kill_plan: dict[int, str] | None = None):
        """Run Algorithm 1 to completion; returns `TrainResult` with two
        extra attributes: `measured_meter` (per-tag bytes actually framed
        on the wire) and `wire_overhead_bytes` (codec prelude+header
        cost, excluded from the protocol meters).

        After a resume handshake, the loop continues from the agreed
        common step: the conductor re-derives its CP-selection stream
        position by replaying the draws of the already-completed
        iterations (the conductor has no durable state of its own — all
        durable state is party-local).

        `kill_plan` maps iteration → party name; the conductor SIGKILLs
        that party right after dispatching the iteration (one-shot:
        entries are consumed), producing a genuine mid-iteration crash
        for the supervisor to recover from."""
        from repro.core.trainer import TrainResult
        assert self._started, "call start() first"
        cfg = self.cfg
        # dedicated CP-selection stream (PipelinedTransport convention —
        # concurrent mask draws can't exist here, but the trajectory
        # stays comparable across the concurrent transports)
        select_rng = seeds.cp_select_rng(cfg.seed)
        for _ in range(self.start_it):          # replay completed draws
            self._select_cps(select_rng)
        t0 = time.perf_counter()
        stop = self._resume_stop
        it = self.start_it
        while it < cfg.max_iter and not stop:
            cps = self._select_cps(select_rng)
            for name in self.names:
                self.tp.send_control(msg.Control(
                    CONDUCTOR, name, kind="iter",
                    payload={"it": it, "cps": list(cps)}))
            if kill_plan and it in kill_plan:
                self.kill_party(kill_plan.pop(it))
            acks = self._collect("iter_done")
            stop = bool(acks["C"].payload["stop"])   # full loss trace comes
            it += 1                                  # with the fetch below
        self.n_iter = it
        # -- result collection (out of protocol; nothing metered) ---------
        for name in self.names:
            self.tp.send_control(msg.Control(CONDUCTOR, name, kind="fetch"))
        results = self._collect("result")
        weights = {}
        meter, measured = CommMeter(), CommMeter()
        overhead = 0
        for name, r in results.items():
            weights[name] = np.asarray(r.payload["weights"], np.float64)
            for src, dst, tag, nbytes in r.payload["sends"]:
                meter.add(src, dst, tag, nbytes)
            for src, dst, tag, nbytes in r.payload["measured"]:
                measured.add(src, dst, tag, nbytes)
            overhead += int(r.payload["overhead_bytes"])
        # analytic latency steps (the paper's rounds column); measured
        # wall-clock is runtime_s
        _, rounds_per_iter = msg.iteration_traffic(
            len(self.names), cfg.batch_size,
            max(p.X.shape[1] for p in self.parties), cfg.key_bits,
            glm=cfg.glm)
        res = TrainResult(
            weights=weights,
            losses=[float(v) for v in results["C"].payload["losses"]],
            meter=meter,
            runtime_s=time.perf_counter() - t0,
            n_iter=it,
            rounds=rounds_per_iter * it)
        res.measured_meter = measured
        res.wire_overhead_bytes = overhead
        return res

    # -- serving ------------------------------------------------------------
    def score(self, features: dict[str, np.ndarray]) -> np.ndarray:
        """Score a batch of vertically-split rows over the socket path.

        Args:
          features: party name -> (n_rows, m_p) feature block.
        Returns:
          (n_rows,) predictions (inverse link applied at C).
        """
        assert self._started, "call start() first"
        rid = int(time.monotonic_ns() % (1 << 31))
        for name in self.names:
            rows = np.asarray(features[name], np.float64)
            if rows.ndim == 1:
                rows = rows[None, :]
            self.tp.send_control(msg.Control(
                CONDUCTOR, name, kind="score",
                payload={"rid": rid, "rows": rows.tolist()}))
        while True:
            try:
                m = self.tp.inbound.get(timeout=self.io_timeout)
            except queue_lib.Empty:
                self._check_alive()
                raise ClusterError("timed out waiting for score_result")
            if not isinstance(m, msg.Control):
                raise ClusterError(
                    f"conductor received protocol frame {m.tag!r} — "
                    "parties must never route data through the conductor")
            if m.kind == "score_result":
                if m.payload.get("rid") != rid:
                    continue          # stale result of an abandoned request
                return np.asarray(m.payload["preds"], np.float64)
            if m.kind == "error":
                raise ClusterError(
                    f"party {m.payload.get('party')} failed:\n"
                    f"{m.payload.get('traceback')}")
            if m.kind == "__closed__":
                self._check_alive()
                raise ClusterError(f"lost connection to {m.src}")
            raise ClusterError(
                f"expected 'score_result', got {m.kind!r} from {m.src}")


def train_vfl_socket(parties: Sequence, y: np.ndarray, cfg,
                     host: str = "127.0.0.1",
                     checkpoint_dir: str | None = None,
                     resume: bool = False):
    """One-call distributed training: spawn, train, tear down."""
    with SocketCluster(parties, y, cfg, host=host,
                       checkpoint_dir=checkpoint_dir, resume=resume) as cl:
        res = cl.train()
        res.resume_report = dict(cl.resume_report)
        return res


def train_vfl_socket_resilient(parties: Sequence, y: np.ndarray, cfg,
                               checkpoint_dir: str,
                               host: str = "127.0.0.1",
                               max_restarts: int = 3,
                               kill_plan: dict[int, str] | None = None):
    """Supervised distributed training: survive party-process crashes.

    Restart policy: on ANY cluster failure (party killed, wedged, or
    errored) the supervisor force-kills the remaining party processes,
    relaunches the full cluster with `resume=True`, and the resume
    handshake rolls every party back to the max common checkpointed
    step — mid-iteration state is deliberately NOT recovered (it is
    never durable), so recovery is always roll-back-and-replay, which
    keeps the trajectory bit-identical to an uninterrupted run
    (tests/test_resumable.py).  `cfg.checkpoint_every` must be > 0 for
    recovery to make progress; with it 0, every restart replays from
    scratch.

    Returns the final `TrainResult` with `res.restarts` (count) and
    `res.resume_report` (last handshake audit) attached.  Raises the
    final `ClusterError` after `max_restarts` consecutive failures.
    """
    attempt = 0
    resume = False
    while True:
        cl = SocketCluster(parties, y, cfg, host=host,
                           checkpoint_dir=checkpoint_dir, resume=resume)
        try:
            cl.start()
            res = cl.train(kill_plan=kill_plan)
            cl.shutdown()
            res.restarts = attempt
            res.resume_report = dict(cl.resume_report)
            return res
        except (ClusterError, OSError) as e:
            cl.shutdown(force=True)
            if isinstance(e, FatalClusterError):
                # deterministic refusal (config/codec mismatch) —
                # restarting replays the identical refusal; surface it
                raise
            # OSError covers the conductor's own send path dying on a
            # lost party (PeerClosed/ConnectionError/TimeoutError are
            # all OSError subclasses) — every transient loss restarts
            attempt += 1
            if attempt > max_restarts:
                raise
            resume = True
        except BaseException:
            # anything else (caller bug, KeyboardInterrupt) must not
            # leak k live party processes
            cl.shutdown(force=True)
            raise


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    from repro.core.trainer import PartyData, VFLConfig
    from repro.data import synthetic, vertical

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--glm", default="logistic",
                    choices=("logistic", "poisson", "linear", "gamma"))
    ap.add_argument("--parties", type=int, default=3)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--features", type=int, default=12)
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--he", default="mock", choices=("mock", "paillier"))
    ap.add_argument("--key-bits", type=int, default=256)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    if args.glm in ("poisson", "gamma"):
        X, y = synthetic.dvisits(n=args.samples, seed=args.seed)
    else:
        X, y = synthetic.credit_default(n=args.samples, d=args.features,
                                        seed=args.seed)
    parts = vertical.split_columns(X, args.parties)
    names = ["C"] + [f"B{i}" for i in range(1, args.parties)]
    parties = [PartyData(nm, p) for nm, p in zip(names, parts)]
    cfg = VFLConfig(glm=args.glm, lr=0.1, max_iter=args.iters,
                    batch_size=args.batch, he_backend=args.he,
                    key_bits=args.key_bits, tol=0.0, seed=args.seed)

    print(f"spawning {args.parties} party processes + conductor "
          f"({args.he} backend)…")
    res = train_vfl_socket(parties, y, cfg)
    print(f"iterations : {res.n_iter}   losses: "
          f"{[round(v, 4) for v in res.losses]}")
    print(f"wall clock : {res.runtime_s:.2f}s")
    print("per-tag wire bytes (measured == analytic asserted per frame):")
    for tag in sorted(res.meter.by_tag):
        print(f"  {tag:18s} analytic {res.meter.by_tag[tag]:>10d} B   "
              f"measured {res.measured_meter.by_tag[tag]:>10d} B")
    print(f"frame overhead (preludes+headers, unmetered): "
          f"{res.wire_overhead_bytes} B")


if __name__ == "__main__":
    main()
