"""Fixed-point codecs bridging floats, the Z_2^64 share ring, and Z_n
Paillier plaintexts.

Conventions (DESIGN.md §7):
* Ring fixed point: value x ↦ round(x·2^f) mod 2^64 (two's complement).
  Default f = 20 fractional bits.
* Z_n plaintexts are non-negative; ring residues embed as their unsigned
  64-bit value, multipliers as residues mod 2^64.  Decrypted integers are
  reduced mod 2^64 to land back in the ring.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint, ring
from repro.crypto.ring import R64

DEFAULT_FRAC_BITS = 20

_U32 = jnp.uint32
_R64_LIMBS = 6  # ceil(64 / 12)


def encode(x, f: int = DEFAULT_FRAC_BITS) -> R64:
    return ring.from_signed_f64(x, f)


def decode(a: R64, f: int = DEFAULT_FRAC_BITS) -> np.ndarray:
    return ring.to_signed_f64(a, f)


def encode_pub_int(x, f: int = DEFAULT_FRAC_BITS) -> np.ndarray:
    """Public floats -> signed int32 fixed-point multipliers (for
    public-by-share products where the multiplier fits 32 bits)."""
    v = np.rint(np.asarray(x, np.float64) * (1 << f))
    if np.any(np.abs(v) >= 2 ** 31):
        raise ValueError("public fixed-point multiplier exceeds int32")
    return v.astype(np.int32)


def r64_to_limbs(a: R64, L: int) -> jnp.ndarray:
    """Ring residue (unsigned 64-bit value) -> L-limb vector (L >= 6)."""
    shifts_lo = [0, 12, 24]           # limbs 0..2 from lo (+ bridge)
    limbs = []
    lo, hi = a.lo, a.hi
    limbs.append(lo & _U32(0xFFF))                                  # bits 0-11
    limbs.append((lo >> 12) & _U32(0xFFF))                          # 12-23
    limbs.append((lo >> 24) | ((hi & _U32(0xF)) << 8))              # 24-35
    limbs.append((hi >> 4) & _U32(0xFFF))                           # 36-47
    limbs.append((hi >> 16) & _U32(0xFFF))                          # 48-59
    limbs.append(hi >> 28)                                          # 60-63
    del shifts_lo
    out = jnp.stack(limbs, axis=-1) & _U32(0xFFF)
    pad = jnp.zeros(out.shape[:-1] + (L - _R64_LIMBS,), _U32)
    return jnp.concatenate([out, pad], axis=-1)


def limbs_to_r64(x: jnp.ndarray) -> R64:
    """Low 64 bits of a limb vector -> ring residue (i.e. reduce mod 2^64)."""
    x = x.astype(_U32)
    l0, l1, l2, l3, l4, l5 = (x[..., i] for i in range(6))
    lo = l0 | (l1 << 12) | (l2 << 24)
    hi = (l2 >> 8) | (l3 << 4) | (l4 << 16) | (l5 << 28)
    return R64(hi, lo)


def u64_bits_msb(a: R64, nbits: int = 64) -> jnp.ndarray:
    """Ring residue -> MSB-first bit vector (for HE scalar multiply)."""
    bits_hi = [(a.hi >> (31 - i)) & _U32(1) for i in range(32)]
    bits_lo = [(a.lo >> (31 - i)) & _U32(1) for i in range(32)]
    full = jnp.stack(bits_hi + bits_lo, axis=-1)
    return full[..., 64 - nbits:]


def int_bits_msb(x: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """Non-negative int32/uint32 array -> MSB-first bit vector."""
    x = x.astype(_U32)
    return jnp.stack([(x >> (nbits - 1 - i)) & _U32(1)
                      for i in range(nbits)], axis=-1)
