"""Paillier cryptosystem on limb vectors.

* Key generation is host-side python (Miller–Rabin primes) — a one-time,
  per-deployment cost, exactly as in production VFL stacks.
* Enc / Dec / homomorphic ops are vectorized JAX over ciphertext batches;
  ciphertexts live in the *Montgomery domain mod n^2* end to end, so
  homomorphic addition is a single `mont_mul` and scalar multiplication is
  a constant-time Montgomery ladder.
* Plaintext convention (see DESIGN.md §7): protocol plaintexts are
  non-negative integers < n; ring-2^64 share semantics are recovered by
  reducing decrypted integers mod 2^64, so multipliers may be lifted to
  their non-negative residues mod 2^64 and no ciphertext inversion is
  ever required.
* Every hot loop (noise modexp, ladder, ⊕-reduce) dispatches through a
  `crypto.engine.CryptoEngine` — pass `engine=` or rely on the process
  default (`crypto.engine.get_engine()`), which selects the fused Pallas
  kernels on TPU and the jnp library on CPU.  All backends are bit-exact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint
from repro.crypto import engine as engine_mod
from repro.crypto.bigint import (LIMB_BITS, Modulus, add_small, big_mul_full,
                                 from_mont, int_to_bits, int_to_limbs,
                                 limbs_to_int, mont_exp_bits, mont_exp_const,
                                 mont_mul, mul_low, nlimbs, sub_small, to_mont)

_U32 = jnp.uint32


def _eng(engine: "engine_mod.CryptoEngine | None") -> "engine_mod.CryptoEngine":
    return engine if engine is not None else engine_mod.get_engine()


# ---------------------------------------------------------------------------
# Host-side prime generation
# ---------------------------------------------------------------------------

_SMALL_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53,
                 59, 61, 67, 71, 73, 79, 83, 89, 97]


def _is_probable_prime(n: int, rng: np.random.Generator, rounds: int = 40) -> bool:
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = 2 + int(rng.integers(0, 1 << 62)) % (n - 3)
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = (x * x) % n
            if x == n - 1:
                break
        else:
            return False
    return True


def gen_prime(bits: int, rng: np.random.Generator) -> int:
    while True:
        raw = int.from_bytes(rng.bytes((bits + 7) // 8), "little")
        cand = (raw | (1 << (bits - 1)) | 1) & ((1 << bits) - 1)
        if _is_probable_prime(cand, rng):
            return cand


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PublicKey:
    n: int
    key_bits: int
    mod_n: Modulus
    mod_n2: Modulus
    n_limbs: np.ndarray          # n as Ln-limb vector (for 1 + m*n)

    @property
    def Ln(self) -> int:
        return self.mod_n.L

    @property
    def Ln2(self) -> int:
        return self.mod_n2.L

    @property
    def msg_bits(self) -> int:
        """Safe plaintext magnitude for exact-integer protocol arithmetic."""
        return self.n.bit_length() - 2


@dataclasses.dataclass(frozen=True)
class CRTComponent:
    """Per-prime data for CRT-accelerated decryption (mod p² / q²)."""
    prime: int
    mod_p2: Modulus
    lam_bits: np.ndarray         # bits of p-1
    h_mont: np.ndarray           # L_p(g^{p-1} mod p²)^{-1} · R_p mod p
    hensel_p: np.ndarray         # p^{-1} mod 2^(12·Lp2)
    mod_p: Modulus


@dataclasses.dataclass(frozen=True)
class PrivateKey:
    pub: PublicKey
    lam: int
    lam_bits: np.ndarray         # MSB-first bit vector of lambda
    mu_mont: np.ndarray          # mu * R_n mod n  (fold mu into one mont_mul)
    hensel_n: np.ndarray         # n^{-1} mod 2^(12*Ln2) for exact (u-1)/n
    # CRT acceleration (≈4×: two half-size modexps with half-size exponents)
    crt_p: CRTComponent | None = None
    crt_q: CRTComponent | None = None
    q_pinv_mont: np.ndarray | None = None   # p^{-1}·R_q mod q (CRT combine)
    # persistent fixed-base noise table (crypto.fixed_base), attached by
    # keygen(table_path=…); consumers fall back to the r^n ladder when None
    noise_table: Any = None


@dataclasses.dataclass(frozen=True)
class PeerKey:
    """A *peer's* keypair as this party sees it: public half only.

    Shaped so HE-backend key dicts can mix `PrivateKey` (own) and
    `PeerKey` (everyone else) — every public-key operation reads `.pub`;
    decryption requires the full `PrivateKey` and fails loudly on a
    `PeerKey` (a party can never decrypt under a key it doesn't own).
    """
    pub: PublicKey


def public_key_from_n(n: int, key_bits: int) -> PublicKey:
    """Rebuild a `PublicKey` from the modulus a peer announced (the
    distributed handshake ships only `n`; all derived constants are
    recomputed locally)."""
    mod_n = Modulus.make(n)
    return PublicKey(n=n, key_bits=key_bits, mod_n=mod_n,
                     mod_n2=Modulus.make(n * n),
                     n_limbs=int_to_limbs(n, mod_n.L))


def _crt_component(prime: int, n: int) -> CRTComponent:
    p2 = prime * prime
    mod_p2 = Modulus.make(p2)
    mod_p = Modulus.make(prime)
    # h_p = L_p(g^{p-1} mod p²)^{-1} mod p, g = n+1
    u = pow(n + 1, prime - 1, p2)
    lp = (u - 1) // prime
    h = pow(lp, -1, prime)
    R_p = 1 << (LIMB_BITS * mod_p.L)
    return CRTComponent(
        prime=prime, mod_p2=mod_p2,
        lam_bits=int_to_bits(prime - 1, (prime - 1).bit_length()),
        h_mont=int_to_limbs((h * R_p) % prime, mod_p.L),
        hensel_p=int_to_limbs(pow(prime, -1, 1 << (LIMB_BITS * mod_p2.L)),
                              mod_p2.L),
        mod_p=mod_p)


def keygen(key_bits: int, seed: int | None = None, *,
           table_path: str | None = None,
           table_window: int | None = None) -> PrivateKey:
    """Generate a Paillier keypair.  `key_bits` is the modulus size
    (paper: 1024; tests default smaller for CPU speed).

    `table_path` additionally builds (or loads, when the file already
    holds THIS keypair's table — fingerprint-checked) the persistent
    fixed-base noise table and attaches it as `PrivateKey.noise_table`;
    `protocols.PaillierBackend` then routes encryption noise through it
    automatically."""
    rng = np.random.default_rng(seed)
    half = key_bits // 2
    while True:
        p = gen_prime(half, rng)
        q = gen_prime(key_bits - half, rng)
        if p != q and (p * q).bit_length() == key_bits:
            break
    if p > q:
        p, q = q, p          # CRT combine below assumes p < q
    n = p * q
    lam = math.lcm(p - 1, q - 1)
    mod_n = Modulus.make(n)
    mod_n2 = Modulus.make(n * n)
    mu = pow(lam, -1, n)
    R_n = 1 << (LIMB_BITS * mod_n.L)
    R_q = 1 << (LIMB_BITS * Modulus.make(q).L)
    pub = PublicKey(
        n=n, key_bits=key_bits, mod_n=mod_n, mod_n2=mod_n2,
        n_limbs=int_to_limbs(n, mod_n.L))
    noise_table = None
    if table_path is not None:
        from repro.crypto import fixed_base
        window = (fixed_base.DEFAULT_WINDOW if table_window is None
                  else table_window)
        noise_table, _ = fixed_base.ensure_table(n, mod_n2, table_path,
                                                 window=window, rng=rng)
    return PrivateKey(
        pub=pub,
        lam=lam,
        lam_bits=int_to_bits(lam, lam.bit_length()),
        mu_mont=int_to_limbs((mu * R_n) % n, mod_n.L),
        hensel_n=int_to_limbs(pow(n, -1, 1 << (LIMB_BITS * mod_n2.L)),
                              mod_n2.L),
        crt_p=_crt_component(p, n),
        crt_q=_crt_component(q, n),
        q_pinv_mont=int_to_limbs((pow(p, -1, q) * R_q) % q,
                                 Modulus.make(q).L),
        noise_table=noise_table,
    )


# ---------------------------------------------------------------------------
# Plaintext encode / decode (host helpers)
# ---------------------------------------------------------------------------

def encode_ints(pub: PublicKey, xs) -> np.ndarray:
    """Non-negative python ints -> (batch, Ln) limb plaintexts."""
    xs = [int(x) for x in np.atleast_1d(np.asarray(xs, dtype=object))]
    for x in xs:
        if x < 0 or x >= pub.n:
            raise ValueError("plaintext out of range [0, n)")
    return bigint.ints_to_limbs(xs, pub.Ln)


def decode_ints(limbs) -> list[int]:
    """(batch…, L) limb array -> python ints.  Vectorized: one object-dtype
    dot against the radix powers instead of a per-limb python loop per
    element."""
    arr = np.asarray(limbs)
    weights = np.array([1 << (LIMB_BITS * i) for i in range(arr.shape[-1])],
                       dtype=object)
    vals = np.dot(arr.astype(object), weights)
    if arr.ndim == 1:
        return [int(vals)]
    return vals.tolist()


# ---------------------------------------------------------------------------
# Core ops (vectorized; ciphertexts are Montgomery-domain mod n^2)
# ---------------------------------------------------------------------------

def raw_noise(pub: PublicKey, batch: int,
              rng: np.random.Generator | None = None) -> np.ndarray:
    """Fresh encryption randomness r ∈ [1, n), as (batch, Ln2) limbs."""
    from repro.crypto import prng
    r = prng.host_uniform_limbs(pub.n, batch, pub.Ln, rng=rng, lo=1)
    pad = np.zeros((batch, pub.Ln2 - pub.Ln), np.uint32)
    return np.concatenate([r, pad], axis=-1)


def noise_to_mont(pub: PublicKey, r_limbs, engine=None) -> jnp.ndarray:
    """r -> r^n mod n^2, Montgomery domain.  Precomputable offline
    (encryption-noise precompute — amortizes the expensive modexp; the
    runtime's noise pool runs exactly this on the scheduler's thread
    pool, overlapped with the Protocol-3 legs)."""
    eng = _eng(engine)
    rm = eng.to_mont(jnp.asarray(r_limbs, _U32), pub.mod_n2)
    return eng.mont_exp_const(rm, pub.n, pub.mod_n2)


def noise_from_table(pub: PublicKey, table, rho_digits,
                     engine=None) -> jnp.ndarray:
    """Table-backed encryption noise: h^ρ mod n², Montgomery domain —
    the DJN short-exponent form of `noise_to_mont` (h = x^n is fixed at
    keygen, ρ is fresh and short), evaluated from a persistent
    `crypto.fixed_base.FixedBaseTable` in ~levels RNS rounds instead of
    an |n|-bit ladder (BENCH fixed_base rows: ≈24× at 1024-bit keys).
    `rho_digits`: (batch, levels) LSB-first window digits
    (`fixed_base.draw_exponent_digits`)."""
    eng = _eng(engine)
    if table.fingerprint != _table_fingerprint(pub):
        from repro.crypto.fixed_base import TableMismatchError
        raise TableMismatchError(
            "noise table was built for a different public key")
    return eng.fixed_base_exp(table, rho_digits, pub.mod_n2)


def _table_fingerprint(pub: PublicKey) -> str:
    from repro.crypto.fixed_base import key_fingerprint
    return key_fingerprint(pub.n)


def encrypt_with_noise(pub: PublicKey, m_limbs, rn_mont,
                       engine=None) -> jnp.ndarray:
    """Enc(m; r) = (1 + m n) * r^n mod n^2, given precomputed r^n.
    With pooled noise, encryption off the critical path costs ~one
    mont_mul."""
    eng = _eng(engine)
    m = jnp.asarray(m_limbs, _U32)
    mn = big_mul_full(m, jnp.asarray(pub.n_limbs, _U32), pub.Ln2)
    c0 = add_small(mn, 1)
    return eng.mont_mul(eng.to_mont(c0, pub.mod_n2),
                        jnp.asarray(rn_mont, _U32), pub.mod_n2)


def encrypt(pub: PublicKey, m_limbs, rng: np.random.Generator | None = None,
            engine=None) -> jnp.ndarray:
    m = jnp.asarray(m_limbs, _U32)
    batch = int(np.prod(m.shape[:-1])) if m.ndim > 1 else 1
    r = raw_noise(pub, batch, rng).reshape(m.shape[:-1] + (pub.Ln2,))
    return encrypt_with_noise(pub, m, noise_to_mont(pub, r, engine),
                              engine)


def decrypt(priv: PrivateKey, c_mont, engine=None) -> jnp.ndarray:
    """-> plaintext limbs (…, Ln)."""
    eng = _eng(engine)
    pub = priv.pub
    u_m = eng.mont_exp_bits(jnp.asarray(c_mont, _U32),
                            jnp.asarray(priv.lam_bits), pub.mod_n2)
    u = eng.from_mont(u_m, pub.mod_n2)
    um1 = sub_small(u, 1)
    k = mul_low(um1, jnp.asarray(priv.hensel_n, _U32), pub.Ln2)[..., :pub.Ln]
    return eng.mont_mul(k, jnp.asarray(priv.mu_mont, _U32), pub.mod_n)


def _dec_component(comp: CRTComponent, c_modp2_mont, eng) -> jnp.ndarray:
    """m_p = L_p(c^{p-1} mod p²) · h_p mod p."""
    u_m = eng.mont_exp_bits(c_modp2_mont, jnp.asarray(comp.lam_bits),
                            comp.mod_p2)
    u = eng.from_mont(u_m, comp.mod_p2)
    um1 = sub_small(u, 1)
    k = mul_low(um1, jnp.asarray(comp.hensel_p, _U32),
                comp.mod_p2.L)[..., :comp.mod_p.L]
    return eng.mont_mul(k, jnp.asarray(comp.h_mont, _U32), comp.mod_p)


def decrypt_crt(priv: PrivateKey, c_mont, engine=None) -> jnp.ndarray:
    """CRT decryption (≈4× fewer limb-ops than `decrypt`): two half-size
    modexps with half-size exponents, then Garner recombination
      m = m_p + p · ((m_q − m_p) · p^{-1} mod q).
    Returns plaintext limbs (…, Ln), identical to `decrypt` (tested)."""
    eng = _eng(engine)
    pub = priv.pub
    cp, cq = priv.crt_p, priv.crt_q
    c = jnp.asarray(c_mont, _U32)
    # ciphertext is Montgomery mod n²: leave the domain, then reduce
    c_plain = eng.from_mont(c, pub.mod_n2)
    cp2 = eng.to_mont(_reduce_mod(c_plain, cp.mod_p2, eng), cp.mod_p2)
    cq2 = eng.to_mont(_reduce_mod(c_plain, cq.mod_p2, eng), cq.mod_p2)
    m_p = _dec_component(cp, cp2, eng)                  # (…, Lp) < p
    m_q = _dec_component(cq, cq2, eng)                  # (…, Lq) < q
    # Garner: t = (m_q − m_p) mod q;  m = m_p + p·(t·p^{-1} mod q)
    Lq = cq.mod_p.L
    m_p_padq = jnp.pad(m_p, [(0, 0)] * (m_p.ndim - 1)
                       + [(0, max(0, Lq - m_p.shape[-1]))])[..., :Lq]
    from repro.crypto.bigint import mod_sub
    t = mod_sub(m_q, _reduce_mod(m_p_padq, cq.mod_p, eng), cq.mod_p)
    u = eng.mont_mul(t, jnp.asarray(priv.q_pinv_mont, _U32), cq.mod_p)
    pu = big_mul_full(jnp.asarray(int_to_limbs(cp.prime, cp.mod_p.L), _U32),
                      u, pub.Ln)
    m_p_padn = jnp.pad(m_p, [(0, 0)] * (m_p.ndim - 1)
                       + [(0, pub.Ln - m_p.shape[-1])])
    from repro.crypto.bigint import _add_limbs
    out, _ = _add_limbs(jnp.broadcast_to(m_p_padn, pu.shape), pu)
    return out


def _fold_below(x: jnp.ndarray, mod: Modulus, eng) -> jnp.ndarray:
    """x mod N for canonical x < R = 2^(12·L): Montgomery round-trip —
    mont_mul's bound holds for a < R, b < N, so to_mont then from_mont is
    an exact general reduction."""
    return eng.from_mont(eng.to_mont(x, mod), mod)


def _reduce_mod(x: jnp.ndarray, mod: Modulus, eng=None) -> jnp.ndarray:
    """General reduction x mod N for canonical x of any width: split into
    R-sized chunks, Horner fold (acc·R + chunk) with Montgomery ops."""
    from repro.crypto.bigint import mod_add
    eng = _eng(eng)
    L = mod.L
    Lx = x.shape[-1]
    n_chunks = -(-Lx // L)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n_chunks * L - Lx)])
    acc = _fold_below(xp[..., (n_chunks - 1) * L:n_chunks * L], mod, eng)
    for i in range(n_chunks - 2, -1, -1):
        acc = eng.to_mont(acc, mod)             # acc · R mod N
        chunk = _fold_below(xp[..., i * L:(i + 1) * L], mod, eng)
        acc = mod_add(acc, chunk, mod)
    return acc


def add_ct(pub: PublicKey, c1, c2, engine=None) -> jnp.ndarray:
    """[[a]] ⊕ [[b]] = [[a + b mod n]]."""
    return _eng(engine).mont_mul(jnp.asarray(c1, _U32),
                                 jnp.asarray(c2, _U32), pub.mod_n2)


def smul_bits(pub: PublicKey, c, exp_bits, engine=None) -> jnp.ndarray:
    """[[a]] ⊗ k = [[a * k mod n]], k given as an MSB-first bit vector
    (traced or constant).  Constant-time ladder."""
    return _eng(engine).mont_exp_bits(jnp.asarray(c, _U32),
                                      jnp.asarray(exp_bits), pub.mod_n2)


def smul_const(pub: PublicKey, c, k: int, engine=None) -> jnp.ndarray:
    if k < 0:
        raise ValueError("lift negative multipliers to residues first")
    # mont_exp_const memoizes the (k, width) bit decomposition
    return _eng(engine).mont_exp_const(jnp.asarray(c, _U32), k, pub.mod_n2)


def hom_sum(pub: PublicKey, c, axis: int = 0, engine=None) -> jnp.ndarray:
    """⊕-reduce a batch of ciphertexts along `axis` (tree reduction —
    the same schedule the mesh collective uses, see distributed/)."""
    eng = _eng(engine)
    c = jnp.asarray(c, _U32)
    c = jnp.moveaxis(c, axis, 0)
    while c.shape[0] > 1:
        half = c.shape[0] // 2
        merged = eng.mont_mul(c[:half], c[half:2 * half], pub.mod_n2)
        if c.shape[0] % 2:
            merged = jnp.concatenate([merged, c[2 * half:]], axis=0)
        c = merged
    return c[0]


def ciphertext_bytes(pub: PublicKey) -> int:
    """Wire size of one ciphertext (serialized canonical form)."""
    from repro.core.comm import ciphertext_wire_bytes
    return ciphertext_wire_bytes(pub.key_bits)
