"""Cryptographic substrate: big-integer limb arithmetic, Paillier HE,
ring-2^64 share arithmetic and fixed-point codecs.

All device code uses radix-2^12 limbs in uint32 so every operation maps to
native int32 TPU vector/MXU ops (no 64-bit multiplier required).
"""
from repro.crypto import bigint, engine, fixed_point, paillier, prng, ring

__all__ = ["bigint", "engine", "paillier", "ring", "fixed_point", "prng"]
