"""Vectorized big-integer arithmetic on radix-2^12 uint32 limb arrays.

Design (TPU adaptation of gmp-style word-serial bignum):

* A k-bit integer is a little-endian vector of ``L = ceil(k/12)`` limbs,
  each stored in a uint32 lane but holding < 2^12.  Limb products are
  < 2^24 and a full convolution row accumulates < L * 2^24 < 2^32 for
  L <= 255 (covers 3060-bit moduli), so the entire schoolbook/Montgomery
  pipeline runs in *native int32 vector ops* — the representation chosen
  because TPUs have no 64x64 multiplier and no carry flag, but do have
  wide int32 vector ALUs and an int MXU.
* All functions broadcast over arbitrary leading batch dimensions; the
  limb axis is always the last axis.
* Montgomery residue arithmetic: ``R = 2^(12*L)``; `mont_mul(a, b)`
  returns ``a*b*R^-1 mod N``.  Ciphertexts are kept in the Montgomery
  domain end-to-end (see paillier.py).

The exactness trick that keeps everything branch-free: the stored uint32
vector always represents the exact value ``sum_j T[j] * 2^(12 j)`` — limbs
are allowed to exceed 2^12 transiently ("lazy carries"), and since no limb
lies *below* limb 0, ``T[0] mod 2^12`` is always exact, which is all the
Montgomery round needs.  A single `lax.scan` carry sweep restores the
canonical form where required.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

LIMB_BITS = 12
LIMB_RADIX = 1 << LIMB_BITS
MASK = LIMB_RADIX - 1
_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Host-side conversions (numpy / python int — used for keys & test oracles)
# ---------------------------------------------------------------------------

def nlimbs(nbits: int) -> int:
    return -(-nbits // LIMB_BITS)


def int_to_limbs(x: int, L: int) -> np.ndarray:
    """Python int -> (L,) uint32 limb vector (host-side)."""
    if x < 0:
        raise ValueError("int_to_limbs takes non-negative integers")
    if x >> (LIMB_BITS * L):
        raise ValueError(f"value needs more than {L} limbs")
    out = np.zeros(L, dtype=np.uint32)
    for i in range(L):
        out[i] = x & MASK
        x >>= LIMB_BITS
    return out


def ints_to_limbs(xs: Sequence[int], L: int) -> np.ndarray:
    return np.stack([int_to_limbs(int(x), L) for x in xs])


def limbs_to_int(limbs) -> int:
    """(… , L) limb array -> python int (host-side; batch -> list)."""
    arr = np.asarray(limbs)
    if arr.ndim == 1:
        val = 0
        for i in range(arr.shape[0] - 1, -1, -1):
            val = (val << LIMB_BITS) | int(arr[i])
        return val
    return [limbs_to_int(a) for a in arr]


# ---------------------------------------------------------------------------
# Carry / borrow sweeps (exact, one sequential pass along the limb axis)
# ---------------------------------------------------------------------------

def carry_sweep(t: jnp.ndarray) -> jnp.ndarray:
    """Exact normalization: limbs < 2^12 afterwards.  Input limbs may hold
    any uint32 value; the final carry out of the top limb is dropped
    (i.e. arithmetic is mod 2^(12 L))."""
    t = t.astype(_U32)
    xs = jnp.moveaxis(t, -1, 0)

    def step(c, x):
        s = x + c
        return s >> LIMB_BITS, s & MASK

    _, ys = jax.lax.scan(step, jnp.zeros(t.shape[:-1], _U32), xs)
    return jnp.moveaxis(ys, 0, -1)


def _sub_with_borrow(a: jnp.ndarray, b: jnp.ndarray):
    """a - b limbwise for canonical inputs.  Returns (diff, borrow_out)
    where diff is canonical and borrow_out is 1 where a < b."""
    xs = jnp.moveaxis(jnp.stack([a, b], axis=0), -1, 0)  # (L, 2, ...)

    def step(borrow, ab):
        aj, bj = ab[0], ab[1]
        t = aj + _U32(LIMB_RADIX) - bj - borrow
        return _U32(1) - (t >> LIMB_BITS), t & MASK

    borrow, ys = jax.lax.scan(
        step, jnp.zeros(a.shape[:-1], _U32), xs)
    return jnp.moveaxis(ys, 0, -1), borrow


def _add_limbs(a: jnp.ndarray, b: jnp.ndarray):
    """a + b, canonical inputs -> (canonical sum mod 2^(12L), carry_out)."""
    s = a + b
    xs = jnp.moveaxis(s, -1, 0)

    def step(c, x):
        t = x + c
        return t >> LIMB_BITS, t & MASK

    carry, ys = jax.lax.scan(step, jnp.zeros(a.shape[:-1], _U32), xs)
    return jnp.moveaxis(ys, 0, -1), carry


def big_lt(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a < b elementwise over the batch (canonical limbs)."""
    _, borrow = _sub_with_borrow(a, b)
    return borrow.astype(jnp.bool_)


def big_eq(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.all(a == b, axis=-1)


# ---------------------------------------------------------------------------
# Modulus descriptor
# ---------------------------------------------------------------------------

def _inv_mod(a: int, m: int) -> int:
    return pow(a, -1, m)


@dataclasses.dataclass(frozen=True)
class Modulus:
    """Static per-key modulus data.  The numpy arrays become constants in
    jitted computations (keys are long-lived)."""

    value: int              # N as python int (host only)
    L: int                  # limb count; R = 2^(12 L) > N
    limbs: np.ndarray       # (L,) uint32
    n0inv: int              # -N^{-1} mod 2^12
    r1: np.ndarray          # R mod N       == mont(1)
    r2: np.ndarray          # R^2 mod N     (to_mont multiplier)
    hensel: np.ndarray | None = None  # N^{-1} mod 2^(12 Lh) for exact div

    @staticmethod
    def make(n: int, hensel_limbs: int | None = None) -> "Modulus":
        if n % 2 == 0:
            raise ValueError("modulus must be odd")
        L = nlimbs(n.bit_length())
        R = 1 << (LIMB_BITS * L)
        hens = None
        if hensel_limbs is not None:
            hm = 1 << (LIMB_BITS * hensel_limbs)
            hens = int_to_limbs(_inv_mod(n, hm), hensel_limbs)
        return Modulus(
            value=n,
            L=L,
            limbs=int_to_limbs(n, L),
            n0inv=(-_inv_mod(n, LIMB_RADIX)) % LIMB_RADIX,
            r1=int_to_limbs(R % n, L),
            r2=int_to_limbs((R * R) % n, L),
            hensel=hens,
        )


# ---------------------------------------------------------------------------
# Core modular ops
# ---------------------------------------------------------------------------

def cond_sub_mod(t: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    """t - N if t >= N else t (canonical t < 2N, same limb count as N)."""
    n = jnp.asarray(mod.limbs, _U32)
    diff, borrow = _sub_with_borrow(t, jnp.broadcast_to(n, t.shape))
    keep = (borrow == 1)[..., None]
    return jnp.where(keep, t, diff)


def mod_add(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    a, b = jnp.broadcast_arrays(a, b)
    s, carry = _add_limbs(a, b)
    # a, b < N < 2^(12L): sum < 2N may carry out one bit; fold the carry in
    # by treating it virtually: if carry==1 the sum >= R > N, must subtract.
    n = jnp.asarray(mod.limbs, _U32)
    diff, borrow = _sub_with_borrow(s, jnp.broadcast_to(n, s.shape))
    need_sub = (carry == 1) | (borrow == 0)
    return jnp.where(need_sub[..., None], diff, s)


def mod_sub(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    a, b = jnp.broadcast_arrays(a, b)
    d, borrow = _sub_with_borrow(a, b)
    n = jnp.asarray(mod.limbs, _U32)
    dn, _ = _add_limbs(d, jnp.broadcast_to(n, d.shape))
    return jnp.where((borrow == 1)[..., None], dn, d)


def _one_shot_carry(t: jnp.ndarray) -> jnp.ndarray:
    """Move each limb's overflow one position up (value-preserving; does
    NOT fully normalize).  Keeps lazy limbs bounded during the Montgomery
    loop.  The top limb's overflow must be representable (guaranteed by
    the round bounds, see module docstring)."""
    low = t & MASK
    hi = t >> LIMB_BITS
    return low + jnp.concatenate(
        [jnp.zeros_like(hi[..., :1]), hi[..., :-1]], axis=-1)


def mont_mul(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    """Montgomery product a*b*R^-1 mod N (CIOS, vectorized over batch).

    a, b canonical (< N).  Output canonical (< N).
    Per-round invariant: lazy limbs stay < 2^16 entering a round, grow to
    < 2^16 + 2^25 after the two MAC rows, and the one-shot carry plus the
    shift restore < 2^16 — all comfortably inside uint32.
    """
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    L = mod.L
    n = jnp.asarray(mod.limbs, _U32)
    n0inv = _U32(mod.n0inv)
    bshape = a.shape[:-1]

    t0 = jnp.zeros(bshape + (L + 1,), _U32)

    def round_fn(i, t):
        ai = jax.lax.dynamic_index_in_dim(a, i, axis=-1, keepdims=True)
        t = t.at[..., :L].add(ai * b)
        m = (t[..., 0] * n0inv) & MASK
        t = t.at[..., :L].add(m[..., None] * n)
        # limb 0 is now ≡ 0 mod 2^12; shift down one limb, carrying its top.
        carry0 = t[..., 0] >> LIMB_BITS
        t = jnp.concatenate(
            [t[..., 1:], jnp.zeros(bshape + (1,), _U32)], axis=-1)
        t = t.at[..., 0].add(carry0)
        return _one_shot_carry(t)

    t = jax.lax.fori_loop(0, L, round_fn, t0)
    t = carry_sweep(t)          # canonical, L+1 limbs, value < 2N
    t = cond_sub_mod(t, Modulus(  # compare against N padded to L+1 limbs
        value=mod.value, L=L + 1,
        limbs=np.concatenate([mod.limbs, np.zeros(1, np.uint32)]),
        n0inv=mod.n0inv, r1=mod.r1, r2=mod.r2))
    return t[..., :L]


def to_mont(a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    return mont_mul(a, jnp.asarray(mod.r2, _U32), mod)


def from_mont(a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    one = jnp.zeros(mod.L, _U32).at[0].set(1)
    return mont_mul(a, one, mod)


def mont_one(mod: Modulus) -> jnp.ndarray:
    return jnp.asarray(mod.r1, _U32)


# ---------------------------------------------------------------------------
# Exponentiation (constant-time square-and-multiply over a bit vector)
# ---------------------------------------------------------------------------

def int_to_bits(e: int, nbits: int) -> np.ndarray:
    """MSB-first bit vector of a host integer (vectorized: one to_bytes
    plus an unpackbits, no per-bit python loop)."""
    e = int(e)
    if e >> nbits:
        raise ValueError("exponent wider than nbits")
    if nbits == 0:
        return np.zeros(0, dtype=np.uint32)
    by = np.frombuffer(e.to_bytes((nbits + 7) // 8, "big"), np.uint8)
    return np.unpackbits(by)[-nbits:].astype(np.uint32)


@functools.lru_cache(maxsize=4096)
def cached_bits(e: int, nbits: int) -> np.ndarray:
    """Memoized MSB-first bit decomposition keyed on (exponent, width) —
    host-known exponents (n, λ, smul_const multipliers) repeat every
    iteration, so the decomposition is paid once per key/constant."""
    out = int_to_bits(e, nbits)
    out.setflags(write=False)
    return out


def limbs_to_bits(x: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """Traced limb vector -> MSB-first bit vector of width nbits."""
    L = x.shape[-1]
    pos = np.arange(nbits - 1, -1, -1)
    limb_idx = pos // LIMB_BITS
    bit_idx = pos % LIMB_BITS
    if (limb_idx >= L).any():
        raise ValueError("nbits exceeds limb capacity")
    gathered = jnp.take(x, jnp.asarray(limb_idx), axis=-1)
    return (gathered >> jnp.asarray(bit_idx, _U32)) & _U32(1)


def mont_exp_bits(base_mont: jnp.ndarray, bits: jnp.ndarray,
                  mod: Modulus) -> jnp.ndarray:
    """base^e in the Montgomery domain.  `bits` is MSB-first, shape
    broadcastable to base's batch + (nbits,).  Constant-time (select, not
    branch) — appropriate for secret exponents (Paillier decryption)."""
    base_mont = jnp.asarray(base_mont, _U32)
    bshape = jnp.broadcast_shapes(base_mont.shape[:-1], bits.shape[:-1])
    base_mont = jnp.broadcast_to(base_mont, bshape + base_mont.shape[-1:])
    bits = jnp.broadcast_to(bits.astype(_U32), bshape + bits.shape[-1:])
    acc0 = jnp.broadcast_to(mont_one(mod), base_mont.shape)

    def step(acc, bit):
        acc = mont_mul(acc, acc, mod)
        mul = mont_mul(acc, base_mont, mod)
        return jnp.where(bit[..., None] == 1, mul, acc), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    return acc


def mont_exp_const(base_mont: jnp.ndarray, e: int, mod: Modulus) -> jnp.ndarray:
    """base^e for a host-known exponent (key material: n, lambda)."""
    if e == 0:
        return jnp.broadcast_to(mont_one(mod), base_mont.shape)
    bits = jnp.asarray(cached_bits(e, e.bit_length()))
    return mont_exp_bits(base_mont, bits, mod)


# ---------------------------------------------------------------------------
# Plain (non-modular) products used by Paillier
# ---------------------------------------------------------------------------

def big_mul_full(a: jnp.ndarray, b: jnp.ndarray, out_limbs: int) -> jnp.ndarray:
    """Exact product of canonical inputs, truncated/padded to out_limbs.
    Accumulation bound: min(La, Lb) * 2^24 < 2^32 for <=255 limbs."""
    a = a.astype(_U32)
    b = b.astype(_U32)
    La = a.shape[-1]
    bshape = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    a = jnp.broadcast_to(a, bshape + (La,))
    b = jnp.broadcast_to(b, bshape + (b.shape[-1],))
    acc0 = jnp.zeros(bshape + (out_limbs,), _U32)
    bpad = jnp.pad(b, [(0, 0)] * (b.ndim - 1)
                   + [(0, max(0, out_limbs - b.shape[-1]))])[..., :out_limbs]

    def step(i, acc):
        ai = jax.lax.dynamic_index_in_dim(a, i, axis=-1, keepdims=True)
        shifted = jnp.roll(bpad, i, axis=-1)
        keep = jnp.arange(out_limbs) >= i
        shifted = jnp.where(keep, shifted, 0)
        return acc + ai * shifted

    acc = jax.lax.fori_loop(0, min(La, out_limbs), step, acc0)
    return carry_sweep(acc)


def mul_low(a: jnp.ndarray, b: jnp.ndarray, L: int) -> jnp.ndarray:
    """a*b mod 2^(12 L) — used for Hensel exact division."""
    return big_mul_full(a, b, L)


def add_small(a: jnp.ndarray, k: int) -> jnp.ndarray:
    """a + k for small k >= 0 (canonical in, canonical out)."""
    t = a.at[..., 0].add(_U32(k))
    return carry_sweep(t)


def sub_small(a: jnp.ndarray, k: int) -> jnp.ndarray:
    """a - k for small k, assuming a >= k."""
    kv = jnp.zeros(a.shape[-1], _U32).at[0].set(k)
    d, _ = _sub_with_borrow(a, jnp.broadcast_to(kv, a.shape))
    return d
