"""RNS/CRT Montgomery pipeline: residue-channel bignum arithmetic.

The CIOS pipeline in `bigint.py` / `kernels/montmul.py` is a *positional*
representation: every Montgomery round threads a carry through the limb
axis, so the inner loop is L sequential rounds of vector MACs.  That
shape interprets fine but leaves the MXU idle — the compiled `pallas`
backend needs a representation whose hot loop is a dense matmul.

This module keeps each big integer as its residues modulo a fixed set of
small *prime channels* (RNS/CRT).  Montgomery reduction over the channel
product follows Bajard et al.'s two-base construction:

* base B (``kB`` channels, product ``B``) carries the Montgomery radix:
  one round computes ``q = -x·y·N⁻¹ mod B`` channel-pointwise;
* base A (``kA`` channels, product ``A``) receives ``q`` through a base
  extension — a (batch, kB) × (kB, kA) matmul — evaluates
  ``t = (x·y + q·N)/B`` pointwise, and sends ``t`` back through a second
  extension.  *All* cross-channel traffic is those two matmuls; every
  other op is embarrassingly channel-parallel.
* one redundant channel ``m_r`` (Shenoy–Kumaresan) makes the second
  extension exact: the first extension may overshoot by ``α·B`` with
  ``α < kB`` (harmless — it only loosens the bound ``t < (kB+2)·N``),
  but the value handed back to base B is reconstructed exactly via
  ``α' = (Σξ'ⱼ·(A/aⱼ) − t) · A⁻¹ mod m_r``.

Exactness of the extension matmuls without 64-bit hardware: channels are
13-bit primes, operands split into 7-bit halves, and each of the four
half-products accumulates to < k·127·127 < 2²⁴ for k ≤ 1040 channels —
integers that size are exactly representable in float32 *regardless of
accumulation order*, so the dots run as plain f32 matmuls (BLAS on CPU,
MXU on TPU) and still return exact integers.

Bit-exact interop with the limb world: `mont_mul` / `mont_exp_bits` /
`he_matvec` here consume and produce the same canonical radix-2¹² limb
vectors as `bigint` (R = 2^(12·L) Montgomery domain).  Internally values
travel in the ·B domain; entry folds the radix change into the
conversion matrix (`to_rns_scaled`, residues of x·B·R⁻¹), exit is one
round against ``R mod N``, and `from_rns` finishes with an exact binary
conditional subtraction — so outputs are the unique canonical
representative, identical to the `bigint` oracle bit for bit
(tests/test_rns.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint
from repro.crypto.bigint import LIMB_BITS, Modulus, nlimbs

_U32 = jnp.uint32
_F32 = jnp.float32

CHANNEL_BITS = 13        # residue channels are primes in (2^11, 2^13)
_SPLIT = 7               # 7-bit halves: 4 f32 dots, each sum < 2^24 exact
_SPLIT_MASK = (1 << _SPLIT) - 1
_MAX_DOT_K = (1 << 24) // (_SPLIT_MASK * _SPLIT_MASK)   # 1040 channels
_ACCUM_CHUNK = 64        # kA-chunk for the no-mod limb accumulation


# ---------------------------------------------------------------------------
# Exact f32 split matmuls
# ---------------------------------------------------------------------------

def _split_halves(x):
    return ((x & _SPLIT_MASK).astype(_F32), (x >> _SPLIT).astype(_F32))


def _dot(a, b):
    return jnp.matmul(a, b, precision=jax.lax.Precision.HIGHEST)


def _mod_u28(x, m):
    """``x mod m`` for uint32 x < 2^28 against the 13-bit channel moduli.
    Plain hardware remainder: on CPU the pipelined integer divide beats
    any f32-reciprocal emulation (which needs ~15 memory-bound element
    ops per call — measured 3× slower end-to-end).  Kept as a named seam
    so a TPU-compiled build (no native integer divide) can swap in a
    reciprocal sequence in ONE place; every call site bounds its operand
    below 2^28 (see the comments there), which is what such a swap needs.
    """
    return x % m


def split_matmul_mod(x: jnp.ndarray, t, mods) -> jnp.ndarray:
    """Exact ``(x @ t) mod mods`` for uint32 entries < 2^13 via four f32
    matmuls of 7-bit halves.  x: (..., k); t: (k, j); mods: (j,)-broadcast.
    Every partial sum is an integer < 2^24 (k ≤ 1040), hence exact in f32
    in any accumulation order; the u32 recombine keeps each congruent
    term below 2^28 before the final reduction."""
    xl, xh = _split_halves(x)
    tl, th = _split_halves(t)
    ll = _dot(xl, tl).astype(_U32)
    lh = _dot(xl, th).astype(_U32)
    hl = _dot(xh, tl).astype(_U32)
    hh = _dot(xh, th).astype(_U32)
    mid = _mod_u28(lh + hl, mods) << _SPLIT           # lh+hl < 2^25
    top = _mod_u28(hh, mods) << (2 * _SPLIT)          # hh < 2^24
    # ll < 2^24, mid < 2^20, top < 2^27 → sum < 2^28
    return _mod_u28(ll + mid + top, mods)


def _split_matmul_raw(x: jnp.ndarray, t) -> jnp.ndarray:
    """Exact un-reduced ``x @ t`` as lazy uint32 limb planes; the caller
    must bound k ≤ _ACCUM_CHUNK so the recombined sum stays < 2^31."""
    xl, xh = _split_halves(x)
    tl, th = _split_halves(t)
    ll = _dot(xl, tl).astype(_U32)
    lh = _dot(xl, th).astype(_U32)
    hl = _dot(xh, tl).astype(_U32)
    hh = _dot(xh, th).astype(_U32)
    return ll + ((lh + hl) << _SPLIT) + (hh << (2 * _SPLIT))


# ---------------------------------------------------------------------------
# Context: per-modulus channel system (host-built, lru-cached)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _prime_pool() -> tuple[int, ...]:
    """13-bit primes, descending (larger channels first → fewer of them)."""
    top = 1 << CHANNEL_BITS
    sieve = np.ones(top, dtype=bool)
    sieve[:2] = False
    for p in range(2, int(top ** 0.5) + 1):
        if sieve[p]:
            sieve[p * p::p] = False
    ps = np.nonzero(sieve)[0]
    ps = ps[ps > (1 << (CHANNEL_BITS - 2))]
    return tuple(int(p) for p in ps[::-1])


@dataclasses.dataclass(frozen=True, eq=False)
class RNSContext:
    """Channel system for one modulus N.  Frozen and identity-hashed so a
    context rides through jit as a static argument; `for_modulus` returns
    the same object per (N, L), so traces cache correctly.

    Channel layout of every state vector: ``[base A | base B | m_r]``
    (kA + kB + 1 = CH channels).  Numpy members become jit constants.
    """

    value: int                  # N (host int)
    L: int                      # limb count of the radix-2^12 world
    R: int                      # 2^(12 L)
    kA: int
    kB: int
    CH: int
    A: int                      # Π base-A channels  (> (kB+2)²·N)
    B: int                      # Π base-B channels  (> (kB+2)²·N)
    m_r: int                    # redundant channel  (> kA)
    ainv_r: int                 # A⁻¹ mod m_r
    a_mods: np.ndarray          # (kA,)
    b_mods: np.ndarray          # (kB,)
    all_mods: np.ndarray        # (CH,)
    t_b: np.ndarray             # (kB, kA+1): (B/bᵢ) mod [a_mods | m_r]
    t_a: np.ndarray             # (kA, kB+1): (A/aⱼ) mod [b_mods | m_r]
    vecs: np.ndarray            # (6, CH) packed per-channel constants:
                                #   0: −N⁻¹ mod bᵢ      (kB)
                                #   1: (B/bᵢ)⁻¹ mod bᵢ  (kB)
                                #   2: (A/aⱼ)⁻¹ mod aⱼ  (kA)
                                #   3: N mod [a|r]      (kA+1)
                                #   4: B⁻¹ mod [a|r]    (kA+1)
                                #   5: A mod bᵢ         (kB)
    pow_mat: np.ndarray         # (L, CH): 2^(12 l) mod channel (to_rns)
    pow_scaled: np.ndarray      # (L, CH): (2^(12 l)·B·R⁻¹ mod N) mod ch —
                                # to_rns with the Montgomery-radix change
                                # folded in (value ≡ x·B·R⁻¹ mod N,
                                # magnitude < L·2^12·N, absorbed by the
                                # 2^44 headroom in the base-B floor)
    limb_a: np.ndarray          # (kA, L_out): limbs of A/aⱼ (from_rns)
    a_limbs: np.ndarray         # (L_out,): limbs of A
    L_out: int                  # nlimbs(A) + headroom for Σξ'·(A/aⱼ)
    nj: np.ndarray              # (n_red, L_out): 2^j·N, j descending
    consts: dict                # residue vectors (CH,): 'one' = B mod N,
                                # 'exit' = R mod N


def _residues(v: int, mods: np.ndarray) -> np.ndarray:
    return np.array([v % int(m) for m in mods], np.uint32)


def make_context(value: int, L: int) -> RNSContext:
    """Build the channel system for modulus `value` with limb count L.
    Raises ValueError if the 13-bit prime pool can't cover the modulus
    (≈2048-bit keys / 4096-bit n² is the practical ceiling)."""
    N = int(value)
    if N % 2 == 0 or N < 3:
        raise ValueError("RNS context needs an odd modulus ≥ 3")
    R = 1 << (LIMB_BITS * L)
    pool = [p for p in _prime_pool() if N % p]

    def take(prod_floor):
        picked, prod = [], 1
        while prod <= prod_floor(len(picked)):
            if not pool:
                raise ValueError(
                    f"13-bit RNS prime pool exhausted for a "
                    f"{N.bit_length()}-bit modulus; the channel pipeline "
                    "covers moduli up to ~4096 bits")
            picked.append(pool.pop(0))
            prod *= picked[-1]
        return picked, prod

    # B > max(2·(kB+2)², 2^44)·N keeps the round output t < (kB+2)·N even
    # when both operands carry the scaled-entry magnitude < L·2^12·N
    # (L·2^12 ≤ 2^22 for every supported modulus): x·y ≤ 2^44·N², so
    # t ≤ x·y/B + kB·N < (kB+2)·N.
    b_list, B = take(lambda k: max(2 * (k + 3) ** 2, 1 << 44) * N)
    kB = len(b_list)
    c = kB + 2
    # A > 2·c²·N ≥ c·N bounds from_rns inputs and the second extension
    a_list, A = take(lambda _k: 2 * c * c * N)
    kA = len(a_list)
    if not pool:
        raise ValueError("no prime left for the redundant RNS channel")
    m_r = pool.pop(0)
    assert m_r > kA, "redundant channel must exceed the base-A count"
    if max(kA, kB) + 1 > _MAX_DOT_K or L > _MAX_DOT_K:
        raise ValueError("channel/limb count exceeds the exact-f32 bound")

    a_mods = np.array(a_list, np.uint32)
    b_mods = np.array(b_list, np.uint32)
    all_mods = np.concatenate([a_mods, b_mods, np.array([m_r], np.uint32)])
    ar = a_list + [m_r]
    br = b_list + [m_r]
    CH = kA + kB + 1

    t_b = np.array([[(B // bi) % aj for aj in ar] for bi in b_list],
                   np.uint32)
    t_a = np.array([[(A // aj) % bi for bi in br] for aj in a_list],
                   np.uint32)

    vecs = np.zeros((6, CH), np.uint32)
    vecs[0, :kB] = [(-pow(N, -1, bi)) % bi for bi in b_list]
    vecs[1, :kB] = [pow(B // bi, -1, bi) for bi in b_list]
    vecs[2, :kA] = [pow(A // aj, -1, aj) for aj in a_list]
    vecs[3, :kA + 1] = [N % m for m in ar]
    vecs[4, :kA + 1] = [pow(B, -1, m) for m in ar]
    vecs[5, :kB] = [A % bi for bi in b_list]

    pow_mat = np.stack([_residues(1 << (LIMB_BITS * l), all_mods)
                        for l in range(L)])
    scale = (B * pow(R, -1, N)) % N
    pow_scaled = np.stack(
        [_residues(((1 << (LIMB_BITS * l)) * scale) % N, all_mods)
         for l in range(L)])

    L_out = nlimbs(A.bit_length() + LIMB_BITS)
    limb_a = np.stack([bigint.int_to_limbs(A // aj, L_out)
                       for aj in a_list])
    a_limbs = bigint.int_to_limbs(A, L_out)
    n_red = max(1, c.bit_length())
    nj = np.stack([bigint.int_to_limbs((1 << j) * N, L_out)
                   for j in range(n_red - 1, -1, -1)])

    consts = {
        "one": _residues(B % N, all_mods),
        "exit": _residues(R % N, all_mods),
    }
    return RNSContext(
        value=N, L=L, R=R, kA=kA, kB=kB, CH=CH, A=A, B=B, m_r=m_r,
        ainv_r=pow(A, -1, m_r), a_mods=a_mods, b_mods=b_mods,
        all_mods=all_mods, t_b=t_b, t_a=t_a, vecs=vecs, pow_mat=pow_mat,
        pow_scaled=pow_scaled, limb_a=limb_a, a_limbs=a_limbs,
        L_out=L_out, nj=nj, consts=consts)


@functools.lru_cache(maxsize=32)
def _context_cached(value: int, L: int) -> RNSContext:
    return make_context(value, L)


def for_modulus(mod: Modulus) -> RNSContext:
    """The (cached) channel system for a `bigint.Modulus`."""
    return _context_cached(mod.value, mod.L)


# ---------------------------------------------------------------------------
# Channel-domain core (shared verbatim by the Pallas kernel bodies)
# ---------------------------------------------------------------------------

def montmul_channels(x, y, mods, t_b, t_a, vecs, *, kA: int, kB: int,
                     ainv_r: int):
    """One RNS Montgomery round on channel states: returns the residues
    of ``t = x·y·B⁻¹`` with t < (kB+2)·N, given x, y < (kB+2)·N.

    Pure jnp on plain arrays, so kernel bodies trace it inline exactly as
    the library path runs it (`kernels/montmul.py` reuses this function —
    the kernels and the library are the same arithmetic by construction).
    """
    CH = kA + kB + 1
    am = mods[..., :kA]
    bm = mods[..., kA:kA + kB]
    rm = mods[..., CH - 1:]
    armods = jnp.concatenate([am, rm], axis=-1)
    brmods = jnp.concatenate([bm, rm], axis=-1)

    s = _mod_u28(x * y, mods)                            # x·y < 2^26
    s_ar = jnp.concatenate([s[..., :kA], s[..., CH - 1:]], axis=-1)
    sb = s[..., kA:kA + kB]

    # q = −x·y·N⁻¹ mod B, channel-pointwise; ξ its mixed-radix form
    qb = _mod_u28(sb * vecs[0, :kB], bm)                 # < 2^26
    xi = _mod_u28(qb * vecs[1, :kB], bm)                 # < 2^26
    # first base extension (approximate: may add α·B, α < kB — absorbed
    # by the t < (kB+2)·N bound, never by correctness)
    qhat = split_matmul_mod(xi, t_b, armods)             # (..., kA+1)

    # t = (s + q̂·N)/B on base A and the redundant channel
    t_ar = _mod_u28(                                     # inner < 2^27
        _mod_u28(s_ar + qhat * vecs[3, :kA + 1], armods)
        * vecs[4, :kA + 1], armods)                      # outer < 2^26
    ta = t_ar[..., :kA]
    tr = t_ar[..., kA:]

    # exact second extension A → B (Shenoy–Kumaresan via m_r)
    xi2 = _mod_u28(ta * vecs[2, :kA], am)                # < 2^26
    ext = split_matmul_mod(xi2, t_a, brmods)             # (..., kB+1)
    sig_b = ext[..., :kB]
    sig_r = ext[..., kB:]
    alpha = _mod_u28((sig_r + rm - tr) * _U32(ainv_r), rm)   # < 2^27
    tb = _mod_u28(sig_b + bm - _mod_u28(alpha * vecs[5, :kB], bm), bm)
    return jnp.concatenate([ta, tb, tr], axis=-1)


# ---------------------------------------------------------------------------
# Conversions limbs <-> channels (exact)
# ---------------------------------------------------------------------------

def _jc(ctx: RNSContext, name: str) -> jnp.ndarray:
    return jnp.asarray(getattr(ctx, name), _U32)


def const_rns(ctx: RNSContext, name: str) -> jnp.ndarray:
    """A named context constant ('one'|'exit') as (CH,)."""
    return jnp.asarray(ctx.consts[name], _U32)


def rns_montmul(ctx: RNSContext, x, y) -> jnp.ndarray:
    return montmul_channels(x, y, _jc(ctx, "all_mods"), _jc(ctx, "t_b"),
                            _jc(ctx, "t_a"), _jc(ctx, "vecs"),
                            kA=ctx.kA, kB=ctx.kB, ainv_r=ctx.ainv_r)


def to_rns(ctx: RNSContext, x: jnp.ndarray) -> jnp.ndarray:
    """(..., L) canonical limbs → (..., CH) channel residues (exact: one
    split-f32 matmul against the 2^(12l) residue matrix)."""
    return split_matmul_mod(x.astype(_U32), _jc(ctx, "pow_mat"),
                            _jc(ctx, "all_mods"))


def to_rns_scaled(ctx: RNSContext, x: jnp.ndarray) -> jnp.ndarray:
    """(..., L) limbs of x ↦ residues of a value ≡ x·B·R⁻¹ (mod N) with
    magnitude < L·2^12·N: the radix change R → B folded into the
    conversion matrix, so entering the ·B domain costs no extra
    Montgomery round (the base-B floor's 2^44 headroom absorbs the
    magnitude — see `make_context`)."""
    return split_matmul_mod(x.astype(_U32), _jc(ctx, "pow_scaled"),
                            _jc(ctx, "all_mods"))


def from_rns(ctx: RNSContext, t: jnp.ndarray) -> jnp.ndarray:
    """(..., CH) channel state with value < (kB+2)·N → (..., L) canonical
    limbs of value mod N.  Exact: mixed-radix reconstruction over base A
    (the redundant channel pins the α'·A overshoot), then a binary
    conditional-subtraction chain brings the value below N."""
    kA = ctx.kA
    ta = t[..., :kA]
    tr = t[..., ctx.CH - 1:]
    am = _jc(ctx, "a_mods")
    xi = _mod_u28(ta * _jc(ctx, "vecs")[2, :kA], am)     # ξ'ⱼ < aⱼ, < 2^26

    t_a = _jc(ctx, "t_a")
    mr = _U32(ctx.m_r)
    sig_r = split_matmul_mod(xi, t_a[:, ctx.kB:], mr)    # Σξ'(A/aⱼ) mod m_r
    alpha = _mod_u28((sig_r + mr - tr) * _U32(ctx.ainv_r), mr)   # < 2^27

    # P = Σⱼ ξ'ⱼ · limbs(A/aⱼ): exact, chunked so lazy limbs stay < 2^31
    limb_a = _jc(ctx, "limb_a")
    acc = jnp.zeros(xi.shape[:-1] + (ctx.L_out,), _U32)
    for c0 in range(0, kA, _ACCUM_CHUNK):
        part = _split_matmul_raw(xi[..., c0:c0 + _ACCUM_CHUNK],
                                 limb_a[c0:c0 + _ACCUM_CHUNK])
        acc = bigint._one_shot_carry(acc + part)
    p = bigint.carry_sweep(acc)
    q = bigint.carry_sweep(alpha * _jc(ctx, "a_limbs"))  # α'·A (α' < 2^13)
    v, _ = bigint._sub_with_borrow(p, q)                 # = t, exact (≥ 0)

    # v < (kB+2)·N → subtract 2^j·N conditionally, MSB-down: v' < N
    nj = _jc(ctx, "nj")
    for j in range(nj.shape[0]):
        d, borrow = bigint._sub_with_borrow(
            v, jnp.broadcast_to(nj[j], v.shape))
        v = jnp.where((borrow == 1)[..., None], v, d)
    return v[..., :ctx.L]


# ---------------------------------------------------------------------------
# Limb-domain ops (drop-in peers of bigint.mont_mul / mont_exp_bits /
# protocols._he_matvec_windowed — bit-exact, jitted per context)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def mont_mul(ctx: RNSContext, a, b) -> jnp.ndarray:
    """a·b·R⁻¹ mod N on canonical limb vectors (bigint.mont_mul peer)."""
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    # b enters pre-scaled by B·R⁻¹, so one round gives a·b·R⁻¹ directly
    t = rns_montmul(ctx, to_rns(ctx, a), to_rns_scaled(ctx, b))
    return from_rns(ctx, t)


@functools.partial(jax.jit, static_argnums=(0,))
def mont_exp_bits(ctx: RNSContext, base, bits) -> jnp.ndarray:
    """Constant-time ladder base^e on Montgomery-domain limb vectors
    (bigint.mont_exp_bits peer).  bits: (..., nbits) MSB-first."""
    base = jnp.asarray(base, _U32)
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    base = jnp.broadcast_to(base, bshape + base.shape[-1:])
    bits = jnp.broadcast_to(bits.astype(_U32), bshape + bits.shape[-1:])
    # enter: b̃ = v·R ↦ v·B; the ladder then lives in the ·B domain
    u = to_rns_scaled(ctx, base)
    acc0 = jnp.broadcast_to(const_rns(ctx, "one"), u.shape)

    def step(acc, bit):
        acc = rns_montmul(ctx, acc, acc)
        mul = rns_montmul(ctx, acc, u)
        return jnp.where(bit[..., None] == 1, mul, acc), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    out = rns_montmul(ctx, acc, const_rns(ctx, "exit"))    # v^e·B ↦ v^e·R
    return from_rns(ctx, out)


def _tree_fold(ctx: RNSContext, c: jnp.ndarray) -> jnp.ndarray:
    """⊕-reduce axis 0 of ·B-domain channel states (log depth)."""
    while c.shape[0] > 1:
        half = c.shape[0] // 2
        merged = rns_montmul(ctx, c[:half], c[half:2 * half])
        if c.shape[0] % 2:
            merged = jnp.concatenate([merged, c[2 * half:]], axis=0)
        c = merged
    return c[0]


@functools.partial(jax.jit, static_argnums=(0, 3))
def he_matvec(ctx: RNSContext, cts, digits, window: int) -> jnp.ndarray:
    """Fixed-window HE matvec on limb vectors: cts (n, L) Montgomery
    ciphertexts, digits (n, m, levels) MSB-first window digits.  Returns
    (m, L) canonical Montgomery limbs — `protocols._he_matvec_windowed`
    peer, bit-exact."""
    cts = jnp.asarray(cts, _U32)
    digits = jnp.asarray(digits, _U32)
    m = digits.shape[1]
    u = to_rns_scaled(ctx, cts)
    one = const_rns(ctx, "one")
    table = [jnp.broadcast_to(one, u.shape), u]
    for _ in range(2, 1 << window):
        table.append(rns_montmul(ctx, table[-1], u))
    table = jnp.stack(table, axis=0)                      # (2^w, n, CH)
    acc0 = jnp.broadcast_to(one, (m, ctx.CH))

    def step(acc, digits_lvl):                            # (n, m)
        for _ in range(window):
            acc = rns_montmul(ctx, acc, acc)
        sel = jnp.take_along_axis(
            table[:, :, None, :], digits_lvl[None, :, :, None], axis=0)[0]
        prod = _tree_fold(ctx, sel)
        return rns_montmul(ctx, acc, prod), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(digits, -1, 0))
    out = rns_montmul(ctx, acc, const_rns(ctx, "exit"))
    return from_rns(ctx, out)


@functools.partial(jax.jit, static_argnums=(0,))
def fixed_base_exp(ctx: RNSContext, table_rns, digits) -> jnp.ndarray:
    """Fixed-base windowed exponentiation from a prepared channel-domain
    table: table_rns (levels, 2^w, CH) holds ``h^(d·2^(w·lvl))`` in the
    ·B domain; digits (..., levels) are LSB-first base-2^w digits of the
    exponent.  Returns (..., L) canonical Montgomery-domain limbs of
    h^e·R — the `noise_to_mont` contract."""
    digits = jnp.asarray(digits, _U32)
    table_rns = jnp.asarray(table_rns, _U32)
    acc0 = jnp.broadcast_to(const_rns(ctx, "one"),
                            digits.shape[:-1] + (ctx.CH,))

    def step(acc, lvl_in):
        tab_lvl, dig = lvl_in                              # (2^w, CH), (...,)
        sel = jnp.take(tab_lvl, dig, axis=0)               # (..., CH)
        return rns_montmul(ctx, acc, sel), None

    acc, _ = jax.lax.scan(
        step, acc0, (table_rns, jnp.moveaxis(digits, -1, 0)))
    out = rns_montmul(ctx, acc, const_rns(ctx, "exit"))
    return from_rns(ctx, out)
