"""Persistent fixed-base windowed exponentiation tables.

The two exponentiations that dominate EFMVFL training both have a FIXED
base once the keypair exists:

* encryption noise — Damgård–Jurik–Nielsen short-exponent form: fix
  ``h = x^n mod n²`` for one random unit x at keygen, then each noise
  term is ``h^ρ`` for a fresh short ρ (`DEFAULT_RHO_BITS`, ≥ 2·80-bit
  statistical security) instead of ``r^n`` with an n-bit ladder;
* the generator — ``g^m mod n²`` for encode/encrypt when g ≠ 1+n (the
  1+n closed form needs no table; the general-g path does).

A `FixedBaseTable` stores ``base^(d·2^(w·lvl))`` for every window digit
d < 2^w and level, as RNS channel states in the ·B domain
(`crypto.rns`), so evaluating ``base^e`` is one table-select ⊕ per
digit level — ``ceil(ρ_bits/w)`` RNS rounds instead of ``2·n_bits``
ladder rounds (the BENCH_crypto.json ``fixed_base`` rows measure the
gap).  Tables are built once per keypair (`paillier.keygen(table_path=…)`
or `ensure_table`), persisted to disk keyed by a key fingerprint, and
validated structurally AND cryptographically on load:

* header mismatch (different key, window, limb layout, channel count)
  → `TableMismatchError` — the caller grabbed the wrong file;
* torn/truncated/bit-rotted content (digest mismatch, unparseable npz)
  → `TableCorruptError` — the file itself is damaged.

Writes follow `checkpoint/manager.py`'s durability protocol: tmp file +
fsync + atomic rename + directory fsync, so a crash mid-write can never
leave a loadable-but-torn table.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import math
import os
import threading
import zipfile

import numpy as np

from repro.crypto import rns
from repro.crypto.bigint import Modulus

TABLE_VERSION = 1
DEFAULT_WINDOW = 4
# Short-exponent noise h^ρ: ρ uniform in [0, 2^320).  320 = 2×80-bit
# statistical security + 160-bit margin — the DJN recommendation for
# ≤ 2048-bit moduli; still 3× shorter than the shortest supported n.
DEFAULT_RHO_BITS = 320


class TableMismatchError(ValueError):
    """Table header disagrees with the expected key / window / layout —
    the file is intact but belongs to a different configuration."""


class TableCorruptError(ValueError):
    """Table file is torn, truncated, or fails its content digest."""


def key_fingerprint(n: int) -> str:
    """Stable fingerprint of a public key: sha256 over n's bytes."""
    nb = int(n)
    return hashlib.sha256(
        nb.to_bytes((nb.bit_length() + 7) // 8, "little")).hexdigest()


@dataclasses.dataclass(frozen=True, eq=False)
class FixedBaseTable:
    """Windowed power table for one fixed base mod n².

    ``table_rns[lvl, d]`` holds the RNS channel residues of
    ``(base^(d·2^(window·lvl)) · B) mod n²`` — the ·B-domain form
    `rns.fixed_base_exp` / `kernels.montexp.rns_fixed_base_tiled`
    consume directly.  `exp_bits` = window·levels is the widest exponent
    the table can walk.
    """

    purpose: str                # "noise" | "generator"
    n: int                      # public key (fingerprint input)
    base: int                   # the fixed base, canonical mod n²
    window: int
    levels: int
    L: int                      # radix-2^12 limb count of the n² world
    table_rns: np.ndarray       # (levels, 2^window, CH) uint32

    @property
    def exp_bits(self) -> int:
        return self.window * self.levels

    @property
    def fingerprint(self) -> str:
        return key_fingerprint(self.n)

    def header(self) -> dict:
        """The identity header persisted with (and checked against) the
        table payload: key fingerprint + window + limb/channel layout."""
        return {
            "version": TABLE_VERSION,
            "purpose": self.purpose,
            "fingerprint": self.fingerprint,
            "window": self.window,
            "levels": self.levels,
            "L": self.L,
            "CH": int(self.table_rns.shape[-1]),
            "channel_bits": rns.CHANNEL_BITS,
            "limb_bits": rns.LIMB_BITS,
        }

    def nbytes(self) -> int:
        return int(self.table_rns.nbytes)


def exp_digits(exps, levels: int, window: int) -> np.ndarray:
    """LSB-first base-2^window digits: (batch,) ints → (batch, levels)
    uint32 — the fixed-base twin of `protocols.window_digits` (which is
    MSB-first for the ladder-style matvec; the table walk is LSB-first
    because level lvl stores base^(d·2^(w·lvl)))."""
    mask = (1 << window) - 1
    out = np.empty((len(exps), levels), np.uint32)
    for i, e in enumerate(exps):
        e = int(e)
        out[i] = [(e >> (window * lvl)) & mask for lvl in range(levels)]
    return out


def draw_exponent_digits(table: FixedBaseTable, batch: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Fresh short exponents ρ, drawn DIRECTLY as their digit vectors:
    (batch, levels) uint32 uniform in [0, 2^window) per digit ≡ ρ uniform
    in [0, 2^exp_bits) — no bigint sampling or decomposition needed."""
    return rng.integers(0, 1 << table.window,
                        size=(batch, table.levels)).astype(np.uint32)


def _build_table(purpose: str, n: int, base: int, mod: Modulus, *,
                 window: int, exp_bits: int) -> FixedBaseTable:
    n2 = mod.value
    ctx = rns.for_modulus(mod)
    levels = -(-exp_bits // window)
    npow = 1 << window
    rows = np.empty((levels, npow, ctx.CH), np.uint32)
    lvl_base = base % n2                 # base^(2^(w·lvl)), updated per level
    for lvl in range(levels):
        p = 1
        for d in range(npow):
            rows[lvl, d] = rns._residues((p * ctx.B) % n2, ctx.all_mods)
            p = (p * lvl_base) % n2
        lvl_base = p                     # p = lvl_base^(2^w) after the loop
    return FixedBaseTable(purpose=purpose, n=n, base=base % n2,
                          window=window, levels=levels, L=mod.L,
                          table_rns=rows)


def build_noise_table(n: int, mod: Modulus, *, window: int = DEFAULT_WINDOW,
                      rho_bits: int = DEFAULT_RHO_BITS,
                      rng: np.random.Generator | None = None,
                      x: int | None = None) -> FixedBaseTable:
    """DJN noise table: h = x^n mod n² for a random unit x (or a caller-
    supplied one — tests), windows over short exponents ρ < 2^rho_bits."""
    n2 = mod.value
    if x is None:
        rng = rng or np.random.default_rng()
        while True:
            x = int.from_bytes(rng.bytes(n2.bit_length() // 8 + 16),
                               "little") % n2
            if x > 1 and math.gcd(x % n, n) == 1:    # unit mod n ⇒ mod n²
                break
    h = pow(int(x), int(n), n2)
    return _build_table("noise", n, h, mod, window=window,
                        exp_bits=rho_bits)


def build_generator_table(n: int, g: int, mod: Modulus, *,
                          window: int = DEFAULT_WINDOW,
                          msg_bits: int) -> FixedBaseTable:
    """g^m table for encode/encrypt with a general generator g (the
    default g = 1+n uses the closed form and needs no table)."""
    return _build_table("generator", n, g, mod, window=window,
                        exp_bits=msg_bits)


# ---------------------------------------------------------------------------
# Persistence: fingerprint-keyed, torn-write-proof
# ---------------------------------------------------------------------------

def _fsync_dir(directory: str) -> None:
    try:
        fd = os.open(directory or ".", os.O_RDONLY)
    except OSError:              # platform without directory fds
        return
    try:
        os.fsync(fd)
    except OSError:              # pragma: no cover — fsync unsupported
        pass
    finally:
        os.close(fd)


def save_table(table: FixedBaseTable, path: str) -> str:
    """Persist header + payload as one npz, durably: tmp + fsync +
    atomic rename + directory fsync (`checkpoint/manager.py` protocol).
    The header carries a sha256 of the payload so loads detect torn or
    bit-rotted content as corruption, distinct from a mismatched key."""
    header = table.header()
    payload = np.ascontiguousarray(table.table_rns)
    header["table_sha256"] = hashlib.sha256(payload.tobytes()).hexdigest()
    base_bytes = np.frombuffer(
        int(table.base).to_bytes((int(table.base).bit_length() + 7) // 8
                                 or 1, "little"), np.uint8)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(tmp, "wb") as f:
        np.savez(f, header=np.frombuffer(
            json.dumps(header).encode(), np.uint8),
            table_rns=payload, base=base_bytes,
            n=np.frombuffer(int(table.n).to_bytes(
                (int(table.n).bit_length() + 7) // 8, "little"), np.uint8))
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    _fsync_dir(directory)
    return path


def load_table(path: str, *, n: int, mod: Modulus,
               purpose: str = "noise",
               window: int | None = None) -> FixedBaseTable:
    """Load and validate a persisted table.

    Raises:
      TableCorruptError: unreadable npz, missing members, or payload
        digest mismatch (torn write, stale partial file, bit rot).
      TableMismatchError: intact file whose header names a different
        key fingerprint, purpose, window, or limb/channel layout.
    """
    try:
        with open(path, "rb") as f:
            raw = f.read()
        with np.load(io.BytesIO(raw)) as z:
            header = json.loads(bytes(z["header"]).decode())
            payload = z["table_rns"]
            base = int.from_bytes(bytes(z["base"]), "little")
            n_stored = int.from_bytes(bytes(z["n"]), "little")
    except (OSError, ValueError, KeyError, EOFError, zipfile.BadZipFile,
            json.JSONDecodeError) as e:
        raise TableCorruptError(
            f"fixed-base table {path!r} is unreadable or torn: {e}") from e

    expect_fp = key_fingerprint(n)
    ctx = rns.for_modulus(mod)
    checks = {
        "version": TABLE_VERSION,
        "purpose": purpose,
        "fingerprint": expect_fp,
        "L": mod.L,
        "CH": ctx.CH,
        "channel_bits": rns.CHANNEL_BITS,
        "limb_bits": rns.LIMB_BITS,
    }
    if window is not None:
        checks["window"] = window
    for key, want in checks.items():
        got = header.get(key)
        if got != want:
            raise TableMismatchError(
                f"fixed-base table {path!r} was built for a different "
                f"configuration: {key}={got!r}, expected {want!r}")
    digest = hashlib.sha256(
        np.ascontiguousarray(payload).tobytes()).hexdigest()
    if digest != header.get("table_sha256"):
        raise TableCorruptError(
            f"fixed-base table {path!r} payload digest mismatch "
            "(torn write or bit rot) — rebuild the table")
    return FixedBaseTable(purpose=header["purpose"], n=n_stored, base=base,
                          window=int(header["window"]),
                          levels=int(header["levels"]), L=int(header["L"]),
                          table_rns=np.asarray(payload, np.uint32))


def ensure_table(n: int, mod: Modulus, path: str, *,
                 purpose: str = "noise",
                 window: int = DEFAULT_WINDOW,
                 rho_bits: int = DEFAULT_RHO_BITS,
                 rng: np.random.Generator | None = None
                 ) -> tuple[FixedBaseTable, bool]:
    """Load `path` if it already holds this keypair's table, else build
    and persist one.  Returns (table, built) — built=True means keygen
    paid the one-time table cost now.  A mismatched table (other key /
    layout) is rebuilt in place; a corrupt file is also rebuilt (the
    write protocol makes overwriting safe)."""
    if os.path.exists(path):
        try:
            return load_table(path, n=n, mod=mod, purpose=purpose,
                              window=window), False
        except (TableMismatchError, TableCorruptError):
            pass
    if purpose != "noise":
        raise ValueError("ensure_table builds noise tables; build "
                         "generator tables via build_generator_table")
    table = build_noise_table(n, mod, window=window, rho_bits=rho_bits,
                              rng=rng)
    save_table(table, path)
    return table, True
