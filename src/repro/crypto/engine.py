"""Crypto compute engine: one dispatch point for every Paillier hot loop.

Every modular-arithmetic hot spot — encryption noise r^n, the Protocol-3
HE matvec, CRT decryption, scalar ⊗, homomorphic ⊕ — funnels through a
`CryptoEngine`, which routes each op to either the pure-jnp library
(`crypto.bigint`) or the fused Pallas kernels (`kernels.montexp` /
`kernels.montmul`).  Backends:

* ``jnp``              — `lax`-loop library code (CPU default; also the
                         bit-exactness oracle).
* ``pallas-interpret`` — fused kernels in interpret mode (CPU: same IR
                         as the TPU path, runs as jitted jax — used by
                         the parity suite and CI).
* ``pallas``           — fused kernels compiled for TPU (deployment).

All three produce bit-identical canonical limbs (tests/test_engine.py),
so the switch is purely a performance knob: select with the
``REPRO_CRYPTO_ENGINE`` env var, `VFLConfig.crypto_engine`, or
`set_engine`/`use_engine`.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.crypto import bigint
from repro.crypto.bigint import Modulus

_U32 = jnp.uint32

BACKENDS = ("jnp", "pallas-interpret", "pallas")
ENV_VAR = "REPRO_CRYPTO_ENGINE"


def resolve_backend(name: str | None = None) -> str:
    """``auto``/None -> env var -> hardware default."""
    if name in (None, "", "auto"):
        name = os.environ.get(ENV_VAR, "auto")
    if name in ("", "auto"):
        name = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown crypto engine {name!r}; "
                         f"choose from {BACKENDS + ('auto',)}")
    return name


@dataclasses.dataclass(frozen=True)
class CryptoEngine:
    """Immutable dispatch descriptor (hashable, so it can ride through
    jit static args)."""

    backend: str = "jnp"
    tile_b: int = 128           # montmul / ladder batch tile
    tile_m: int = 128           # he_matvec output-column tile
    chunk_n: int = 512          # he_matvec ciphertext-row chunk (VMEM)

    @property
    def uses_kernels(self) -> bool:
        return self.backend != "jnp"

    @property
    def interpret(self) -> bool:
        return self.backend != "pallas"

    # -- fused hot-path ops -------------------------------------------------
    def mont_mul(self, a: jnp.ndarray, b: jnp.ndarray,
                 mod: Modulus) -> jnp.ndarray:
        if not self.uses_kernels:
            return bigint.mont_mul(a, b, mod)
        from repro.kernels import ops
        return ops.montmul(a, b, mod, tile_b=self.tile_b,
                           interpret=self.interpret)

    def mont_exp_bits(self, base: jnp.ndarray, bits: jnp.ndarray,
                      mod: Modulus) -> jnp.ndarray:
        """Constant-time ladder; kernel path runs it in ONE pallas_call."""
        if not self.uses_kernels:
            return bigint.mont_exp_bits(base, bits, mod)
        from repro.kernels import ops
        return ops.mont_exp_fused(base, bits, mod, tile_b=self.tile_b,
                                  interpret=self.interpret)

    def mont_exp_const(self, base: jnp.ndarray, e: int,
                       mod: Modulus) -> jnp.ndarray:
        if e == 0:
            return jnp.broadcast_to(bigint.mont_one(mod), base.shape)
        bits = jnp.asarray(bigint.cached_bits(int(e), int(e).bit_length()))
        return self.mont_exp_bits(base, bits, mod)

    def he_matvec_windowed(self, cts: jnp.ndarray, digits,
                           mod: Modulus, window: int) -> jnp.ndarray:
        """Fused windowed matvec (kernel backends only; protocols routes
        the jnp backend to its library ladders).  digits: (n, m, levels)
        MSB-first window digits."""
        from repro.kernels import ops
        return ops.he_matvec_fused(cts, jnp.asarray(digits, _U32), mod,
                                   window=window, tile_m=self.tile_m,
                                   chunk_n=self.chunk_n,
                                   interpret=self.interpret)

    # -- derived conveniences (same dispatch, used by paillier.py) ----------
    def to_mont(self, a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
        return self.mont_mul(a, jnp.asarray(mod.r2, _U32), mod)

    def from_mont(self, a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
        one = jnp.zeros(mod.L, _U32).at[0].set(1)
        return self.mont_mul(a, one, mod)


def make(name: str | None = None, **kw) -> CryptoEngine:
    return CryptoEngine(backend=resolve_backend(name), **kw)


_DEFAULT: CryptoEngine | None = None


def get_engine() -> CryptoEngine:
    """Process-default engine (env-resolved on first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make()
    return _DEFAULT


def set_engine(engine: CryptoEngine | str | None) -> CryptoEngine:
    """Install the process-default engine; accepts a backend name."""
    global _DEFAULT
    _DEFAULT = make(engine) if isinstance(engine, (str, type(None))) \
        else engine
    return _DEFAULT


@contextlib.contextmanager
def use_engine(engine: CryptoEngine | str):
    """Temporarily switch the process-default engine (tests/benchmarks)."""
    global _DEFAULT
    prev = _DEFAULT
    set_engine(engine)
    try:
        yield get_engine()
    finally:
        _DEFAULT = prev
