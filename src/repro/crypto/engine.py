"""Crypto compute engine: one dispatch point for every Paillier hot loop.

Every modular-arithmetic hot spot — encryption noise r^n, the Protocol-3
HE matvec, CRT decryption, scalar ⊗, homomorphic ⊕ — funnels through a
`CryptoEngine`, which routes each op to either the pure-jnp library
(`crypto.bigint`) or the fused Pallas kernels (`kernels.montexp` /
`kernels.montmul`).  Backends:

* ``jnp``              — `lax`-loop library code (CPU default; also the
                         bit-exactness oracle).
* ``pallas-interpret`` — fused kernels in interpret mode (CPU: same IR
                         as the TPU path, runs as jitted jax — used by
                         the parity suite and CI).
* ``pallas``           — fused kernels compiled for TPU (deployment).

All three produce bit-identical canonical limbs (tests/test_engine.py),
so the switch is purely a performance knob: select with the
``REPRO_CRYPTO_ENGINE`` env var, `VFLConfig.crypto_engine`, or
`set_engine`/`use_engine`.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.

Orthogonal to the backend, the ``pipeline`` field picks the *arithmetic*
each op runs (docs/engine.md §RNS):

* ``cios`` — radix-2^12 CIOS limb loops (mask/shift only; wins at small
             moduli and is the only pipeline the sharded path runs);
* ``rns``  — the residue-number-system channel pipeline (`crypto.rns`):
             montmul becomes one pointwise round + two exact f32
             matmuls, the MXU-shaped form that wins at large moduli;
* ``auto`` — per-modulus routing at `RNS_MIN_BITS`: RNS at ≥ 512-bit
             moduli, CIOS/library below — so engine-routed ops are
             never slower than the library at any committed key size
             (benchmarks/kernel_bench.py guards this).

Fixed-base exponentiation (`fixed_base_exp`, fed by
`crypto.fixed_base.FixedBaseTable`) is always RNS — the table stores
·B-domain channel states and beats the ladder at every size.

Scale-out: give the engine a device ``mesh`` (or construct a
`distributed.he_sharding.ShardedCryptoEngine`) and every batched op runs
under `shard_map` with the ciphertext batch axis sharded over
``mesh.shape[mesh_axis]`` devices — still bit-exact against the
single-device path (tests/test_he_sharding.py); `shard_batch=False`
turns the routing off without dropping the mesh.  See
docs/architecture.md for where the sharded path sits in the stack.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.crypto import bigint
from repro.crypto.bigint import Modulus

_U32 = jnp.uint32

BACKENDS = ("jnp", "pallas-interpret", "pallas")
ENV_VAR = "REPRO_CRYPTO_ENGINE"

PIPELINES = ("auto", "cios", "rns")
PIPELINE_ENV_VAR = "REPRO_CRYPTO_PIPELINE"
# Modulus width (bits of N, i.e. of n² for ciphertext ops) at and above
# which ``auto`` routes to the RNS pipeline.  Measured crossover on CPU
# (BENCH_crypto.json): at 1024-bit montmul RNS runs 0.7–0.8× the
# library; at 256-bit its ~14 integer-divides per round lose to CIOS's
# pure mask/shift arithmetic.  docs/engine.md §amortization.
RNS_MIN_BITS = 512


def resolve_backend(name: str | None = None) -> str:
    """Resolve a backend name to one of `BACKENDS`.

    Args:
      name: backend name, ``"auto"``, ``""`` or None.  ``auto``/None/""
        consults the ``REPRO_CRYPTO_ENGINE`` env var, then the hardware
        default (``pallas`` on TPU, ``jnp`` elsewhere).
    Returns:
      One of ``"jnp" | "pallas-interpret" | "pallas"``.
    Raises:
      ValueError: for any other name.
    """
    if name in (None, "", "auto"):
        name = os.environ.get(ENV_VAR, "auto")
    if name in ("", "auto"):
        name = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown crypto engine {name!r}; "
                         f"choose from {BACKENDS + ('auto',)}")
    return name


def resolve_pipeline(name: str | None = None) -> str:
    """Resolve a pipeline name to one of `PIPELINES`.  ``auto``/None/""
    consults ``REPRO_CRYPTO_PIPELINE`` and stays ``auto`` (per-modulus
    routing) when unset."""
    if name in (None, "", "auto"):
        name = os.environ.get(PIPELINE_ENV_VAR, "auto") or "auto"
    if name not in PIPELINES:
        raise ValueError(f"unknown crypto pipeline {name!r}; "
                         f"choose from {PIPELINES}")
    return name


@dataclasses.dataclass(frozen=True)
class CryptoEngine:
    """Immutable dispatch descriptor (hashable, so it can ride through
    jit static args).

    Every op takes and returns *canonical* uint32 limb arrays (radix-2^12
    limbs, values < the modulus; Montgomery-domain where noted) — the
    representation `crypto.bigint` defines — so engines with different
    backends or meshes are interchangeable bit for bit.

    Fields:
      backend: ``"jnp"`` (library lax loops), ``"pallas-interpret"``
        (fused kernels, interpret mode) or ``"pallas"`` (fused kernels
        compiled for TPU).
      pipeline: ``"cios"`` | ``"rns"`` | ``"auto"`` — which arithmetic
        the big ops run.  ``auto`` (default) picks per modulus at
        `RNS_MIN_BITS` and additionally drops *interpret-mode* small-
        modulus ops to the jnp library (an interpreted CIOS kernel can
        never beat the same loop jitted directly).  Explicit values pin
        the arithmetic — the parity suite uses that.
      tile_b: batch tile for the montmul / fused-ladder kernels.
      tile_m: output-column tile for the fused HE matvec kernel.
      chunk_n: ciphertext-row chunk bounding the matvec power table's
        VMEM footprint.
      mesh: optional `jax.sharding.Mesh`; when set (and `shard_batch`),
        batched ops run under `shard_map` with the ciphertext batch axis
        sharded over ``mesh.shape[mesh_axis]`` devices
        (`distributed.he_sharding`).
      mesh_axis: name of the mesh axis carrying the ciphertext batch.
      shard_batch: master switch for the sharded routing (lets callers
        thread a mesh through config without committing to sharding).
    """

    backend: str = "jnp"
    pipeline: str = "auto"      # arithmetic: auto | cios | rns
    tile_b: int = 128           # montmul / ladder batch tile
    tile_m: int = 128           # he_matvec output-column tile
    chunk_n: int = 512          # he_matvec ciphertext-row chunk (VMEM)
    mesh: Any = None            # device mesh for ciphertext-batch sharding
    mesh_axis: str = "data"     # mesh axis the batch shards over
    shard_batch: bool = True    # route batched ops through he_sharding

    @property
    def uses_kernels(self) -> bool:
        """True when ops go to the fused Pallas kernels (non-jnp)."""
        return self.backend != "jnp"

    @property
    def interpret(self) -> bool:
        """Pallas interpret mode (CPU); False only for backend="pallas"."""
        return self.backend != "pallas"

    @property
    def sharded(self) -> bool:
        """True when batched ops run mesh-sharded over `mesh_axis`.
        Raises a clear ValueError for a mesh without that axis, or with
        a non-power-of-two axis size (the matvec ⊕-combine is the
        `modmul_reduce` butterfly, which needs one) — instead of an
        opaque error mid-protocol."""
        if self.mesh is None or not self.shard_batch:
            return False
        if self.mesh_axis not in self.mesh.shape:
            raise ValueError(f"engine mesh has no axis {self.mesh_axis!r};"
                             f" axes are {tuple(self.mesh.shape)}")
        size = self.mesh.shape[self.mesh_axis]
        if size & (size - 1):
            raise ValueError(
                f"mesh axis {self.mesh_axis!r} has size {size}; the "
                "sharded ⊕-combine (modmul_reduce butterfly) needs a "
                "power of two")
        return size > 1

    def single_device(self) -> "CryptoEngine":
        """This engine with the mesh dropped — the per-shard inner
        engine `he_sharding` runs inside each shard_map body."""
        if self.mesh is None:
            return self
        return CryptoEngine(backend=self.backend, pipeline=self.pipeline,
                            tile_b=self.tile_b, tile_m=self.tile_m,
                            chunk_n=self.chunk_n)

    def _route(self, mod: Modulus) -> str:
        """Pick the arithmetic for one op on modulus `mod`:
        ``"lib"`` (bigint CIOS loops), ``"cios"`` (CIOS kernel),
        ``"rns-jnp"`` (`crypto.rns` library) or ``"rns"`` (RNS kernel).

        ``auto`` routes by modulus width (`RNS_MIN_BITS`), and below the
        threshold keeps the CIOS *kernel* only for the compiled backend:
        in interpret mode the kernel is the library algorithm plus
        interpreter overhead, so the library path is strictly faster —
        this is what makes engine-routed interpret mode never slower
        than the library (the kernel_bench guard rows assert it)."""
        pipe = resolve_pipeline(self.pipeline)
        if pipe == "auto":
            if mod.value.bit_length() >= RNS_MIN_BITS:
                return "rns" if self.uses_kernels else "rns-jnp"
            return "cios" if self.backend == "pallas" else "lib"
        if pipe == "rns":
            return "rns" if self.uses_kernels else "rns-jnp"
        return "cios" if self.uses_kernels else "lib"

    # -- fused hot-path ops -------------------------------------------------
    def mont_mul(self, a: jnp.ndarray, b: jnp.ndarray,
                 mod: Modulus) -> jnp.ndarray:
        """Batched Montgomery product a ⊙ b mod N.

        Args:
          a, b: (..., L) canonical Montgomery-domain limb arrays
            (broadcast against each other over the batch dims).
          mod: the modulus descriptor (L limbs).
        Returns:
          (..., L) canonical Montgomery-domain product.
        """
        if self.sharded:
            from repro.distributed import he_sharding
            return he_sharding.sharded_mont_mul(self, a, b, mod)
        route = self._route(mod)
        if route == "lib":
            return bigint.mont_mul(a, b, mod)
        if route == "rns-jnp":
            from repro.crypto import rns
            return rns.mont_mul(rns.for_modulus(mod), a, b)
        from repro.kernels import ops
        if route == "rns":
            return ops.rns_montmul(a, b, mod, tile_b=self.tile_b,
                                   interpret=self.interpret)
        return ops.montmul(a, b, mod, tile_b=self.tile_b,
                           interpret=self.interpret)

    def mont_exp_bits(self, base: jnp.ndarray, bits: jnp.ndarray,
                      mod: Modulus) -> jnp.ndarray:
        """Constant-time square-and-multiply ladder base^e mod N.

        Args:
          base: (..., L) Montgomery-domain bases.
          bits: (..., nbits) MSB-first exponent bits (uint32 0/1;
            broadcast against base's batch dims — a single shared bit
            vector is the decrypt-λ pattern).
          mod: the modulus descriptor.
        Returns:
          (..., L) Montgomery-domain base^e, canonical.  The kernel path
          runs the whole ladder in ONE pallas_call.
        """
        if self.sharded:
            from repro.distributed import he_sharding
            return he_sharding.sharded_mont_exp_bits(self, base, bits, mod)
        route = self._route(mod)
        if route == "lib":
            return bigint.mont_exp_bits(base, bits, mod)
        if route == "rns-jnp":
            from repro.crypto import rns
            return rns.mont_exp_bits(rns.for_modulus(mod), base, bits)
        from repro.kernels import ops
        if route == "rns":
            return ops.rns_mont_exp_fused(base, bits, mod,
                                          tile_b=self.tile_b,
                                          interpret=self.interpret)
        return ops.mont_exp_fused(base, bits, mod, tile_b=self.tile_b,
                                  interpret=self.interpret)

    def mont_exp_const(self, base: jnp.ndarray, e: int,
                       mod: Modulus) -> jnp.ndarray:
        """Ladder with a host-constant exponent `e` ≥ 0 (bit decomposition
        memoized via `bigint.cached_bits`).  Same contract as
        `mont_exp_bits`; e == 0 short-circuits to mont(1)."""
        if e == 0:
            return jnp.broadcast_to(bigint.mont_one(mod), base.shape)
        bits = jnp.asarray(bigint.cached_bits(int(e), int(e).bit_length()))
        return self.mont_exp_bits(base, bits, mod)

    def he_matvec_windowed(self, cts: jnp.ndarray, digits,
                           mod: Modulus, window: int) -> jnp.ndarray:
        """Fixed-window HE matvec: (m, L) ciphertexts of Σ_i exps[i,j]·m_i.

        Args:
          cts: (n, L) Montgomery-domain ciphertexts mod n².
          digits: (n, m, levels) MSB-first window digits of the uint32
            exponents (window=1 → plain MSB-first bits).
          mod: the ciphertext modulus (n²).
          window: window width in bits (≥ 1).
        Returns:
          (m, L) Montgomery-domain ciphertexts, canonical.

        Kernel backends run the fused kernel; a mesh-sharded engine
        shards the ciphertext-row axis and ⊕-combines partials across
        devices.  For the plain jnp single-device engine, callers
        (`protocols.he_matvec`) route to the jitted library ladders
        instead — this method is reachable on the jnp backend only via
        the sharded path, whose shard bodies carry their own library
        ladder.
        """
        if self.sharded:
            from repro.distributed import he_sharding
            return he_sharding.sharded_he_matvec(self, cts, digits, mod,
                                                 window)
        route = self._route(mod)
        if route == "rns-jnp":
            from repro.crypto import rns
            return rns.he_matvec(rns.for_modulus(mod), cts,
                                 jnp.asarray(digits, _U32), window)
        from repro.kernels import ops
        if route == "rns":
            return ops.rns_he_matvec_fused(cts, jnp.asarray(digits, _U32),
                                           mod, window=window,
                                           tile_m=self.tile_m,
                                           chunk_n=self.chunk_n,
                                           interpret=self.interpret)
        return ops.he_matvec_fused(cts, jnp.asarray(digits, _U32), mod,
                                   window=window, tile_m=self.tile_m,
                                   chunk_n=self.chunk_n,
                                   interpret=self.interpret)

    def fixed_base_exp(self, table, digits, mod: Modulus) -> jnp.ndarray:
        """Windowed fixed-base exponentiation from a persistent table.

        Args:
          table: a `crypto.fixed_base.FixedBaseTable` for base h mod N
            (its ``table_rns`` holds ·B-domain channel states).
          digits: (..., levels) LSB-first base-2^window exponent digits
            (`fixed_base.exp_digits`).
          mod: the table's modulus (n² for noise tables).
        Returns:
          (..., L) canonical Montgomery-domain limbs of h^e·R — the
          `paillier.noise_to_mont` contract, at ~levels RNS rounds
          instead of a 2·|N|-round ladder (BENCH fixed_base rows).

        Always the RNS pipeline regardless of `pipeline` — the table
        format *is* RNS, and the digit walk beats the ladder at every
        committed size.  Not mesh-routed: noise prefetch is party-local
        (runtime noise pool), so a sharded engine evaluates on its own
        device.
        """
        digits = jnp.asarray(digits, _U32)
        table_rns = jnp.asarray(table.table_rns, _U32)
        if self.uses_kernels:
            from repro.kernels import ops
            return ops.rns_fixed_base_fused(table_rns, digits, mod,
                                            window=table.window,
                                            tile_b=self.tile_b,
                                            interpret=self.interpret)
        from repro.crypto import rns
        return rns.fixed_base_exp(rns.for_modulus(mod), table_rns, digits)

    # -- derived conveniences (same dispatch, used by paillier.py) ----------
    def to_mont(self, a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
        """Lift canonical residues into the Montgomery domain (⊙ R² )."""
        return self.mont_mul(a, jnp.asarray(mod.r2, _U32), mod)

    def from_mont(self, a: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
        """Drop Montgomery-domain residues back to plain canonical form."""
        one = jnp.zeros(mod.L, _U32).at[0].set(1)
        return self.mont_mul(a, one, mod)


def make(name: str | None = None, **kw) -> CryptoEngine:
    """Build a `CryptoEngine` from a backend name (resolved like
    `resolve_backend`); extra kwargs (tile sizes, ``mesh=``, …) pass
    through to the dataclass."""
    return CryptoEngine(backend=resolve_backend(name), **kw)


_DEFAULT: CryptoEngine | None = None


def get_engine() -> CryptoEngine:
    """Process-default engine (env-resolved on first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make()
    return _DEFAULT


def set_engine(engine: CryptoEngine | str | None) -> CryptoEngine:
    """Install the process-default engine; accepts a backend name
    (resolved via `make`) or a ready `CryptoEngine`.  Returns it."""
    global _DEFAULT
    _DEFAULT = make(engine) if isinstance(engine, (str, type(None))) \
        else engine
    return _DEFAULT


@contextlib.contextmanager
def use_engine(engine: CryptoEngine | str):
    """Temporarily switch the process-default engine (tests/benchmarks).
    Yields the installed engine; restores the previous default on exit."""
    global _DEFAULT
    prev = _DEFAULT
    set_engine(engine)
    try:
        yield get_engine()
    finally:
        _DEFAULT = prev
