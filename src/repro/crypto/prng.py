"""Randomness for shares, masks and Paillier noise.

Two tiers:

* Device tier — counter-based threefry (`jax.random`) for ring-2^64 share
  material inside jitted protocol steps (cheap, reproducible, shardable).
* Host tier — python `secrets`-grade integers for Paillier encryption
  noise r ∈ [1, n) and statistical masks, converted to limb arrays.  On a
  real deployment this would be an HSM/TRNG feed; the interface is the
  same either way.
"""
from __future__ import annotations

import secrets
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import bigint


def u32_pair(key: jax.Array, shape: Sequence[int]):
    """Uniform (hi, lo) uint32 pairs == uniform Z_2^64 elements."""
    k1, k2 = jax.random.split(key)
    hi = jax.random.bits(k1, tuple(shape), dtype=jnp.uint32)
    lo = jax.random.bits(k2, tuple(shape), dtype=jnp.uint32)
    return hi, lo


def host_uniform_below(n: int, size: int, *, rng: np.random.Generator | None = None,
                       lo: int = 0) -> list[int]:
    """size uniform ints in [lo, n).  Uses rejection sampling over raw
    entropy; `rng` (seeded) is for reproducible tests, default is secrets."""
    span = n - lo
    nbits = span.bit_length()
    out: list[int] = []
    while len(out) < size:
        if rng is None:
            v = secrets.randbits(nbits)
        else:
            nbytes = (nbits + 7) // 8
            v = int.from_bytes(rng.bytes(nbytes), "little") & ((1 << nbits) - 1)
        if v < span:
            out.append(lo + v)
    return out


def host_uniform_limbs(n: int, size: int, L: int, *,
                       rng: np.random.Generator | None = None,
                       lo: int = 0) -> np.ndarray:
    vals = host_uniform_below(n, size, rng=rng, lo=lo)
    return bigint.ints_to_limbs(vals, L)
