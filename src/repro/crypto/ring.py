"""Z_{2^64} arithmetic as (hi, lo) uint32 pairs.

The MPC share ring.  TPUs have no native 64-bit integer multiplier, so a
ring element is a pair of uint32 lanes and every op is built from 32-bit
(and, inside kernels, 8/16-bit MXU) primitives.  uint32 add/sub/mul in XLA
wrap modulo 2^32, which is exactly the semantics we need.

A `R64` is a NamedTuple pytree of two equal-shape uint32 arrays.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

_U32 = jnp.uint32


class R64(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray

    @property
    def shape(self):
        return self.lo.shape


def r64(hi, lo) -> R64:
    return R64(jnp.asarray(hi, _U32), jnp.asarray(lo, _U32))


def zeros(shape) -> R64:
    return R64(jnp.zeros(shape, _U32), jnp.zeros(shape, _U32))


def from_numpy_u64(x: np.ndarray) -> R64:
    x = np.asarray(x, np.uint64)
    return R64(jnp.asarray((x >> np.uint64(32)).astype(np.uint32)),
               jnp.asarray((x & np.uint64(0xFFFFFFFF)).astype(np.uint32)))


def to_numpy_u64(x: R64) -> np.ndarray:
    hi = np.asarray(x.hi, np.uint64)
    lo = np.asarray(x.lo, np.uint64)
    return (hi << np.uint64(32)) | lo


def add(a: R64, b: R64) -> R64:
    lo = a.lo + b.lo
    carry = (lo < a.lo).astype(_U32)
    return R64(a.hi + b.hi + carry, lo)


def sub(a: R64, b: R64) -> R64:
    lo = a.lo - b.lo
    borrow = (a.lo < b.lo).astype(_U32)
    return R64(a.hi - b.hi - borrow, lo)


def neg(a: R64) -> R64:
    return sub(zeros(a.lo.shape), a)


def umul32(a: jnp.ndarray, b: jnp.ndarray):
    """Full 32x32 -> 64-bit product as (hi, lo), via 16-bit halves."""
    a0 = a & _U32(0xFFFF)
    a1 = a >> 16
    b0 = b & _U32(0xFFFF)
    b1 = b >> 16
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = (p00 >> 16) + (p01 & _U32(0xFFFF)) + (p10 & _U32(0xFFFF))
    lo = (p00 & _U32(0xFFFF)) | (mid << 16)
    hi = p11 + (p01 >> 16) + (p10 >> 16) + (mid >> 16)
    return hi, lo


def mul(a: R64, b: R64) -> R64:
    """a*b mod 2^64."""
    hi, lo = umul32(a.lo, b.lo)
    hi = hi + a.lo * b.hi + a.hi * b.lo
    return R64(hi, lo)


def mul_pub_int(a: R64, k: int) -> R64:
    """Multiply by a public python integer (reduced mod 2^64)."""
    k %= 1 << 64
    kb = R64(jnp.full(a.lo.shape, (k >> 32) & 0xFFFFFFFF, _U32),
             jnp.full(a.lo.shape, k & 0xFFFFFFFF, _U32))
    return mul(a, kb)


def shift_left(a: R64, s: int) -> R64:
    if s == 0:
        return a
    if s >= 64:
        return zeros(a.lo.shape)
    if s >= 32:
        return R64(a.lo << (s - 32) if s > 32 else a.lo, jnp.zeros_like(a.lo))
    return R64((a.hi << s) | (a.lo >> (32 - s)), a.lo << s)


def shift_right_logical(a: R64, s: int) -> R64:
    if s == 0:
        return a
    if s >= 64:
        return zeros(a.lo.shape)
    if s >= 32:
        return R64(jnp.zeros_like(a.hi),
                   a.hi >> (s - 32) if s > 32 else a.hi)
    return R64(a.hi >> s, (a.lo >> s) | (a.hi << (32 - s)))


def from_signed_f64(x, f: int) -> R64:
    """Encode floats as fixed-point ring elements: round(x * 2^f) mod 2^64.
    Uses float64-safe two-stage splitting so 64-bit precision survives."""
    x = np.asarray(x, np.float64) * float(1 << f)
    v = np.asarray(np.rint(x), np.int64).astype(np.uint64)
    return from_numpy_u64(v)


def to_signed_f64(a: R64, f: int) -> np.ndarray:
    """Decode: centered lift to [-2^63, 2^63) then scale by 2^-f."""
    v = to_numpy_u64(a).astype(np.int64)  # two's complement reinterpret
    return v.astype(np.float64) / float(1 << f)


def eq(a: R64, b: R64) -> jnp.ndarray:
    return (a.hi == b.hi) & (a.lo == b.lo)


def sum_axis(a: R64, axis: int) -> R64:
    """Sum along an axis mod 2^64: widen lo into (carry-tracked) pieces.
    Implemented as pairwise tree-reduction using `add` semantics."""
    hi, lo = a.hi, a.lo
    n = hi.shape[axis]
    # move axis first, then fold sequentially in log steps
    hi = jnp.moveaxis(hi, axis, 0)
    lo = jnp.moveaxis(lo, axis, 0)
    cur = R64(hi, lo)
    length = n
    while length > 1:
        half = length // 2
        a1 = R64(cur.hi[:half], cur.lo[:half])
        a2 = R64(cur.hi[half:2 * half], cur.lo[half:2 * half])
        s = add(a1, a2)
        if length % 2:
            tail = R64(cur.hi[2 * half:], cur.lo[2 * half:])
            s = R64(jnp.concatenate([s.hi, tail.hi], 0),
                    jnp.concatenate([s.lo, tail.lo], 0))
        cur = s
        length = half + (length % 2)
    return R64(cur.hi[0], cur.lo[0])


def matmul(x_pub_int: jnp.ndarray, a: R64) -> R64:
    """Public signed-int32 matrix times ring matrix — used where one
    operand is public (e.g. X^T times a revealed-masked vector).  For
    share-by-share products use mpc.beaver instead.

    x: (..., m, n) int32 (signed, public); a: R64 of shape (..., n, k).
    Signed entries are lifted to their Z_2^64 residues (hi = sign
    extension), which is exact under mod-2^64 semantics.
    """
    xlo = x_pub_int.astype(_U32)
    xhi = jnp.where(x_pub_int < 0, _U32(0xFFFFFFFF), _U32(0))
    # elementwise product then sum: broadcast (..., m, n, 1) x (..., 1, n, k)
    xa = R64(xhi[..., :, :, None], xlo[..., :, :, None])
    av = R64(a.hi[..., None, :, :], a.lo[..., None, :, :])
    prod = mul(xa, av)
    return sum_axis(prod, axis=-2)
