"""int8 gradient compression with error feedback — cross-pod DP traffic
is the multi-pod bottleneck; 4× smaller all-reduces with EF keep
convergence (1-bit-Adam-family result).

Pure-functional: `compress` quantizes grad+error to int8 with a per-tensor
scale; `decompress` restores float; the residual carries to the next step.
The launcher wires this around the pod-axis mean; the unit test checks
EF-SGD matches plain SGD to <1% on a quadratic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error):
    """-> (q_int8 tree, scales tree, new_error tree)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat = jax.tree.map(one, grads, error)
    q = jax.tree.map(lambda t: t[0], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e


def decompress(q, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda qi, si: (qi.astype(jnp.float32) * si).astype(dtype), q, scales)


def wire_bytes(tree) -> int:
    """Bytes on the wire for a compressed gradient exchange."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
