"""Compression: lossy int8 gradients (data-parallel) and lossless wire
frame deflation (the EFMVFL socket link).

Two regimes with opposite contracts:

* int8 + error feedback (`compress`/`decompress`) — LOSSY.  Cross-pod
  DP traffic is the multi-pod bottleneck; 4× smaller all-reduces with
  EF keep convergence (1-bit-Adam-family result).  Pure-functional:
  `compress` quantizes grad+error to int8 with a per-tensor scale;
  `decompress` restores float; the residual carries to the next step.
* wire frame deflation (`deflate_frame`/`inflate_frame`) — LOSSLESS
  (zlib), the only kind admissible on the EFMVFL socket wire: the
  protocol's bit-exactness guarantee (losses, weights, per-tag bytes
  identical across transports) would not survive quantization.
  `validate_wire_scheme` is the gate — the lossy scheme is refused BY
  NAME, never silently accepted.  `worth_deflating` is a deterministic
  probe (first 4 KiB at level 1): entropy-dense Paillier/ring payloads
  are skipped without paying full-frame compression, while zero-padded
  mock ciphertexts and JSON control frames compress well.  The chaos
  link layer (`runtime.chaos`) applies these BELOW the metering
  boundary, so analytic == measured accounting is untouched; actual
  wire savings are reported in `ChaosStats`.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

#: schemes admissible on the socket wire — lossless only
WIRE_SCHEMES = ("none", "zlib")

#: deflate level for wire frames (speed-leaning; the probe already
#: filtered out incompressible payloads)
_WIRE_LEVEL = 6

#: probe: compress the first `_PROBE_BYTES` at level 1; deflate the
#: whole frame only when the probe shrinks below `_PROBE_RATIO`
_PROBE_BYTES = 4096
_PROBE_RATIO = 0.9


def validate_wire_scheme(name: str) -> str:
    """Refuse silently-lossy wire paths: only bit-exact schemes pass.
    The int8/EF path exists for DP gradients and must never be routed
    onto the protocol wire."""
    if name in WIRE_SCHEMES:
        return name
    if name == "int8":
        raise ValueError(
            "wire_compression='int8' refused: int8 error-feedback "
            "quantization is LOSSY — the socket wire requires bit-exact "
            f"frames (choose one of {WIRE_SCHEMES})")
    raise ValueError(f"unknown wire_compression {name!r} "
                     f"(choose one of {WIRE_SCHEMES})")


def worth_deflating(frame: bytes, probe_bytes: int = _PROBE_BYTES,
                    ratio: float = _PROBE_RATIO) -> bool:
    """Deterministic cheap decision: is this frame compressible enough
    to bother?  Pure function of the frame bytes — both link endpoints
    and any replay reach the same verdict."""
    if len(frame) < 64:                 # tiny frames: header dominates
        return False
    head = frame[:probe_bytes]
    return len(zlib.compress(head, 1)) < ratio * len(head)


def deflate_frame(frame: bytes) -> bytes:
    """Losslessly deflate one codec frame for the wire."""
    return zlib.compress(frame, _WIRE_LEVEL)


def inflate_frame(body: bytes) -> bytes:
    """Exact inverse of `deflate_frame` (zlib is bit-exact by
    construction; the link envelope's crc32 additionally guards the
    compressed body in transit)."""
    return zlib.decompress(body)


def init_error(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error):
    """-> (q_int8 tree, scales tree, new_error tree)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat = jax.tree.map(one, grads, error)
    q = jax.tree.map(lambda t: t[0], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e


def decompress(q, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda qi, si: (qi.astype(jnp.float32) * si).astype(dtype), q, scales)


def wire_bytes(tree) -> int:
    """Bytes on the wire for a compressed gradient exchange."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
